"""Host-side timing sink (`repro.telemetry.timing`).

A tiny append-only event buffer the instrumented hot paths write into:
``repro.sweep.cache`` records program build / first-call (compile) times,
``repro.sweep.runners.run_bucketed`` records per-bucket dispatch times, and
the sharded runners record per-mesh dispatch times.  ``repro.api.run``
drains the buffer around each dispatch and folds the events into the run's
``RunRecord`` (see ``.ledger``), which is how compile-ms vs warm-ms gets
attributed without touching any jitted code.

Deliberately stdlib-only and overhead-free when nothing drains it: an event
is one small dict appended to a list under a lock.  This module must stay a
leaf (no repro imports) so every layer can use it without cycles.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List

__all__ = ["record_timing", "drain_timings", "peek_timings", "timed"]

_LOCK = threading.Lock()
_EVENTS: List[Dict[str, Any]] = []

# names api.run treats as compile-side when splitting elapsed time into
# compile-ms vs warm-ms (program construction + the first dispatch of a
# freshly built executable, where XLA compiles synchronously on CPU)
COMPILE_EVENT_NAMES = ("program_build", "program_first_call")


def record_timing(name: str, ms: float, **meta: Any) -> None:
    """Append one timing event: ``{"name", "ms", **meta}``."""
    ev = {"name": str(name), "ms": float(ms)}
    for k, v in meta.items():
        ev[k] = v
    with _LOCK:
        _EVENTS.append(ev)


def drain_timings() -> List[Dict[str, Any]]:
    """Return all buffered events and clear the buffer."""
    with _LOCK:
        out, _EVENTS[:] = list(_EVENTS), []
    return out


def peek_timings() -> List[Dict[str, Any]]:
    """A copy of the buffered events without clearing them."""
    with _LOCK:
        return list(_EVENTS)


class timed:
    """``with timed("name", key=...):`` context recording wall-clock ms."""

    def __init__(self, name: str, **meta: Any):
        self.name, self.meta = name, meta

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        record_timing(self.name, (time.perf_counter() - self._t0) * 1e3,
                      **self.meta)
        return False
