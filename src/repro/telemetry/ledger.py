"""The structured run ledger (`repro.telemetry.ledger`).

Every ``repro.api.run`` builds one ``RunRecord`` -- spec fingerprint,
solver/backend, chosen horizon, measured tau-bar, the delay histogram,
compile-ms vs warm-ms, program-cache hit/miss/evict deltas, mesh shape and
a scan-carry size estimate -- surfaces it on ``Results.telemetry``, and
(when a ledger path is configured) appends it as one JSON line.

The ledger is OPT-IN on disk: nothing is written unless
``set_ledger_path(path)`` was called or the ``REPRO_TELEMETRY_LEDGER``
environment variable names a file.  The in-memory record on ``Results`` is
always built -- observability costs one host-side dict per run, never a
device sync.

``launch/report.py`` renders a ledger file into a human-readable summary;
``repro.analysis.run_timeline`` consumes it programmatically.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Union

__all__ = ["RunRecord", "set_ledger_path", "get_ledger_path",
           "append_record", "read_ledger", "spec_fingerprint",
           "estimate_carry_bytes", "cache_delta", "warn_clip_pressure"]

LEDGER_ENV = "REPRO_TELEMETRY_LEDGER"

_LEDGER_PATH: Optional[str] = None


def set_ledger_path(path: Optional[Union[str, Path]]) -> None:
    """Route ``append_record`` to ``path`` (None restores the env-var
    default, i.e. no writes unless ``REPRO_TELEMETRY_LEDGER`` is set)."""
    global _LEDGER_PATH
    _LEDGER_PATH = None if path is None else str(path)


def get_ledger_path() -> Optional[str]:
    return _LEDGER_PATH if _LEDGER_PATH is not None \
        else (os.environ.get(LEDGER_ENV) or None)


@dataclasses.dataclass
class RunRecord:
    """One ``api.run`` as a flat, JSON-able record.

    ``delay_hist`` is summed over cells; with ``hist_source ==
    "accumulator"`` it is the exact in-scan histogram (sums to
    ``n_cells * n_events`` regardless of ``record_every``), with
    ``"recorded"`` it was binned from the RECORDED tau rows on the host --
    exact at stride 1 only (a 1/s sample otherwise).

    ``compile_ms`` sums the drained ``program_build`` / first-dispatch
    timing events of this run (executable construction + XLA's synchronous
    first-call compile); ``warm_ms = max(elapsed - compile, 0)`` is the
    execution-side remainder.  Solo-backend runs bypass the program cache,
    so their compile attribution is 0 by construction.
    """

    ts: float
    fingerprint: str
    solver: str
    backend: str
    n_cells: int
    n_events: int
    record_every: int
    horizon: Optional[int]
    tau_bar: Optional[int]
    devices: int
    mesh_shape: Optional[List[int]]
    carry_bytes: int
    elapsed_ms: float
    compile_ms: float
    warm_ms: float
    cache: Dict[str, Any]
    delay_hist: List[int]
    hist_source: str
    tau_stats: Dict[str, float]
    gamma_stats: Dict[str, float]
    clipped: Dict[str, int]
    policies: List[str]
    timings: List[Dict[str, Any]]
    # fault-injection counters (summed over cells): injected / dropped /
    # duplicated / rejected_nonfinite / rejected_stale / degraded.
    # None when the run had no FaultSpec (faults-off runs ledger
    # identically to pre-fault records).
    faults: Optional[Dict[str, int]] = None

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunRecord":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def append_record(record: RunRecord,
                  path: Optional[Union[str, Path]] = None) -> bool:
    """Append one JSON line; returns False (and writes nothing) when no
    ledger path is configured."""
    p = str(path) if path is not None else get_ledger_path()
    if not p:
        return False
    with open(p, "a") as fh:
        fh.write(record.to_json() + "\n")
    return True


def read_ledger(path: Union[str, Path]) -> Iterator[Dict[str, Any]]:
    """Yield one dict per ledger line (blank lines skipped)."""
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                yield json.loads(line)


def spec_fingerprint(spec: Any, grid: Any = None) -> str:
    """A short stable digest of the experiment configuration.

    Built from the spec's declarative knobs (never from array contents --
    component specs embed whole problems); two value-equal declarative
    specs fingerprint identically, and component/escape-hatch specs fall
    back to the grid's cell labels."""
    try:
        faults = getattr(spec, "faults", None)
        if spec is not None and getattr(spec.problem, "problem", None) is None:
            desc = repr((spec.problem, spec.solver, spec.topology,
                         spec.policies, spec.delay, spec.execution,
                         spec.n_events, faults))
        elif grid is not None:
            desc = repr((type(spec).__name__ if spec is not None else None,
                         tuple(grid.labels()), grid.n_events, faults))
        else:
            desc = repr(spec)
    except Exception:  # never let fingerprinting break a run
        desc = "unfingerprintable"
    return hashlib.sha1(desc.encode()).hexdigest()[:12]


def estimate_carry_bytes(solver: str, dim: int, width: int, horizon: int,
                         n_cells: int) -> int:
    """Order-of-magnitude scan-carry footprint of a batched run: per-cell
    iterate-shaped carry leaves (iterate + per-worker snapshot/gradient
    tables) plus the step-size circular buffer, in float32 bytes.  An
    ESTIMATE for ledger trend lines -- not an allocator measurement."""
    per_cell = {
        "piag": dim * (1 + 2 * width),        # x + g_table + x_read
        "bcd": dim * (1 + width),             # x + x_read snapshots
        "fedasync": dim * (1 + width),        # x + client snapshot table
        "fedbuff": dim * (2 + width),         # + the delta buffer
    }.get(solver, dim * (1 + width))
    return int(4 * (per_cell + int(horizon) + 4) * int(n_cells))


def cache_delta(before: Dict[str, Any], after: Dict[str, Any]) -> Dict[str, Any]:
    """Per-run ``program_cache_stats()`` delta, reset-scoped: when
    ``clear_program_cache()`` ran between the snapshots (generation bump)
    the absolute counters restarted from zero, so the after-side values ARE
    the delta since the clear -- flagged with ``reset`` so consumers know
    the scope boundary moved."""
    reset = before.get("generation") != after.get("generation")
    base = {k: 0 for k in ("hits", "misses", "evictions")} if reset else before
    return {
        "hits": int(after.get("hits", 0)) - int(base.get("hits", 0)),
        "misses": int(after.get("misses", 0)) - int(base.get("misses", 0)),
        "evictions": (int(after.get("evictions", 0))
                      - int(base.get("evictions", 0))),
        "size": int(after.get("size", 0)),
        "reset": bool(reset),
    }


def warn_clip_pressure(clip: Dict[str, int],
                       horizon: Optional[int] = None) -> Optional[str]:
    """THE clip-pressure warning path (satellite: ``launch.sweep`` used to
    hand-roll a bare print that JSON consumers never saw).  Given an
    ``analysis.clipped_summary`` block, emits a ``RuntimeWarning`` and
    returns the message when any cell clipped delays at the policy horizon;
    returns None when clean."""
    import warnings

    if not clip.get("cells_clipped"):
        return None
    h = f" (H={horizon})" if horizon is not None else ""
    msg = (f"{clip['cells_clipped']}/{clip['cells']} cells clipped "
           f"{clip['events_clipped']} delays at the policy horizon{h}; "
           "window sums were silently truncated -- raise the horizon")
    warnings.warn(msg, RuntimeWarning, stacklevel=2)
    return msg
