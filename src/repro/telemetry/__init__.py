"""Delay-telemetry subsystem (`repro.telemetry`).

Three layers, smallest first:

- ``accumulators``: jit-compatible in-scan aggregates (delay histogram,
  tau/gamma running moments, per-window clip counters) threaded through the
  solver scans as an extra carry element.  Bitwise-neutral by contract --
  enabling telemetry never changes a solver output bit.
- ``timing``: a host-side timing event buffer the instrumented hot paths
  (program cache, bucketed/sharded runners) write into.
- ``ledger``: the structured per-run ``RunRecord`` -- built by every
  ``api.run``, surfaced on ``Results.telemetry``, and appended as JSON
  lines when a ledger path is configured (``REPRO_TELEMETRY_LEDGER`` or
  ``set_ledger_path``).  ``launch/report.py`` renders ledgers;
  ``repro.analysis`` bridges (``delay_profile`` / ``clip_pressure`` /
  ``run_timeline``) consume them.
"""
from .accumulators import (TelemetryConfig, TelemetryState, DelayTelemetry,
                           init_telemetry, observe, emit_window, finalize,
                           summarize_telemetry)
from .timing import (record_timing, drain_timings, peek_timings, timed,
                     COMPILE_EVENT_NAMES)
from .ledger import (RunRecord, set_ledger_path, get_ledger_path,
                     append_record, read_ledger, spec_fingerprint,
                     estimate_carry_bytes, cache_delta, warn_clip_pressure,
                     LEDGER_ENV)

__all__ = [
    "TelemetryConfig", "TelemetryState", "DelayTelemetry",
    "init_telemetry", "observe", "emit_window", "finalize",
    "summarize_telemetry",
    "record_timing", "drain_timings", "peek_timings", "timed",
    "COMPILE_EVENT_NAMES",
    "RunRecord", "set_ledger_path", "get_ledger_path", "append_record",
    "read_ledger", "spec_fingerprint", "estimate_carry_bytes",
    "cache_delta", "warn_clip_pressure", "LEDGER_ENV",
]
