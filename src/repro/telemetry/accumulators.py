"""In-scan metric accumulators (`repro.telemetry.accumulators`).

Jit-compatible aggregate statistics that ride in the solver scan carries:
a fixed-bucket delay histogram (in-carry bincount), running min/max and
Welford mean/M2 moments for the delay tau and the emitted step-size gamma,
and a per-recording-window horizon-clip counter.  Because the accumulator
updates on EVERY event -- silent decimated steps included (see
``core.engine.strided_scan``) -- the aggregates are exact even when
``record_every=s`` drops s-1 of every s trajectory rows.

The contract that makes the layer safe to leave on in sweeps is
**bitwise neutrality**: accumulator state is an extra, data-independent
carry element; no solver value ever depends on it, so solver outputs with
telemetry on are bitwise-equal to telemetry off (pinned in
``tests/test_telemetry.py`` for all four solvers and all three backends).

This module imports only jax/numpy (no repro.core) so the solver scans can
depend on it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["TelemetryConfig", "TelemetryState", "DelayTelemetry",
           "init_telemetry", "observe", "emit_window", "finalize",
           "summarize_telemetry"]

_I32_MAX = np.iinfo(np.int32).max


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static accumulator configuration.

    Frozen + hashable by design: the config participates in the sweep
    program-cache keys (``repro.sweep.cache``), so a telemetry-on build can
    never be served a telemetry-off executable or vice versa.

    ``delay_bins``: histogram buckets.  Bin ``i < delay_bins - 1`` counts
    events with ``tau == i``; the LAST bin is the overflow bucket counting
    every ``tau >= delay_bins - 1`` (delays are never dropped, only
    coarsened -- the histogram always sums to the event count).
    """

    delay_bins: int = 64

    def __post_init__(self):
        if self.delay_bins < 2:
            raise ValueError(
                f"delay_bins must be >= 2, got {self.delay_bins}")


class TelemetryState(NamedTuple):
    """The in-carry accumulator (all leaves scalar except ``hist``)."""

    hist: jnp.ndarray      # (delay_bins,) int32 delay histogram
    count: jnp.ndarray     # () int32 events observed
    tau_min: jnp.ndarray   # () int32 (INT32_MAX before any event)
    tau_max: jnp.ndarray   # () int32 (-1 before any event)
    tau_mean: jnp.ndarray  # () float32 Welford running mean
    tau_m2: jnp.ndarray    # () float32 Welford sum of squared deviations
    g_min: jnp.ndarray     # () float32 (+inf before any event)
    g_max: jnp.ndarray     # () float32 (-inf before any event)
    g_mean: jnp.ndarray    # () float32
    g_m2: jnp.ndarray      # () float32
    win_clip: jnp.ndarray  # () int32 horizon clips since the last emit


class DelayTelemetry(NamedTuple):
    """Finalized per-cell aggregates, returned on the solver result's
    ``telemetry`` field (leading cell axis under vmap/shard_map).

    ``window_clips`` is the per-recorded-window clip column (K // s,): at
    stride 1 it is the per-event clip flag sequence; at stride s, entry j
    counts horizon-clipped delays in the window ending at recorded event
    ``j*s + s - 1`` -- decimation loses nothing, the counts just batch up.
    """

    hist: jnp.ndarray
    count: jnp.ndarray
    tau_min: jnp.ndarray
    tau_max: jnp.ndarray
    tau_mean: jnp.ndarray
    tau_m2: jnp.ndarray
    gamma_min: jnp.ndarray
    gamma_max: jnp.ndarray
    gamma_mean: jnp.ndarray
    gamma_m2: jnp.ndarray
    window_clips: jnp.ndarray


def init_telemetry(cfg: TelemetryConfig) -> TelemetryState:
    f32, i32 = jnp.float32, jnp.int32
    return TelemetryState(
        hist=jnp.zeros((cfg.delay_bins,), i32),
        count=jnp.zeros((), i32),
        tau_min=jnp.full((), _I32_MAX, i32),
        tau_max=jnp.full((), -1, i32),
        tau_mean=jnp.zeros((), f32),
        tau_m2=jnp.zeros((), f32),
        g_min=jnp.full((), jnp.inf, f32),
        g_max=jnp.full((), -jnp.inf, f32),
        g_mean=jnp.zeros((), f32),
        g_m2=jnp.zeros((), f32),
        win_clip=jnp.zeros((), i32),
    )


def observe(state: TelemetryState, tau, gamma,
            was_clipped) -> TelemetryState:
    """Fold one event into the accumulator (runs on silent AND loud steps).

    ``was_clipped`` is the per-event horizon-clip flag, i.e. the delta of
    the policy state's ``clipped`` counter across ``policy.step``
    (``core.stepsize.clip_delta``).  Pure arithmetic on the telemetry
    leaves only -- nothing here feeds back into the solver carry.
    """
    tau_i = jnp.asarray(tau, jnp.int32)
    tau_f = jnp.asarray(tau_i, jnp.float32)
    g = jnp.asarray(gamma, jnp.float32)
    n_bins = state.hist.shape[-1]
    cnt = state.count + 1
    cnt_f = jnp.asarray(cnt, jnp.float32)
    d_tau = tau_f - state.tau_mean
    tau_mean = state.tau_mean + d_tau / cnt_f
    d_g = g - state.g_mean
    g_mean = state.g_mean + d_g / cnt_f
    return TelemetryState(
        hist=state.hist.at[jnp.minimum(tau_i, n_bins - 1)].add(1),
        count=cnt,
        tau_min=jnp.minimum(state.tau_min, tau_i),
        tau_max=jnp.maximum(state.tau_max, tau_i),
        tau_mean=tau_mean,
        tau_m2=state.tau_m2 + d_tau * (tau_f - tau_mean),
        g_min=jnp.minimum(state.g_min, g),
        g_max=jnp.maximum(state.g_max, g),
        g_mean=g_mean,
        g_m2=state.g_m2 + d_g * (g - g_mean),
        win_clip=state.win_clip + jnp.asarray(was_clipped, jnp.int32),
    )


def emit_window(state: TelemetryState) -> Tuple[TelemetryState, jnp.ndarray]:
    """Close the current recording window: return the clips accumulated
    since the previous emit (the ``window_clips`` column value) and the
    state with the window counter reset."""
    return state._replace(win_clip=jnp.zeros((), jnp.int32)), state.win_clip


def finalize(state: TelemetryState,
             window_clips: jnp.ndarray) -> DelayTelemetry:
    """Repackage the final carry state + the scanned window-clip column as
    the result-side ``DelayTelemetry``."""
    return DelayTelemetry(
        hist=state.hist, count=state.count,
        tau_min=state.tau_min, tau_max=state.tau_max,
        tau_mean=state.tau_mean, tau_m2=state.tau_m2,
        gamma_min=state.g_min, gamma_max=state.g_max,
        gamma_mean=state.g_mean, gamma_m2=state.g_m2,
        window_clips=window_clips)


def summarize_telemetry(tel: DelayTelemetry) -> dict:
    """Host-side merge of a (possibly cell-batched) ``DelayTelemetry`` into
    one aggregate dict: histograms sum, min/max reduce, and Welford moments
    combine with the standard parallel update (so the merged mean/std are
    exact, not means-of-means)."""
    hist = np.asarray(tel.hist).reshape(-1, np.asarray(tel.hist).shape[-1])
    counts = np.asarray(tel.count, np.float64).reshape(-1)
    total = counts.sum()

    def merge_moments(means, m2s):
        means = np.asarray(means, np.float64).reshape(-1)
        m2s = np.asarray(m2s, np.float64).reshape(-1)
        if total <= 0:
            return 0.0, 0.0
        mean = float((counts * means).sum() / total)
        m2 = float(m2s.sum() + (counts * (means - mean) ** 2).sum())
        return mean, float(np.sqrt(m2 / total))

    tau_mean, tau_std = merge_moments(tel.tau_mean, tel.tau_m2)
    g_mean, g_std = merge_moments(tel.gamma_mean, tel.gamma_m2)
    wc = np.asarray(tel.window_clips)
    return {
        "count": int(total),
        "hist": hist.sum(axis=0).astype(np.int64).tolist(),
        "tau": {"min": int(np.asarray(tel.tau_min).min()),
                "max": int(np.asarray(tel.tau_max).max()),
                "mean": tau_mean, "std": tau_std},
        "gamma": {"min": float(np.asarray(tel.gamma_min).min()),
                  "max": float(np.asarray(tel.gamma_max).max()),
                  "mean": g_mean, "std": g_std},
        "window_clips": {"total": int(wc.sum()),
                         "max": int(wc.max()) if wc.size else 0,
                         "windows_clipped": int((wc > 0).sum())},
    }
