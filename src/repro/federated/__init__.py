"""Delay-adaptive asynchronous federated learning (`repro.federated`).

The paper's thesis -- step-sizes should track *measured* delays, not
worst-case bounds -- applied to the server side of asynchronous federated
learning.  Mapping between this package, the FedAsync/FedBuff literature,
and the paper's delay notation:

===========================  ====================  ==========================
this package                 federated literature  paper (Wu et al. '22)
===========================  ====================  ==========================
server version counter       round counter t       write-event counter k
``FederatedTrace.read_at``   client timestamp      stamp s^(i) (Alg. 1 l.12)
``FederatedTrace.tau``       staleness t - tau_i   delay tau_k = k - s^(i)
mixing weight alpha*s(tau)   FedAsync s(t-tau)     step-size gamma_k(tau_k)
``hinge``/``poly`` policies  Xie'19 Sec. 5.2       delay-adaptive gamma(tau)
``constant`` policy          FedAvg-style mixing   fixed worst-case gamma
FedBuff buffer |R|           Nguyen'22 K=|R|       semi-async write batching
===========================  ====================  ==========================

Three layers:

* ``events``  -- deterministic round-trip client simulation (local epochs,
  upload jitter, dropout/rejoin) generalizing ``core.engine``; emits a
  ``FederatedTrace`` with per-upload staleness measured in server writes.
  Two interchangeable paths: the heapq reference (``simulate_federated``)
  and the fully-jitted ``federated_trace_scan`` (bitwise-equal on the same
  pre-sampled ``ClientRounds``; vmaps and shard_maps for sweeps).
* ``server``  -- FedAsync staleness-weighted mixing and FedBuff buffered
  aggregation as jitted ``lax.scan`` loops; mixing weights come from
  ``core.stepsize.make_policy`` (``hinge`` / ``poly`` / ``constant``).
* drivers     -- ``launch/train_federated.py`` (convex problems + small
  transformer presets), ``examples/fedasync_logreg.py``,
  ``benchmarks/fig5_federated.py``.
"""
from .events import (ClientModel, ClientRounds, FederatedTrace,
                     FederatedTraceArrays, client_arrays, default_fed_steps,
                     federated_trace_scan, generate_federated_trace,
                     heterogeneous_clients, sample_client_rounds,
                     simulate_federated)
from .server import (FedResult, fedasync_scan, fedbuff_scan, local_prox_sgd,
                     run_fedasync, run_fedasync_problem, run_fedbuff,
                     run_fedbuff_problem)

__all__ = [
    "ClientModel", "ClientRounds", "FederatedTrace", "FederatedTraceArrays",
    "client_arrays", "default_fed_steps", "federated_trace_scan",
    "generate_federated_trace", "heterogeneous_clients",
    "sample_client_rounds", "simulate_federated", "FedResult",
    "fedasync_scan", "fedbuff_scan", "local_prox_sgd", "run_fedasync",
    "run_fedasync_problem", "run_fedbuff", "run_fedbuff_problem",
]
