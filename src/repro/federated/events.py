"""Round-trip federated client simulation (the event layer of `repro.federated`).

Generalizes ``core.engine`` from single-shot gradient returns to full client
*round trips*: a client reads the current server model (recording the server
version, Algorithm 1's stamp), runs ``local_epochs`` of local training, pays
upload jitter on the way back, and may drop out mid-round and rejoin later.
The server version counter only advances on *aggregation* events, so with a
FedBuff buffer of size ``|R| >= 1`` the staleness of an upload is measured in
server writes -- exactly the paper's write-event delay ``tau_k = k - s^(i)``,
with "write" now meaning "server aggregation".

As with ``core.engine``, the simulation produces a deterministic integer
trace; the server (``repro.federated.server``) consumes it inside a fully
jitted ``lax.scan``, so a simulated trace + a jitted server loop is *exactly*
FedAsync/FedBuff for that realization of client timings.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.engine import EventHeap, WorkerModel

__all__ = ["ClientModel", "FederatedTrace", "heterogeneous_clients",
           "simulate_federated"]

# event kinds inside the heap
_START, _UPLOAD = 0, 1


@dataclasses.dataclass(frozen=True)
class ClientModel:
    """One federated client's timing/lifecycle model.

    compute:       service-time model for ONE local epoch (reuses
                   ``core.engine.WorkerModel`` -- lognormal + stragglers).
    upload:        service-time model for the upload leg (network jitter).
    local_epochs:  local training epochs per round (recorded in the trace so
                   the solver can replay the exact local computation).
    p_dropout:     probability a started round is lost (client goes offline
                   and never uploads that round's model).
    rejoin_after:  offline time before a dropped client re-reads the server
                   model and starts a fresh round.
    """

    compute: WorkerModel = WorkerModel()
    upload: WorkerModel = WorkerModel(mean=0.1, sigma=0.5)
    local_epochs: int = 1
    p_dropout: float = 0.0
    rejoin_after: float = 5.0

    def round_duration(self, rng: np.random.Generator) -> float:
        dt = sum(self.compute.sample(rng) for _ in range(self.local_epochs))
        return dt + self.upload.sample(rng)


def heterogeneous_clients(
    n: int,
    spread: float = 4.0,
    seed: int = 0,
    p_straggle: float = 0.05,
    straggle_x: float = 8.0,
    p_dropout: float = 0.02,
    rejoin_after: float = 5.0,
    local_epochs: int = 1,
    upload_mean: float = 0.1,
) -> list:
    """n clients with epoch times log-spaced over [1, spread] -- federated
    populations are far more heterogeneous than co-located workers (edge
    devices vs. datacenter nodes), hence the wider default spread."""
    rng = np.random.default_rng(seed)
    means = np.geomspace(1.0, spread, n)
    rng.shuffle(means)
    return [ClientModel(
        compute=WorkerModel(mean=float(m), p_straggle=p_straggle,
                            straggle_x=straggle_x),
        upload=WorkerModel(mean=upload_mean, sigma=0.5),
        local_epochs=local_epochs,
        p_dropout=p_dropout,
        rejoin_after=rejoin_after,
    ) for m in means]


class FederatedTrace(NamedTuple):
    """One row per client *upload* event (model arriving at the server).

    client:      (K,) int32 -- uploading client.
    read_at:     (K,) int32 -- server version the client's round started from.
    tau:         (K,) int32 -- staleness in server versions at arrival.
    aggregate:   (K,) int32 -- 1 iff this upload completes the buffer and
                               triggers a server write (FedAsync: always 1).
    version:     (K,) int32 -- server version AFTER processing the event.
    local_steps: (K,) int32 -- local epochs the client ran this round.
    t_wall:      (K,) float64 -- simulated wall-clock arrival time.
    """

    client: np.ndarray
    read_at: np.ndarray
    tau: np.ndarray
    aggregate: np.ndarray
    version: np.ndarray
    local_steps: np.ndarray
    t_wall: np.ndarray

    @property
    def n_events(self) -> int:
        return int(self.client.shape[0])

    @property
    def n_writes(self) -> int:
        return int(self.aggregate.sum())

    def max_delay(self) -> int:
        return int(self.tau.max(initial=0))


def simulate_federated(
    n_clients: int,
    n_uploads: int,
    clients: Optional[Sequence[ClientModel]] = None,
    buffer_size: int = 1,
    seed: int = 0,
) -> FederatedTrace:
    """Simulate the event structure of async federated aggregation.

    ``buffer_size = 1`` is FedAsync (every upload is a server write);
    ``buffer_size = |R| > 1`` is FedBuff's semi-async buffer.  Clients start
    their next round immediately after uploading (reading the post-write
    model), and dropped rounds re-enter via a rejoin event, so slow/flaky
    clients naturally accumulate large staleness -- the regime where
    delay-adaptive mixing weights matter.
    """
    if clients is None:
        clients = heterogeneous_clients(n_clients, seed=seed)
    assert len(clients) == n_clients
    if buffer_size < 1:
        raise ValueError(f"buffer_size must be >= 1 (|R| >= 1), got {buffer_size}")
    rng = np.random.default_rng(seed + 3)

    heap = EventHeap()  # payload: (kind, client, read_version, epochs)
    for i in range(n_clients):
        heap.push(0.0, _START, i, 0, 0)

    client = np.zeros((n_uploads,), np.int32)
    read_at = np.zeros((n_uploads,), np.int32)
    tau = np.zeros((n_uploads,), np.int32)
    aggregate = np.zeros((n_uploads,), np.int32)
    version_arr = np.zeros((n_uploads,), np.int32)
    local_steps = np.zeros((n_uploads,), np.int32)
    t_wall = np.zeros((n_uploads,), np.float64)

    version = 0
    buffered = 0
    k = 0
    while k < n_uploads:
        t, kind, i, v, epochs = heap.pop()
        cm = clients[i]
        if kind == _START:
            # the client reads the server model *now*: stamp = current version
            if cm.p_dropout > 0 and rng.random() < cm.p_dropout:
                # round lost; client rejoins later and re-reads a fresh model
                heap.push(t + cm.rejoin_after, _START, i, 0, 0)
            else:
                heap.push(t + cm.round_duration(rng), _UPLOAD, i, version,
                          cm.local_epochs)
            continue
        # upload arrival: record the row, maybe aggregate, start next round
        client[k] = i
        read_at[k] = v
        tau[k] = version - v
        local_steps[k] = epochs
        t_wall[k] = t
        buffered += 1
        if buffered >= buffer_size:
            version += 1
            buffered = 0
            aggregate[k] = 1
        version_arr[k] = version
        heap.push(t, _START, i, 0, 0)
        k += 1
    return FederatedTrace(client, read_at, tau, aggregate, version_arr,
                          local_steps, t_wall)
