"""Round-trip federated client simulation (the event layer of `repro.federated`).

Generalizes ``core.engine`` from single-shot gradient returns to full client
*round trips*: a client reads the current server model (recording the server
version, Algorithm 1's stamp), runs ``local_epochs`` of local training, pays
upload jitter on the way back, and may drop out mid-round and rejoin later.
The server version counter only advances on *aggregation* events, so with a
FedBuff buffer of size ``|R| >= 1`` the staleness of an upload is measured in
server writes -- exactly the paper's write-event delay ``tau_k = k - s^(i)``,
with "write" now meaning "server aggregation".

As with ``core.engine``, the simulation produces a deterministic integer
trace; the server (``repro.federated.server``) consumes it inside a fully
jitted ``lax.scan``, so a simulated trace + a jitted server loop is *exactly*
FedAsync/FedBuff for that realization of client timings.

Two trace paths
---------------

Mirroring ``core.engine``, there are two interchangeable implementations:

* the **reference path** -- ``simulate_federated`` -- a Python ``heapq``
  discrete-event loop over START/UPLOAD events.  Handed a pre-sampled
  ``ClientRounds`` (per-client dropout coins + round durations, indexed by
  attempt), it accumulates times in float32 and becomes the bitwise ground
  truth for the jitted path; without one it keeps its legacy on-the-fly
  float64 sampling (seeded traces from earlier PRs are unchanged).
* the **jitted path** -- ``federated_trace_scan`` / the
  ``generate_federated_trace`` host wrapper -- the same event structure
  inside one ``lax.scan``.  The key invariant making this possible: every
  client has EXACTLY ONE in-flight heap event at all times (its pending
  START or its pending UPLOAD), so the heap collapses to per-client
  ``(time, seq, kind)`` arrays and a pop is a lexicographic ``(time, seq)``
  argmin -- the same tie-break discipline as ``core.engine.trace_scan``.
  Each pop performs exactly one push (rejoin START, in-flight UPLOAD, or
  next-round START), so push sequence numbers advance one per scan step in
  pop order, exactly like ``EventHeap``'s monotone tie counter.  It jits,
  vmaps (``repro.sweep`` fuses it with the server scans so FedAsync/FedBuff
  sweeps are one XLA program) and shard_maps (``repro.sweep.shard``).

The two paths agree *bitwise* (same rows, same float32 arrival times) when
driven by the same ``ClientRounds``; ``tests/test_fed_scan.py`` pins this,
including simultaneous-upload tie-breaks and dropout/rejoin chains.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EventHeap, WorkerModel

__all__ = ["ClientModel", "ClientRounds", "FederatedTrace",
           "FederatedTraceArrays", "client_arrays", "default_fed_steps",
           "federated_trace_scan", "generate_federated_trace",
           "heterogeneous_clients", "sample_client_rounds",
           "simulate_federated"]

# event kinds inside the heap
_START, _UPLOAD = 0, 1


@dataclasses.dataclass(frozen=True)
class ClientModel:
    """One federated client's timing/lifecycle model.

    compute:       service-time model for ONE local epoch (reuses
                   ``core.engine.WorkerModel`` -- lognormal + stragglers).
    upload:        service-time model for the upload leg (network jitter).
    local_epochs:  local training epochs per round (recorded in the trace so
                   the solver can replay the exact local computation).
    p_dropout:     probability a started round is lost (client goes offline
                   and never uploads that round's model).
    rejoin_after:  offline time before a dropped client re-reads the server
                   model and starts a fresh round.
    """

    compute: WorkerModel = WorkerModel()
    upload: WorkerModel = WorkerModel(mean=0.1, sigma=0.5)
    local_epochs: int = 1
    p_dropout: float = 0.0
    rejoin_after: float = 5.0

    def round_duration(self, rng: np.random.Generator) -> float:
        dt = sum(self.compute.sample(rng) for _ in range(self.local_epochs))
        return dt + self.upload.sample(rng)


def heterogeneous_clients(
    n: int,
    spread: float = 4.0,
    seed: int = 0,
    p_straggle: float = 0.05,
    straggle_x: float = 8.0,
    p_dropout: float = 0.02,
    rejoin_after: float = 5.0,
    local_epochs: int = 1,
    upload_mean: float = 0.1,
) -> list:
    """n clients with epoch times log-spaced over [1, spread] -- federated
    populations are far more heterogeneous than co-located workers (edge
    devices vs. datacenter nodes), hence the wider default spread."""
    rng = np.random.default_rng(seed)
    means = np.geomspace(1.0, spread, n)
    rng.shuffle(means)
    return [ClientModel(
        compute=WorkerModel(mean=float(m), p_straggle=p_straggle,
                            straggle_x=straggle_x),
        upload=WorkerModel(mean=upload_mean, sigma=0.5),
        local_epochs=local_epochs,
        p_dropout=p_dropout,
        rejoin_after=rejoin_after,
    ) for m in means]


class ClientRounds(NamedTuple):
    """Pre-sampled per-client round randomness, indexed by START attempt.

    ``drop_u[i, a]`` is the dropout coin and ``duration[i, a]`` the full
    round duration (``local_epochs`` compute legs + the upload leg) of client
    ``i``'s ``a``-th START attempt.  Each client draws from its own
    counter-based substream, so the arrays are independent of event order --
    the property that lets the heapq reference and ``federated_trace_scan``
    consume identical randomness (same role as
    ``core.engine.sample_service_times``).  Durations are pre-rounded to
    float32 because the jitted path accumulates arrival times in float32.
    """

    drop_u: np.ndarray      # (n_clients, n_attempts) float32 in [0, 1)
    duration: np.ndarray    # (n_clients, n_attempts) float32 round durations

    @property
    def n_attempts(self) -> int:
        return int(np.shape(self.drop_u)[-1])


def sample_client_rounds(clients: Sequence[ClientModel], n_attempts: int,
                         seed: int = 0) -> ClientRounds:
    """Pre-sample every client's dropout coins and round durations.

    Client ``i`` uses ``default_rng([seed, i])`` and draws, in order: all
    ``n_attempts`` dropout uniforms, then all compute-epoch durations, then
    all upload durations -- a fixed convention shared by both trace paths
    (it need not match the legacy on-the-fly draw order; only cross-path
    consistency matters).  Dropped attempts waste their pre-sampled duration
    by construction, which is what keeps the attempt cursor identical in
    both paths.
    """
    def leg(model: WorkerModel, rng_ln, rng_st, shape):
        mu = np.log(model.mean) - 0.5 * model.sigma ** 2
        t = rng_ln.lognormal(mu, model.sigma, size=shape)
        if model.p_straggle > 0:
            t = np.where(rng_st.random(shape) < model.p_straggle,
                         t * model.straggle_x, t)
        return t

    n = len(clients)
    drop_u = np.empty((n, n_attempts), np.float32)
    duration = np.empty((n, n_attempts), np.float32)
    for i, cm in enumerate(clients):
        # one substream per distribution, each consumed attempt-major, so the
        # first A rows of a larger draw equal the A-attempt draw exactly --
        # generate_federated_trace's budget doubling then extends the trace
        # realization instead of resampling it
        streams = [np.random.default_rng([seed, i, j]) for j in range(5)]
        drop_u[i] = streams[0].random(n_attempts).astype(np.float32)
        compute = leg(cm.compute, streams[1], streams[2],
                      (n_attempts, cm.local_epochs)).sum(axis=1)
        upload = leg(cm.upload, streams[3], streams[4], (n_attempts,))
        duration[i] = (compute + upload).astype(np.float32)
    return ClientRounds(drop_u=drop_u, duration=duration)


def client_arrays(clients: Sequence[ClientModel]):
    """The per-client lifecycle constants ``federated_trace_scan`` consumes:
    ``(p_dropout (n,) f32, rejoin_after (n,) f32, local_epochs (n,) i32)``."""
    return (np.asarray([c.p_dropout for c in clients], np.float32),
            np.asarray([c.rejoin_after for c in clients], np.float32),
            np.asarray([c.local_epochs for c in clients], np.int32))


class FederatedTrace(NamedTuple):
    """One row per client *upload* event (model arriving at the server).

    client:      (K,) int32 -- uploading client.
    read_at:     (K,) int32 -- server version the client's round started from.
    tau:         (K,) int32 -- staleness in server versions at arrival.
    aggregate:   (K,) int32 -- 1 iff this upload completes the buffer and
                               triggers a server write (FedAsync: always 1).
    version:     (K,) int32 -- server version AFTER processing the event.
    local_steps: (K,) int32 -- local epochs the client ran this round.
    t_wall:      (K,) float64 -- simulated wall-clock arrival time.
    """

    client: np.ndarray
    read_at: np.ndarray
    tau: np.ndarray
    aggregate: np.ndarray
    version: np.ndarray
    local_steps: np.ndarray
    t_wall: np.ndarray

    @property
    def n_events(self) -> int:
        return int(self.client.shape[0])

    @property
    def n_writes(self) -> int:
        return int(self.aggregate.sum())

    def max_delay(self) -> int:
        return int(self.tau.max(initial=0))


def simulate_federated(
    n_clients: int,
    n_uploads: int,
    clients: Optional[Sequence[ClientModel]] = None,
    buffer_size: int = 1,
    seed: int = 0,
    client_rounds: Optional[ClientRounds] = None,
) -> FederatedTrace:
    """Simulate the event structure of async federated aggregation.

    ``buffer_size = 1`` is FedAsync (every upload is a server write);
    ``buffer_size = |R| > 1`` is FedBuff's semi-async buffer.  Clients start
    their next round immediately after uploading (reading the post-write
    model), and dropped rounds re-enter via a rejoin event, so slow/flaky
    clients naturally accumulate large staleness -- the regime where
    delay-adaptive mixing weights matter.

    ``client_rounds`` (``sample_client_rounds``), if given, replaces on-the-
    fly sampling: attempt ``a`` of client ``i`` uses ``drop_u[i, a]`` and
    ``duration[i, a]``, and event times accumulate in float32 -- the
    reference against which the jitted ``federated_trace_scan`` is
    bitwise-tested.  Without it the legacy float64 shared-stream sampling is
    used, so traces from earlier PRs are unchanged.
    """
    if clients is None:
        clients = heterogeneous_clients(n_clients, seed=seed)
    assert len(clients) == n_clients
    if buffer_size < 1:
        raise ValueError(f"buffer_size must be >= 1 (|R| >= 1), got {buffer_size}")
    rng = np.random.default_rng(seed + 3)
    cursor = np.zeros((n_clients,), np.int64)  # attempt index per client

    heap = EventHeap()  # payload: (kind, client, read_version, epochs)
    for i in range(n_clients):
        heap.push(0.0, _START, i, 0, 0)

    client = np.zeros((n_uploads,), np.int32)
    read_at = np.zeros((n_uploads,), np.int32)
    tau = np.zeros((n_uploads,), np.int32)
    aggregate = np.zeros((n_uploads,), np.int32)
    version_arr = np.zeros((n_uploads,), np.int32)
    local_steps = np.zeros((n_uploads,), np.int32)
    t_wall = np.zeros((n_uploads,), np.float64)

    version = 0
    buffered = 0
    k = 0
    while k < n_uploads:
        t, kind, i, v, epochs = heap.pop()
        cm = clients[i]
        if kind == _START:
            # the client reads the server model *now*: stamp = current version
            if client_rounds is not None:
                a = cursor[i]
                if a >= client_rounds.n_attempts:
                    raise ValueError(
                        f"client {i} exhausted its {client_rounds.n_attempts} "
                        "pre-sampled attempts; enlarge n_attempts in "
                        "sample_client_rounds")
                cursor[i] += 1
                # float32 time accumulation, matching federated_trace_scan
                if client_rounds.drop_u[i, a] < cm.p_dropout:
                    heap.push(np.float32(t) + np.float32(cm.rejoin_after),
                              _START, i, 0, 0)
                else:
                    heap.push(np.float32(t) + client_rounds.duration[i, a],
                              _UPLOAD, i, version, cm.local_epochs)
            elif cm.p_dropout > 0 and rng.random() < cm.p_dropout:
                # round lost; client rejoins later and re-reads a fresh model
                heap.push(t + cm.rejoin_after, _START, i, 0, 0)
            else:
                heap.push(t + cm.round_duration(rng), _UPLOAD, i, version,
                          cm.local_epochs)
            continue
        # upload arrival: record the row, maybe aggregate, start next round
        client[k] = i
        read_at[k] = v
        tau[k] = version - v
        local_steps[k] = epochs
        t_wall[k] = t
        buffered += 1
        if buffered >= buffer_size:
            version += 1
            buffered = 0
            aggregate[k] = 1
        version_arr[k] = version
        heap.push(t, _START, i, 0, 0)
        k += 1
    return FederatedTrace(client, read_at, tau, aggregate, version_arr,
                          local_steps, t_wall)


class FederatedTraceArrays(NamedTuple):
    """``FederatedTrace`` columns as jnp arrays -- the jit/vmap-side twin.

    Field meanings match ``FederatedTrace`` (``t_wall`` is float32, the
    accumulation dtype of the jitted path), plus two diagnostics the host
    cannot know ahead of time because dropout chains consume scan steps:

    n_uploads:  scalar i32 -- uploads actually emitted (< the requested K
                means ``n_steps`` was too small and trailing rows are zero).
    exhausted:  scalar bool -- some client ran past its pre-sampled attempts
                (enlarge ``n_attempts``); rows after that point are invalid.
    """

    client: jnp.ndarray
    read_at: jnp.ndarray
    tau: jnp.ndarray
    aggregate: jnp.ndarray
    version: jnp.ndarray
    local_steps: jnp.ndarray
    t_wall: jnp.ndarray
    n_uploads: jnp.ndarray
    exhausted: jnp.ndarray


def default_fed_steps(n_uploads: int) -> int:
    """Default scan length: every upload costs two pops (its successful START
    and the UPLOAD itself) plus slack for dropout/rejoin chains."""
    return 2 * n_uploads + max(64, n_uploads // 4)


def federated_trace_scan(
    rounds: ClientRounds,           # (n, A) leaves, jnp or np
    p_dropout: jnp.ndarray,         # (n,) f32
    rejoin_after: jnp.ndarray,      # (n,) f32
    local_epochs: jnp.ndarray,      # (n,) i32
    n_uploads: int,
    buffer_size: int = 1,
    n_steps: Optional[int] = None,
    active: Optional[jnp.ndarray] = None,
) -> FederatedTraceArrays:
    """The jitted/vmappable federated event-structure kernel.

    One scan step = one heap pop.  The heap state is per-client ``(t, seq,
    kind)`` -- valid because every client always has exactly one in-flight
    event -- and a pop is the lexicographic ``(t, seq)`` argmin, the exact
    ``EventHeap`` order of the ``simulate_federated`` reference (initial
    STARTs carry seq 0..n-1 in client order; the single push performed by
    pop number p carries seq n + p).  START pops consume attempt ``a``'s
    pre-sampled dropout coin and duration; UPLOAD pops emit a trace row and
    re-read immediately (a same-time START with a fresh seq).  Upload rows
    are compacted to the first ``n_uploads`` inside the program, so the
    output is fixed-shape and the whole thing fuses with the server scans
    under one jit (``repro.sweep.sweep_fedasync`` / ``sweep_fedbuff``).

    ``active`` masks padded clients in ragged-bucket sweeps: padded rows
    never win the pop race, hence never start rounds, never upload, and
    never touch the version counter -- a padded cell's trace is bitwise the
    exact-width cell's trace.

    The compaction happens INSIDE the scan carry (the same idiom
    ``api.Results.virtual_time`` uses to stride ``t_wall``): each upload
    row is scattered straight into K-sized output buffers riding the
    carry, so the S-length pop columns are never materialized -- only the
    K compacted rows ever exist (S is ~2.25 K; the old post-scan
    cumsum/scatter compaction paid for both).  Values are bitwise the old
    compaction's: the slot of upload number p is p, rows past K drop.

    ``n_steps`` bounds total pops (default ``default_fed_steps``); if
    dropout chains eat the budget before ``n_uploads`` uploads arrive, the
    returned ``n_uploads`` field is short -- callers must check it (the
    ``generate_federated_trace`` wrapper retries with a doubled budget).
    """
    if buffer_size < 1:
        raise ValueError(f"buffer_size must be >= 1 (|R| >= 1), got {buffer_size}")
    drop_u = jnp.asarray(rounds.drop_u, jnp.float32)
    dur = jnp.asarray(rounds.duration, jnp.float32)
    n, A = drop_u.shape
    K = int(n_uploads)
    S = default_fed_steps(K) if n_steps is None else int(n_steps)
    i32 = jnp.int32
    imax = jnp.iinfo(i32).max
    p_drop = jnp.asarray(p_dropout, jnp.float32)
    rejoin = jnp.asarray(rejoin_after, jnp.float32)
    epochs = jnp.asarray(local_epochs, i32)
    act = None if active is None else jnp.asarray(active, jnp.bool_)

    # K-sized output buffers ride the carry: (client, read_at, tau,
    # aggregate, version, local_steps) i32 + t_wall f32, plus the upload
    # counter that addresses them
    rows0 = tuple(jnp.zeros((K,), i32) for _ in range(6)) + (
        jnp.zeros((K,), jnp.float32),)
    init = (
        jnp.zeros((n,), jnp.float32),    # t: pop time of the in-flight event
        jnp.arange(n, dtype=i32),        # seq: its push order
        jnp.zeros((n,), i32),            # kind: _START / _UPLOAD
        jnp.zeros((n,), i32),            # stamp: version the round read
        jnp.zeros((n,), i32),            # attempt: pre-sample cursor
        jnp.zeros((), i32),              # version: server aggregation counter
        jnp.zeros((), i32),              # buffered: uploads since last write
        jnp.full((), n, i32),            # seq_next: next push sequence number
        jnp.zeros((), jnp.bool_),        # exhausted: attempts overran A
        jnp.zeros((), i32),              # n_up: uploads emitted so far
        rows0,                           # compacted upload rows (K,) each
    )

    def step(carry, _):
        (t, seq, kind, stamp, attempt, version, buffered, seq_next,
         exhausted, n_up, rows) = carry
        # pop: lexicographic argmin over (t, seq) == EventHeap order
        t_race = t if act is None else jnp.where(act, t, jnp.inf)
        at_min = t_race == jnp.min(t_race)
        i = jnp.argmin(jnp.where(at_min, seq, imax)).astype(i32)
        ti = t[i]
        stamp_i = stamp[i]
        is_start = kind[i] == _START
        a = attempt[i]
        a_c = jnp.minimum(a, A - 1)
        dropped = is_start & (drop_u[i, a_c] < p_drop[i])
        started = is_start & ~dropped
        uploaded = ~is_start
        exhausted = exhausted | (is_start & (a >= A))

        # the single push this pop performs: rejoin START at t + rejoin,
        # in-flight UPLOAD at t + duration, or next-round START at t
        t = t.at[i].add(jnp.where(dropped, rejoin[i],
                                  jnp.where(started, dur[i, a_c], 0.0)))
        kind = kind.at[i].set(jnp.where(started, _UPLOAD, _START))
        stamp = stamp.at[i].set(jnp.where(started, version, stamp_i))
        attempt = attempt.at[i].add(is_start.astype(i32))
        seq = seq.at[i].set(seq_next)

        # upload bookkeeping: row + (maybe) aggregation
        buffered = buffered + uploaded.astype(i32)
        agg = uploaded & (buffered >= buffer_size)
        version_new = version + agg.astype(i32)
        buffered = jnp.where(agg, 0, buffered)

        # scatter the upload row straight into the K-sized carry buffers:
        # upload number p lands in slot p, non-uploads and overflow (p >= K)
        # route to the out-of-bounds slot K and drop
        row = (i, stamp_i, version - stamp_i, agg.astype(i32), version_new,
               epochs[i], ti)
        slot = jnp.where(uploaded & (n_up < K), n_up, K)
        rows = tuple(buf.at[slot].set(val.astype(buf.dtype), mode="drop")
                     for buf, val in zip(rows, row))
        n_up = n_up + uploaded.astype(i32)
        return (t, seq, kind, stamp, attempt, version_new, buffered,
                seq_next + 1, exhausted, n_up, rows), None

    carry_fin = jax.lax.scan(step, init, None, length=S)[0]
    exhausted_fin, n_up_fin, rows_fin = carry_fin[-3:]
    ci, ra, tu, ag, ve, ls, tw = rows_fin

    return FederatedTraceArrays(
        client=ci, read_at=ra, tau=tu, aggregate=ag, version=ve,
        local_steps=ls, t_wall=tw,
        n_uploads=jnp.minimum(n_up_fin, K),
        exhausted=exhausted_fin)


@partial(jax.jit, static_argnames=("n_uploads", "buffer_size", "n_steps"))
def _fed_scan_jit(rounds, p_dropout, rejoin_after, local_epochs, n_uploads,
                  buffer_size, n_steps):
    return federated_trace_scan(rounds, p_dropout, rejoin_after, local_epochs,
                                n_uploads, buffer_size=buffer_size,
                                n_steps=n_steps)


_INJECT_ROUNDS_JIT = {}


def _inject_rounds_jit(faults):
    """Per-FaultSpec jitted round-duration injector (memoized so repeated
    solo cells reuse the compiled transform)."""
    fn = _INJECT_ROUNDS_JIT.get(faults)
    if fn is None:
        from repro.faults.inject import inject_client_rounds
        fn = jax.jit(lambda r, s: inject_client_rounds(r, faults, s))
        _INJECT_ROUNDS_JIT[faults] = fn
    return fn


def generate_federated_trace(
    n_clients: int,
    n_uploads: int,
    clients: Optional[Sequence[ClientModel]] = None,
    buffer_size: int = 1,
    seed: int = 0,
    n_steps: Optional[int] = None,
    max_doublings: int = 4,
    faults=None,
) -> FederatedTrace:
    """Host-side wrapper: run ``federated_trace_scan`` jitted and return a
    ``FederatedTrace``.

    Drop-in replacement for ``simulate_federated`` at a fraction of the
    Python cost -- bitwise-equal to ``simulate_federated(...,
    client_rounds=...)`` on the same pre-sampled rounds.  Dropout chains
    make the required pop budget data-dependent, so if the scan runs out of
    steps (or a client runs out of pre-sampled attempts) the budget is
    doubled and the scan re-run; each budget is its own static shape, so
    repeated calls at the same size reuse the compiled program.

    ``faults`` (a ``repro.faults.FaultSpec``) fault-injects the round
    durations (crash/rejoin slowdowns, straggler spikes) with ``seed`` as
    the fault cell seed -- the same jitted transform the fused sweep cells
    apply, so the solo trace stays bitwise the batched cell's row.
    """
    if clients is None:
        clients = heterogeneous_clients(n_clients, seed=seed)
    assert len(clients) == n_clients
    from repro.faults.spec import normalize_faults
    faults = normalize_faults(faults)
    p_drop, rejoin, epochs = client_arrays(clients)
    S = default_fed_steps(n_uploads) if n_steps is None else int(n_steps)
    for _ in range(max_doublings + 1):
        rounds = sample_client_rounds(clients, S, seed=seed)
        jr = ClientRounds(*map(jnp.asarray, rounds))
        if faults is not None:
            jr = _inject_rounds_jit(faults)(jr, jnp.int32(seed))
        out = jax.device_get(_fed_scan_jit(
            jr, jnp.asarray(p_drop),
            jnp.asarray(rejoin), jnp.asarray(epochs), n_uploads,
            buffer_size, S))
        if int(out.n_uploads) >= n_uploads and not bool(out.exhausted):
            return FederatedTrace(out.client, out.read_at, out.tau,
                                  out.aggregate, out.version, out.local_steps,
                                  out.t_wall.astype(np.float64))
        S *= 2
    raise RuntimeError(
        f"federated trace did not produce {n_uploads} uploads within "
        f"{S // 2} pops; dropout/rejoin chains are extreme -- pass a larger "
        "n_steps explicitly")
