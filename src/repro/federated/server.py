"""Delay-adaptive asynchronous federated servers (FedAsync / FedBuff).

Both servers consume a ``FederatedTrace`` inside one jitted ``lax.scan`` --
the federated analogue of ``core.piag.run_piag``.  The server state carries
the global model, a per-client snapshot table (the model version each client
is training on), and the staleness-weight state; the mixing weight
``alpha * s(tau)`` is emitted by the same ``StepsizePolicy`` machinery that
drives the paper's gamma(tau) (``core.stepsize``: ``hinge`` / ``poly`` /
``constant`` via ``make_policy``).

* ``run_fedasync`` -- FedAsync [Xie et al. '19]: every upload is a server
  write, x <- (1 - alpha_t) x + alpha_t x_c with alpha_t = alpha * s(tau_k).
* ``run_fedbuff``  -- FedBuff [Nguyen et al. '22]: uploads accumulate
  staleness-weighted *deltas* in a buffer of size |R|; each aggregation
  applies x <- x + eta * mean_R(s(tau_j) Delta_j) and bumps the version.

``local_prox_sgd`` builds the client update for the paper's convex problems
(local epochs of proximal gradient descent on the client shard), so FedAsync
convergence is checkable against the centralized optimum of
``core.problems``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import strided_scan
from repro.core.prox import ProxOp
from repro.core.stepsize import (StepsizePolicy, auto_horizon, clip_delta,
                                 clipped_count)
from repro.telemetry.accumulators import (TelemetryConfig, init_telemetry,
                                          observe, emit_window, finalize)
from repro.faults.spec import CODE_CORRUPT, FaultSpec, normalize_faults
from repro.faults.inject import corrupt_value, update_fault_codes
from repro.faults.guards import (guard_event, guarded_gamma, init_faults,
                                 payload_finite)

from .events import FederatedTrace

__all__ = ["FedResult", "fedasync_scan", "fedbuff_scan", "run_fedasync",
           "run_fedbuff", "local_prox_sgd", "run_fedasync_problem",
           "run_fedbuff_problem"]

Pytree = Any


class FedResult(NamedTuple):
    x: Pytree                 # final server model
    objective: jnp.ndarray    # (K,) P(x) after each upload event
    weights: jnp.ndarray      # (K,) emitted mixing weights alpha * s(tau_k)
    taus: jnp.ndarray         # (K,) staleness fed to the weight policy
    versions: jnp.ndarray     # (K,) server version after each event
    clipped: jnp.ndarray = 0  # plain-int default: no jax init at import time
    # ^ final StepsizeState.clipped: uploads whose staleness exceeded the
    #   weight-policy horizon (H - 1 cap); nonzero flags undersized horizons.
    telemetry: Any = None     # DelayTelemetry when telemetry= was passed
    faults: Any = None        # FaultState counters when faults= was passed


def _tmap(fn, *ts):
    return jax.tree_util.tree_map(fn, *ts)


def _leaves(t):
    return jax.tree_util.tree_leaves(t)


def local_prox_sgd(worker_loss: Callable, prox: ProxOp, lr: float,
                   grad_fn: Callable | None = None) -> Callable:
    """Client update: ``n_steps`` local epochs of proximal gradient descent.

    ``worker_loss(x, *data)`` is the client's local objective f_i; the
    returned callable has the server's client-update signature
    ``update(x, n_steps, *data) -> x_c`` with a traced step count (clients
    may run different numbers of local epochs per round).

    ``grad_fn`` is the data-parallel seam: the 2-D sharded backend injects
    ``repro.mesh.pmean_grad(worker_loss, "data", D)`` so each mesh data
    shard differentiates its slice of the client samples and psums back the
    full gradient.  ``grad_fn=None`` is bitwise the old jaxpr."""
    grad = jax.grad(worker_loss) if grad_fn is None else grad_fn

    def update(x, n_steps, *data):
        def body(_, xs):
            g = grad(xs, *data)
            return prox.prox(_tmap(lambda a, b: a - lr * b, xs, g), lr)
        return jax.lax.fori_loop(0, n_steps, body, x)

    return update


def _prep(x0, client_data, trace: FederatedTrace):
    n = _leaves(client_data)[0].shape[0]
    x_read0 = _tmap(lambda leaf: jnp.broadcast_to(leaf, (n,) + leaf.shape), x0)
    events = (
        jnp.asarray(trace.client, jnp.int32),
        jnp.asarray(trace.tau, jnp.int32),
        jnp.asarray(trace.local_steps, jnp.int32),
        jnp.asarray(trace.aggregate, jnp.float32),
        jnp.asarray(trace.version, jnp.int32),
    )
    return n, x_read0, events


def fedasync_scan(
    client_update: Callable,    # (x, n_steps, *client_data_slice) -> x_c
    x0: Pytree,
    client_data: Pytree,        # each leaf (n_clients, ...)
    events,                     # stacked (client, tau, local_steps, aggregate, version)
    policy: StepsizePolicy,
    objective: Optional[Callable] = None,
    horizon: int = 4096,
    record_every: int = 1,
    telemetry: Optional[TelemetryConfig] = None,
    engine: str = "scan",
    faults: Optional[FaultSpec] = None,
    fault_codes: Optional[jnp.ndarray] = None,
) -> FedResult:
    """The traceable FedAsync core: one ``lax.scan`` over upload events.

    Shared verbatim by the solo ``run_fedasync`` jit and the vmapped
    ``repro.sweep.sweep_fedasync`` batch (events and policy parameters get a
    leading grid dimension there).  ``record_every=s`` materializes (and
    evaluates the objective for) only every s-th upload row -- bitwise rows
    ``s-1, 2s-1, ...`` of the stride-1 run (``engine.strided_scan``).

    ``engine='fused'`` launches the per-upload weight select + convex mix as
    one Pallas kernel (``kernels.fused_step.fused_policy_mix_step``) --
    bitwise-equal to ``engine='scan'``; needs a single-1-D-leaf model.

    ``faults``/``fault_codes`` switch in the guarded step (see
    ``core.piag.piag_scan``): the uploaded client model is the guarded
    payload -- corrupt events poison ``x_c``, non-finite / over-stale
    uploads are rejected (no server write) -- and ``faults=None`` is
    bitwise the pre-fault jaxpr."""
    if engine not in ("scan", "fused"):
        raise ValueError(f"engine must be 'scan' or 'fused', got {engine!r}")
    faults = normalize_faults(faults)
    if faults is not None:
        if engine == "fused":
            raise TypeError("engine='fused' does not support fault "
                            "injection; use engine='scan'")
        if fault_codes is None:
            raise ValueError("faults is set but fault_codes is None; build "
                             "the event codes with "
                             "repro.faults.update_fault_codes")
    if engine == "fused":
        from repro.kernels.fused_step import (as_policy_params, fused_leaf,
                                              fused_policy_mix_step)
        fparams = as_policy_params(policy)
        _, x_treedef = fused_leaf(x0, "FedAsync server model")
    n = _leaves(client_data)[0].shape[0]
    x_read0 = _tmap(lambda leaf: jnp.broadcast_to(leaf, (n,) + leaf.shape), x0)

    def data_at(w):
        return _tmap(lambda leaf: leaf[w], client_data)

    obj = objective if objective is not None else (lambda x: jnp.full((), jnp.nan))

    def make_step(emit):
        if faults is not None:
            return _make_fault_step(emit)

        def step(carry, event):
            x, x_read, ss = carry[:3]
            w, tau, steps, _, ver = event
            xw = _tmap(lambda leaf: leaf[w], x_read)
            xc = client_update(xw, steps, *_leaves(data_at(w)))
            ss_old = ss
            if engine == "fused":
                gamma, ss, x_leaf = fused_policy_mix_step(
                    fparams, ss, tau, _leaves(x)[0], _leaves(xc)[0])
                x_new = jax.tree_util.tree_unflatten(x_treedef, [x_leaf])
            else:
                gamma, ss = policy.step(ss, tau)
                # x <- (1 - alpha_t) x + alpha_t x_c
                x_new = _tmap(lambda a, c: a + gamma * (c - a), x, xc)
            # the uploading client picks up the freshly-written model
            x_read = _tmap(lambda buf, xv: buf.at[w].set(xv), x_read, x_new)
            if telemetry is None:
                if not emit:
                    return (x_new, x_read, ss), None
                return (x_new, x_read, ss), (obj(x_new), gamma, tau, ver)
            tel = observe(carry[3], tau, gamma, clip_delta(ss_old, ss))
            if not emit:
                return (x_new, x_read, ss, tel), None
            tel, wclip = emit_window(tel)
            return (x_new, x_read, ss, tel), (obj(x_new), gamma, tau, ver,
                                              wclip)
        return step

    fi = 4 if telemetry is not None else 3

    def _make_fault_step(emit):
        poison = corrupt_value(faults)

        def step(carry, event):
            x, x_read, ss = carry[:3]
            fs = carry[fi]
            w, tau, steps, _, ver, code = event
            xw = _tmap(lambda leaf: leaf[w], x_read)
            xc = client_update(xw, steps, *_leaves(data_at(w)))
            xc = _tmap(lambda a: (a + jnp.where(code == CODE_CORRUPT, poison,
                                                jnp.float32(0.0))
                                  ).astype(a.dtype), xc)
            finite = payload_finite(xc) if faults.guard_nonfinite \
                else jnp.ones((), jnp.bool_)
            accept, mult, fs = guard_event(faults, code, tau, finite, fs)
            ss_old = ss
            gamma, ss, fs = guarded_gamma(policy, ss, tau, mult, faults, fs)
            x_cand = _tmap(lambda a, c: a + gamma * (c - a), x, xc)
            x_new = _tmap(lambda cnd, old: jnp.where(accept, cnd, old),
                          x_cand, x)
            x_read = _tmap(lambda buf, xv: buf.at[w].set(xv), x_read, x_new)
            tel = None
            if telemetry is not None:
                tel = observe(carry[3], tau, gamma, clip_delta(ss_old, ss))
            extras = ((tel,) if telemetry is not None else ()) + (fs,)
            if not emit:
                return (x_new, x_read, ss) + extras, None
            wtail = ()
            if telemetry is not None:
                tel, wclip = emit_window(tel)
                extras = (tel, fs)
                wtail = (wclip,)
            out = (obj(x_new), gamma, tau, ver) + wtail
            return (x_new, x_read, ss) + extras, out
        return step

    if faults is not None:
        events = tuple(events) + (jnp.asarray(fault_codes, jnp.int32),)
    carry0 = (x0, x_read0, policy.init(horizon))
    if telemetry is not None:
        carry0 = carry0 + (init_telemetry(telemetry),)
    if faults is not None:
        carry0 = carry0 + (init_faults(),)
    carry_fin, outs = strided_scan(make_step, carry0, events, record_every)
    x_fin, ss_fin = carry_fin[0], carry_fin[2]
    o, g, t, v = outs[:4]
    tel_out = finalize(carry_fin[3], outs[4]) if telemetry is not None else None
    faults_out = carry_fin[fi] if faults is not None else None
    return FedResult(x=x_fin, objective=o, weights=g, taus=t, versions=v,
                     clipped=clipped_count(ss_fin), telemetry=tel_out,
                     faults=faults_out)


def run_fedasync(
    client_update: Callable,
    x0: Pytree,
    client_data: Pytree,
    trace: FederatedTrace,
    policy: StepsizePolicy,     # gamma_prime = alpha; emits alpha * s(tau)
    objective: Optional[Callable] = None,   # P(x); nan if omitted
    horizon: int | str = 4096,
    record_every: int = 1,
    telemetry: Optional[TelemetryConfig] = None,
    engine: str = "scan",
    faults: Optional[FaultSpec] = None,
    fault_seed: int = 0,
) -> FedResult:
    """FedAsync: staleness-weighted model mixing, one write per upload.

    ``horizon='auto'`` sizes the weight-policy buffer from the trace's own
    measured staleness (bitwise-identical whenever delays fit)."""
    if horizon == "auto":
        horizon = auto_horizon(int(np.max(np.asarray(trace.tau), initial=0)))
    _, _, events = _prep(x0, client_data, trace)
    faults = normalize_faults(faults)

    if faults is None:
        @jax.jit
        def run(events):
            return fedasync_scan(client_update, x0, client_data, events,
                                 policy, objective=objective, horizon=horizon,
                                 record_every=record_every,
                                 telemetry=telemetry, engine=engine)

        return run(events)

    n_events = int(events[0].shape[0])

    @jax.jit
    def run_faulted(events, fseed):
        codes = update_fault_codes(faults, n_events, fseed)
        return fedasync_scan(client_update, x0, client_data, events, policy,
                             objective=objective, horizon=horizon,
                             record_every=record_every, telemetry=telemetry,
                             engine=engine, faults=faults, fault_codes=codes)

    return run_faulted(events, jnp.int32(fault_seed))


def fedbuff_scan(
    client_update: Callable,    # (x, n_steps, *client_data_slice) -> x_c
    x0: Pytree,
    client_data: Pytree,        # each leaf (n_clients, ...)
    events,                     # stacked (client, tau, local_steps, aggregate, version)
    policy: StepsizePolicy,     # per-delta staleness weight s(tau) (gamma'=1)
    eta: float = 1.0,           # server learning rate applied per aggregation
    buffer_size: int = 1,       # |R|; must match the trace's buffer
    objective: Optional[Callable] = None,
    horizon: int = 4096,
    record_every: int = 1,
    telemetry: Optional[TelemetryConfig] = None,
    engine: str = "scan",
    faults: Optional[FaultSpec] = None,
    fault_codes: Optional[jnp.ndarray] = None,
) -> FedResult:
    """The traceable FedBuff core: buffered semi-async aggregation of
    staleness-weighted deltas as one ``lax.scan`` over upload events.

    Uploads accumulate ``s(tau_j) * (x_cj - x_read_j)``; when the trace marks
    the buffer full the server applies the mean buffered delta scaled by
    ``eta``.  ``buffer_size = 1`` makes every upload a write event and the
    update rule collapses to sequential delta application (tested against a
    plain python reference).  Shared verbatim by the solo ``run_fedbuff`` jit
    and the vmapped/sharded ``repro.sweep.sweep_fedbuff`` batch, which fuses
    this scan with the jitted ``federated.events.federated_trace_scan``.

    ``engine='fused'`` launches the per-upload weight select + delta
    accumulate + buffered apply/decay as one Pallas kernel
    (``kernels.fused_step.fused_policy_buff_step``) -- bitwise-equal to
    ``engine='scan'``; needs a single-1-D-leaf model.

    ``faults``/``fault_codes`` guard the buffered delta (see
    ``core.piag.piag_scan``): rejected uploads contribute nothing to the
    buffer; the trace's aggregation schedule is untouched, so a buffer
    whose uploads were all rejected applies a zero delta.  ``faults=None``
    is bitwise the pre-fault jaxpr."""
    if engine not in ("scan", "fused"):
        raise ValueError(f"engine must be 'scan' or 'fused', got {engine!r}")
    faults = normalize_faults(faults)
    if faults is not None:
        if engine == "fused":
            raise TypeError("engine='fused' does not support fault "
                            "injection; use engine='scan'")
        if fault_codes is None:
            raise ValueError("faults is set but fault_codes is None; build "
                             "the event codes with "
                             "repro.faults.update_fault_codes")
    if engine == "fused":
        from repro.kernels.fused_step import (as_policy_params, fused_leaf,
                                              fused_policy_buff_step)
        fparams = as_policy_params(policy)
        _, x_treedef = fused_leaf(x0, "FedBuff server model")
    n = _leaves(client_data)[0].shape[0]
    x_read0 = _tmap(lambda leaf: jnp.broadcast_to(leaf, (n,) + leaf.shape), x0)

    def data_at(w):
        return _tmap(lambda leaf: leaf[w], client_data)

    obj = objective if objective is not None else (lambda x: jnp.full((), jnp.nan))
    delta0 = _tmap(jnp.zeros_like, x0)

    def make_step(emit):
        if faults is not None:
            return _make_fault_step(emit)

        def step(carry, event):
            x, x_read, delta, ss = carry[:4]
            w, tau, steps, agg, ver = event
            xw = _tmap(lambda leaf: leaf[w], x_read)
            xc = client_update(xw, steps, *_leaves(data_at(w)))
            ss_old = ss
            if engine == "fused":
                gamma, ss, x_leaf, d_leaf = fused_policy_buff_step(
                    fparams, ss, tau, _leaves(x)[0], _leaves(xc)[0],
                    _leaves(xw)[0], _leaves(delta)[0], agg,
                    eta / buffer_size)
                x_new = jax.tree_util.tree_unflatten(x_treedef, [x_leaf])
                delta = jax.tree_util.tree_unflatten(x_treedef, [d_leaf])
            else:
                gamma, ss = policy.step(ss, tau)
                delta = _tmap(lambda d, c, a: d + gamma * (c - a), delta, xc,
                              xw)
                x_new = _tmap(lambda a, d: a + agg * (eta / buffer_size) * d,
                              x, delta)
                delta = _tmap(lambda d: (1.0 - agg) * d, delta)
            x_read = _tmap(lambda buf, xv: buf.at[w].set(xv), x_read, x_new)
            if telemetry is None:
                if not emit:
                    return (x_new, x_read, delta, ss), None
                return (x_new, x_read, delta, ss), (obj(x_new), gamma, tau,
                                                    ver)
            tel = observe(carry[4], tau, gamma, clip_delta(ss_old, ss))
            if not emit:
                return (x_new, x_read, delta, ss, tel), None
            tel, wclip = emit_window(tel)
            return (x_new, x_read, delta, ss, tel), (obj(x_new), gamma, tau,
                                                     ver, wclip)
        return step

    fi = 5 if telemetry is not None else 4

    def _make_fault_step(emit):
        poison = corrupt_value(faults)

        def step(carry, event):
            x, x_read, delta, ss = carry[:4]
            fs = carry[fi]
            w, tau, steps, agg, ver, code = event
            xw = _tmap(lambda leaf: leaf[w], x_read)
            xc = client_update(xw, steps, *_leaves(data_at(w)))
            xc = _tmap(lambda a: (a + jnp.where(code == CODE_CORRUPT, poison,
                                                jnp.float32(0.0))
                                  ).astype(a.dtype), xc)
            finite = payload_finite(xc) if faults.guard_nonfinite \
                else jnp.ones((), jnp.bool_)
            accept, mult, fs = guard_event(faults, code, tau, finite, fs)
            ss_old = ss
            gamma, ss, fs = guarded_gamma(policy, ss, tau, mult, faults, fs)
            # rejected uploads add an exact zero to the buffered delta; the
            # aggregation schedule (agg flags from the trace) is untouched
            delta = _tmap(lambda d, c, a: d + jnp.where(
                accept, gamma * (c - a), jnp.float32(0.0)), delta, xc, xw)
            x_new = _tmap(lambda a, d: a + agg * (eta / buffer_size) * d,
                          x, delta)
            delta = _tmap(lambda d: (1.0 - agg) * d, delta)
            x_read = _tmap(lambda buf, xv: buf.at[w].set(xv), x_read, x_new)
            tel = None
            if telemetry is not None:
                tel = observe(carry[4], tau, gamma, clip_delta(ss_old, ss))
            extras = ((tel,) if telemetry is not None else ()) + (fs,)
            if not emit:
                return (x_new, x_read, delta, ss) + extras, None
            wtail = ()
            if telemetry is not None:
                tel, wclip = emit_window(tel)
                extras = (tel, fs)
                wtail = (wclip,)
            out = (obj(x_new), gamma, tau, ver) + wtail
            return (x_new, x_read, delta, ss) + extras, out
        return step

    if faults is not None:
        events = tuple(events) + (jnp.asarray(fault_codes, jnp.int32),)
    carry0 = (x0, x_read0, delta0, policy.init(horizon))
    if telemetry is not None:
        carry0 = carry0 + (init_telemetry(telemetry),)
    if faults is not None:
        carry0 = carry0 + (init_faults(),)
    carry_fin, outs = strided_scan(make_step, carry0, events, record_every)
    x_fin, ss_fin = carry_fin[0], carry_fin[3]
    o, g, t, v = outs[:4]
    tel_out = finalize(carry_fin[4], outs[4]) if telemetry is not None else None
    faults_out = carry_fin[fi] if faults is not None else None
    return FedResult(x=x_fin, objective=o, weights=g, taus=t, versions=v,
                     clipped=clipped_count(ss_fin), telemetry=tel_out,
                     faults=faults_out)


def run_fedbuff(
    client_update: Callable,
    x0: Pytree,
    client_data: Pytree,
    trace: FederatedTrace,
    policy: StepsizePolicy,     # per-delta staleness weight s(tau) (gamma'=1)
    eta: float = 1.0,           # server learning rate applied per aggregation
    buffer_size: int = 1,       # |R|; must match the trace's buffer
    objective: Optional[Callable] = None,
    horizon: int | str = 4096,
    record_every: int = 1,
    telemetry: Optional[TelemetryConfig] = None,
    engine: str = "scan",
    faults: Optional[FaultSpec] = None,
    fault_seed: int = 0,
) -> FedResult:
    """FedBuff [Nguyen et al. '22] over a simulated trace; one jit."""
    if horizon == "auto":
        horizon = auto_horizon(int(np.max(np.asarray(trace.tau), initial=0)))
    _, _, events = _prep(x0, client_data, trace)
    faults = normalize_faults(faults)

    if faults is None:
        @jax.jit
        def run(events):
            return fedbuff_scan(client_update, x0, client_data, events,
                                policy, eta=eta, buffer_size=buffer_size,
                                objective=objective, horizon=horizon,
                                record_every=record_every,
                                telemetry=telemetry, engine=engine)

        return run(events)

    n_events = int(events[0].shape[0])

    @jax.jit
    def run_faulted(events, fseed):
        codes = update_fault_codes(faults, n_events, fseed)
        return fedbuff_scan(client_update, x0, client_data, events, policy,
                            eta=eta, buffer_size=buffer_size,
                            objective=objective, horizon=horizon,
                            record_every=record_every, telemetry=telemetry,
                            engine=engine, faults=faults, fault_codes=codes)

    return run_faulted(events, jnp.int32(fault_seed))


def _problem_pieces(problem, prox: ProxOp, local_lr: Optional[float],
                    grad_fn: Callable | None = None):
    Aw, bw = problem.worker_slices()
    lr = (0.9 / problem.L) if local_lr is None else local_lr
    update = local_prox_sgd(
        lambda x, A, b: problem.worker_loss(x, A, b), prox, lr,
        grad_fn=grad_fn)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    return update, x0, (Aw, bw)


def run_fedasync_problem(problem, trace, policy, prox,
                         local_lr: Optional[float] = None,
                         horizon: int = 4096) -> FedResult:
    """FedAsync on a ``core.problems`` convex problem (logreg / lasso):
    clients run local prox-SGD epochs on their shard, the server mixes with
    the delay-adaptive weight, and ``objective`` is the TRUE composite P so
    convergence is checkable against the centralized optimum."""
    update, x0, data = _problem_pieces(problem, prox, local_lr)
    return run_fedasync(update, x0, data, trace, policy,
                        objective=problem.P, horizon=horizon)


def run_fedbuff_problem(problem, trace, policy, prox,
                        eta: float = 1.0, buffer_size: int = 1,
                        local_lr: Optional[float] = None,
                        horizon: int = 4096) -> FedResult:
    update, x0, data = _problem_pieces(problem, prox, local_lr)
    return run_fedbuff(update, x0, data, trace, policy, eta=eta,
                       buffer_size=buffer_size, objective=problem.P,
                       horizon=horizon)
