"""hubert-xlarge [audio]: encoder-only transformer backbone (same arch as
wav2vec2).  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit
prediction classes) [arXiv:2106.07447].

The mel-spectrogram + conv feature extractor frontend is a STUB per the
assignment carve-out: input_specs() provides precomputed frame embeddings of
shape (B, S, 1280).  Encoder-only => no decode step (decode_32k / long_500k
skipped; see DESIGN.md §6)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        source="arXiv:2106.07447",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab=504,
        act="gelu",
        norm="layernorm",
        causal=False,
        has_decode=False,
        embed_inputs=True,
        rope="none",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
