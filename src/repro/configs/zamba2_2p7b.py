"""zamba2-2.7b [hybrid]: Mamba2 trunk + shared attention blocks.
54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242].  Shared attention+MLP block applied every 6 Mamba2
layers (9 invocations of one weight set)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        source="arXiv:2411.15242",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab=32000,
        act="silu_glu",
        norm="rmsnorm",
        rope="rope",
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        attn_every=6,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
