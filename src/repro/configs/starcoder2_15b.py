"""starcoder2-15b [dense]: GQA + RoPE, LayerNorm, GELU, bias, native 4k
sliding window.  40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152
[arXiv:2402.19173]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        source="arXiv:2402.19173",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        act="gelu",
        norm="layernorm",
        qkv_bias=True,
        mlp_bias=True,
        rope="rope",
        sliding_window=4096,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
