"""qwen2-vl-72b [vlm]: M-RoPE + dynamic resolution.  80L d_model=8192 64H
(GQA kv=8) d_ff=29568 vocab=152064 [arXiv:2409.12191].

The ViT vision encoder + projector frontend is a STUB per the assignment
carve-out: input_specs() provides precomputed patch embeddings (B, S, 8192)
plus (3, B, S) M-RoPE position grids.  The language backbone (M-RoPE
sections 16/24/24 over head_dim/2 = 64) is fully implemented; text decode
uses the token table."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        source="arXiv:2409.12191",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,
        act="silu_glu",
        norm="rmsnorm",
        rope="mrope",
        mrope_sections=(16, 24, 24),
        embed_inputs=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
