"""The four assigned input shapes + abstract input specs for the dry-run.

  train_4k       seq=  4,096  global_batch=256  (training)
  prefill_32k    seq= 32,768  global_batch= 32  (inference-prefill)
  decode_32k     seq= 32,768  global_batch=128  (inference-decode: ONE new
                                                  token vs a seq-long cache)
  long_500k      seq=524,288  global_batch=  1  (long-context decode;
                                                  sub-quadratic attention
                                                  required -> sliding-window
                                                  ring cache / SSM state)

``input_specs`` returns ShapeDtypeStructs only -- weak-type-correct,
shardable, no device allocation -- for the step each shape lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import make_cache
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq: int
    global_batch: int
    kind: str  # train | prefill | decode | decode_long


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode_long"),
}


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) runs, and the reason when skipped."""
    if shape.kind in ("decode", "decode_long") and not cfg.has_decode:
        return False, "encoder-only: no decode step (DESIGN.md §6)"
    return True, ""


def decode_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    """Attention window used for the decode shapes.

    long_500k requires sub-quadratic attention: SSM archs carry no cache at
    all; attention archs run the sliding-window variant (ring cache of
    ``long_context_window``).  decode_32k keeps native behaviour."""
    if shape.kind == "decode_long" and cfg.family != "ssm":
        return (cfg.sliding_window if cfg.sliding_window
                else cfg.long_context_window)
    return cfg.sliding_window


def cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.kind == "decode_long" and cfg.family != "ssm":
        w = decode_window(cfg, shape)
        return int(w)
    return shape.seq


def uses_ring(cfg: ModelConfig, shape: InputShape) -> bool:
    return shape.kind == "decode_long" and cfg.family != "ssm"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, object]:
    """Abstract inputs for the step the shape lowers."""
    B, S = shape.global_batch, shape.seq
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, object] = {}
        if cfg.embed_inputs:
            batch["embeds"] = _sds((B, S, cfg.d_model), cfg.cdtype)
        else:
            batch["tokens"] = _sds((B, S), i32)
        if cfg.rope == "mrope":
            batch["positions"] = _sds((3, B, S), i32)
        if shape.kind == "train":
            batch["targets"] = _sds((B, S), i32)
        return {"batch": batch}

    # decode shapes: ONE new token against a cache
    ring = uses_ring(cfg, shape)
    clen = cache_len(cfg, shape)
    cache = jax.eval_shape(lambda: make_cache(cfg, B, clen, ring=ring))
    return {
        "cache": cache,
        "token": _sds((B, 1), i32),
        "pos": _sds((), i32),
    }
