"""qwen2.5-32b [dense]: GQA with QKV bias.  64L d_model=5120 40H (GQA kv=8)
d_ff=27648 vocab=152064 [hf:Qwen/Qwen2.5-0.5B (family card)]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        source="hf:Qwen/Qwen2.5-0.5B",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab=152064,
        qkv_bias=True,
        act="silu_glu",
        norm="rmsnorm",
        rope="rope",
        rope_theta=1000000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
