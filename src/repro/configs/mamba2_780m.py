"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.
48L d_model=1536 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060].
expand=2 -> d_inner=3072, head_dim=64 -> 48 SSM heads."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        source="arXiv:2405.21060",
        n_layers=48,
        d_model=1536,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=256,
        rope="none",
        norm="rmsnorm",
        tie_embeddings=True,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
