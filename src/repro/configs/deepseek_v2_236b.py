"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared / 160 routed top-6.
60L d_model=5120 128H expert d_ff=1536 vocab=102400 [arXiv:2405.04434].
MLA: q_lora=1536, rope_head_dim=64, nope=128, v=128; decode uses the
absorbed latent form (cache = 512+64 per token per layer).
Deviation noted in DESIGN.md: the real model's first dense layer is modeled
as MoE for scan homogeneity."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        source="arXiv:2405.04434",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
        d_ff=1536,
        moe_ff=1536,
        n_experts=160,
        top_k=6,
        shared_ff=3072,
        vocab=102400,
        act="silu_glu",
        norm="rmsnorm",
        rope="rope",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
