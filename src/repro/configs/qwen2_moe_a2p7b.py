"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4.
24L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=151936
[hf:Qwen/Qwen1.5-MoE-A2.7B].  The 4 shared experts are modeled as one fused
shared MLP of intermediate size 4*1408 = 5632 (matching the released
shared-expert intermediate size)."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        moe_ff=1408,
        n_experts=60,
        top_k=4,
        shared_ff=5632,
        vocab=151936,
        qkv_bias=True,
        act="silu_glu",
        norm="rmsnorm",
        rope="rope",
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
