"""yi-34b [dense]: llama-architecture GQA.  60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000 [arXiv:2403.04652]."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        family="dense",
        source="arXiv:2403.04652",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab=64000,
        act="silu_glu",
        norm="rmsnorm",
        rope="rope",
        rope_theta=5000000.0,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
    )
