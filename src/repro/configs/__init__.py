"""Architecture registry: ``get_config(arch_id)`` for every assigned arch."""
from importlib import import_module
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "yi-34b": "repro.configs.yi_34b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "nemotron-4-15b": "repro.configs.nemotron4_15b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen2.5-32b": "repro.configs.qwen2p5_32b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise ValueError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    cfg = import_module(_MODULES[arch_id]).get_config()
    cfg.validate()
    return cfg


from .shapes import SHAPES, InputShape, applicable, input_specs  # noqa: E402

__all__ = ["ARCH_IDS", "get_config", "SHAPES", "InputShape", "applicable",
           "input_specs"]
