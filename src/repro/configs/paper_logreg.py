"""The paper's own experimental workload (§4): l1-regularized logistic
regression on rcv1-like / MNIST-like data (synthetic stand-ins offline).
(lam1, lam2) follow the paper: (1e-5, 1e-4) rcv1, (1e-3, 1e-4) MNIST."""
import dataclasses

from repro.core.problems import LogRegProblem, make_logreg


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    name: str
    n_samples: int
    dim: int
    n_workers: int
    sparse_like: bool
    lam1: float
    lam2: float
    m_blocks: int = 20

    def build(self, seed: int = 0) -> LogRegProblem:
        return make_logreg(self.n_samples, self.dim, self.n_workers,
                           sparse_like=self.sparse_like, lam1=self.lam1,
                           lam2=self.lam2, seed=seed)


RCV1_LIKE = PaperWorkload("rcv1-like", n_samples=4000, dim=800, n_workers=10,
                          sparse_like=True, lam1=1e-5, lam2=1e-4)
MNIST_LIKE = PaperWorkload("mnist-like", n_samples=4000, dim=784, n_workers=10,
                           sparse_like=False, lam1=1e-3, lam2=1e-4)
