"""In-scan guards: reject, degrade, count.

The solver scans call two helpers per event when a ``FaultSpec`` is
present:

* :func:`guard_event` -- given the event's fault code, payload finiteness
  and delay, decide acceptance and the step multiplier (0 = skip, 1 =
  normal, 2 = duplicated update);
* :func:`guarded_gamma` -- compute gamma WITHOUT pushing (via the
  policy's ``_gamma`` split), apply graceful degradation on horizon
  overflow (fall back to the worst-case-bound ``gamma' / (tau + 1)``
  instead of trusting a silently-truncated window sum), scale by the
  multiplier, and push ONCE.

Counters ride the scan carry as a :class:`FaultState` (all int32
scalars), exactly like ``telemetry.TelemetryState`` -- reduced on-device,
summed over cells host-side by :func:`summarize_faults`.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.spec import (CODE_CORRUPT, CODE_DROP, CODE_DUP, FaultSpec)

__all__ = ["FaultState", "init_faults", "guard_event", "guarded_gamma",
           "payload_finite", "summarize_faults", "fault_gamma_prime"]


class FaultState(NamedTuple):
    """Per-cell fault counters (int32 scalars) riding the scan carry."""

    injected: jnp.ndarray            # corrupt codes seen (payload poisoned)
    dropped: jnp.ndarray             # drop codes seen (update lost)
    duplicated: jnp.ndarray          # dup codes applied (2*gamma steps)
    rejected_nonfinite: jnp.ndarray  # guard: non-finite payload skipped
    rejected_stale: jnp.ndarray      # guard: tau > staleness_cutoff skipped
    degraded: jnp.ndarray            # guard: worst-case-bound gamma fallback


def init_faults() -> FaultState:
    z = jnp.zeros((), jnp.int32)
    return FaultState(z, z, z, z, z, z)


def payload_finite(tree) -> jnp.ndarray:
    """Scalar bool: every leaf of the update payload is finite."""
    leaves = jax.tree_util.tree_leaves(tree)
    ok = jnp.ones((), jnp.bool_)
    for leaf in leaves:
        ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok


def guard_event(spec: FaultSpec, code, tau, finite, fs: FaultState
                ) -> Tuple[jnp.ndarray, jnp.ndarray, FaultState]:
    """Acceptance decision for one event.

    Returns ``(accept, mult, fs)``: ``accept`` scalar bool (apply a server
    write at all), ``mult`` float32 in {0, 1, 2} (step multiplier), and
    the advanced counters.  ``finite`` is the payload finiteness AFTER
    corruption injection; with ``guard_nonfinite`` off, non-finite
    payloads pass through (documented chaos mode -- NaN then propagates,
    which is the failure the guard exists to prevent).
    """
    code = jnp.asarray(code, jnp.int32)
    is_drop = code == CODE_DROP
    is_dup = code == CODE_DUP
    is_corrupt = code == CODE_CORRUPT

    finite_ok = finite | (not spec.guard_nonfinite)
    if spec.staleness_cutoff is not None:
        fresh = jnp.asarray(tau, jnp.int32) <= jnp.int32(spec.staleness_cutoff)
    else:
        fresh = jnp.ones((), jnp.bool_)

    accept = (~is_drop) & finite_ok & fresh
    mult = jnp.where(accept, jnp.where(is_dup, 2.0, 1.0), 0.0
                     ).astype(jnp.float32)

    one = jnp.int32(1)
    zero = jnp.int32(0)
    fs = FaultState(
        injected=fs.injected + jnp.where(is_corrupt, one, zero),
        dropped=fs.dropped + jnp.where(is_drop, one, zero),
        duplicated=fs.duplicated + jnp.where(is_dup & accept, one, zero),
        rejected_nonfinite=fs.rejected_nonfinite
        + jnp.where((~is_drop) & ~finite_ok, one, zero),
        rejected_stale=fs.rejected_stale
        + jnp.where((~is_drop) & finite_ok & ~fresh, one, zero),
        degraded=fs.degraded,
    )
    return accept, mult, fs


def fault_gamma_prime(policy) -> jnp.ndarray:
    """The policy's gamma' as a traceable float32 -- static float on the
    concrete dataclasses, the traced params field on ``ParamPolicy``."""
    params = getattr(policy, "params", None)
    if params is not None:
        return jnp.asarray(params.gamma_prime, jnp.float32)
    return jnp.asarray(np.float32(policy.gamma_prime))


def guarded_gamma(policy, ss, tau, mult, spec: FaultSpec, fs: FaultState
                  ) -> Tuple[jnp.ndarray, Any, FaultState]:
    """Gamma with guards, pushed once.

    Splits the policy step via ``_gamma`` (every sweep-able policy has
    one; ``AdaptiveLipschitz`` does not and is rejected at dispatch), then:

    * horizon overflow (``was_clipped``): with ``degrade_on_clip``, fall
      back to the worst-case-bound step ``gamma' / (tau + 1)`` -- the
      FixedStepSize rule evaluated at the OBSERVED delay -- instead of the
      window-based gamma whose sum was silently truncated;
    * scale by ``mult`` (0 skip / 1 normal / 2 duplicate) -- the scaled
      gamma is what enters the cumulative window buffer, so future window
      sums reflect the progress actually applied.

    Returns ``(gamma_eff, new_ss, fs)``.
    """
    # deferred: repro.core imports this module (scan cores use the guards),
    # so a top-level stepsize import would be circular for `import repro.faults`
    from repro.core.stepsize import _push

    gamma_fn = getattr(policy, "_gamma", None)
    if gamma_fn is None:
        raise TypeError(
            f"{type(policy).__name__} exposes no _gamma split; fault guards "
            "cannot intercept its step (use a window/fixed-family policy, "
            "or run without faults)")
    gamma, was_clipped = gamma_fn(ss, tau)
    gamma = jnp.asarray(gamma, jnp.float32)
    if spec.degrade_on_clip:
        clipped_b = jnp.asarray(was_clipped, jnp.int32) > 0
        fallback = fault_gamma_prime(policy) \
            / (jnp.asarray(tau, jnp.float32) + 1.0)
        gamma = jnp.where(clipped_b, fallback, gamma)
        fs = fs._replace(degraded=fs.degraded
                         + jnp.where(clipped_b, jnp.int32(1), jnp.int32(0)))
    gamma_eff = gamma * jnp.asarray(mult, jnp.float32)
    return gamma_eff, _push(ss, gamma_eff, was_clipped), fs


def summarize_faults(fs) -> dict:
    """Host-side dict of totals (summed over any leading cell axes)."""
    if fs is None:
        return {}
    return {name: int(np.asarray(getattr(fs, name)).sum())
            for name in FaultState._fields}
