"""Deterministic, jittable fault-process injection.

Two entry points, both pure functions of ``(spec, cell_seed)``:

* :func:`inject_service_times` transforms a pre-sampled service-time
  matrix BEFORE ``core.engine.trace_scan`` consumes it -- per-worker
  crash/rejoin Markov chains (the in-flight task of a "down" worker is
  stretched by ``crash_scale``, so its next completion lands with a huge
  measured staleness: the rejoin spike) and heavy-tail Pareto straggler
  spikes.  The same transform applies to federated round durations
  (:func:`inject_client_rounds`).
* :func:`update_fault_codes` draws the per-event drop/dup/corrupt codes
  the solver scans consume as an extra event column.

Randomness is ``jax.random`` keyed by ``fold_in(PRNGKey(spec.seed),
cell_seed)`` with a static stream tag per draw site -- ``cell_seed`` may be
a traced scalar, so the SAME key arithmetic runs inside a vmapped batched
cell and in a solo per-cell call, making the three backends bitwise equal
under faults.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.faults.spec import (CODE_CORRUPT, CODE_DROP, CODE_DUP, FaultSpec)

__all__ = ["inject_service_times", "inject_client_rounds",
           "update_fault_codes", "corrupt_value"]

# Static stream tags keeping the three draw sites independent.
_STREAM_CRASH = 0x5EED0001
_STREAM_SPIKE = 0x5EED0002
_STREAM_CODES = 0x5EED0003


def _key(spec: FaultSpec, cell_seed, stream: int):
    k = jax.random.fold_in(jax.random.PRNGKey(spec.seed),
                           jnp.asarray(cell_seed, jnp.uint32))
    return jax.random.fold_in(k, stream)


def _down_mask(spec: FaultSpec, key, shape):
    """(n, T) float32 {0,1} per-worker down-state Markov chain over tasks.

    up -> down w.p. ``p_crash``; down -> up w.p. ``p_rejoin``.  Workers
    start up.  One uniform per (worker, task)."""
    u = jax.random.uniform(key, shape, jnp.float32)

    def step(down, u_t):
        # down: (n,) bool state BEFORE task t; u_t: (n,) uniforms
        new_down = jnp.where(down, u_t >= spec.p_rejoin, u_t < spec.p_crash)
        return new_down, new_down

    down0 = jnp.zeros(shape[:1], jnp.bool_)
    _, down = lax.scan(step, down0, jnp.swapaxes(u, 0, 1))
    return jnp.swapaxes(down, 0, 1).astype(jnp.float32)


def inject_service_times(T, spec: FaultSpec, cell_seed):
    """Transform an ``(n_workers, n_tasks)`` service-time matrix.

    Applied before ``trace_scan``/``generate_trace``; the event *selection*
    stays the untouched lexicographic argmin, only durations change.
    Returns float32 of the same shape.  With ``spec.injects_traces`` False
    this still runs (the multipliers are identically 1) -- callers gate on
    the spec being present, keeping one code path.
    """
    T = jnp.asarray(T, jnp.float32)
    scale = jnp.ones_like(T)
    if spec.p_crash > 0.0:
        down = _down_mask(spec, _key(spec, cell_seed, _STREAM_CRASH), T.shape)
        scale = scale * (1.0 + down * (spec.crash_scale - 1.0))
    if spec.p_spike > 0.0:
        k = _key(spec, cell_seed, _STREAM_SPIKE)
        k_hit, k_mag = jax.random.split(k)
        hit = jax.random.uniform(k_hit, T.shape, jnp.float32) < spec.p_spike
        u = jax.random.uniform(k_mag, T.shape, jnp.float32,
                               minval=1e-6, maxval=1.0)
        pareto = spec.spike_scale * jnp.power(u, -1.0 / spec.spike_tail)
        scale = scale * jnp.where(hit, pareto, 1.0)
    return T * scale


def inject_client_rounds(rounds, spec: FaultSpec, cell_seed):
    """Federated twin: stretch ``ClientRounds.duration`` (n_clients,
    n_attempts) by the same crash-chain / spike processes; the dropout
    uniforms (``drop_u``) stay untouched -- client dropout is already a
    first-class trace knob, faults add *delay* pathology on top."""
    return rounds._replace(
        duration=inject_service_times(rounds.duration, spec, cell_seed))


def update_fault_codes(spec: FaultSpec, n_events: int, cell_seed):
    """(n_events,) int32 per-event fault code: 0 ok, 1 drop, 2 dup,
    3 corrupt.  One uniform per event, thresholded corrupt < drop < dup
    (precedence fixed so probabilities partition [0, 1))."""
    u = jax.random.uniform(_key(spec, cell_seed, _STREAM_CODES),
                           (int(n_events),), jnp.float32)
    pc, pdr, pdu = spec.p_corrupt, spec.p_drop, spec.p_dup
    codes = jnp.where(
        u < pc, CODE_CORRUPT,
        jnp.where(u < pc + pdr, CODE_DROP,
                  jnp.where(u < pc + pdr + pdu, CODE_DUP, 0)))
    return codes.astype(jnp.int32)


def corrupt_value(spec: FaultSpec):
    """The poison payload a corrupt event adds into the update leaves."""
    return jnp.float32(jnp.nan) if spec.corrupt_mode == "nan" \
        else jnp.float32(jnp.inf)
