"""`FaultSpec`: the declarative fault-injection & guard configuration.

One frozen, hashable dataclass describes EVERYTHING the fault layer does:

* trace-level injection (crash/rejoin Markov chains over worker service
  times, heavy-tail straggler spikes) -- consumed by
  ``repro.faults.inject`` BEFORE ``trace_scan`` / ``federated_trace_scan``;
* update-level injection (dropped / duplicated / NaN-or-Inf-corrupted
  updates) -- a per-event int32 fault code riding the solver event arrays;
* in-scan guards (non-finite rejection, staleness-cutoff rejection,
  horizon-overflow graceful degradation) -- applied by the solver scans.

The telemetry contract carries over verbatim from ``TelemetryConfig``:
``faults=None`` (or a disabled spec, via :func:`normalize_faults`) produces
EXACTLY the pre-fault jaxpr -- bitwise, not just numerically -- and a
`FaultSpec` rides every program-cache key (it is hashable by construction,
so two value-equal specs share one executable).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["FaultSpec", "normalize_faults", "CORRUPT_MODES", "FAULT_PRESETS",
           "parse_faults"]

CORRUPT_MODES = ("nan", "inf")

# Update fault codes (per event, int32): the order encodes precedence when
# probabilities are checked against one uniform draw.
CODE_OK = 0
CODE_DROP = 1
CODE_DUP = 2
CODE_CORRUPT = 3


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Deterministic fault process + guard knobs for one experiment.

    Trace-level (service-time) injection:
      p_crash:      per-completed-task probability a worker goes down
                    (two-state Markov chain over the worker's task index).
      p_rejoin:     per-task probability a down worker comes back
                    (geometric downtime of mean ``1/p_rejoin`` tasks).
      crash_scale:  service-time multiplier while down -- the in-flight
                    task stalls, so the worker produces no event for a long
                    virtual-time stretch and its NEXT completion lands with
                    a large measured staleness (the rejoin spike).
      p_spike:      per-task heavy-tail straggler probability.
      spike_scale / spike_tail:  Pareto spike ``scale * u^(-1/tail)``.

    Update-level injection (per server event):
      p_drop:       update silently lost (no server write).
      p_dup:        update applied twice (one prox/mix step at 2*gamma).
      p_corrupt:    payload poisoned with NaN (``corrupt_mode='nan'``) or
                    Inf before the server consumes it.

    Guards (active whenever a FaultSpec is present, even with all
    injection probabilities zero):
      guard_nonfinite:   reject non-finite payloads (skip-and-count)
                         instead of letting NaN/Inf poison the iterate.
      staleness_cutoff:  reject updates with tau > cutoff (None = off).
      degrade_on_clip:   on horizon overflow (delay beyond the window
                         buffer) fall back to the worst-case-bound step
                         ``gamma' / (tau + 1)`` instead of trusting the
                         silently-truncated window sum.

    ``seed`` keys the fault randomness; it is folded with the per-cell
    seed so solo/batched/sharded runs of the same cell are bitwise equal.
    """

    # trace-level
    p_crash: float = 0.0
    p_rejoin: float = 0.25
    crash_scale: float = 25.0
    p_spike: float = 0.0
    spike_scale: float = 8.0
    spike_tail: float = 1.5
    # update-level
    p_drop: float = 0.0
    p_dup: float = 0.0
    p_corrupt: float = 0.0
    corrupt_mode: str = "nan"
    # guards
    guard_nonfinite: bool = True
    staleness_cutoff: Optional[int] = None
    degrade_on_clip: bool = True
    # randomness / master switch
    seed: int = 0
    enabled: bool = True

    def __post_init__(self):
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"corrupt_mode must be one of {CORRUPT_MODES}, "
                f"got {self.corrupt_mode!r}")
        for name in ("p_crash", "p_rejoin", "p_spike", "p_drop", "p_dup",
                     "p_corrupt"):
            v = getattr(self, name)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"{name} must be a probability, got {v}")
        if self.p_crash > 0.0 and self.p_rejoin <= 0.0:
            raise ValueError("p_crash > 0 requires p_rejoin > 0 "
                             "(a crashed worker must eventually rejoin)")
        if self.staleness_cutoff is not None and int(self.staleness_cutoff) < 0:
            raise ValueError("staleness_cutoff must be >= 0 or None")

    # ------------------------------------------------------------------
    @property
    def injects_traces(self) -> bool:
        """True when service times / round durations get transformed."""
        return self.p_crash > 0.0 or self.p_spike > 0.0

    @property
    def injects_updates(self) -> bool:
        """True when per-event drop/dup/corrupt codes can be nonzero."""
        return self.p_drop > 0.0 or self.p_dup > 0.0 or self.p_corrupt > 0.0

    def replace(self, **kw) -> "FaultSpec":
        return dataclasses.replace(self, **kw)


def normalize_faults(faults: Optional[FaultSpec]) -> Optional[FaultSpec]:
    """Collapse "no faults" to None -- THE switch the bitwise-off contract
    hangs on.  ``None`` and ``FaultSpec(enabled=False)`` both normalize to
    None, and every consumer (solver scans, sweep runners, cache keys)
    branches on ``faults is None`` only."""
    if faults is None:
        return None
    if not isinstance(faults, FaultSpec):
        raise TypeError(f"faults must be a FaultSpec or None, "
                        f"got {type(faults).__name__}")
    return faults if faults.enabled else None


# Named regimes for the CLI (--faults crash) and benchmarks.  Values are
# kwargs over the FaultSpec defaults.
FAULT_PRESETS = {
    # crash/rejoin staleness spikes: rare long outages
    "crash": dict(p_crash=0.05, p_rejoin=0.2, crash_scale=40.0),
    # heavy-tail stragglers, no outright crashes
    "straggler": dict(p_spike=0.1, spike_scale=8.0, spike_tail=1.2),
    # corrupt payloads exercising the non-finite guard
    "corrupt": dict(p_corrupt=0.05),
    # a bit of everything
    "chaos": dict(p_crash=0.03, p_rejoin=0.25, crash_scale=30.0,
                  p_spike=0.05, p_drop=0.02, p_dup=0.02, p_corrupt=0.02),
}


def parse_faults(text: Optional[str]) -> Optional[FaultSpec]:
    """CLI mini-grammar: a preset name, optionally followed by
    ``key=value`` overrides, comma-separated.

        --faults crash
        --faults crash,seed=7,staleness_cutoff=64
        --faults p_drop=0.1,p_corrupt=0.05
    """
    if not text:
        return None
    kw: dict = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            if part not in FAULT_PRESETS:
                raise ValueError(
                    f"unknown fault preset {part!r}; options: "
                    f"{sorted(FAULT_PRESETS)} or key=value pairs")
            kw.update(FAULT_PRESETS[part])
            continue
        key, val = part.split("=", 1)
        key = key.strip()
        fields = {f.name: f for f in dataclasses.fields(FaultSpec)}
        if key not in fields:
            raise ValueError(f"unknown FaultSpec field {key!r}")
        if key == "corrupt_mode":
            kw[key] = val.strip()
        elif key in ("seed",):
            kw[key] = int(val)
        elif key in ("staleness_cutoff",):
            kw[key] = None if val.strip().lower() == "none" else int(val)
        elif key in ("guard_nonfinite", "degrade_on_clip", "enabled"):
            kw[key] = val.strip().lower() in ("1", "true", "yes", "on")
        else:
            kw[key] = float(val)
    return FaultSpec(**kw)
