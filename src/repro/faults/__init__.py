"""Fault injection & resilience (`repro.faults`).

Chaos-testing layer for the delay-adaptive solvers: deterministic,
jittable fault processes (crash/rejoin staleness spikes, heavy-tail
stragglers, dropped/duplicated/corrupted updates) injected into trace
generation and the solver scans, plus in-scan guards (non-finite
rejection, staleness cutoff, horizon-overflow graceful degradation) with
counters riding the telemetry carry.

Contract (mirrors ``repro.telemetry``): ``faults=None`` -- or a spec
normalized away by :func:`normalize_faults` -- yields bitwise the
pre-fault jaxpr, and `FaultSpec` rides every program-cache key.
"""
from repro.faults.spec import (FAULT_PRESETS, FaultSpec, normalize_faults,
                               parse_faults)
from repro.faults.inject import (corrupt_value, inject_client_rounds,
                                 inject_service_times, update_fault_codes)
from repro.faults.guards import (FaultState, fault_gamma_prime, guard_event,
                                 guarded_gamma, init_faults, payload_finite,
                                 summarize_faults)

__all__ = [
    "FaultSpec", "normalize_faults", "parse_faults", "FAULT_PRESETS",
    "inject_service_times", "inject_client_rounds", "update_fault_codes",
    "corrupt_value",
    "FaultState", "init_faults", "guard_event", "guarded_gamma",
    "payload_finite", "fault_gamma_prime", "summarize_faults",
]
