"""repro: Delay-Adaptive Step-sizes for Asynchronous Learning (Wu et al.,
ICML 2022) as a production-grade multi-pod JAX framework.

Subpackages:
  core        the paper: step-size principle (8), policies, PIAG, Async-BCD,
              delay tracking, event engine, threaded runtimes, theory checks
  federated   delay-adaptive async federated learning: FedAsync/FedBuff
              servers driven by the same staleness-weight machinery
  sweep       vectorized experiment sweeps: policy x seed x topology grids
              as one vmapped XLA program (policies as data, jitted traces)
  models      dense / MoE / SSM / hybrid / audio / VLM substrate
  optim       optimizers + DelayAdaptiveOptimizer composition
  data        deterministic synthetic pipelines
  checkpoint  npz pytree checkpointing
  kernels     Pallas TPU kernels + jnp oracles
  serving     continuous-batching scheduler
  configs     assigned architectures + input shapes
  launch      mesh / sharding planner / dry-run / roofline / trainers
"""

__version__ = "1.0.0"
