"""repro: Delay-Adaptive Step-sizes for Asynchronous Learning (Wu et al.,
ICML 2022) as a production-grade multi-pod JAX framework.

The documented entry point is the declarative spec API::

    from repro import api, analysis

    res = api.run(api.ExperimentSpec(...))   # solo | batched | sharded
    analysis.summarize(res)                  # per-policy aggregation

Subpackages:
  api         the unified experiment-spec API: ExperimentSpec -> run() ->
              Results, one declarative surface over every runner below
  analysis    sweep-level aggregation: per-policy summaries,
              time-to-tolerance, fixed-vs-adaptive gaps, clip summaries
  core        the paper: step-size principle (8), policies, PIAG, Async-BCD,
              delay tracking, event engine, threaded runtimes, theory checks
  federated   delay-adaptive async federated learning: FedAsync/FedBuff
              servers driven by the same staleness-weight machinery
  sweep       vectorized experiment sweeps: policy x seed x topology grids
              as one vmapped XLA program (policies as data, jitted traces)
  telemetry   observability: in-scan metric accumulators (bitwise-neutral),
              host timing sinks, and the structured JSONL run ledger
  models      dense / MoE / SSM / hybrid / audio / VLM substrate
  optim       optimizers + DelayAdaptiveOptimizer composition
  data        deterministic synthetic pipelines
  checkpoint  npz pytree checkpointing
  kernels     Pallas TPU kernels + jnp oracles
  serving     continuous-batching scheduler
  configs     assigned architectures + input shapes
  launch      mesh / sharding planner / dry-run / roofline / trainers
  staticcheck jaxpr contract verifier, cache-key completeness checker,
              trace-safety lint (CI's static-analysis lane)
"""
import importlib

__version__ = "1.1.0"

# the curated public surface; submodules are imported lazily (PEP 562) so
# `import repro` stays light and `from repro import api` works everywhere
__all__ = ["api", "analysis", "core", "federated", "sweep", "telemetry",
           "models", "optim", "data", "checkpoint", "kernels", "serving",
           "configs", "launch", "staticcheck"]


def __getattr__(name):
    if name in __all__:
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
