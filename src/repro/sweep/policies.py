"""Data-driven step-size policies: `StepsizePolicy` instances as arrays.

``core.stepsize`` policies are frozen dataclasses whose parameters are
Python floats -- compile-time constants.  A sweep wants the OPPOSITE: one
compiled program where the policy (type and parameters) is a runtime value,
so a whole policy x seed x topology grid shares a single XLA executable.

``PolicyParams`` flattens any supported policy into four scalars
(``policy_id`` + three floats) -- a pytree, so it stacks and ``vmap``s.
``ParamPolicy`` is the `StepsizePolicy`-shaped adapter that dispatches on
``policy_id`` with ``lax.switch``; each branch reproduces the concrete
policy's ``_gamma`` arithmetic operation-for-operation (float32 throughout,
fixed-family per-step constants precomputed in float64 exactly like the
dataclass does), so a sweep row is bitwise-equal in (gammas, taus) to a solo
run of the concrete policy.  ``tests/test_sweep.py`` pins that equality.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stepsize import (Adaptive1, Adaptive2, DavisFixed,
                                 FixedStepSize, HingeWeight, NaiveAdaptive,
                                 PolyWeight, StepsizePolicy, SunDengFixed,
                                 _push, init_state, window_sum)

__all__ = ["PolicyParams", "ParamPolicy", "policy_params", "stack_params",
           "POLICY_IDS"]

POLICY_IDS = {
    "fixed_like": 0,   # FixedStepSize / SunDengFixed / DavisFixed
    "naive": 1,
    "adaptive1": 2,
    "adaptive2": 3,
    "hinge": 4,
    "poly": 5,
}


class PolicyParams(NamedTuple):
    """A `StepsizePolicy` as a vmappable pytree of scalars.

    Field meaning depends on ``policy_id``:

    ==========  ===========================  =====================  ======
    policy_id   family                       c0                     c1
    ==========  ===========================  =====================  ======
    0           fixed / sun_deng / davis     precomputed gamma_k    --
    1           naive c/(tau+b)              b                      --
    2           adaptive1 (Eq. 13)           alpha                  --
    3           adaptive2 (Eq. 14)           --                     --
    4           hinge weight [Xie'19]        a                      b
    5           poly weight [Xie'19]         a                      --
    ==========  ===========================  =====================  ======
    """

    policy_id: jnp.ndarray   # int32 scalar
    gamma_prime: jnp.ndarray  # float32 scalar
    c0: jnp.ndarray          # float32 scalar
    c1: jnp.ndarray          # float32 scalar


def policy_params(policy: StepsizePolicy) -> PolicyParams:
    """Flatten a concrete policy instance into ``PolicyParams``.

    Fixed-family per-step constants are computed here in Python float64 and
    rounded once to float32 -- the same rounding the dataclass performs via
    ``jnp.full`` -- preserving bitwise equality with the solo path.
    """
    gp, c0, c1 = float(policy.gamma_prime), 0.0, 0.0
    if isinstance(policy, FixedStepSize):
        pid, c0 = POLICY_IDS["fixed_like"], gp / (policy.tau_bound + 1)
    elif isinstance(policy, SunDengFixed):
        pid, c0 = POLICY_IDS["fixed_like"], gp / (policy.tau_bound + 0.5)
    elif isinstance(policy, DavisFixed):
        pid, c0 = (POLICY_IDS["fixed_like"],
                   gp / (1.0 + policy.ratio * policy.tau_bound))
    elif isinstance(policy, NaiveAdaptive):
        pid, c0 = POLICY_IDS["naive"], policy.b
    elif isinstance(policy, Adaptive1):
        pid, c0 = POLICY_IDS["adaptive1"], policy.alpha
    elif isinstance(policy, Adaptive2):
        pid = POLICY_IDS["adaptive2"]
    elif isinstance(policy, HingeWeight):
        pid, c0, c1 = POLICY_IDS["hinge"], policy.a, policy.b
    elif isinstance(policy, PolyWeight):
        pid, c0 = POLICY_IDS["poly"], policy.a
    else:
        raise TypeError(
            f"{type(policy).__name__} has no PolicyParams flattening "
            "(stateful policies like AdaptiveLipschitz carry extra state and "
            "are out of sweep scope)")
    return PolicyParams(
        policy_id=jnp.asarray(pid, jnp.int32),
        gamma_prime=jnp.asarray(np.float32(gp)),
        c0=jnp.asarray(np.float32(c0)),
        c1=jnp.asarray(np.float32(c1)),
    )


def stack_params(policies) -> PolicyParams:
    """Stack per-cell ``PolicyParams`` into one batched pytree (leading B)."""
    ps = [policy_params(p) if isinstance(p, StepsizePolicy) else p
          for p in policies]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)


class ParamPolicy:
    """`StepsizePolicy`-shaped adapter around traced ``PolicyParams``.

    Duck-types the two methods the solver scans use (``init`` / ``step``);
    constructed INSIDE the vmapped cell function, so its fields are the
    per-cell slices of the batched parameter arrays.
    """

    def __init__(self, params: PolicyParams):
        self.params = params

    def init(self, horizon: int = 4096):
        return init_state(horizon)

    def _gamma(self, state, tau):
        """(gamma, was_clipped) WITHOUT advancing the state -- the same
        split every concrete ``StepsizePolicy`` exposes.  ``repro.faults``
        guards hook here: they may override gamma (graceful degradation,
        rejection) before the single ``_push``."""
        p = self.params
        ws, clip = window_sum(state, tau)
        t = jnp.asarray(tau, jnp.float32)
        branches = {
            # fixed family -- per-step constant precomputed at flatten time
            "fixed_like": lambda: jnp.broadcast_to(p.c0, ws.shape),
            # naive gamma' / (tau + b)  (Eq. 7, the diverging baseline)
            "naive": lambda: p.gamma_prime / (t + p.c0),
            # adaptive1 alpha * max(gamma' - window_sum, 0)  (Eq. 13)
            "adaptive1": lambda: p.c0 * jnp.maximum(p.gamma_prime - ws, 0.0),
            # adaptive2 gamma'/(tau+1) gated by the window budget (Eq. 14)
            "adaptive2": lambda: jnp.where(
                p.gamma_prime / (t + 1.0) <= p.gamma_prime - ws,
                p.gamma_prime / (t + 1.0), 0.0),
            # hinge staleness weight [Xie'19]
            "hinge": lambda: p.gamma_prime * jnp.where(
                t <= p.c1, 1.0,
                1.0 / (p.c0 * jnp.maximum(t - p.c1, 0.0) + 1.0)),
            # poly staleness weight [Xie'19]
            "poly": lambda: p.gamma_prime * jnp.power(t + 1.0, -p.c0),
        }
        assert set(branches) == set(POLICY_IDS)
        ordered = [branches[name] for name, _ in
                   sorted(POLICY_IDS.items(), key=lambda kv: kv[1])]
        gamma = jax.lax.switch(p.policy_id, ordered)
        gamma = jnp.asarray(gamma, jnp.float32)
        return gamma, clip

    def step(self, state, tau):
        gamma, clip = self._gamma(state, tau)
        return gamma, _push(state, gamma, clip)
