"""Sweep grids: the cartesian product of policies x seeds x topologies
(x worker counts).

A ``SweepGrid`` is a flat list of cells, each pinning one policy instance,
one RNG seed, and one worker topology (a list of ``WorkerModel``/
``ClientModel``).  The grid knows how to materialize the batched inputs the
runners consume: a stacked service-time tensor (B, width, K+1) for the
jitted trace generator and stacked ``PolicyParams`` for the parametric
policy.

Ragged worker counts
--------------------

Since PR 3 a grid may mix worker counts (``make_grid(..., n_workers=[4, 8])``
grows an ``n_workers`` axis from topology *factories*).  Stacking still needs
rectangular arrays, so ragged grids are **bucketed**: cells are grouped by
a padded width (next power of two by default), each cell's service-time
matrix is padded to the bucket width with ``+inf`` rows, and an
``active_workers`` mask tells the trace/solver scans which rows are real --
padded workers never win the event race and never contribute gradients
(``core.engine.trace_scan`` / ``core.piag.piag_scan``), so a bucketed cell
is the SAME computation as its exact-width run.  Each bucket compiles once;
a homogeneous grid is a single exact-width bucket, i.e. exactly the PR 2
path.

Worker-data semantics for ragged grids: runners slice the shared
``worker_data`` pytree to the bucket width, and a cell with ``w`` active
workers uses rows ``0..w-1``.  A ragged grid therefore sweeps *worker
participation* out of a fixed maximal population -- the FedBuff-style
worker-count axis -- rather than re-partitioning the dataset per cell.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (WorkerModel, heterogeneous_workers,
                               sample_service_times, trace_scan)
from repro.core.stepsize import StepsizePolicy, next_pow2

from .policies import PolicyParams, stack_params

__all__ = ["SweepCell", "SweepGrid", "SweepBucket", "make_grid",
           "measure_tau_bar", "next_pow2", "standard_topologies",
           "standard_topology_factories"]

# one jitted trace-delay program for every tau-bar measurement in the repo
# (module-level so repeated resolves/builds reuse the trace instead of
# re-tracing an anonymous jit each call; jax re-specializes per shape)
_tau_max_jit = jax.jit(jax.vmap(lambda T: trace_scan(T).tau_max))


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One grid cell: (policy, seed, topology)."""

    policy_name: str
    policy: StepsizePolicy
    seed: int
    topology_name: str
    workers: Tuple = ()

    @property
    def n_workers(self) -> int:
        return len(self.workers)


class SweepBucket(NamedTuple):
    """One rectangular slice of a (possibly ragged) grid.

    width:  the padded worker count every cell in the bucket is stacked to.
    index:  positions of the bucket's cells in the parent grid (used to
            stitch per-bucket results back into parent cell order).
    grid:   the sub-``SweepGrid`` of exactly those cells.
    """

    width: int
    index: np.ndarray
    grid: "SweepGrid"

    @property
    def uniform(self) -> bool:
        """True iff no cell actually needs padding (mask would be all-True);
        runners then use the unmasked builders -- the exact PR 2 program."""
        return all(c.n_workers == self.width for c in self.grid.cells)


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A flat batch of sweep cells plus the shared event count."""

    cells: Tuple[SweepCell, ...]
    n_events: int

    def __len__(self) -> int:
        return len(self.cells)

    def measure_tau_bar(self) -> int:
        """Worst-case trace delay over the grid's own (topology, seed) cells
        -- the measured bound ``horizon='auto'`` sizes buffers from.

        Policies don't influence traces, so cells are deduplicated by
        (topology, seed) and measured per worker-count group with the shared
        jitted trace program (PIAG/BCD service-time grids only; federated
        staleness is measured by ``runners.measure_fed_tau_bar``)."""
        seen = {}
        for c in self.cells:
            seen.setdefault((c.topology_name, c.seed), c)
        by_width: Dict[int, list] = {}
        for c in seen.values():
            by_width.setdefault(c.n_workers, []).append(c)
        worst = 0
        for cs in by_width.values():
            Ts = np.stack([sample_service_times(c.workers, self.n_events + 1,
                                                seed=c.seed) for c in cs])
            taus = _tau_max_jit(jnp.asarray(Ts))
            worst = max(worst, int(np.max(np.asarray(taus))))
        return worst

    @property
    def is_ragged(self) -> bool:
        return len({c.n_workers for c in self.cells}) > 1

    @property
    def n_workers(self) -> int:
        ns = {c.n_workers for c in self.cells}
        if len(ns) > 1:
            raise ValueError(
                f"ragged grid (worker counts {sorted(ns)}); use "
                "n_workers_max or iterate buckets()")
        return next(iter(ns))

    @property
    def n_workers_max(self) -> int:
        return max(c.n_workers for c in self.cells)

    def subset(self, index: Sequence[int]) -> "SweepGrid":
        return SweepGrid(cells=tuple(self.cells[int(i)] for i in index),
                         n_events=self.n_events)

    def buckets(self, bucket_widths: Optional[Sequence[int]] = None
                ) -> Tuple[SweepBucket, ...]:
        """Group cells into rectangular buckets by padded worker count.

        ``bucket_widths`` is the sorted menu of allowed widths (each cell
        lands in the smallest width >= its worker count).  Default: a
        homogeneous grid is ONE exact-width bucket (no padding, no mask --
        bitwise the PR 2 path); a ragged grid pads each cell to the next
        power of two capped at the grid's widest cell (padding past the
        widest real topology would only waste FLOPs and outgrow the shared
        worker data), trading a <2x per-cell FLOP overhead for one compile
        per octave instead of one per distinct worker count.
        """
        if bucket_widths is None:
            if not self.is_ragged:
                widths = [self.n_workers_max]
            else:
                widths = sorted({min(next_pow2(c.n_workers),
                                     self.n_workers_max)
                                 for c in self.cells})
        else:
            widths = sorted(int(w) for w in bucket_widths)
        out = []
        for w in widths:
            idx = np.asarray([i for i, c in enumerate(self.cells)
                              if c.n_workers <= w
                              and not any(c.n_workers <= v for v in widths
                                          if v < w)], np.int64)
            if idx.size:
                out.append(SweepBucket(width=w, index=idx,
                                       grid=self.subset(idx)))
        placed = sum(b.index.size for b in out)
        if placed != len(self.cells):
            big = max(c.n_workers for c in self.cells)
            raise ValueError(
                f"bucket_widths {widths} cannot hold all cells "
                f"(max worker count {big})")
        return tuple(out)

    def policy_params(self) -> PolicyParams:
        """Stacked (B,) ``PolicyParams`` for the parametric policy."""
        return stack_params([c.policy for c in self.cells])

    def service_times(self, width: Optional[int] = None) -> np.ndarray:
        """(B, width, n_events + 1) float32 -- one matrix per cell, sampled
        from the cell's seed (per-worker counter substreams).  ``width``
        defaults to the (homogeneous) worker count; padded rows are ``+inf``
        so an unmasked consumer can never mistake them for real tasks (the
        mask from ``active_masks`` is still required for ``tau_max``)."""
        w = self.n_workers if width is None else int(width)
        out = np.full((len(self.cells), w, self.n_events + 1), np.inf,
                      np.float32)
        for i, c in enumerate(self.cells):
            if c.n_workers > w:
                raise ValueError(
                    f"cell {i} has {c.n_workers} workers > width {w}")
            out[i, :c.n_workers] = sample_service_times(
                c.workers, self.n_events + 1, seed=c.seed)
        return out

    def active_masks(self, width: Optional[int] = None) -> np.ndarray:
        """(B, width) bool -- True where a worker row is real, False where
        it is bucket padding."""
        w = self.n_workers if width is None else int(width)
        return np.asarray([
            np.arange(w) < c.n_workers for c in self.cells])

    def labels(self) -> List[str]:
        return [f"{c.policy_name}/s{c.seed}/{c.topology_name}"
                for c in self.cells]


def standard_topologies(n_workers: int, seed: int = 0) -> Dict[str, list]:
    """The four worker regimes the paper's figures probe: homogeneous,
    mildly/strongly heterogeneous speeds (Fig. 3 shows ~2.4x per-worker
    spread), and straggler-dominated (Fig. 2's long-tail delays)."""
    return {name: factory(n_workers)
            for name, factory in standard_topology_factories(seed).items()}


def standard_topology_factories(seed: int = 0) -> Dict[str, Callable]:
    """The same four regimes as ``standard_topologies`` but as width ->
    worker-list factories, the form ``make_grid``'s ``n_workers`` axis
    consumes (each cell instantiates the regime at its own worker count)."""
    return {
        "uniform": lambda n: [WorkerModel() for _ in range(n)],
        "hetero2": lambda n: heterogeneous_workers(n, spread=2.0, seed=seed),
        "hetero4": lambda n: heterogeneous_workers(n, spread=4.0,
                                                   seed=seed + 1),
        "straggler": lambda n: [WorkerModel(mean=1.0, p_straggle=0.1,
                                            straggle_x=12.0)
                                for _ in range(n)],
    }


def measure_tau_bar(topologies: Dict[str, Sequence], seeds: Sequence[int],
                    n_events: int) -> int:
    """The worst-case delay bound tau-bar over every (topology, seed) trace
    of a prospective grid -- what the paper's fixed baselines are tuned from.

    Runs the jitted trace generator over all topology x seed cells in one
    vmapped program (policies don't influence traces, so none are needed).
    Shared by ``benchmarks/sweep_grid.py`` and ``repro.launch.sweep``.
    Ragged topology menus are measured per width (stacking is rectangular).
    """
    by_width: Dict[int, list] = {}
    for ws in topologies.values():
        by_width.setdefault(len(ws), []).append(ws)
    worst = 0
    for groups in by_width.values():
        Ts = np.stack([
            sample_service_times(ws, n_events + 1, seed=int(s))
            for ws in groups for s in seeds])
        taus = _tau_max_jit(jnp.asarray(Ts))
        worst = max(worst, int(np.max(np.asarray(taus))))
    return worst


def make_grid(policies: Dict[str, StepsizePolicy],
              seeds: Sequence[int],
              topologies: Dict[str, Sequence],
              n_events: int,
              n_workers: Optional[Sequence[int]] = None) -> SweepGrid:
    """Cartesian product in deterministic (policy, seed, topology[, width])
    order.

    Without ``n_workers``, topology values are concrete worker lists (the
    PR 2 form).  With ``n_workers``, the grid grows a worker-count axis:
    topology values must be factories ``width -> worker list`` (see
    ``standard_topology_factories``) and each (topology, width) pair becomes
    its own topology named ``{name}/w{width}``.  Mixed widths make the grid
    ragged; see ``SweepGrid.buckets``.
    """
    if n_workers is None:
        topo_items = [(tn, tuple(ws)) for tn, ws in topologies.items()]
    else:
        topo_items = []
        for tn, factory in topologies.items():
            if not callable(factory):
                raise TypeError(
                    f"topology {tn!r} must be a width -> workers factory "
                    "when n_workers is given (got a concrete sequence)")
            for w in n_workers:
                topo_items.append((f"{tn}/w{int(w)}",
                                   tuple(factory(int(w)))))
    cells = tuple(
        SweepCell(policy_name=pn, policy=pol, seed=int(s),
                  topology_name=tn, workers=ws)
        for (pn, pol), s, (tn, ws) in itertools.product(
            policies.items(), seeds, topo_items))
    return SweepGrid(cells=cells, n_events=n_events)
