"""Sweep grids: the cartesian product of policies x seeds x topologies.

A ``SweepGrid`` is a flat list of cells, each pinning one policy instance,
one RNG seed, and one worker topology (a list of ``WorkerModel``/
``ClientModel``).  The grid knows how to materialize the batched inputs the
runners consume: a stacked service-time tensor (B, n_workers, K+1) for the
jitted trace generator and stacked ``PolicyParams`` for the parametric
policy.  All topologies in one grid must share ``n_workers`` (stacking needs
rectangular arrays); sweep worker counts across separate grids.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (WorkerModel, heterogeneous_workers,
                               sample_service_times, trace_scan)
from repro.core.stepsize import StepsizePolicy

from .policies import PolicyParams, stack_params

__all__ = ["SweepCell", "SweepGrid", "make_grid", "measure_tau_bar",
           "standard_topologies"]


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One grid cell: (policy, seed, topology)."""

    policy_name: str
    policy: StepsizePolicy
    seed: int
    topology_name: str
    workers: Tuple = ()

    @property
    def n_workers(self) -> int:
        return len(self.workers)


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A flat batch of sweep cells plus the shared event count."""

    cells: Tuple[SweepCell, ...]
    n_events: int

    def __post_init__(self):
        ns = {c.n_workers for c in self.cells}
        if len(ns) > 1:
            raise ValueError(f"all cells must share n_workers, got {sorted(ns)}")

    def __len__(self) -> int:
        return len(self.cells)

    @property
    def n_workers(self) -> int:
        return self.cells[0].n_workers

    def policy_params(self) -> PolicyParams:
        """Stacked (B,) ``PolicyParams`` for the parametric policy."""
        return stack_params([c.policy for c in self.cells])

    def service_times(self) -> np.ndarray:
        """(B, n_workers, n_events + 1) float32 -- one matrix per cell,
        sampled from the cell's seed (per-worker counter substreams)."""
        return np.stack([
            sample_service_times(c.workers, self.n_events + 1, seed=c.seed)
            for c in self.cells])

    def labels(self) -> List[str]:
        return [f"{c.policy_name}/s{c.seed}/{c.topology_name}"
                for c in self.cells]


def standard_topologies(n_workers: int, seed: int = 0) -> Dict[str, list]:
    """The four worker regimes the paper's figures probe: homogeneous,
    mildly/strongly heterogeneous speeds (Fig. 3 shows ~2.4x per-worker
    spread), and straggler-dominated (Fig. 2's long-tail delays)."""
    return {
        "uniform": [WorkerModel() for _ in range(n_workers)],
        "hetero2": heterogeneous_workers(n_workers, spread=2.0, seed=seed),
        "hetero4": heterogeneous_workers(n_workers, spread=4.0, seed=seed + 1),
        "straggler": [WorkerModel(mean=1.0, p_straggle=0.1, straggle_x=12.0)
                      for _ in range(n_workers)],
    }


def measure_tau_bar(topologies: Dict[str, Sequence], seeds: Sequence[int],
                    n_events: int) -> int:
    """The worst-case delay bound tau-bar over every (topology, seed) trace
    of a prospective grid -- what the paper's fixed baselines are tuned from.

    Runs the jitted trace generator over all topology x seed cells in one
    vmapped program (policies don't influence traces, so none are needed).
    Shared by ``benchmarks/sweep_grid.py`` and ``repro.launch.sweep``.
    """
    Ts = np.stack([
        sample_service_times(ws, n_events + 1, seed=int(s))
        for ws in topologies.values() for s in seeds])
    taus = jax.jit(jax.vmap(lambda T: trace_scan(T).tau_max))(jnp.asarray(Ts))
    return int(np.max(np.asarray(taus)))


def make_grid(policies: Dict[str, StepsizePolicy],
              seeds: Sequence[int],
              topologies: Dict[str, Sequence],
              n_events: int) -> SweepGrid:
    """Cartesian product in deterministic (policy, seed, topology) order."""
    cells = tuple(
        SweepCell(policy_name=pn, policy=pol, seed=int(s),
                  topology_name=tn, workers=tuple(ws))
        for (pn, pol), s, (tn, ws) in itertools.product(
            policies.items(), seeds, topologies.items()))
    return SweepGrid(cells=cells, n_events=n_events)
