"""Bounded cache of built sweep executables (`repro.sweep.cache`).

Every ``sweep_*`` / ``sharded_sweep_*`` call used to rebuild its per-bucket
cell closure and re-``jit`` it -- so a ragged grid re-traced one program per
bucket on EVERY call, and repeated ``api.run`` invocations of the same spec
paid the full compile again.  ``jax.jit`` caches traces per *function
object*; the missing piece is keeping the function objects alive and keyed.

``cached_program(key, build)`` is that piece: an LRU keyed on the program's
static configuration -- ``(solver tag, bucket width, masked?, horizon,
record_every, ... , captured objects)``.  Captured objects (loss closures,
data pytrees, prox ops) are keyed by IDENTITY via ``IdKey``; meshes ride
keys as ``repro.mesh.mesh_topology`` tuples -- TOPOLOGY, not identity, so a
reshaped or rebuilt mesh with the same axes/shape/device-kind/process-count
reuses the executable while a 1-D vs 2-D reshape keys fresh.  The
cache holds a strong reference through the key, so an id can never be
recycled while its entry lives.  Two calls that pass the *same* objects and
static knobs therefore reuse the same jitted callable -- and jax's own
shape-keyed trace cache underneath it -- while different objects (or a
mutated knob) build fresh.

The cache is deliberately small and clearable: programs pin their captured
constants (worker data!) in memory, so eviction is as important as reuse.

CONTRACT: identity keying means captured arrays are treated as FROZEN --
mutating a numpy ``worker_data`` buffer in place between sweeps would keep
serving the executable compiled against the old contents (the same is true
of any jit-captured constant, but before this cache each call re-traced and
re-read).  Treat sweep inputs as immutable, or build new arrays; after an
in-place mutation, call ``clear_program_cache()``.

``REPRO_CACHE_CHECK=1`` turns that contract into a runtime assertion:
array-valued captures are fingerprinted (shape/dtype + content hash) when
their entry is built and re-verified on every cache hit, so an in-place
mutation raises instead of silently serving the stale executable.

``set_capture_hook`` lets ``repro.staticcheck`` intercept ``cached_program``
dispatches -- the hook sees ``(key, build)`` and substitutes its own
callable, bypassing the cache entirely -- to record cache keys and traced
jaxprs without compiling or executing anything.
"""
from __future__ import annotations

import hashlib
import os
import time
from collections import OrderedDict
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro.telemetry.timing import record_timing

__all__ = ["IdKey", "LRU", "tree_key", "cached_program",
           "clear_program_cache", "mesh_fingerprint", "program_cache_stats",
           "set_capture_hook", "PROGRAM_CACHE_MAXSIZE"]

PROGRAM_CACHE_MAXSIZE = 128


class IdKey:
    """Identity-keyed cache handle: hashes/compares by ``id(obj)`` while
    holding a strong reference, so the id stays valid for the entry's life."""

    __slots__ = ("obj",)

    def __init__(self, obj: Any):
        self.obj = obj

    def __hash__(self) -> int:
        return id(self.obj)

    def __eq__(self, other) -> bool:
        return isinstance(other, IdKey) and self.obj is other.obj

    def __repr__(self) -> str:
        return f"IdKey({type(self.obj).__name__}@{id(self.obj):#x})"


def tree_key(tree: Any) -> Tuple:
    """Identity key of a pytree: one ``IdKey`` per leaf (None for a leafless
    tree).  Array leaves are unhashable by design; identity is the right
    equivalence for captured constants -- same arrays, same program."""
    return tuple(IdKey(leaf) for leaf in jax.tree_util.tree_leaves(tree))


class LRU:
    """Tiny LRU keyed on hashable tuples; also reused by ``repro.api`` to
    memoize resolve-time artifacts (problems, prox ops, runner pieces)."""

    def __init__(self, maxsize: int,
                 on_evict: Optional[Callable[[Any], None]] = None):
        self.maxsize = maxsize
        self.data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.on_evict = on_evict

    def get(self, key, build: Callable[[], Any]):
        try:
            val = self.data[key]
        except KeyError:
            self.misses += 1
            val = build()
            self.data[key] = val
            while len(self.data) > self.maxsize:
                evicted, _ = self.data.popitem(last=False)
                self.evictions += 1
                if self.on_evict is not None:
                    self.on_evict(evicted)
            return val
        self.hits += 1
        self.data.move_to_end(key)
        return val


_PROGRAMS = LRU(PROGRAM_CACHE_MAXSIZE,
                on_evict=lambda key: _FINGERPRINTS.pop(key, None))

# bumped by clear_program_cache(); snapshot consumers (api.run's per-call
# cache deltas) compare generations to detect that the absolute counters
# were reset between their snapshots
_GENERATION = 0

# REPRO_CACHE_CHECK fingerprints, keyed like _PROGRAMS (pruned on eviction)
_FINGERPRINTS: dict = {}

# staticcheck's dispatch interceptor; None in normal operation
_CAPTURE_HOOK: Optional[Callable[[Tuple, Callable[[], Any]], Any]] = None


def set_capture_hook(hook):
    """Install ``hook(key, build)`` to intercept every ``cached_program``
    dispatch (pass ``None`` to uninstall); returns the previous hook.  While
    installed, the cache is bypassed entirely: the hook's return value is
    handed back to the runner in place of the cached executable.  This is
    the seam ``repro.staticcheck.cachekey`` uses to observe cache keys and
    capture traced jaxprs without compiling."""
    global _CAPTURE_HOOK
    prev = _CAPTURE_HOOK
    _CAPTURE_HOOK = hook
    return prev


def _cache_check_enabled() -> bool:
    return (os.environ.get("REPRO_CACHE_CHECK", "").strip().lower()
            in ("1", "true", "yes", "on"))


def _captured_arrays(key: Any, path: str = "key"):
    """Yield ``(path, IdKey)`` for every identity-keyed array inside a
    (possibly nested) key tuple -- numpy buffers and jax Arrays both; other
    captures (closures, prox ops) have no mutable numeric payload worth
    hashing.  Meshes are fingerprinted separately (``_captured_meshes``)."""
    if isinstance(key, tuple):
        for i, el in enumerate(key):
            yield from _captured_arrays(el, f"{path}[{i}]")
    elif isinstance(key, IdKey) and isinstance(key.obj, (np.ndarray, jax.Array)):
        yield path, key


def _captured_meshes(key: Any, path: str = "key"):
    """Yield ``(path, Mesh)`` for every ``jax.sharding.Mesh`` inside a key,
    raw or ``IdKey``-wrapped.  The sharded runners key by
    ``repro.mesh.mesh_topology`` tuples (plain hashables, nothing to
    fingerprint), but external/legacy keys may still carry Mesh objects --
    those fingerprint by TOPOLOGY (axis names, shape, device kind, process
    count), not value identity, matching the runner contract that
    same-topology meshes share executables."""
    if isinstance(key, tuple):
        for i, el in enumerate(key):
            yield from _captured_meshes(el, f"{path}[{i}]")
    elif isinstance(key, jax.sharding.Mesh):
        yield path, key
    elif isinstance(key, IdKey) and isinstance(key.obj, jax.sharding.Mesh):
        yield path, key.obj


def mesh_fingerprint(mesh) -> str:
    """Topology fingerprint of a mesh: stringified
    ``repro.mesh.mesh_topology`` (axis names + shape + device kind +
    process count)."""
    from repro.mesh import mesh_topology
    return str(mesh_topology(mesh))


def _array_fingerprint(obj: Any) -> str:
    try:
        arr = np.asarray(obj)
    except Exception as exc:  # deleted buffer (e.g. donated jax Array)
        return f"<unreadable:{type(exc).__name__}>"
    h = hashlib.sha1()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    flat = np.ascontiguousarray(arr).reshape(-1)
    if flat.size > 65536:  # cheap strided sample for big buffers
        flat = np.ascontiguousarray(flat[:: flat.size // 65536 + 1])
    h.update(flat.tobytes())
    return h.hexdigest()


def _key_fingerprints(key: Tuple) -> Tuple:
    return (tuple((path, _array_fingerprint(ik.obj))
                  for path, ik in _captured_arrays(key)) +
            tuple((path, mesh_fingerprint(m))
                  for path, m in _captured_meshes(key)))


def _verify_fingerprints(key: Tuple) -> None:
    fresh = _key_fingerprints(key)
    prior = _FINGERPRINTS.get(key)
    if prior is None:
        _FINGERPRINTS[key] = fresh
        return
    if prior == fresh:
        return
    changed = [p for (p, a), (_, b) in zip(prior, fresh) if a != b]
    raise RuntimeError(
        "REPRO_CACHE_CHECK: captured array(s) mutated in place after "
        f"capture by cached_program (key tag {key[0]!r}, changed: "
        f"{', '.join(changed)}).  Identity-keyed captures are FROZEN by "
        "contract -- the cache would have kept serving the executable "
        "compiled against the old contents.  Build new arrays instead of "
        "mutating, or call clear_program_cache() after an intentional "
        "mutation.")


class _TimedFirstCall:
    """Callable proxy recording the first dispatch of a freshly built
    program as a ``program_first_call`` timing event -- on CPU, jax compiles
    synchronously inside that call, so its wall time is the per-key compile
    cost the run ledger attributes.  Subsequent calls go straight through."""

    __slots__ = ("fn", "tag", "pending")

    def __init__(self, fn: Callable, tag: str):
        self.fn = fn
        self.tag = tag
        self.pending = True

    def __call__(self, *args, **kwargs):
        if not self.pending:
            return self.fn(*args, **kwargs)
        self.pending = False
        t0 = time.perf_counter()
        out = self.fn(*args, **kwargs)
        record_timing("program_first_call",
                      (time.perf_counter() - t0) * 1e3, key=self.tag)
        return out


def cached_program(key: Tuple, build: Callable[[], Any]):
    """Return the cached executable for ``key``, building (and caching) it on
    first use.  ``key`` must be a tuple of hashables; wrap captured objects
    in ``IdKey`` / ``tree_key``.

    Misses are instrumented: ``build()`` wall time lands in the telemetry
    timing buffer as ``program_build``, and callable programs come back
    wrapped so their first dispatch records ``program_first_call``."""
    if _CAPTURE_HOOK is not None:
        return _CAPTURE_HOOK(key, build)
    if _cache_check_enabled():
        _verify_fingerprints(key)

    def timed_build():
        tag = str(key[0]) if key else "?"
        t0 = time.perf_counter()
        val = build()
        record_timing("program_build", (time.perf_counter() - t0) * 1e3,
                      key=tag)
        return _TimedFirstCall(val, tag) if callable(val) else val

    return _PROGRAMS.get(key, timed_build)


def clear_program_cache() -> None:
    """Drop every cached executable (tests; memory pressure).  Bumps the
    stats generation so per-call deltas can reset-scope correctly."""
    global _GENERATION
    _PROGRAMS.data.clear()
    _PROGRAMS.hits = _PROGRAMS.misses = _PROGRAMS.evictions = 0
    _FINGERPRINTS.clear()
    _GENERATION += 1


def program_cache_stats() -> dict:
    return {"size": len(_PROGRAMS.data), "hits": _PROGRAMS.hits,
            "misses": _PROGRAMS.misses, "evictions": _PROGRAMS.evictions,
            "maxsize": _PROGRAMS.maxsize, "generation": _GENERATION}
