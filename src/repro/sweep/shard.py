"""Device-sharded mega-grid sweeps (`repro.sweep.shard`).

The batched runners in ``.runners`` collapse a whole grid into one XLA
program -- but that program lives on ONE device.  This module partitions the
**cell axis** of a mega-grid across devices with
``jax.experimental.shard_map`` over a ``("cells",)`` or 2-D
``("cells", "data")`` ``Mesh`` (see :mod:`repro.mesh`):

* the per-cell program is the SAME vmapped cell function the single-device
  runners use (``_piag_cell`` / ``_bcd_cell`` / ``_fed_cell``), so a sharded
  row is the same computation as a batched row is the same computation as a
  solo run -- the equivalence chain tested end-to-end;
* cells are embarrassingly parallel (no cross-cell communication) on the
  cells axis: ``shard_map`` pins cell-shard ``d`` of the stacked inputs to
  the ``d``-th mesh row and runs the batched program there;
* on a 2-D mesh the per-worker gradient batch inside each cell additionally
  runs data-parallel across the ``"data"`` axis: the in/out specs stay
  ``P("cells")`` (args and outputs replicated over data), and the injected
  ``repro.mesh.pmean_grad`` slices the sample axis per data shard and psums
  the partial gradients -- taus and every integer leaf stay bitwise-equal
  to the 1-D path, objectives equal under jit (see the psum-axis contract
  in ``repro.mesh``);
* the stacked service-time / client-round tensors -- the only O(B * n * K)
  inputs -- are **donated** (``donate_argnums=0``), so XLA reuses their
  buffers and peak memory stays flat instead of doubling at dispatch;
* B rarely divides the cell-shard count: ``round_robin_pad`` pads the batch
  to the next cells-axis multiple by cycling cell indices (so padding
  replays real cells -- every device gets live work and identical per-cell
  shapes), and the wrappers strip the padded rows before returning;
* executables cache by **mesh topology** (``repro.mesh.mesh_topology``:
  axis names + shape + device kind + process count), never mesh identity,
  so 1-D / reshaped 2-D / multi-host meshes never collide on a program.

``sharded_sweep_*`` convenience wrappers mirror ``sweep_*`` exactly
(including ragged-grid bucketing) and return identical row values; keep the
``make_sharded_*`` builders when amortizing compiles across repeated calls
(see ``benchmarks/mega_grid.py``, which scales a >= 512-cell
policy x seed x topology x n_workers grid across forced host devices).
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core.bcd import BCDResult, sample_blocks
from repro.core.piag import PIAGResult
from repro.core.prox import ProxOp
from repro.federated.events import default_fed_steps
from repro.federated.server import FedResult
from repro.mesh import (DATA_AXIS, cell_axis_size, cell_mesh, data_axis_size,
                        grid_mesh, mesh_topology, pmean_grad)

from repro.telemetry.timing import timed

from repro.faults.spec import normalize_faults

from .cache import IdKey, cached_program, tree_key
from .grid import SweepBucket, SweepGrid
from .runners import (Horizon, _bcd_cell, _cell_seeds, _fed_cell,
                      _fedasync_scan_adapter, _fedbuff_scan_adapter,
                      _piag_cell, _slice_workers, _stack_fed_rounds,
                      _check_fed_diag, resolve_grid_horizon, run_bucketed)

__all__ = ["cell_mesh", "grid_mesh", "mesh_topology", "round_robin_pad",
           "shard_cells",
           "make_sharded_sweep_piag", "sharded_sweep_piag",
           "sharded_sweep_piag_logreg",
           "make_sharded_sweep_bcd", "sharded_sweep_bcd",
           "sharded_sweep_fedasync", "sharded_sweep_fedbuff"]


def round_robin_pad(n_cells: int, n_cell_shards: int) -> np.ndarray:
    """Index map of length ``max(ceil(B / C), 2) * C`` (the 2 only when
    ``C > 1``) cycling through the B cells, where C is the size of the
    mesh's **cells axis** -- NOT the total device count.  On a 2-D
    ``(cells, data)`` mesh the data axis replicates the batch, so only the
    cells axis constrains padding; a (2, 4) mesh pads exactly like a (2,)
    mesh.

    Gathering the stacked inputs through this map pads the batch to a
    cells-axis multiple with REPLAYED cells (not zeros), so every shard
    keeps identical shapes and live work; callers drop rows ``>= n_cells``
    on the way out.

    Multi-shard cell axes are padded to >= 2 cells per shard: a per-shard
    batch of exactly 1 makes XLA's sharding propagation reject the
    ``while``-loop trace scan on jax 0.4 ("tile_assignment should have N
    devices" on a degenerate ``devices=[0,1]`` sharding), so small grids
    replay one extra round instead of crashing.
    """
    if n_cells < 1:
        raise ValueError("empty grid")
    per_shard = max(-(-n_cells // n_cell_shards), 2 if n_cell_shards > 1 else 1)
    return np.arange(per_shard * n_cell_shards) % n_cells


def shard_cells(vmapped_fn: Callable, mesh: Mesh, n_args: int,
                donate: bool = True) -> Callable:
    """Wrap a vmapped cell function in ``shard_map`` over ``mesh`` and jit.

    Every argument and output is partitioned on its leading (cell) axis
    over the mesh's "cells" axis; argument 0 -- the big stacked
    service-time / client-rounds tensor -- is donated so its buffer is
    reused in place.  The batch size fed to the returned function must be a
    multiple of the cells-axis size (``round_robin_pad``).

    On a 2-D ``(cells, data)`` mesh the specs are unchanged: arguments and
    outputs are replicated over the data axis, and the data axis only
    carries gradient COMPUTE via an injected ``pmean_grad`` whose psum makes
    every data shard's output identical -- so ``P("cells")`` out_specs stay
    valid and row values match the 1-D mesh bitwise on integer leaves."""
    specs = tuple(PartitionSpec("cells") for _ in range(n_args))
    # check_rep=False: jax 0.4's replication checker has no rule for `while`
    # (the federated client update is a fori_loop with a traced bound); the
    # body is collective-free on the cells axis and every output is sharded
    # over it, so the check is vacuous here anyway.  (On 2-D meshes outputs
    # ARE replicated over "data" -- by the psum argument above -- which the
    # 0.4 checker could not verify through `while` either.)
    fn = shard_map(vmapped_fn, mesh=mesh, in_specs=specs,
                   out_specs=PartitionSpec("cells"), check_rep=False)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def _pad_gather(tree, idx: np.ndarray):
    return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[idx], tree)


def _unpad(tree, n: int):
    return jax.tree_util.tree_map(lambda x: x[:n], tree)


def _settle_replicas(out, mesh: Mesh):
    """Reshard 2-D-mesh results onto the 1-D cells submesh (one data
    column), dropping the data-axis replica copies.

    jax 0.4 sharp edge: a ``check_rep=False`` shard_map output on a
    ``(cells, data)`` mesh carries ``P("cells")`` sharding, but the SPMD
    partitioner treats the D identical data-axis copies as PARTIAL SUMS in
    some downstream multi-operand ops -- ``jnp.concatenate`` of two bucket
    results returns rows multiplied by exactly D.  ``device_put`` onto a
    mesh without the data axis materializes one replica and severs the
    hazard for every consumer (including ``run_bucketed``'s stitch and
    user code)."""
    if data_axis_size(mesh) <= 1:
        return out
    sub = Mesh(mesh.devices[:, 0], ("cells",))
    return jax.device_put(out, jax.sharding.NamedSharding(
        sub, PartitionSpec("cells")))


def _run_sharded_bucket(cell_build, mesh: Mesh, args, n_cells: int,
                        n_args: int, cache_key: Optional[tuple] = None):
    """Pad the stacked args to a cells-axis multiple, run the sharded
    program, strip the padding.  ``cell_build()`` makes the per-cell
    function; the wrapped executable is cached under ``cache_key`` (when
    given) so repeated sweeps skip rebuild+retrace, exactly like the
    batched path."""
    idx = round_robin_pad(n_cells, cell_axis_size(mesh))

    def build():
        return shard_cells(jax.vmap(cell_build()), mesh, n_args=n_args)

    fn = build() if cache_key is None else cached_program(cache_key, build)
    # telemetry: dispatch wall time across the mesh (per-device skew shows
    # up as dispatch >> cells/devices * per-cell cost on the warm path)
    with timed("sharded_dispatch", devices=int(mesh.devices.size),
               data_shards=data_axis_size(mesh),
               cells=int(n_cells)):
        out = fn(*(_pad_gather(a, idx) for a in args))
    return _unpad(_settle_replicas(out, mesh), n_cells)


# ---------------------------------------------------------------- PIAG ----

def _dp_grad_for(worker_loss: Callable, mesh: Mesh) -> Optional[Callable]:
    """``pmean_grad`` over the mesh's data axis, or None on a 1-D mesh."""
    D = data_axis_size(mesh)
    return pmean_grad(worker_loss, DATA_AXIS, D) if D > 1 else None


def make_sharded_sweep_piag(worker_loss: Callable, x0, worker_data,
                            prox: ProxOp, objective: Optional[Callable] = None,
                            horizon: int = 4096, use_tau_max: bool = True,
                            masked: bool = False,
                            mesh: Optional[Mesh] = None,
                            record_every: int = 1, telemetry=None,
                            engine: str = "scan", faults=None) -> Callable:
    """Sharded twin of ``make_sweep_piag``: same signature and row values,
    but the batch axis is partitioned across ``mesh``'s cells axis (batch
    size must be a cells-axis multiple; see ``round_robin_pad``).  On a 2-D
    ``(cells, data)`` mesh worker gradients are additionally computed
    data-parallel via ``pmean_grad``.  Arg 0 is donated.  With ``faults``
    the signature grows a trailing ``seeds (B,)`` argument."""
    mesh = cell_mesh() if mesh is None else mesh
    faults = normalize_faults(faults)
    cell = _piag_cell(worker_loss, x0, worker_data, prox, objective, horizon,
                      use_tau_max, masked, record_every, telemetry, engine,
                      faults, grad_fn=_dp_grad_for(worker_loss, mesh))
    n_args = (3 if masked else 2) + (1 if faults is not None else 0)
    return shard_cells(jax.vmap(cell), mesh, n_args=n_args)


def sharded_sweep_piag(worker_loss: Callable, x0, worker_data,
                       grid: SweepGrid, prox: ProxOp,
                       objective: Optional[Callable] = None,
                       horizon: Horizon = 4096, use_tau_max: bool = True,
                       mesh: Optional[Mesh] = None,
                       bucket_widths: Optional[Sequence[int]] = None,
                       record_every: int = 1, telemetry=None,
                       engine: str = "scan", faults=None,
                       checkpoint=None) -> PIAGResult:
    """``sweep_piag`` with the cell axis sharded across the mesh's cells
    axis; a 2-D ``(cells, data)`` mesh adds data-parallel worker gradients
    (``pmean_grad`` psums over "data"; rows stay bitwise on integer
    leaves)."""
    mesh = cell_mesh() if mesh is None else mesh
    horizon = resolve_grid_horizon(horizon, grid)
    faults = normalize_faults(faults)
    grad_fn = _dp_grad_for(worker_loss, mesh)

    def run_bucket(b: SweepBucket):
        key = ("piag/sharded", b.width, not b.uniform, horizon, use_tau_max,
               record_every, telemetry, engine, faults, mesh_topology(mesh),
               IdKey(worker_loss), tree_key(x0), tree_key(worker_data),
               IdKey(prox), IdKey(objective))
        T = jnp.asarray(b.grid.service_times(b.width))
        pp = b.grid.policy_params()
        args = ((T, pp) if b.uniform else
                (T, jnp.asarray(b.grid.active_masks(b.width)), pp))
        if faults is not None:
            args = args + (_cell_seeds(b),)
        return _run_sharded_bucket(
            lambda: _piag_cell(worker_loss, x0,
                               _slice_workers(worker_data, b.width), prox,
                               objective, horizon, use_tau_max,
                               not b.uniform, record_every, telemetry,
                               engine, faults, grad_fn=grad_fn),
            mesh, args, len(b.grid), n_args=len(args), cache_key=key)

    return run_bucketed(grid, run_bucket, bucket_widths,
                        checkpoint=checkpoint)


def sharded_sweep_piag_logreg(problem, grid: SweepGrid, prox: ProxOp,
                              horizon: int = 4096,
                              mesh: Optional[Mesh] = None) -> PIAGResult:
    """DEPRECATED shim over ``repro.api`` (sharded twin of
    ``sweep_piag_logreg``); bitwise-equal rows -- the spec routes back to
    ``sharded_sweep_piag`` with the same arguments."""
    from .runners import _warn_legacy
    _warn_legacy("sharded_sweep_piag_logreg")
    from repro.api import run_components
    return run_components("piag", "sharded", problem=problem, grid=grid,
                          prox=prox, horizon=horizon, mesh=mesh).raw


# ----------------------------------------------------------- Async-BCD ----

def _pick_bcd_grad(grad_f: Callable, dp_grad_f: Optional[Callable],
                   mesh: Mesh) -> Callable:
    """On a 2-D mesh, swap in the data-parallel full gradient when given.

    BCD's ``grad_f`` is an opaque x->grad closure, so the runner cannot
    rebuild it data-parallel itself (unlike PIAG's ``worker_loss``); the
    api layer derives ``dp_grad_f`` from ``problem.worker_loss`` via
    ``pmean_grad``.  A 2-D mesh without one still computes correct rows --
    just replicated over the data axis -- so we warn instead of raising."""
    if data_axis_size(mesh) <= 1:
        return grad_f
    if dp_grad_f is None:
        warnings.warn(
            "sharded BCD on a (cells, data) mesh without dp_grad_f: the "
            "gradient runs replicated on every data shard (correct but no "
            "speedup); pass dp_grad_f (e.g. built with repro.mesh."
            "pmean_grad) or use the repro.api spec path",
            RuntimeWarning, stacklevel=3)
        return grad_f
    return dp_grad_f


def make_sharded_sweep_bcd(grad_f: Callable, objective: Callable, x0, m: int,
                           n_workers: int, prox: ProxOp, horizon: int = 4096,
                           masked: bool = False,
                           mesh: Optional[Mesh] = None,
                           record_every: int = 1, telemetry=None,
                           engine: str = "scan", faults=None,
                           dp_grad_f: Optional[Callable] = None) -> Callable:
    """Sharded twin of ``make_sweep_bcd`` (batch must be a cells-axis
    multiple).  ``dp_grad_f`` replaces ``grad_f`` on 2-D meshes (see
    ``_pick_bcd_grad``)."""
    mesh = cell_mesh() if mesh is None else mesh
    faults = normalize_faults(faults)
    gf = _pick_bcd_grad(grad_f, dp_grad_f, mesh)
    cell = _bcd_cell(gf, objective, x0, m, n_workers, prox, horizon,
                     masked, record_every, telemetry, engine, faults)
    n_args = (4 if masked else 3) + (1 if faults is not None else 0)
    return shard_cells(jax.vmap(cell), mesh, n_args=n_args)


def sharded_sweep_bcd(grad_f: Callable, objective: Callable, x0, m: int,
                      grid: SweepGrid, prox: ProxOp, horizon: Horizon = 4096,
                      mesh: Optional[Mesh] = None,
                      bucket_widths: Optional[Sequence[int]] = None,
                      record_every: int = 1, telemetry=None,
                      engine: str = "scan", faults=None,
                      checkpoint=None,
                      dp_grad_f: Optional[Callable] = None) -> BCDResult:
    """``sweep_bcd`` with the cell axis sharded; on a 2-D mesh pass
    ``dp_grad_f`` (a psum-over-"data" full gradient) to actually partition
    the gradient compute (see ``_pick_bcd_grad``)."""
    mesh = cell_mesh() if mesh is None else mesh
    horizon = resolve_grid_horizon(horizon, grid)
    faults = normalize_faults(faults)
    gf = _pick_bcd_grad(grad_f, dp_grad_f, mesh)

    def run_bucket(b: SweepBucket):
        key = ("bcd/sharded", b.width, not b.uniform, horizon, m,
               record_every, telemetry, engine, faults, mesh_topology(mesh),
               IdKey(gf),
               IdKey(objective), tree_key(x0), IdKey(prox))
        T = jnp.asarray(b.grid.service_times(b.width))
        blocks = jnp.asarray(np.stack([
            sample_blocks(m, grid.n_events, seed=c.seed)
            for c in b.grid.cells]))
        pp = b.grid.policy_params()
        args = ((T, blocks, pp) if b.uniform else
                (T, jnp.asarray(b.grid.active_masks(b.width)), blocks, pp))
        if faults is not None:
            args = args + (_cell_seeds(b),)
        return _run_sharded_bucket(
            lambda: _bcd_cell(gf, objective, x0, m, b.width, prox,
                              horizon, not b.uniform, record_every,
                              telemetry, engine, faults),
            mesh, args, len(b.grid), n_args=len(args), cache_key=key)

    return run_bucketed(grid, run_bucket, bucket_widths,
                        checkpoint=checkpoint)


# ------------------------------------------------- FedAsync / FedBuff ----

def _sharded_sweep_fed(adapter_for, grid: SweepGrid, client_data,
                       buffer_size: int, n_steps: Optional[int],
                       mesh: Optional[Mesh],
                       bucket_widths: Optional[Sequence[int]] = None,
                       cache_key: Optional[tuple] = None, faults=None,
                       checkpoint=None) -> FedResult:
    mesh = cell_mesh() if mesh is None else mesh
    K = grid.n_events
    S = default_fed_steps(K) if n_steps is None else int(n_steps)

    def run_bucket(b: SweepBucket):
        key = None if cache_key is None else \
            cache_key + (b.width, S, mesh_topology(mesh))
        rounds, cparams, active = _stack_fed_rounds(b.grid, b.width, S)
        args = (rounds, cparams, active, b.grid.policy_params())
        if faults is not None:
            args = args + (_cell_seeds(b),)
        res, n_up, exhausted = _run_sharded_bucket(
            lambda: _fed_cell(adapter_for(_slice_workers(client_data,
                                                         b.width)),
                              K, buffer_size, S, faults),
            mesh, args, len(b.grid), n_args=len(args), cache_key=key)
        _check_fed_diag(n_up, exhausted, K, S)
        return res

    return run_bucketed(grid, run_bucket, bucket_widths,
                        checkpoint=checkpoint)


def sharded_sweep_fedasync(client_update: Callable, x0, client_data,
                           grid: SweepGrid,
                           objective: Optional[Callable] = None,
                           buffer_size: int = 1, horizon: Horizon = 4096,
                           n_steps: Optional[int] = None,
                           mesh: Optional[Mesh] = None,
                           bucket_widths: Optional[Sequence[int]] = None,
                           record_every: int = 1, telemetry=None,
                           engine: str = "scan", faults=None,
                           checkpoint=None) -> FedResult:
    """``sweep_fedasync`` (fused path) with the cell axis sharded.

    On a 2-D mesh pass a data-parallel ``client_update`` (one built with
    ``local_prox_sgd(..., grad_fn=pmean_grad(...))``, as the api path
    does); a plain update runs replicated over "data" -- correct rows, no
    speedup."""
    horizon = resolve_grid_horizon(horizon, grid, fed=True,
                                   buffer_size=buffer_size, n_steps=n_steps)
    faults = normalize_faults(faults)

    def adapter_for(cd):
        return _fedasync_scan_adapter(client_update, x0, cd, objective,
                                      horizon, record_every, telemetry,
                                      engine, faults)

    key = ("fedasync/sharded", grid.n_events, buffer_size, horizon,
           record_every, telemetry, engine, faults, IdKey(client_update),
           tree_key(x0), tree_key(client_data), IdKey(objective))
    return _sharded_sweep_fed(adapter_for, grid, client_data, buffer_size,
                              n_steps, mesh, bucket_widths=bucket_widths,
                              cache_key=key, faults=faults,
                              checkpoint=checkpoint)


def sharded_sweep_fedbuff(client_update: Callable, x0, client_data,
                          grid: SweepGrid, eta: float = 1.0,
                          buffer_size: int = 1,
                          objective: Optional[Callable] = None,
                          horizon: Horizon = 4096,
                          n_steps: Optional[int] = None,
                          mesh: Optional[Mesh] = None,
                          bucket_widths: Optional[Sequence[int]] = None,
                          record_every: int = 1, telemetry=None,
                          engine: str = "scan", faults=None,
                          checkpoint=None) -> FedResult:
    """``sweep_fedbuff`` (fused path) with the cell axis sharded.

    On a 2-D mesh pass a data-parallel ``client_update`` (one built with
    ``local_prox_sgd(..., grad_fn=pmean_grad(...))``, as the api path
    does); a plain update runs replicated over "data" -- correct rows, no
    speedup."""
    horizon = resolve_grid_horizon(horizon, grid, fed=True,
                                   buffer_size=buffer_size, n_steps=n_steps)
    faults = normalize_faults(faults)

    def adapter_for(cd):
        return _fedbuff_scan_adapter(client_update, x0, cd, objective,
                                     horizon, eta, buffer_size, record_every,
                                     telemetry, engine, faults)

    key = ("fedbuff/sharded", grid.n_events, eta, buffer_size, horizon,
           record_every, telemetry, engine, faults, IdKey(client_update),
           tree_key(x0), tree_key(client_data), IdKey(objective))
    return _sharded_sweep_fed(adapter_for, grid, client_data, buffer_size,
                              n_steps, mesh, bucket_widths=bucket_widths,
                              cache_key=key, faults=faults,
                              checkpoint=checkpoint)
