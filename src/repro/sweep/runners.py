"""Batched sweep runners: whole policy x seed x topology (x worker-count)
grids as ONE program per bucket.

Each ``make_sweep_*`` builder returns a single jitted function mapping the
grid's stacked inputs -- a (B, width, K+1) service-time tensor and (B,)
``PolicyParams`` -- to a batched result.  Inside, ``jax.vmap`` composes the
jitted trace generator (``core.engine.trace_scan`` for PIAG/BCD,
``federated.events.federated_trace_scan`` for FedAsync/FedBuff) with the
corresponding solver scan (``core.piag.piag_scan`` / ``core.bcd.bcd_scan`` /
``federated.server.fedasync_scan`` / ``fedbuff_scan``), so trace generation
AND optimization for every cell run in one XLA executable with one compile.

Row semantics: cell ``i`` of a sweep is the SAME computation as a solo run
of that cell's config (same trace bitwise, same step code via the shared
scan cores, same policy arithmetic via ``ParamPolicy``); only XLA's batching
of the gradient linear algebra can differ, at the last-ulp level.
``sweep_*`` convenience wrappers build + call in one shot; keep the builder
when you need to amortize the compile across repeated calls (benchmarks).

Ragged grids (mixed worker counts) dispatch per ``SweepGrid.buckets()``:
each bucket pads cells to a common width, runs the ``masked=True`` builder
(trace + PIAG aggregation take the ``active_workers`` mask so padded rows
never win the event race or contribute gradients), and rows are stitched
back into grid order.  A homogeneous grid is one exact-width bucket running
the unmasked builder -- the PR 2 program, unchanged.  ``repro.sweep.shard``
wraps the same vmapped cell functions in ``shard_map`` to spread the cell
axis across devices.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bcd import BCDResult, bcd_scan, sample_blocks
from repro.core.engine import trace_scan
from repro.core.piag import PIAGResult, piag_scan
from repro.core.prox import ProxOp
from repro.core.stepsize import auto_horizon
from repro.federated.events import (ClientRounds, client_arrays,
                                    default_fed_steps, federated_trace_scan,
                                    sample_client_rounds, simulate_federated)
from repro.federated.server import (FedResult, fedasync_scan, fedbuff_scan)
from repro.faults.spec import normalize_faults
from repro.faults.inject import (inject_client_rounds, inject_service_times,
                                 update_fault_codes)

from repro.telemetry.timing import timed

from .cache import IdKey, LRU, cached_program, tree_key
from .grid import SweepBucket, SweepGrid
from .policies import ParamPolicy

__all__ = ["make_sweep_piag", "sweep_piag", "sweep_piag_logreg",
           "make_sweep_bcd", "sweep_bcd", "sweep_bcd_logreg",
           "make_sweep_fedasync", "sweep_fedasync", "sweep_fedasync_problem",
           "make_sweep_fedbuff", "sweep_fedbuff", "sweep_fedbuff_problem",
           "run_bucketed", "resolve_grid_horizon", "measure_fed_tau_bar"]

Horizon = Union[int, str]  # a concrete H or "auto" (measured-delay sizing)


# ------------------------------------------------------------- plumbing ----

# grids are frozen dataclasses and their traces are pure functions of the
# pre-sampled randomness, so the measured bound is memoized per grid --
# repeated 'auto' sweeps skip the O(B*K) re-measurement, like the programs
_TAU_BAR_MEMO = LRU(64)


def _donate_default() -> bool:
    """Donation of the stacked input tensors is a real memory win on
    accelerators but a no-op plus a per-compile warning on the CPU backend
    -- gate it (evaluated at build time, after any forced-device flags)."""
    return jax.default_backend() != "cpu"


def resolve_grid_horizon(horizon: Horizon, grid: SweepGrid, *,
                         fed: bool = False, buffer_size: int = 1,
                         n_steps: Optional[int] = None,
                         slack: int = 1,
                         bound: Optional[int] = None) -> int:
    """THE one home of the ``horizon='auto'|int`` -> concrete-H rule
    (shared by every runner here, ``.shard``, and ``api.run``'s resolver,
    which passes its declared/already-measured ``bound`` and spec slack).

    ``'auto'`` measures the grid's own worst-case delay (service-time trace
    delays for PIAG/BCD, upload staleness for the federated servers;
    memoized per grid) and sizes the circular window buffer to
    ``next_pow2(bound + slack)`` -- bitwise-identical results to any larger
    horizon, at a fraction of the scan carry (``core.stepsize.auto_horizon``).
    """
    if horizon != "auto":
        return int(horizon)
    if bound is None:
        key = (IdKey(grid), fed, buffer_size if fed else 0,
               n_steps if fed else None)
        bound = _TAU_BAR_MEMO.get(
            key,
            lambda: (measure_fed_tau_bar(grid, buffer_size=buffer_size,
                                         n_steps=n_steps)
                     if fed else grid.measure_tau_bar()))
    return auto_horizon(bound, slack)


def _warn_legacy(name: str) -> None:
    """The problem-level conveniences are shims over ``repro.api`` now; the
    spec API is the documented entry point.  Rows stay bitwise-equal (the
    shim routes to the exact same runner), only the surface is deprecated."""
    warnings.warn(
        f"repro.sweep.{name} is deprecated; build an "
        "api.ExperimentSpec (or api.component_spec) and call repro.api.run "
        "instead", DeprecationWarning, stacklevel=3)


def run_bucketed(grid: SweepGrid, run_bucket: Callable,
                 bucket_widths: Optional[Sequence[int]] = None,
                 checkpoint=None):
    """Run ``run_bucket(bucket) -> result (leading B_bucket)`` over every
    bucket of ``grid`` and stitch rows back into grid cell order.  Shared by
    the single-device runners here and the sharded runners in ``.shard``.

    ``checkpoint`` (a ``repro.checkpoint.SweepCheckpoint``) makes the loop
    resumable at bucket granularity: a bucket already on disk is loaded
    instead of run, and each freshly-computed bucket is persisted (with a
    device sync first -- a checkpoint must never record an enqueued-but-
    unfinished computation) before the next one starts, so a killed
    mega-grid sweep resumes at its first unfinished bucket."""
    buckets = grid.buckets(bucket_widths)
    parts = []
    for i, b in enumerate(buckets):
        if checkpoint is not None:
            cached = checkpoint.load_bucket(b.width, i)
            if cached is not None:
                parts.append(cached)
                continue
        # telemetry: per-bucket dispatch wall time (build + trace + enqueue;
        # execution may still be async -- api.run's block covers that)
        with timed("bucket_dispatch", width=b.width, cells=len(b.index)):
            part = run_bucket(b)
        if checkpoint is not None:
            part = jax.block_until_ready(part)
            checkpoint.save_bucket(b.width, i, part)
        parts.append(part)
    if len(parts) == 1:
        return parts[0]
    order = np.concatenate([b.index for b in buckets])
    inv = np.argsort(order)
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0)[inv], *parts)


def _slice_workers(worker_data, width: int):
    """Rows 0..width-1 of every leaf: the bucket's view of the shared
    worker population (ragged cells use a prefix of it -- participation
    semantics, see ``sweep.grid``)."""
    leaves = jax.tree_util.tree_leaves(worker_data)
    if leaves and leaves[0].shape[0] < width:
        raise ValueError(
            f"worker_data has {leaves[0].shape[0]} rows < bucket width "
            f"{width}; provide data for the widest cell")
    return jax.tree_util.tree_map(lambda leaf: leaf[:width], worker_data)


# ---------------------------------------------------------------- PIAG ----

def _cell_seeds(b: SweepBucket) -> jnp.ndarray:
    """(B,) per-cell seeds -- the traced argument keying the fault streams
    (fold_in inside the jit, so solo/batched/sharded rows stay bitwise)."""
    return jnp.asarray([c.seed for c in b.grid.cells], jnp.int32)


def _piag_cell(worker_loss, x0, worker_data, prox, objective, horizon,
               use_tau_max, masked, record_every=1, telemetry=None,
               engine="scan", faults=None, grad_fn=None):
    """The per-cell program (trace generation fused with the solver scan);
    ``jax.vmap`` of this is the batched program, ``shard_map(vmap(...))``
    the sharded one.  With ``faults`` the cell signature grows a trailing
    per-cell ``seed`` (i32 scalar): service times are fault-injected before
    the trace scan and the per-event codes drawn from the same seed, all
    inside the one executable.  ``grad_fn`` is the 2-D mesh seam: the
    sharded runner injects ``pmean_grad`` so worker gradients psum over the
    mesh's data axis (None everywhere else -- off-is-absent)."""
    if faults is not None:
        def faulted(T, active, pp, seed):
            T = inject_service_times(T, faults, seed)
            tr = trace_scan(T, active=active) if active is not None \
                else trace_scan(T)
            events = (tr.worker, tr.tau_max if use_tau_max else tr.tau)
            codes = update_fault_codes(faults, events[0].shape[0], seed)
            return piag_scan(worker_loss, x0, worker_data, events,
                             ParamPolicy(pp), prox, objective=objective,
                             horizon=horizon, active=active,
                             record_every=record_every, telemetry=telemetry,
                             engine=engine, faults=faults, fault_codes=codes,
                             grad_fn=grad_fn)
        if masked:
            return lambda T, active, pp, seed: faulted(T, active, pp, seed)
        return lambda T, pp, seed: faulted(T, None, pp, seed)
    if masked:
        def cell(T, active, pp):
            tr = trace_scan(T, active=active)
            events = (tr.worker, tr.tau_max if use_tau_max else tr.tau)
            return piag_scan(worker_loss, x0, worker_data, events,
                             ParamPolicy(pp), prox, objective=objective,
                             horizon=horizon, active=active,
                             record_every=record_every, telemetry=telemetry,
                             engine=engine, grad_fn=grad_fn)
    else:
        def cell(T, pp):
            tr = trace_scan(T)
            events = (tr.worker, tr.tau_max if use_tau_max else tr.tau)
            return piag_scan(worker_loss, x0, worker_data, events,
                             ParamPolicy(pp), prox, objective=objective,
                             horizon=horizon, record_every=record_every,
                             telemetry=telemetry, engine=engine,
                             grad_fn=grad_fn)
    return cell


def make_sweep_piag(worker_loss: Callable, x0, worker_data, prox: ProxOp,
                    objective: Optional[Callable] = None, horizon: int = 4096,
                    use_tau_max: bool = True, masked: bool = False,
                    record_every: int = 1, donate: bool = False,
                    telemetry=None, engine: str = "scan",
                    faults=None) -> Callable:
    """Build the batched PIAG program.

    Returns jitted ``fn(service_times (B, n, K+1), params (B,)) ->
    PIAGResult`` with a leading B on every leaf; with ``masked=True`` the
    signature grows an ``active (B, n) bool`` argument between the two (the
    ragged-bucket form).  ``donate=True`` donates the stacked service-time
    tensor (arg 0) so its buffer is reused in place -- pass a fresh array
    per call (the ``sweep_*`` runners do).  ``engine='fused'`` selects the
    Pallas fused per-event kernel inside the scan core (bitwise-equal).
    """
    return jax.jit(jax.vmap(_piag_cell(
        worker_loss, x0, worker_data, prox, objective, horizon, use_tau_max,
        masked, record_every, telemetry, engine, normalize_faults(faults))),
        donate_argnums=(0,) if donate else ())


def sweep_piag(worker_loss: Callable, x0, worker_data, grid: SweepGrid,
               prox: ProxOp, objective: Optional[Callable] = None,
               horizon: Horizon = 4096, use_tau_max: bool = True,
               bucket_widths: Optional[Sequence[int]] = None,
               record_every: int = 1, telemetry=None,
               engine: str = "scan", faults=None,
               checkpoint=None) -> PIAGResult:
    """Run PIAG on every cell of ``grid`` in one batched program per
    bucket (a homogeneous grid is exactly one program).  ``bucket_widths``
    overrides the ragged grid's padded-width menu (``SweepGrid.buckets``).

    Per-bucket executables are cached (``sweep.cache``) keyed on the static
    configuration and the identity of the captured objects, so repeated
    calls -- and every bucket after the first sweep of a ragged grid --
    skip rebuild+retrace entirely.  ``horizon='auto'`` sizes the window
    buffer from the grid's measured tau-bar (``resolve_grid_horizon``).
    ``faults`` (a ``FaultSpec``) rides the cache key and switches the cell
    program to the fault-injected form (extra per-cell seed argument);
    ``checkpoint`` makes the bucket loop resumable (``run_bucketed``)."""
    horizon = resolve_grid_horizon(horizon, grid)
    faults = normalize_faults(faults)

    def run_bucket(b: SweepBucket):
        key = ("piag", b.width, not b.uniform, horizon, use_tau_max,
               record_every, telemetry, engine, faults, IdKey(worker_loss),
               tree_key(x0), tree_key(worker_data), IdKey(prox),
               IdKey(objective))
        fn = cached_program(key, lambda: make_sweep_piag(
            worker_loss, x0, _slice_workers(worker_data, b.width), prox,
            objective=objective, horizon=horizon, use_tau_max=use_tau_max,
            masked=not b.uniform, record_every=record_every,
            donate=_donate_default(), telemetry=telemetry, engine=engine,
            faults=faults))
        T = jnp.asarray(b.grid.service_times(b.width))
        pp = b.grid.policy_params()
        tail = (_cell_seeds(b),) if faults is not None else ()
        if b.uniform:
            return fn(T, pp, *tail)
        return fn(T, jnp.asarray(b.grid.active_masks(b.width)), pp, *tail)

    return run_bucketed(grid, run_bucket, bucket_widths,
                        checkpoint=checkpoint)


def sweep_piag_logreg(problem, grid: SweepGrid, prox: ProxOp,
                      horizon: int = 4096) -> PIAGResult:
    """DEPRECATED shim over ``repro.api`` (grid analogue of
    ``core.piag.run_piag_logreg``); rows are bitwise-equal to the
    spec-routed run, which dispatches back to ``sweep_piag`` with the same
    arguments.

    For ragged grids the problem must be built with ``n_workers`` >= the
    grid's widest cell; a cell with ``w`` workers runs on the first ``w``
    shards of that fixed partition (worker-participation semantics)."""
    _warn_legacy("sweep_piag_logreg")
    from repro.api import run_components
    return run_components("piag", "batched", problem=problem, grid=grid,
                          prox=prox, horizon=horizon).raw


# ----------------------------------------------------------- Async-BCD ----

def _bcd_cell(grad_f, objective, x0, m, n_workers, prox, horizon, masked,
              record_every=1, telemetry=None, engine="scan", faults=None):
    if faults is not None:
        def faulted(T, active, blocks, pp, seed):
            T = inject_service_times(T, faults, seed)
            tr = trace_scan(T, active=active) if active is not None \
                else trace_scan(T)
            events = (tr.worker, tr.tau, blocks)
            codes = update_fault_codes(faults, events[0].shape[0], seed)
            return bcd_scan(grad_f, objective, x0, m, n_workers, events,
                            ParamPolicy(pp), prox, horizon=horizon,
                            record_every=record_every, telemetry=telemetry,
                            engine=engine, faults=faults, fault_codes=codes)
        if masked:
            return lambda T, active, blocks, pp, seed: \
                faulted(T, active, blocks, pp, seed)
        return lambda T, blocks, pp, seed: faulted(T, None, blocks, pp, seed)
    if masked:
        def cell(T, active, blocks, pp):
            tr = trace_scan(T, active=active)
            events = (tr.worker, tr.tau, blocks)
            return bcd_scan(grad_f, objective, x0, m, n_workers, events,
                            ParamPolicy(pp), prox, horizon=horizon,
                            record_every=record_every, telemetry=telemetry,
                            engine=engine)
    else:
        def cell(T, blocks, pp):
            tr = trace_scan(T)
            events = (tr.worker, tr.tau, blocks)
            return bcd_scan(grad_f, objective, x0, m, n_workers, events,
                            ParamPolicy(pp), prox, horizon=horizon,
                            record_every=record_every, telemetry=telemetry,
                            engine=engine)
    return cell


def make_sweep_bcd(grad_f: Callable, objective: Callable, x0, m: int,
                   n_workers: int, prox: ProxOp, horizon: int = 4096,
                   masked: bool = False, record_every: int = 1,
                   donate: bool = False, telemetry=None,
                   engine: str = "scan", faults=None) -> Callable:
    """Build the batched Async-BCD program: jitted ``fn(service_times
    (B, n, K+1)[, active (B, n)], blocks (B, K), params (B,)) ->
    BCDResult``.  BCD has no cross-worker reduction, so the mask only
    guards the trace (see ``core.bcd.bcd_scan``).  With ``faults`` the
    signature grows a trailing per-cell ``seeds (B,)`` argument."""
    return jax.jit(jax.vmap(_bcd_cell(
        grad_f, objective, x0, m, n_workers, prox, horizon, masked,
        record_every, telemetry, engine, normalize_faults(faults))),
        donate_argnums=(0,) if donate else ())


def sweep_bcd(grad_f: Callable, objective: Callable, x0, m: int,
              grid: SweepGrid, prox: ProxOp, horizon: Horizon = 4096,
              bucket_widths: Optional[Sequence[int]] = None,
              record_every: int = 1, telemetry=None,
              engine: str = "scan", faults=None,
              checkpoint=None) -> BCDResult:
    """Run Async-BCD on every cell; block choices replay the solo sampling
    (``core.bcd.sample_blocks`` with the cell's seed) so rows match solo
    runs.  Per-bucket executables are cached; ``horizon='auto'`` sizes the
    window buffer from the grid's measured tau-bar.  ``faults`` /
    ``checkpoint`` as in ``sweep_piag``."""
    horizon = resolve_grid_horizon(horizon, grid)
    faults = normalize_faults(faults)

    def run_bucket(b: SweepBucket):
        key = ("bcd", b.width, not b.uniform, horizon, m, record_every,
               telemetry, engine, faults, IdKey(grad_f), IdKey(objective),
               tree_key(x0), IdKey(prox))
        fn = cached_program(key, lambda: make_sweep_bcd(
            grad_f, objective, x0, m, b.width, prox, horizon=horizon,
            masked=not b.uniform, record_every=record_every,
            donate=_donate_default(), telemetry=telemetry, engine=engine,
            faults=faults))
        T = jnp.asarray(b.grid.service_times(b.width))
        blocks = jnp.asarray(np.stack([
            sample_blocks(m, grid.n_events, seed=c.seed)
            for c in b.grid.cells]))
        pp = b.grid.policy_params()
        tail = (_cell_seeds(b),) if faults is not None else ()
        if b.uniform:
            return fn(T, blocks, pp, *tail)
        return fn(T, jnp.asarray(b.grid.active_masks(b.width)), blocks, pp,
                  *tail)

    return run_bucketed(grid, run_bucket, bucket_widths,
                        checkpoint=checkpoint)


def sweep_bcd_logreg(problem, grid: SweepGrid, prox: ProxOp, m: int = 20,
                     horizon: int = 4096) -> BCDResult:
    """DEPRECATED shim over ``repro.api``; bitwise-equal rows (the spec
    routes back to ``sweep_bcd`` with the same arguments)."""
    _warn_legacy("sweep_bcd_logreg")
    from repro.api import run_components
    return run_components("bcd", "batched", problem=problem, grid=grid,
                          prox=prox, m=m, horizon=horizon).raw


# ------------------------------------------------- FedAsync / FedBuff ----

def _stack_fed_rounds(grid: SweepGrid, width: int, n_steps: int):
    """Stack per-cell pre-sampled client rounds + lifecycle constants +
    active masks to the bucket width -- the inputs of the fused federated
    runners.  Padded client rows carry benign constants (they never run:
    the ``active`` mask keeps them out of the event race entirely)."""
    B = len(grid.cells)
    drop_u = np.zeros((B, width, n_steps), np.float32)
    dur = np.ones((B, width, n_steps), np.float32)
    p_drop = np.zeros((B, width), np.float32)
    rejoin = np.ones((B, width), np.float32)
    epochs = np.ones((B, width), np.int32)
    for i, c in enumerate(grid.cells):
        n = c.n_workers
        r = sample_client_rounds(list(c.workers), n_steps, seed=c.seed)
        drop_u[i, :n], dur[i, :n] = r.drop_u, r.duration
        p_drop[i, :n], rejoin[i, :n], epochs[i, :n] = client_arrays(
            list(c.workers))
    rounds = ClientRounds(jnp.asarray(drop_u), jnp.asarray(dur))
    cparams = (jnp.asarray(p_drop), jnp.asarray(rejoin), jnp.asarray(epochs))
    return rounds, cparams, jnp.asarray(grid.active_masks(width))


def _fed_cell(server_scan, n_uploads, buffer_size, n_steps, faults=None):
    """One federated cell: the jitted trace scan fused with a server scan
    (``server_scan(events, pp[, fault_codes]) -> FedResult``), like PIAG/BCD
    fuse ``trace_scan`` with their solver scans.  Returns the result plus
    the trace diagnostics the host must check (uploads emitted, attempt
    exhaustion).  With ``faults`` the cell signature grows a trailing
    per-cell ``seed``: client round durations are fault-injected before the
    trace scan and the per-upload codes drawn from the same seed."""

    def run(rounds, cparams, active, pp, seed=None):
        if faults is not None:
            rounds = inject_client_rounds(rounds, faults, seed)
        p_drop, rejoin, epochs = cparams
        ftr = federated_trace_scan(rounds, p_drop, rejoin, epochs, n_uploads,
                                   buffer_size=buffer_size, n_steps=n_steps,
                                   active=active)
        events = (ftr.client, ftr.tau, ftr.local_steps,
                  jnp.asarray(ftr.aggregate, jnp.float32), ftr.version)
        if faults is not None:
            codes = update_fault_codes(faults, n_uploads, seed)
            return server_scan(events, pp, codes), ftr.n_uploads, ftr.exhausted
        return server_scan(events, pp), ftr.n_uploads, ftr.exhausted

    if faults is not None:
        return lambda rounds, cparams, active, pp, seed: \
            run(rounds, cparams, active, pp, seed)
    return lambda rounds, cparams, active, pp: run(rounds, cparams, active, pp)


def _check_fed_diag(n_up, exhausted, n_uploads: int, n_steps: int) -> None:
    n_up, exhausted = np.asarray(n_up), np.asarray(exhausted)
    if bool(np.any(n_up < n_uploads)) or bool(np.any(exhausted)):
        short = int(np.sum(n_up < n_uploads))
        raise RuntimeError(
            f"{short} cell(s) produced fewer than {n_uploads} uploads within "
            f"{n_steps} pops (or exhausted pre-sampled attempts): "
            "dropout/rejoin chains exceeded the scan budget -- pass a larger "
            "n_steps")


def make_sweep_fedasync(client_update: Callable, x0, client_data,
                        objective: Optional[Callable] = None,
                        horizon: int = 4096,
                        record_every: int = 1, telemetry=None,
                        engine: str = "scan") -> Callable:
    """Build the events-driven batched FedAsync program: jitted
    ``fn(events (5 x (B, K)), params (B,)) -> FedResult``.  This is the
    reference-path entry (events stacked on host, e.g. by
    ``_stack_fed_events``); the default sweep path fuses trace generation
    via ``make_sweep_fedasync_fused``."""

    def cell(events, pp):
        return fedasync_scan(client_update, x0, client_data, events,
                             ParamPolicy(pp), objective=objective,
                             horizon=horizon, record_every=record_every,
                             telemetry=telemetry, engine=engine)

    return jax.jit(jax.vmap(cell))


def _fedasync_scan_adapter(client_update, x0, client_data, objective, horizon,
                           record_every=1, telemetry=None, engine="scan",
                           faults=None):
    def server_scan(events, pp, fault_codes=None):
        return fedasync_scan(client_update, x0, client_data, events,
                             ParamPolicy(pp), objective=objective,
                             horizon=horizon, record_every=record_every,
                             telemetry=telemetry, engine=engine,
                             faults=faults, fault_codes=fault_codes)
    return server_scan


def _fedbuff_scan_adapter(client_update, x0, client_data, objective, horizon,
                          eta, buffer_size, record_every=1, telemetry=None,
                          engine="scan", faults=None):
    def server_scan(events, pp, fault_codes=None):
        return fedbuff_scan(client_update, x0, client_data, events,
                            ParamPolicy(pp), eta=eta,
                            buffer_size=buffer_size, objective=objective,
                            horizon=horizon, record_every=record_every,
                            telemetry=telemetry, engine=engine,
                            faults=faults, fault_codes=fault_codes)
    return server_scan


def make_sweep_fedasync_fused(client_update: Callable, x0, client_data,
                              n_uploads: int, buffer_size: int = 1,
                              objective: Optional[Callable] = None,
                              horizon: int = 4096,
                              n_steps: Optional[int] = None,
                              record_every: int = 1,
                              donate: bool = False, telemetry=None,
                              engine: str = "scan", faults=None) -> Callable:
    """Build the fused batched FedAsync program: jitted ``fn(rounds,
    cparams, active, params) -> (FedResult, n_uploads (B,), exhausted (B,))``
    with trace generation (``federated_trace_scan``) and the server scan in
    ONE executable, like the PIAG/BCD runners.  ``donate=True`` donates the
    stacked client-rounds tensors (arg 0) -- pass fresh arrays per call.
    With ``faults`` the signature grows a trailing ``seeds (B,)``."""
    n_steps = default_fed_steps(n_uploads) if n_steps is None else int(n_steps)
    faults = normalize_faults(faults)
    return jax.jit(jax.vmap(_fed_cell(
        _fedasync_scan_adapter(client_update, x0, client_data, objective,
                               horizon, record_every, telemetry, engine,
                               faults),
        n_uploads, buffer_size, n_steps, faults)),
        donate_argnums=(0,) if donate else ())


def make_sweep_fedbuff(client_update: Callable, x0, client_data,
                       n_uploads: int, eta: float = 1.0, buffer_size: int = 1,
                       objective: Optional[Callable] = None,
                       horizon: int = 4096,
                       n_steps: Optional[int] = None,
                       record_every: int = 1,
                       donate: bool = False, telemetry=None,
                       engine: str = "scan", faults=None) -> Callable:
    """Build the fused batched FedBuff program (same shape as
    ``make_sweep_fedasync_fused`` with the buffered-delta server scan)."""
    n_steps = default_fed_steps(n_uploads) if n_steps is None else int(n_steps)
    faults = normalize_faults(faults)
    return jax.jit(jax.vmap(_fed_cell(
        _fedbuff_scan_adapter(client_update, x0, client_data, objective,
                              horizon, eta, buffer_size, record_every,
                              telemetry, engine, faults),
        n_uploads, buffer_size, n_steps, faults)),
        donate_argnums=(0,) if donate else ())


@partial(jax.jit, static_argnames=("n_uploads", "buffer_size", "n_steps"))
def _fed_taus_jit(rounds, cparams, active, n_uploads, buffer_size, n_steps):
    def one(r, cp, a):
        p_drop, rejoin, epochs = cp
        return federated_trace_scan(r, p_drop, rejoin, epochs, n_uploads,
                                    buffer_size=buffer_size, n_steps=n_steps,
                                    active=a).tau
    return jax.vmap(one)(rounds, cparams, active)


def measure_fed_tau_bar(grid: SweepGrid, buffer_size: int = 1,
                        n_steps: Optional[int] = None) -> int:
    """Worst-case upload staleness over a federated grid's pre-sampled
    traces -- the federated analogue of ``SweepGrid.measure_tau_bar``, and
    what ``horizon='auto'`` sizes the weight-policy buffer from.  Runs only
    the jitted trace scan (no client updates), one vmapped program per
    bucket."""
    K = grid.n_events
    S = default_fed_steps(K) if n_steps is None else int(n_steps)
    worst = 0
    for b in grid.buckets():
        rounds, cparams, active = _stack_fed_rounds(b.grid, b.width, S)
        taus = _fed_taus_jit(rounds, cparams, active, K, buffer_size, S)
        worst = max(worst, int(np.max(np.asarray(taus), initial=0)))
    return worst


def _stack_fed_events(grid: SweepGrid, buffer_size: int,
                      n_steps: Optional[int] = None):
    """REFERENCE TWIN of the fused path: simulate one federated trace per
    cell with the heapq reference driven by the SAME pre-sampled client
    rounds the jitted ``federated_trace_scan`` consumes, and stack the event
    columns the server scan expects.  Kept for validation (bitwise-equal
    events to the fused path) and as the ``reference=True`` escape hatch of
    ``sweep_fedasync`` / ``sweep_fedbuff``; it costs Python time per event
    and cannot shard."""
    S = default_fed_steps(grid.n_events) if n_steps is None else int(n_steps)
    traces = [simulate_federated(
        c.n_workers, grid.n_events, clients=list(c.workers),
        buffer_size=buffer_size, seed=c.seed,
        client_rounds=sample_client_rounds(list(c.workers), S, seed=c.seed))
        for c in grid.cells]
    return tuple(
        jnp.stack([jnp.asarray(getattr(t, f), dt) for t in traces])
        for f, dt in [("client", jnp.int32), ("tau", jnp.int32),
                      ("local_steps", jnp.int32), ("aggregate", jnp.float32),
                      ("version", jnp.int32)])


def _sweep_fed(server_adapter, make_fused, grid: SweepGrid, client_data,
               buffer_size: int, reference: bool, n_steps: Optional[int],
               bucket_widths: Optional[Sequence[int]] = None,
               cache_key: Optional[Tuple] = None, faults=None,
               checkpoint=None) -> FedResult:
    """Shared driver for ``sweep_fedasync`` / ``sweep_fedbuff``.

    ``cache_key`` is the wrapper's static-configuration tuple; per-bucket
    fused executables are cached under ``cache_key + (width,)`` so repeated
    sweeps (and later buckets of ragged grids) skip rebuild+retrace."""
    K = grid.n_events
    S = default_fed_steps(K) if n_steps is None else int(n_steps)
    if reference:
        if faults is not None:
            raise TypeError(
                "reference=True does not support fault injection (the heapq "
                "reference path has no per-cell seed stream); use the fused "
                "path")
        fn = jax.jit(jax.vmap(server_adapter))
        return fn(_stack_fed_events(grid, buffer_size, n_steps=S),
                  grid.policy_params())

    def run_bucket(b: SweepBucket):
        def build():
            return make_fused(_slice_workers(client_data, b.width), S)
        fn = build() if cache_key is None else cached_program(
            cache_key + (b.width, S), build)
        rounds, cparams, active = _stack_fed_rounds(b.grid, b.width, S)
        tail = (_cell_seeds(b),) if faults is not None else ()
        res, n_up, exhausted = fn(rounds, cparams, active,
                                  b.grid.policy_params(), *tail)
        _check_fed_diag(n_up, exhausted, K, S)
        return res

    return run_bucketed(grid, run_bucket, bucket_widths,
                        checkpoint=checkpoint)


def sweep_fedasync(client_update: Callable, x0, client_data, grid: SweepGrid,
                   objective: Optional[Callable] = None,
                   buffer_size: int = 1, horizon: Horizon = 4096,
                   reference: bool = False,
                   n_steps: Optional[int] = None,
                   bucket_widths: Optional[Sequence[int]] = None,
                   record_every: int = 1, telemetry=None,
                   engine: str = "scan", faults=None,
                   checkpoint=None) -> FedResult:
    """Run FedAsync on every cell of a grid whose topologies are
    ``ClientModel`` lists.

    Default path: client round-trip traces AND server mixing run fused in
    one jitted program per bucket (``federated_trace_scan`` +
    ``fedasync_scan``), so the whole sweep is XLA end-to-end like PIAG/BCD.
    ``reference=True`` routes trace generation through the Python heapq
    reference instead (same pre-sampled rounds, bitwise-equal events) --
    the escape hatch for validating the fused path or debugging host-side.
    ``horizon='auto'`` sizes the weight-policy buffer from the grid's
    measured upload staleness (``measure_fed_tau_bar``).
    """
    horizon = resolve_grid_horizon(horizon, grid, fed=True,
                                   buffer_size=buffer_size, n_steps=n_steps)
    faults = normalize_faults(faults)
    adapter = _fedasync_scan_adapter(client_update, x0, client_data,
                                     objective, horizon, record_every,
                                     telemetry, engine)

    def make_fused(cd, S):
        return make_sweep_fedasync_fused(client_update, x0, cd, grid.n_events,
                                         buffer_size=buffer_size,
                                         objective=objective, horizon=horizon,
                                         n_steps=S, record_every=record_every,
                                         donate=_donate_default(),
                                         telemetry=telemetry, engine=engine,
                                         faults=faults)

    key = ("fedasync", grid.n_events, buffer_size, horizon, record_every,
           telemetry, engine, faults, IdKey(client_update), tree_key(x0),
           tree_key(client_data), IdKey(objective))
    return _sweep_fed(adapter, make_fused, grid, client_data, buffer_size,
                      reference, n_steps, bucket_widths=bucket_widths,
                      cache_key=key, faults=faults, checkpoint=checkpoint)


def sweep_fedbuff(client_update: Callable, x0, client_data, grid: SweepGrid,
                  eta: float = 1.0, buffer_size: int = 1,
                  objective: Optional[Callable] = None,
                  horizon: Horizon = 4096,
                  reference: bool = False,
                  n_steps: Optional[int] = None,
                  bucket_widths: Optional[Sequence[int]] = None,
                  record_every: int = 1, telemetry=None,
                  engine: str = "scan", faults=None,
                  checkpoint=None) -> FedResult:
    """Run FedBuff on every cell: fused jitted trace generation + buffered
    delta aggregation (``federated_trace_scan`` + ``fedbuff_scan``), one
    program per bucket; ``reference=True`` / ``horizon='auto'`` as in
    ``sweep_fedasync``."""
    horizon = resolve_grid_horizon(horizon, grid, fed=True,
                                   buffer_size=buffer_size, n_steps=n_steps)
    faults = normalize_faults(faults)
    adapter = _fedbuff_scan_adapter(client_update, x0, client_data, objective,
                                    horizon, eta, buffer_size, record_every,
                                    telemetry, engine)

    def make_fused(cd, S):
        return make_sweep_fedbuff(client_update, x0, cd, grid.n_events,
                                  eta=eta, buffer_size=buffer_size,
                                  objective=objective, horizon=horizon,
                                  n_steps=S, record_every=record_every,
                                  donate=_donate_default(),
                                  telemetry=telemetry, engine=engine,
                                  faults=faults)

    key = ("fedbuff", grid.n_events, eta, buffer_size, horizon, record_every,
           telemetry, engine, faults, IdKey(client_update), tree_key(x0),
           tree_key(client_data), IdKey(objective))
    return _sweep_fed(adapter, make_fused, grid, client_data, buffer_size,
                      reference, n_steps, bucket_widths=bucket_widths,
                      cache_key=key, faults=faults, checkpoint=checkpoint)


def sweep_fedasync_problem(problem, grid: SweepGrid, prox: ProxOp,
                           local_lr: Optional[float] = None,
                           horizon: int = 4096, reference: bool = False,
                           n_steps: Optional[int] = None) -> FedResult:
    """DEPRECATED shim over ``repro.api`` (grid analogue of
    ``federated.server.run_fedasync_problem``); bitwise-equal rows."""
    _warn_legacy("sweep_fedasync_problem")
    from repro.api import run_components
    return run_components("fedasync", "batched", problem=problem, grid=grid,
                          prox=prox, local_lr=local_lr, horizon=horizon,
                          reference=reference, n_steps=n_steps).raw


def sweep_fedbuff_problem(problem, grid: SweepGrid, prox: ProxOp,
                          eta: float = 1.0, buffer_size: int = 1,
                          local_lr: Optional[float] = None,
                          horizon: int = 4096, reference: bool = False,
                          n_steps: Optional[int] = None) -> FedResult:
    """DEPRECATED shim over ``repro.api`` (grid analogue of
    ``federated.server.run_fedbuff_problem``); bitwise-equal rows."""
    _warn_legacy("sweep_fedbuff_problem")
    from repro.api import run_components
    return run_components("fedbuff", "batched", problem=problem, grid=grid,
                          prox=prox, eta=eta, buffer_size=buffer_size,
                          local_lr=local_lr, horizon=horizon,
                          reference=reference, n_steps=n_steps).raw
