"""Batched sweep runners: whole policy x seed x topology grids as ONE program.

Each ``make_sweep_*`` builder returns a single jitted function mapping the
grid's stacked inputs -- a (B, n_workers, K+1) service-time tensor and (B,)
``PolicyParams`` -- to a batched result.  Inside, ``jax.vmap`` composes the
jitted trace generator (``core.engine.trace_scan``) with the corresponding
solver scan (``core.piag.piag_scan`` / ``core.bcd.bcd_scan`` /
``federated.server.fedasync_scan``), so trace generation AND optimization
for every cell run in one XLA executable with one compile.

Row semantics: cell ``i`` of a sweep is the SAME computation as a solo run
of that cell's config (same trace bitwise, same step code via the shared
scan cores, same policy arithmetic via ``ParamPolicy``); only XLA's batching
of the gradient linear algebra can differ, at the last-ulp level.
``sweep_*`` convenience wrappers build + call in one shot; keep the builder
when you need to amortize the compile across repeated calls (benchmarks).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bcd import BCDResult, bcd_scan, sample_blocks
from repro.core.engine import trace_scan
from repro.core.piag import PIAGResult, piag_scan
from repro.core.prox import ProxOp
from repro.federated.events import simulate_federated
from repro.federated.server import FedResult, fedasync_scan

from .grid import SweepGrid
from .policies import ParamPolicy

__all__ = ["make_sweep_piag", "sweep_piag", "sweep_piag_logreg",
           "make_sweep_bcd", "sweep_bcd", "sweep_bcd_logreg",
           "make_sweep_fedasync", "sweep_fedasync", "sweep_fedasync_problem"]


# ---------------------------------------------------------------- PIAG ----

def make_sweep_piag(worker_loss: Callable, x0, worker_data, prox: ProxOp,
                    objective: Optional[Callable] = None, horizon: int = 4096,
                    use_tau_max: bool = True) -> Callable:
    """Build the batched PIAG program.

    Returns jitted ``fn(service_times (B, n, K+1), params (B,)) ->
    PIAGResult`` with a leading B on every leaf.
    """

    def cell(T, pp):
        tr = trace_scan(T)
        events = (tr.worker, tr.tau_max if use_tau_max else tr.tau)
        return piag_scan(worker_loss, x0, worker_data, events,
                         ParamPolicy(pp), prox, objective=objective,
                         horizon=horizon)

    return jax.jit(jax.vmap(cell))


def sweep_piag(worker_loss: Callable, x0, worker_data, grid: SweepGrid,
               prox: ProxOp, objective: Optional[Callable] = None,
               horizon: int = 4096, use_tau_max: bool = True) -> PIAGResult:
    """Run PIAG on every cell of ``grid`` in one batched program."""
    fn = make_sweep_piag(worker_loss, x0, worker_data, prox,
                         objective=objective, horizon=horizon,
                         use_tau_max=use_tau_max)
    return fn(jnp.asarray(grid.service_times()), grid.policy_params())


def sweep_piag_logreg(problem, grid: SweepGrid, prox: ProxOp,
                      horizon: int = 4096) -> PIAGResult:
    """Grid analogue of ``core.piag.run_piag_logreg`` (the Fig. 2 cell)."""
    Aw, bw = problem.worker_slices()
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    return sweep_piag(lambda x, A, b: problem.worker_loss(x, A, b), x0,
                      (Aw, bw), grid, prox, objective=problem.P,
                      horizon=horizon)


# ----------------------------------------------------------- Async-BCD ----

def make_sweep_bcd(grad_f: Callable, objective: Callable, x0, m: int,
                   n_workers: int, prox: ProxOp,
                   horizon: int = 4096) -> Callable:
    """Build the batched Async-BCD program: jitted ``fn(service_times
    (B, n, K+1), blocks (B, K), params (B,)) -> BCDResult``."""

    def cell(T, blocks, pp):
        tr = trace_scan(T)
        events = (tr.worker, tr.tau, blocks)
        return bcd_scan(grad_f, objective, x0, m, n_workers, events,
                        ParamPolicy(pp), prox, horizon=horizon)

    return jax.jit(jax.vmap(cell))


def sweep_bcd(grad_f: Callable, objective: Callable, x0, m: int,
              grid: SweepGrid, prox: ProxOp, horizon: int = 4096) -> BCDResult:
    """Run Async-BCD on every cell; block choices replay the solo sampling
    (``core.bcd.sample_blocks`` with the cell's seed) so rows match solo
    runs."""
    fn = make_sweep_bcd(grad_f, objective, x0, m, grid.n_workers, prox,
                        horizon=horizon)
    blocks = np.stack([sample_blocks(m, grid.n_events, seed=c.seed)
                       for c in grid.cells])
    return fn(jnp.asarray(grid.service_times()), jnp.asarray(blocks),
              grid.policy_params())


def sweep_bcd_logreg(problem, grid: SweepGrid, prox: ProxOp, m: int = 20,
                     horizon: int = 4096) -> BCDResult:
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    return sweep_bcd(problem.grad_f, problem.P, x0, m, grid, prox,
                     horizon=horizon)


# ------------------------------------------------------------- FedAsync ----

def make_sweep_fedasync(client_update: Callable, x0, client_data,
                        objective: Optional[Callable] = None,
                        horizon: int = 4096) -> Callable:
    """Build the batched FedAsync program: jitted ``fn(events (5 x (B, K)),
    params (B,)) -> FedResult``."""

    def cell(events, pp):
        return fedasync_scan(client_update, x0, client_data, events,
                             ParamPolicy(pp), objective=objective,
                             horizon=horizon)

    return jax.jit(jax.vmap(cell))


def _stack_fed_events(grid: SweepGrid, buffer_size: int):
    """Simulate one federated trace per cell (cell.workers are ClientModels)
    and stack the event columns the server scan consumes."""
    traces = [simulate_federated(c.n_workers, grid.n_events,
                                 clients=list(c.workers),
                                 buffer_size=buffer_size, seed=c.seed)
              for c in grid.cells]
    return tuple(
        jnp.stack([jnp.asarray(getattr(t, f), dt) for t in traces])
        for f, dt in [("client", jnp.int32), ("tau", jnp.int32),
                      ("local_steps", jnp.int32), ("aggregate", jnp.float32),
                      ("version", jnp.int32)])


def sweep_fedasync(client_update: Callable, x0, client_data, grid: SweepGrid,
                   objective: Optional[Callable] = None,
                   buffer_size: int = 1, horizon: int = 4096) -> FedResult:
    """Run FedAsync on every cell of a grid whose topologies are
    ``ClientModel`` lists.  Client round-trip traces come from the
    (reference) federated event simulator; server mixing for all cells runs
    in one batched program."""
    fn = make_sweep_fedasync(client_update, x0, client_data,
                             objective=objective, horizon=horizon)
    return fn(_stack_fed_events(grid, buffer_size), grid.policy_params())


def sweep_fedasync_problem(problem, grid: SweepGrid, prox: ProxOp,
                           local_lr: Optional[float] = None,
                           horizon: int = 4096) -> FedResult:
    """Grid analogue of ``federated.server.run_fedasync_problem``."""
    from repro.federated.server import _problem_pieces
    update, x0, data = _problem_pieces(problem, prox, local_lr)
    return sweep_fedasync(update, x0, data, grid, objective=problem.P,
                          horizon=horizon)
