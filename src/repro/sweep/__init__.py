"""Vectorized experiment sweeps (`repro.sweep`).

The paper's headline claims are sweep-shaped -- grids over step-size
policies, seeds, worker counts and straggler regimes (Figs. 2-5).  This
package turns a whole grid into ONE compiled XLA program per bucket, and
(since PR 3) spreads the cell axis across every available device:

* ``policies``  -- ``PolicyParams`` / ``ParamPolicy``: step-size policies as
  vmappable data (``lax.switch`` dispatch), arithmetic-identical to the
  ``core.stepsize`` dataclasses.
* ``grid``      -- ``SweepGrid`` / ``make_grid`` / ``standard_topologies``:
  the cartesian product of policies x seeds x topologies (x worker counts;
  ragged grids are bucketed by padded width with ``active_workers`` masks),
  and the stacked tensors that feed the runners.
* ``runners``   -- ``sweep_piag`` / ``sweep_bcd`` / ``sweep_fedasync`` /
  ``sweep_fedbuff`` (and ``make_sweep_*`` builders): ``vmap`` of the jitted
  trace generators (``core.engine.trace_scan``,
  ``federated.events.federated_trace_scan``) composed with the shared solver
  scan cores; one compile per bucket, B cells, bit-identical rows to solo
  runs.  The federated sweeps fuse client round-trip simulation with the
  server scan under the same jit (``reference=True`` falls back to the
  heapq twin).
* ``shard``     -- ``sharded_sweep_*``: the same cell programs with the cell
  axis partitioned across a ``("cells",)`` or 2-D ``("cells", "data")``
  device mesh via ``shard_map`` (donated input buffers, round-robin batch
  padding; 2-D meshes additionally psum per-worker gradients over the data
  axis -- see ``repro.mesh``) -- mega-grids at device-count scaling.

Quick taste::

    from repro.core import Adaptive1, Adaptive2, L1, make_logreg
    from repro.sweep import (make_grid, standard_topology_factories,
                             sweep_piag_logreg)

    prob = make_logreg(800, 100, n_workers=8, seed=0)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=0.99 / prob.L),
                  "a2": Adaptive2(gamma_prime=0.99 / prob.L)},
        seeds=range(8),
        topologies=standard_topology_factories(),
        n_events=2000,
        n_workers=[4, 8])          # ragged: bucketed + masked automatically
    res = sweep_piag_logreg(prob, grid, L1(lam=prob.lam1))  # (128, 2000)
"""
from .cache import (clear_program_cache, program_cache_stats)
from .grid import (SweepBucket, SweepCell, SweepGrid, make_grid,
                   measure_tau_bar, next_pow2, standard_topologies,
                   standard_topology_factories)
from .policies import POLICY_IDS, ParamPolicy, PolicyParams, policy_params, stack_params
from .runners import (make_sweep_bcd, make_sweep_fedasync,
                      make_sweep_fedasync_fused, make_sweep_fedbuff,
                      make_sweep_piag, measure_fed_tau_bar,
                      resolve_grid_horizon, run_bucketed, sweep_bcd,
                      sweep_bcd_logreg, sweep_fedasync,
                      sweep_fedasync_problem, sweep_fedbuff,
                      sweep_fedbuff_problem, sweep_piag, sweep_piag_logreg)
from .shard import (cell_mesh, grid_mesh, make_sharded_sweep_bcd,
                    make_sharded_sweep_piag, mesh_topology, round_robin_pad,
                    shard_cells, sharded_sweep_bcd, sharded_sweep_fedasync,
                    sharded_sweep_fedbuff, sharded_sweep_piag,
                    sharded_sweep_piag_logreg)

__all__ = [
    "SweepBucket", "SweepCell", "SweepGrid", "make_grid", "measure_tau_bar",
    "next_pow2", "standard_topologies", "standard_topology_factories",
    "clear_program_cache", "program_cache_stats", "measure_fed_tau_bar",
    "resolve_grid_horizon",
    "POLICY_IDS", "ParamPolicy", "PolicyParams", "policy_params",
    "stack_params", "make_sweep_bcd", "make_sweep_fedasync",
    "make_sweep_fedasync_fused", "make_sweep_fedbuff", "make_sweep_piag",
    "run_bucketed", "sweep_bcd", "sweep_bcd_logreg", "sweep_fedasync",
    "sweep_fedasync_problem", "sweep_fedbuff", "sweep_fedbuff_problem",
    "sweep_piag", "sweep_piag_logreg",
    "cell_mesh", "grid_mesh", "mesh_topology",
    "make_sharded_sweep_bcd", "make_sharded_sweep_piag",
    "round_robin_pad", "shard_cells", "sharded_sweep_bcd",
    "sharded_sweep_fedasync", "sharded_sweep_fedbuff", "sharded_sweep_piag",
    "sharded_sweep_piag_logreg",
]
