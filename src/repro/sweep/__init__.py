"""Vectorized experiment sweeps (`repro.sweep`).

The paper's headline claims are sweep-shaped -- grids over step-size
policies, seeds, worker counts and straggler regimes (Figs. 2-4).  This
package turns a whole grid into ONE compiled XLA program:

* ``policies``  -- ``PolicyParams`` / ``ParamPolicy``: step-size policies as
  vmappable data (``lax.switch`` dispatch), arithmetic-identical to the
  ``core.stepsize`` dataclasses.
* ``grid``      -- ``SweepGrid`` / ``make_grid`` / ``standard_topologies``:
  the cartesian product of policies x seeds x topologies, and the stacked
  tensors that feed the runners.
* ``runners``   -- ``sweep_piag`` / ``sweep_bcd`` / ``sweep_fedasync`` (and
  ``make_sweep_*`` builders): ``vmap`` of the jitted trace generator
  (``core.engine.trace_scan``) composed with the shared solver scan cores;
  one compile, B cells, bit-identical rows to solo runs.

Quick taste::

    from repro.core import Adaptive1, Adaptive2, L1, make_logreg
    from repro.sweep import make_grid, standard_topologies, sweep_piag_logreg

    prob = make_logreg(800, 100, n_workers=8, seed=0)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=0.99 / prob.L),
                  "a2": Adaptive2(gamma_prime=0.99 / prob.L)},
        seeds=range(8),
        topologies=standard_topologies(8),
        n_events=2000)
    res = sweep_piag_logreg(prob, grid, L1(lam=prob.lam1))  # (64, 2000) objectives
"""
from .grid import (SweepCell, SweepGrid, make_grid, measure_tau_bar,
                   standard_topologies)
from .policies import POLICY_IDS, ParamPolicy, PolicyParams, policy_params, stack_params
from .runners import (make_sweep_bcd, make_sweep_fedasync, make_sweep_piag,
                      sweep_bcd, sweep_bcd_logreg, sweep_fedasync,
                      sweep_fedasync_problem, sweep_piag, sweep_piag_logreg)

__all__ = [
    "SweepCell", "SweepGrid", "make_grid", "measure_tau_bar",
    "standard_topologies",
    "POLICY_IDS", "ParamPolicy", "PolicyParams", "policy_params",
    "stack_params", "make_sweep_bcd", "make_sweep_fedasync",
    "make_sweep_piag", "sweep_bcd", "sweep_bcd_logreg", "sweep_fedasync",
    "sweep_fedasync_problem", "sweep_piag", "sweep_piag_logreg",
]
