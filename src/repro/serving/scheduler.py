"""Continuous-batching serving scheduler (vLLM-style, single host).

Requests arrive with prompts of different lengths and different generation
budgets; the scheduler packs up to ``max_slots`` concurrent sequences into a
fixed decode batch, prefills new requests into free slots (one jit'd
prefill per admission, padded to ``prompt_pad``), and runs ONE shared
decode step per tick for all active slots.  Finished slots are immediately
recycled -- throughput does not stall on the longest request.

Design notes (TPU-friendly):
* fixed shapes everywhere: decode batch is always (max_slots, 1); caches are
  preallocated to ``max_len``; prompts are right-aligned into the cache so
  every slot's next position is its own ``pos`` scalar -- we pass per-slot
  positions as a vector and mask finished slots.
* per-slot positions require position-vector decode: `decode_step` takes a
  scalar ``pos``; we run it with the max position and mask invalid cache
  slots per sequence via each slot's own write index (see _SlotState).
  For simplicity and exactness, slots advance in lock-step per tick but each
  slot has its own length; a slot whose sequence finished is masked out and
  refilled on the next admission.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_params, make_cache, prefill
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    arrived_at: float = 0.0
    # filled by the scheduler
    output: Optional[np.ndarray] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                  # next write position in this slot's cache
    generated: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.req is None


class ContinuousBatcher:
    """Slot-based continuous batching over a shared decode step."""

    def __init__(self, cfg: ModelConfig, params=None, *, max_slots: int = 4,
                 max_len: int = 512, seed: int = 0,
                 temperature: float = 0.0):
        assert cfg.has_decode and not cfg.embed_inputs
        self.cfg = cfg
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.max_slots = max_slots
        self.max_len = max_len
        self.temperature = temperature
        self.slots = [_Slot() for _ in range(max_slots)]
        self.queue: Deque[Request] = deque()
        self.done: List[Request] = []
        self._key = jax.random.PRNGKey(seed + 1)

        # one cache per slot (batch dim 1) so prefill/recycle are per-slot
        self.caches = [make_cache(cfg, 1, max_len) for _ in range(max_slots)]
        self._prefill = jax.jit(lambda p, b: prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))

    # ------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        req.arrived_at = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if not self.queue:
                return
            if not slot.free:
                continue
            req = self.queue.popleft()
            P = len(req.prompt)
            logits, pf_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt)[None, :]})
            # graft prefill cache (len P) into the slot's max_len cache
            fresh = make_cache(self.cfg, 1, self.max_len)

            def graft(buf, c):
                if buf.ndim == c.ndim and buf.shape != c.shape:
                    ax = next(a for a in range(buf.ndim)
                              if buf.shape[a] != c.shape[a])
                    return jax.lax.dynamic_update_slice_in_dim(
                        buf, c.astype(buf.dtype), 0, axis=ax)
                return c.astype(buf.dtype)
            self.caches[i] = jax.tree_util.tree_map(graft, fresh, pf_cache)
            slot.req = req
            slot.pos = P
            slot.generated = 0
            slot.tokens = [int(self._sample(logits[:, -1])[0])]
            req.t_first_token = time.perf_counter()

    def _sample(self, logits_row) -> np.ndarray:
        if self.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            return np.asarray(jax.random.categorical(
                sub, logits_row / self.temperature))
        return np.asarray(jnp.argmax(logits_row, axis=-1))

    # -------------------------------------------------------------- tick
    def step(self) -> int:
        """Admit waiting requests, run one decode tick for every active
        slot; returns number of active slots processed."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if not s.free]
        for i in active:
            slot = self.slots[i]
            tok = jnp.asarray([[slot.tokens[-1]]], jnp.int32)
            logits, self.caches[i] = self._decode(
                self.params, self.caches[i], tok, jnp.int32(slot.pos))
            slot.pos += 1
            slot.generated += 1
            nxt = int(self._sample(logits[:, -1])[0])
            if slot.generated < slot.req.max_new and slot.pos < self.max_len - 1:
                slot.tokens.append(nxt)
            else:
                self._finish(i)
        return len(active)

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        req = slot.req
        req.output = np.asarray(slot.tokens, np.int32)
        req.t_done = time.perf_counter()
        self.done.append(req)
        self.slots[i] = _Slot()

    # --------------------------------------------------------------- run
    def run_until_idle(self, max_ticks: int = 10_000) -> Dict[str, float]:
        t0 = time.perf_counter()
        toks = 0
        ticks = 0
        while (self.queue or any(not s.free for s in self.slots)) and \
                ticks < max_ticks:
            toks += self.step()
            ticks += 1
        dt = time.perf_counter() - t0
        return {"ticks": ticks, "tokens": toks, "wall_s": dt,
                "tok_per_s": toks / max(dt, 1e-9),
                "completed": len(self.done)}
