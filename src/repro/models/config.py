"""Model configuration for all six assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""                # citation for the config

    # trunk
    n_layers: int = 2
    d_model: int = 256
    vocab: int = 32000
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False

    # attention
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    qkv_bias: bool = False
    causal: bool = True             # False => encoder-only (bidirectional)
    rope: str = "rope"              # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # splits of head_dim//2 for M-RoPE
    sliding_window: Optional[int] = None   # native window (starcoder2 trains 4k)
    attention_impl: str = "chunked"        # chunked | naive | pallas
    q_chunk: int = 512

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # mlp
    d_ff: int = 1024
    act: str = "silu_glu"           # silu_glu | gelu | relu2
    mlp_bias: bool = False

    # MoE
    n_experts: int = 0              # routed experts (0 => dense MLP)
    top_k: int = 2
    shared_ff: int = 0              # fused shared-expert intermediate size
    moe_ff: int = 0                 # routed expert intermediate size
    router_aux_coef: float = 0.01
    moe_impl: str = "capacity"      # capacity (bucketed) | dense (oracle)
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_n_groups: int = 1
    ssm_chunk: int = 256

    # hybrid (zamba2): shared attention+MLP block applied every N layers
    attn_every: int = 0

    # modality frontend stub (audio/vlm): inputs are precomputed embeddings
    embed_inputs: bool = False
    has_decode: bool = True         # False for encoder-only (hubert)

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True
    # long-context variant: replace full attention by this sliding window
    long_context_window: int = 8192

    # chunked cross-entropy: compute logits + CE one sequence chunk at a
    # time so the (B, S, V) logits tensor is never materialized (matters for
    # vocab >= 100k: nemotron's 256k vocab at train_4k is 537 GB of f32
    # logits otherwise).  0 = off.
    ce_chunk: int = 0

    # ---- beyond-paper performance knobs (EXPERIMENTS.md §Perf) ----------
    # checkpoint each q-chunk of attention: the backward recomputes scores
    # instead of stacking f32 score chunks across the scan (huge HBM win)
    remat_chunk: bool = False
    # pin activation shardings inside the layer stack: batch over act_dp_axes
    # (and sequence over "model" when seq_shard=True -- megatron-style
    # sequence parallelism for the norm/elementwise segments)
    shard_activations: bool = False
    seq_shard: bool = False
    act_dp_axes: Tuple[str, ...] = ("data",)

    # ---------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model <= 512, <= 4 experts."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = min(self.head_dim, 64)
        sections = ()
        if self.rope == "mrope":
            # keep three sections summing to head_dim // 2
            half = hd // 2
            sections = (half - 2 * (half // 3), half // 3, half // 3)
        return self.replace(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2),
            moe_ff=min(self.moe_ff, 128) if self.moe_ff else 0,
            shared_ff=min(self.shared_ff, 128) if self.shared_ff else 0,
            kv_lora_rank=min(self.kv_lora_rank, 32),
            rope_head_dim=min(self.rope_head_dim, 16),
            nope_head_dim=min(self.nope_head_dim, 48) if self.use_mla else self.nope_head_dim,
            v_head_dim=min(self.v_head_dim, 64) if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_chunk=64,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            mrope_sections=sections,
            q_chunk=64,
            param_dtype="float32",
            compute_dtype="float32",
            long_context_window=256,
        )

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA group must divide"
        if self.rope == "mrope":
            assert sum(self.mrope_sections) == (
                self.rope_head_dim if self.use_mla else self.head_dim) // 2
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0 and self.d_inner % self.ssm_head_dim == 0
        if self.n_experts:
            assert self.moe_ff > 0 and self.top_k <= self.n_experts
        if self.family in ("audio", "vlm"):
            assert self.embed_inputs
        if not self.causal:
            assert not self.has_decode, "encoder-only models have no decode step"
