"""Attention: GQA/MHA, MLA (DeepSeek-V2), sliding-window, M-RoPE-compatible;
train / prefill / decode paths with plain and ring (sliding-window) KV caches.

The default implementation is *query-chunked*: the (Sq, Sk) score matrix is
materialized only one q-chunk at a time inside a ``lax.scan``, so peak
activation memory is O(q_chunk * Sk) instead of O(Sq * Sk).  Softmax over the
full key axis is exact per chunk (no online rescaling needed; the Pallas
flash kernel in repro.kernels tiles the key axis too and does use online
softmax).  ``attention_impl``: "chunked" (default), "naive" (materialize all
scores; oracle), "pallas" (TPU kernel, validated in interpret mode).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import act_constraint, apply_rope, norm_params, rmsnorm

NEG_INF = -1e30


# ------------------------------------------------------------------ params


def attn_params(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    std = D ** -0.5
    if cfg.use_mla:
        ks = jax.random.split(key, 6)
        H = cfg.n_heads
        qk_dim = cfg.nope_head_dim + cfg.rope_head_dim
        p = {
            "w_dkv": (jax.random.normal(ks[1], (D, cfg.kv_lora_rank + cfg.rope_head_dim)) * std).astype(cfg.pdtype),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), cfg.pdtype),
            "w_uk": (jax.random.normal(ks[2], (cfg.kv_lora_rank, H * cfg.nope_head_dim))
                     * cfg.kv_lora_rank ** -0.5).astype(cfg.pdtype),
            "w_uv": (jax.random.normal(ks[3], (cfg.kv_lora_rank, H * cfg.v_head_dim))
                     * cfg.kv_lora_rank ** -0.5).astype(cfg.pdtype),
            "wo": (jax.random.normal(ks[4], (H * cfg.v_head_dim, D))
                   * (H * cfg.v_head_dim) ** -0.5).astype(cfg.pdtype),
        }
        if cfg.q_lora_rank:
            p["wq_a"] = (jax.random.normal(ks[0], (D, cfg.q_lora_rank)) * std).astype(cfg.pdtype)
            p["q_norm"] = jnp.ones((cfg.q_lora_rank,), cfg.pdtype)
            p["wq_b"] = (jax.random.normal(ks[5], (cfg.q_lora_rank, H * qk_dim))
                         * cfg.q_lora_rank ** -0.5).astype(cfg.pdtype)
        else:
            p["wq"] = (jax.random.normal(ks[0], (D, H * qk_dim)) * std).astype(cfg.pdtype)
        return p
    ks = jax.random.split(key, 4)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": (jax.random.normal(ks[0], (D, H * hd)) * std).astype(cfg.pdtype),
        "wk": (jax.random.normal(ks[1], (D, KV * hd)) * std).astype(cfg.pdtype),
        "wv": (jax.random.normal(ks[2], (D, KV * hd)) * std).astype(cfg.pdtype),
        "wo": (jax.random.normal(ks[3], (H * hd, D)) * (H * hd) ** -0.5).astype(cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.pdtype)
    return p


# ----------------------------------------------------------------- attend


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    """(Sq, Sk) additive mask from absolute positions (invalid kpos = -1)."""
    valid = kpos[None, :] >= 0
    if causal:
        valid &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        valid &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def attend(q, k, v, qpos, kpos, *, causal: bool, window: Optional[int],
           scale: float, q_chunk: int, impl: str = "chunked",
           remat_chunk: bool = False) -> jnp.ndarray:
    """q (B,Sq,H,dq), k (B,Sk,KV,dq), v (B,Sk,KV,dv) -> (B,Sq,H,dv).

    GQA grouping is einsum-native (no repeated-KV materialization)."""
    B, Sq, H, dq = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dq)

    if impl == "pallas" and Sq > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, qpos, kpos, causal=causal,
                                    window=window, scale=scale)

    def chunk_attend(qc, qpc):
        # bf16-native operands with f32 accumulation (MXU-style): keeps any
        # sharding-induced gathers of q/k in bf16 (§Perf H1 iter 4)
        s = jnp.einsum("bqcgd,bscd->bcgqs", qc * jnp.asarray(scale, qc.dtype),
                       k, preferred_element_type=jnp.float32)
        s = s + _mask(qpc, kpos, causal, window)[None, None, None]
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bcgqs,bscd->bqcgd", w.astype(v.dtype), v)

    if remat_chunk:
        # backward recomputes the (q_chunk x Sk) scores instead of stacking
        # f32 score chunks across the scan (EXPERIMENTS.md §Perf H1)
        chunk_attend = jax.checkpoint(chunk_attend)

    if impl == "naive" or Sq <= q_chunk:
        out = chunk_attend(qg, qpos)
        return out.reshape(B, Sq, H, -1)

    nc = -(-Sq // q_chunk)
    pad = nc * q_chunk - Sq
    qg_p = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(qpos, (0, pad), constant_values=-1)
    qg_c = qg_p.reshape(B, nc, q_chunk, KV, G, dq).swapaxes(0, 1)
    qpos_c = qpos_p.reshape(nc, q_chunk)

    def body(_, xs):
        qc, qpc = xs
        return None, chunk_attend(qc, qpc)

    _, outs = jax.lax.scan(body, None, (qg_c, qpos_c))
    out = outs.swapaxes(0, 1).reshape(B, nc * q_chunk, KV, G, -1)[:, :Sq]
    return out.reshape(B, Sq, H, -1)


# ------------------------------------------------------------- GQA block


def init_cache(cfg: ModelConfig, batch: int, max_len: int, ring: bool) -> dict:
    """Per-layer KV cache (stacked over layers by the caller)."""
    dt = cfg.cdtype
    if cfg.use_mla:
        c = {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dt),
            "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dt),
        }
    else:
        c = {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        }
    if ring:
        c["positions"] = jnp.full((max_len,), -1, jnp.int32)
    return c


def _cache_write(cache: dict, updates: dict, pos, ring: bool):
    """Write one token's entries at absolute position ``pos`` (scalar)."""
    S = next(iter(cache.values())).shape[1]
    slot = (pos % S) if ring else pos
    out = dict(cache)
    for name, u in updates.items():
        out[name] = jax.lax.dynamic_update_slice_in_dim(cache[name], u, slot, axis=1)
    if ring:
        out["positions"] = cache["positions"].at[slot].set(pos)
    return out


def _kpos_of(cache: dict, pos, ring: bool):
    S = next(iter(cache.values())).shape[1]
    if ring:
        return cache["positions"]
    # plain cache: slots [0, pos] are valid
    idx = jnp.arange(S, dtype=jnp.int32)
    return jnp.where(idx <= pos, idx, -1)


def gqa_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, rope_cs,
                  positions, mode: str, cache: Optional[dict] = None,
                  pos=None, window: Optional[int] = None,
                  ring: bool = False) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Standard multi-head / grouped-query attention with RoPE and caching.

    mode: "train" (no cache) | "prefill" (fill cache) | "decode" (Sq == 1).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.cdtype
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    scale = hd ** -0.5

    if mode == "decode":
        cache = _cache_write(cache, {"k": k, "v": v}, pos, ring)
        kpos = _kpos_of(cache, pos, ring)
        qpos = jnp.full((1,), pos, jnp.int32)
        out = attend(q, cache["k"], cache["v"], qpos, kpos, causal=cfg.causal,
                     window=window, scale=scale, q_chunk=cfg.q_chunk,
                     impl="chunked")
    else:
        # masking uses *sequence order*, independent of the (possibly
        # multimodal) RoPE position streams
        qpos = jnp.arange(S, dtype=jnp.int32)
        out = attend(q, k, v, qpos, qpos, causal=cfg.causal, window=window,
                     scale=scale, q_chunk=cfg.q_chunk, impl=cfg.attention_impl,
                     remat_chunk=cfg.remat_chunk)
        if mode == "prefill":
            cache = {"k": k, "v": v}
            if ring:
                # keep only the last `window` entries in a ring layout
                cache = {"k": k[:, -window:] if window and S > window else k,
                         "v": v[:, -window:] if window and S > window else v}
                W = cache["k"].shape[1]
                start = jnp.maximum(S - W, 0)
                cache["positions"] = jnp.arange(W, dtype=jnp.int32) + start
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), p["wo"].astype(dt))
    return y, cache


# ------------------------------------------------------------- MLA block


def mla_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, rope_cs,
                  positions, mode: str, cache: Optional[dict] = None,
                  pos=None, window: Optional[int] = None,
                  ring: bool = False) -> Tuple[jnp.ndarray, Optional[dict]]:
    """DeepSeek-V2 Multi-head Latent Attention.

    Train/prefill: expand the compressed KV once (like a normal MHA).
    Decode: *absorbed* form -- scores and values computed directly in the
    kv_lora latent space, so the per-token cost is O(S * r) not O(S * H * d).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    dt = cfg.cdtype
    scale = (dn + dr) ** -0.5

    if cfg.q_lora_rank:
        qa = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt))
        qa = rmsnorm(qa, p["q_norm"])
        q = jnp.einsum("bsr,rh->bsh", qa, p["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt))
    ckv_full = act_constraint(ckv_full, cfg)  # keep batch-sharded (§Perf H2)
    ckv, k_rope = ckv_full[..., :r], ckv_full[..., r:]
    ckv = rmsnorm(ckv, p["kv_norm"])
    if rope_cs is not None:
        cos, sin = rope_cs
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if mode == "decode":
        cache = _cache_write(cache, {"ckv": ckv, "krope": k_rope}, pos, ring)
        kpos = _kpos_of(cache, pos, ring)
        Sk = cache["ckv"].shape[1]
        # absorbed scores: q_nope W_uk^T . ckv   (+ rope part)
        w_uk = p["w_uk"].astype(dt).reshape(r, H, dn)
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)         # (B,1,H,r)
        s = jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(jnp.float32),
                       cache["ckv"].astype(jnp.float32))
        s += jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                        cache["krope"].astype(jnp.float32))
        qpos_arr = jnp.full((1,), pos, jnp.int32)
        s = s * scale + _mask(qpos_arr, kpos, cfg.causal, window)[None, None]
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", w.astype(dt), cache["ckv"])  # (B,1,H,r)
        w_uv = p["w_uv"].astype(dt).reshape(r, H, dv)
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv)            # (B,1,H,dv)
    else:
        # expand once; standard MHA (KV == H)
        k_nope = jnp.einsum("bsr,rh->bsh", ckv, p["w_uk"].astype(dt)).reshape(B, S, H, dn)
        vvec = jnp.einsum("bsr,rh->bsh", ckv, p["w_uv"].astype(dt)).reshape(B, S, H, dv)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        qpos = jnp.arange(S, dtype=jnp.int32)
        out = attend(q_full, k_full, vvec, qpos, qpos, causal=cfg.causal,
                     window=window, scale=scale, q_chunk=cfg.q_chunk,
                     impl=cfg.attention_impl, remat_chunk=cfg.remat_chunk)
        if mode == "prefill":
            if ring and window and S > window:
                cache = {"ckv": ckv[:, -window:], "krope": k_rope[:, -window:],
                         "positions": jnp.arange(window, dtype=jnp.int32) + (S - window)}
            else:
                cache = {"ckv": ckv, "krope": k_rope}
                if ring:
                    cache["positions"] = jnp.arange(S, dtype=jnp.int32)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * dv), p["wo"].astype(dt))
    return y, cache


def attention_block(p, x, cfg: ModelConfig, rope_cs, positions, mode: str,
                    cache=None, pos=None, window=None, ring=False):
    fn = mla_attention if cfg.use_mla else gqa_attention
    return fn(p, x, cfg, rope_cs, positions, mode, cache=cache, pos=pos,
              window=window, ring=ring)
