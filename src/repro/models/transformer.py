"""Model assembly: scan-over-layers transformer for all six families.

* dense / moe / audio / vlm: pre-norm attention + MLP (or MoE) blocks.
* ssm: Mamba2 mixer blocks (attention-free).
* hybrid (zamba2-style): groups of ``attn_every`` Mamba2 layers, each group
  preceded by ONE application of a *shared* attention+MLP block (one set of
  weights reused by all groups, as in Zamba/Zamba2).

Layers are stacked (leading L axis on every leaf) and executed with
``lax.scan`` so the compiled HLO is O(1) in depth -- essential for lowering
the 512-device production mesh in reasonable time.  ``cfg.remat`` wraps the
layer body in ``jax.checkpoint`` for training.

Three entry points (mirroring the assigned input shapes):
  forward()      -- train_4k and encoder workloads (logits over all positions)
  prefill()      -- prefill_32k: full-sequence forward that returns the cache
  decode_step()  -- decode_32k / long_500k: one token against the cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention_block, attn_params, init_cache
from .config import ModelConfig
from .layers import (apply_norm, embed, embed_params, make_positions, mlp,
                     mlp_params, norm_params, rope_cos_sin, unembed)
from .moe import moe_block, moe_block_capacity, moe_params
from .ssm import init_ssm_state, mamba2_block, ssm_params

Params = Dict[str, Any]

from .layers import act_constraint  # noqa: E402  (shared with attention.py)


# ----------------------------------------------------------------- params


def _layer_params(key, cfg: ModelConfig) -> Params:
    if cfg.family in ("ssm", "hybrid"):
        k1, _ = jax.random.split(key)
        return {"ln": norm_params(cfg), "mixer": ssm_params(k1, cfg)}
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_params(cfg), "attn": attn_params(k1, cfg),
         "ln2": norm_params(cfg)}
    if cfg.n_experts:
        p["moe"] = moe_params(k2, cfg)
    else:
        p["mlp"] = mlp_params(k2, cfg)
    return p


def _shared_block_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_params(cfg), "attn": attn_params(k1, cfg),
            "ln2": norm_params(cfg),
            "mlp": mlp_params(k2, cfg)}


def init_params(cfg: ModelConfig, key) -> Params:
    cfg.validate()
    k_emb, k_layers, k_shared, k_out = jax.random.split(key, 4)
    keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_params(k, cfg))(keys)
    p: Params = {
        "embed": embed_params(k_emb, cfg),
        "layers": layers,
        "final_norm": norm_params(cfg),
    }
    if cfg.family == "hybrid":
        p["shared"] = _shared_block_params(k_shared, cfg)
    return p


def param_specs(cfg: ModelConfig) -> Params:
    """Abstract shapes (no allocation) -- used by the multi-pod dry-run."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


# ------------------------------------------------------------ layer bodies


def _attn_mlp_layer(lp, x, cfg, rope_cs, positions, mode, cache, pos,
                    window, ring):
    x = act_constraint(x, cfg)
    h, new_cache = attention_block(lp["attn"], apply_norm(lp["ln1"], x, cfg),
                                   cfg, rope_cs, positions, mode, cache=cache,
                                   pos=pos, window=window, ring=ring)
    x = x + h
    z = apply_norm(lp["ln2"], x, cfg)
    if cfg.n_experts:
        if cfg.moe_impl == "dense":
            y, aux = moe_block(lp["moe"], z, cfg)
        else:
            y, aux = moe_block_capacity(lp["moe"], z, cfg, cfg.capacity_factor)
    else:
        y, aux = mlp(lp["mlp"], z, cfg), jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux


def _mamba_layer(lp, x, cfg, mode, state):
    x = act_constraint(x, cfg)
    h, new_state = mamba2_block(lp["mixer"], apply_norm(lp["ln"], x, cfg),
                                cfg, mode, state=state)
    return x + h, new_state


# ----------------------------------------------------------- trunk (scan)


def _run_trunk(params: Params, x: jnp.ndarray, cfg: ModelConfig, *,
               mode: str, positions, cache=None, pos=None,
               window: Optional[int] = None, ring: bool = False):
    """Apply all layers.  Returns (x, new_cache, aux_loss)."""
    rope_cs = rope_cos_sin(cfg, positions)
    L = cfg.n_layers
    use_remat = cfg.remat and mode == "train"

    if cfg.family == "ssm":
        zero = jnp.zeros((), jnp.float32)
        if mode == "train":
            def body_tr(carry, lp):
                xc, _ = _mamba_layer(lp, carry, cfg, "train", None)
                return xc, 0.0
            if use_remat:
                body_tr = jax.checkpoint(body_tr)
            x, _ = jax.lax.scan(body_tr, x, params["layers"])
            return x, None, zero
        if mode == "prefill":
            def body_pf(carry, lp):
                xc, st = _mamba_layer(lp, carry, cfg, "prefill", None)
                return xc, st
            x, new_cache = jax.lax.scan(body_pf, x, params["layers"])
            return x, new_cache, zero
        def body_dec(carry, xs):
            lp, st = xs
            xc, new_st = _mamba_layer(lp, carry, cfg, "decode", st)
            return xc, new_st
        x, new_cache = jax.lax.scan(body_dec, x, (params["layers"], cache))
        return x, new_cache, zero

    if cfg.family == "hybrid":
        return _run_hybrid(params, x, cfg, mode=mode, positions=positions,
                           rope_cs=rope_cs, cache=cache, pos=pos,
                           window=window, ring=ring)

    # dense / moe / audio / vlm
    def body(carry, xs):
        xc, aux = carry
        lp, c_in = xs
        xc, c_out, aux_l = _attn_mlp_layer(lp, xc, cfg, rope_cs, positions,
                                           mode, c_in, pos, window, ring)
        return (xc, aux + aux_l), c_out

    if use_remat:
        body = jax.checkpoint(body)

    if mode == "train":
        def body_nc(carry, lp):
            xc, aux = carry
            xc, _, aux_l = _attn_mlp_layer(lp, xc, cfg, rope_cs, positions,
                                           mode, None, pos, window, ring)
            return (xc, aux + aux_l), 0.0
        if use_remat:
            body_nc = jax.checkpoint(body_nc)
        (x, aux), _ = jax.lax.scan(body_nc, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        return x, None, aux

    if mode == "prefill":
        # cache is created inside the layer; scan emits it
        def body_pf(carry, lp):
            xc, aux = carry
            xc, c_out, aux_l = _attn_mlp_layer(lp, xc, cfg, rope_cs, positions,
                                               "prefill", None, pos, window, ring)
            return (xc, aux + aux_l), c_out
        (x, aux), new_cache = jax.lax.scan(
            body_pf, (x, jnp.zeros((), jnp.float32)), params["layers"])
        return x, new_cache, aux

    # decode
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], cache))
    return x, new_cache, aux


def _run_hybrid(params, x, cfg, *, mode, positions, rope_cs, cache, pos,
                window, ring):
    """Zamba2-style: outer scan over groups; each group = one shared
    attention+MLP application + ``attn_every`` Mamba2 layers."""
    E = cfg.attn_every
    L = cfg.n_layers
    assert L % E == 0, "hybrid requires n_layers % attn_every == 0"
    G = L // E
    shared = params["shared"]

    group_layers = jax.tree_util.tree_map(
        lambda leaf: leaf.reshape((G, E) + leaf.shape[1:]), params["layers"])

    def group_body(carry, xs):
        xc, aux = carry
        glp, gcache = xs
        attn_cache_in = gcache["attn"] if gcache is not None else None
        ssm_state_in = gcache["ssm"] if gcache is not None else None
        # shared attention + MLP block (weights shared across groups)
        h, attn_cache_out = attention_block(
            shared["attn"], apply_norm(shared["ln1"], xc, cfg), cfg, rope_cs,
            positions, mode, cache=attn_cache_in, pos=pos, window=window,
            ring=ring)
        xc = xc + h
        xc = xc + mlp(shared["mlp"], apply_norm(shared["ln2"], xc, cfg), cfg)

        # E mamba layers
        if ssm_state_in is not None:
            def ssm_body(c, l_xs):
                lp, st = l_xs
                c, new_st = _mamba_layer(lp, c, cfg, mode, st)
                return c, new_st
            xc, ssm_state_out = jax.lax.scan(ssm_body, xc, (glp, ssm_state_in))
        else:
            def ssm_body_ns(c, lp):
                c, _ = _mamba_layer(lp, c, cfg, mode, None)
                return c, 0.0
            xc, _ = jax.lax.scan(ssm_body_ns, xc, glp)
            ssm_state_out = None

        out_cache = None
        if mode in ("prefill", "decode"):
            out_cache = {"attn": attn_cache_out, "ssm": ssm_state_out}
        return (xc, aux), out_cache

    if cfg.remat and mode == "train":
        group_body = jax.checkpoint(group_body)

    if mode == "train":
        def gb(carry, glp):
            (xc, aux), _ = group_body(carry, (glp, None))
            return (xc, aux), 0.0
        if cfg.remat:
            gb = jax.checkpoint(gb)
        (x, aux), _ = jax.lax.scan(gb, (x, jnp.zeros((), jnp.float32)),
                                   group_layers)
        return x, None, aux

    if mode == "prefill":
        def gb_pf2(carry, glp):
            xc, aux = carry
            # shared attn
            h, attn_c = attention_block(
                shared["attn"], apply_norm(shared["ln1"], xc, cfg), cfg,
                rope_cs, positions, "prefill", cache=None, pos=pos,
                window=window, ring=ring)
            xc = xc + h
            xc = xc + mlp(shared["mlp"], apply_norm(shared["ln2"], xc, cfg), cfg)
            def ssm_body(c, lp):
                c, st = _mamba_layer(lp, c, cfg, "prefill", None)
                return c, st
            xc, ssm_states = jax.lax.scan(ssm_body, xc, glp)
            return (xc, aux), {"attn": attn_c, "ssm": ssm_states}
        (x, aux), new_cache = jax.lax.scan(
            gb_pf2, (x, jnp.zeros((), jnp.float32)), group_layers)
        return x, new_cache, aux

    # decode
    (x, aux), new_cache = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), (group_layers, cache))
    return x, new_cache, aux


# -------------------------------------------------------------- frontends


def _inputs_to_x(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.cdtype)
    else:
        x = embed(params["embed"], batch["tokens"], cfg)
    B, S = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, B, S)
    return x, positions


# ------------------------------------------------------------ entry points


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            window: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward (training / encoding).  Returns (logits, aux)."""
    x, positions = _inputs_to_x(params, cfg, batch)
    window = window if window is not None else cfg.sliding_window
    x, _, aux = _run_trunk(params, x, cfg, mode="train", positions=positions,
                           window=window)
    x = apply_norm(params["final_norm"], x, cfg)
    return unembed(params["embed"], x, cfg), aux


def _ce_terms(logits: jnp.ndarray, labels: jnp.ndarray):
    """(sum nll, sum mask) for logits (B, S, V), labels (B, S) (-1 = pad)."""
    mask = (labels >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    return -jnp.sum(ll * mask), jnp.sum(mask)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            window: Optional[int] = None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    labels = batch["targets"]
    if cfg.ce_chunk and labels.shape[1] % cfg.ce_chunk == 0:
        # chunked CE (§Perf): run the trunk once, then unembed + CE one
        # sequence chunk at a time under jax.checkpoint so the (B, S, V)
        # logits are never materialized (forward OR backward).
        x, positions = _inputs_to_x(params, cfg, batch)
        win = window if window is not None else cfg.sliding_window
        x, _, aux = _run_trunk(params, x, cfg, mode="train",
                               positions=positions, window=win)
        x = apply_norm(params["final_norm"], x, cfg)
        C = cfg.ce_chunk
        B, S, D = x.shape
        xc = x.reshape(B, S // C, C, D).swapaxes(0, 1)          # (nc,B,C,D)
        lc = labels.reshape(B, S // C, C).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_ce(xck, lck):
            logits = unembed(params["embed"], xck, cfg)
            return _ce_terms(logits, lck)

        def body(carry, xs):
            nll, cnt = carry
            n, c = chunk_ce(*xs)
            return (nll + n, cnt + c), 0.0

        (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     (xc, lc))
        ce = nll / jnp.maximum(cnt, 1.0)
    else:
        logits, aux = forward(params, cfg, batch, window=window)
        nll, cnt = _ce_terms(logits, labels)
        ce = nll / jnp.maximum(cnt, 1.0)
    total = ce + cfg.router_aux_coef * aux
    return total, {"ce": ce, "aux": aux}


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               ring: bool = False) -> Any:
    """Stacked decode cache for all layers (family-dependent structure)."""
    if cfg.family == "ssm":
        st = init_ssm_state(cfg, batch)
        return jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (cfg.n_layers,) + leaf.shape),
            st)
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        attn = init_cache(cfg, batch, max_len, ring)
        attn = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (G,) + leaf.shape), attn)
        st = init_ssm_state(cfg, batch)
        ssm = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(
                leaf, (G, cfg.attn_every) + leaf.shape), st)
        return {"attn": attn, "ssm": ssm}
    c = init_cache(cfg, batch, max_len, ring)
    return jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.n_layers,) + leaf.shape), c)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            window: Optional[int] = None, ring: bool = False):
    """Full-sequence forward that also returns the cache and last logits."""
    x, positions = _inputs_to_x(params, cfg, batch)
    window = window if window is not None else cfg.sliding_window
    x, cache, _ = _run_trunk(params, x, cfg, mode="prefill",
                             positions=positions, window=window, ring=ring)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x[:, -1:], cfg)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: Any,
                token: jnp.ndarray, pos: jnp.ndarray,
                window: Optional[int] = None, ring: bool = False):
    """One decode step.  token (B, 1) int32 (or (B,1,D) embeds); pos scalar."""
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    if token.ndim == 3:
        x = token.astype(cfg.cdtype)
        B = x.shape[0]
    else:
        x = embed(params["embed"], token, cfg)
        B = token.shape[0]
    positions = make_positions(cfg, B, 1, offset=pos)
    window = window if window is not None else cfg.sliding_window
    x, cache, _ = _run_trunk(params, x, cfg, mode="decode",
                             positions=positions, cache=cache, pos=pos,
                             window=window, ring=ring)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)
    return logits, cache
