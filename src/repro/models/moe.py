"""Mixture-of-Experts layer: top-k router with auxiliary load-balance loss,
shared (always-on) experts + routed experts.

Dispatch is dense-einsum based ("no token dropping"): for each token the
top-k expert outputs are computed by gathering expert weights per token is
avoided; instead we compute a (tokens, experts) combine matrix and contract.
For pod-scale meshes the experts (or their hidden dim, when the expert count
does not divide the mesh axis) are sharded over the "model" axis, which turns
the combine contraction into the expert-parallel all-to-all pattern under
GSPMD.  A capacity-bucketed gather dispatch is provided as the optimized
variant (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def moe_params(key, cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_ff
    ks = jax.random.split(key, 7)
    std = D ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (D, E)) * std).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, D, F)) * std).astype(cfg.pdtype),
        "w3": (jax.random.normal(ks[2], (E, D, F)) * std).astype(cfg.pdtype),
        "w2": (jax.random.normal(ks[3], (E, F, D)) * F ** -0.5).astype(cfg.pdtype),
    }
    if cfg.shared_ff:
        Fs = cfg.shared_ff
        p["shared_w1"] = (jax.random.normal(ks[4], (D, Fs)) * std).astype(cfg.pdtype)
        p["shared_w3"] = (jax.random.normal(ks[5], (D, Fs)) * std).astype(cfg.pdtype)
        p["shared_w2"] = (jax.random.normal(ks[6], (Fs, D)) * Fs ** -0.5).astype(cfg.pdtype)
    return p


def router_topk(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """logits (T, E) -> (combine (T, E) with top-k softmax weights, aux loss,
    top-k indices).  Aux loss follows Switch/GShard: E * sum_e f_e * p_e."""
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)    # (T, k, E)
    combine = jnp.einsum("tk,tke->te", top_w, onehot)
    frac_tokens = jnp.mean(jnp.max(onehot, axis=1), axis=0)  # f_e
    mean_prob = jnp.mean(probs, axis=0)                       # p_e
    aux = E * jnp.sum(frac_tokens * mean_prob)
    return combine, aux, top_i


def moe_block(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    dt = cfg.cdtype
    t = x.reshape(B * S, D)
    combine, aux, _ = router_topk(
        jnp.einsum("td,de->te", t.astype(jnp.float32), p["router"]), cfg.top_k)
    combine = combine.astype(dt)  # (T, E)

    # dense dispatch: per-expert activations masked by the combine weights.
    h1 = jnp.einsum("td,edf->tef", t, p["w1"].astype(dt))
    h3 = jnp.einsum("td,edf->tef", t, p["w3"].astype(dt))
    h = jax.nn.silu(h3) * h1
    y = jnp.einsum("tef,efd,te->td", h, p["w2"].astype(dt), combine)

    if cfg.shared_ff:
        hs = jax.nn.silu(jnp.einsum("td,df->tf", t, p["shared_w3"].astype(dt))) * \
             jnp.einsum("td,df->tf", t, p["shared_w1"].astype(dt))
        y = y + jnp.einsum("tf,fd->td", hs, p["shared_w2"].astype(dt))
    return y.reshape(B, S, D), aux


def moe_block_capacity(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                       capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-bucketed dispatch (gather/scatter): each expert processes at
    most C = ceil(T * k / E * cf) tokens.  FLOPs scale with active experts
    instead of all experts -- the beyond-paper optimized MoE path."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = cfg.cdtype
    t = x.reshape(B * S, D)
    T = t.shape[0]
    C = max(1, int(T * k / E * capacity_factor))

    logits = jnp.einsum("td,de->te", t.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # position of each (token, slot) within its expert's bucket
    flat_e = top_i.reshape(-1)                                # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1        # (T*k, E)
    slot = jnp.max(pos_in_e, axis=-1)                          # (T*k,)
    keep = slot < C
    dest = jnp.where(keep, flat_e * C + slot, E * C)           # overflow bin

    buckets = jnp.zeros((E * C + 1, D), dt).at[dest].set(
        jnp.repeat(t, k, axis=0))
    xb = buckets[:E * C].reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p["w3"].astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", xb, p["w1"].astype(dt))
    yb = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt)).reshape(E * C, D)
    yb = jnp.concatenate([yb, jnp.zeros((1, D), dt)], axis=0)
    y_slots = yb[dest] * (top_w.reshape(-1, 1).astype(dt))
    y = jnp.sum(y_slots.reshape(T, k, D), axis=1)

    frac_tokens = jnp.mean(jax.nn.one_hot(top_i, E, dtype=jnp.float32).max(axis=1), axis=0)
    aux = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))

    if cfg.shared_ff:
        hs = jax.nn.silu(jnp.einsum("td,df->tf", t, p["shared_w3"].astype(dt))) * \
             jnp.einsum("td,df->tf", t, p["shared_w1"].astype(dt))
        y = y + jnp.einsum("tf,fd->td", hs, p["shared_w2"].astype(dt))
    return y.reshape(B, S, D), aux
