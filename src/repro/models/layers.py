"""Shared neural building blocks: norms, activations, MLPs, embeddings,
rotary embeddings (standard RoPE, partial rotary, and Qwen2-VL M-RoPE)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig

def act_constraint(x: jnp.ndarray, cfg: ModelConfig,
                   seq_dim: int = 1) -> jnp.ndarray:
    """Pin activation sharding (EXPERIMENTS.md §Perf): batch -> act_dp_axes,
    and optionally sequence -> "model" (megatron-style sequence parallelism
    for the norm/elementwise segments).  No-op unless cfg.shard_activations
    (which requires an ambient mesh, i.e. the dry-run / pod trainer)."""
    if not cfg.shard_activations:
        return x
    from jax.sharding import PartitionSpec as P
    dp = (cfg.act_dp_axes if len(cfg.act_dp_axes) > 1
          else cfg.act_dp_axes[0])
    spec = [None] * x.ndim
    if x.shape[0] > 1:
        spec[0] = dp
    if cfg.seq_shard and x.ndim >= 3 and x.shape[seq_dim] > 1:
        spec[seq_dim] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ----------------------------------------------------------------- norms


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def apply_norm(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_params(cfg: ModelConfig, d: Optional[int] = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), cfg.pdtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.pdtype)
    return p


# ------------------------------------------------------------ activations


def activation(name: str):
    if name == "silu_glu":
        raise ValueError("GLU handled inside mlp()")
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "silu":
        return jax.nn.silu
    raise ValueError(name)


# ------------------------------------------------------------------- MLP


def mlp_params(key, cfg: ModelConfig, d_in: Optional[int] = None,
               d_ff: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    p = {
        "w1": (jax.random.normal(k1, (d, f)) * std).astype(cfg.pdtype),
        "w2": (jax.random.normal(k2, (f, d)) * (f ** -0.5)).astype(cfg.pdtype),
    }
    if cfg.act == "silu_glu":
        p["w3"] = (jax.random.normal(k3, (d, f)) * std).astype(cfg.pdtype)
    if cfg.mlp_bias:
        p["b1"] = jnp.zeros((f,), cfg.pdtype)
        p["b2"] = jnp.zeros((d,), cfg.pdtype)
    return p


def mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.cdtype
    h = jnp.einsum("...d,df->...f", x, p["w1"].astype(dt))
    if cfg.mlp_bias and "b1" in p:
        h = h + p["b1"].astype(dt)
    if cfg.act == "silu_glu":
        g = jnp.einsum("...d,df->...f", x, p["w3"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = activation(cfg.act)(h)
    y = jnp.einsum("...f,fd->...d", h, p["w2"].astype(dt))
    if cfg.mlp_bias and "b2" in p:
        y = y + p["b2"].astype(dt)
    return y


# ------------------------------------------------------------- embeddings


def embed_params(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {}
    # audio (hubert) consumes frame embeddings only; VLMs still need the text
    # token table for decode
    if not cfg.embed_inputs or cfg.family == "vlm":
        p["tok"] = (jax.random.normal(k1, (cfg.vocab, cfg.d_model)) * 0.02).astype(cfg.pdtype)
    if not cfg.tie_embeddings or cfg.embed_inputs:
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab))
                        * cfg.d_model ** -0.5).astype(cfg.pdtype)
    return p


def embed(p: dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return p["tok"].astype(cfg.cdtype)[tokens]


def unembed(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings and "unembed" not in p:
        w = p["tok"].astype(cfg.cdtype).T
    else:
        w = p["unembed"].astype(cfg.cdtype)
    return jnp.einsum("...d,dv->...v", x, w)


# ------------------------------------------------------------------ RoPE


def rope_angles(positions: jnp.ndarray, dim_half: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> cos/sin (..., dim_half) in float32."""
    inv = theta ** (-jnp.arange(0, dim_half, dtype=jnp.float32) / dim_half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads.

    Rotates pairs (x[..., :hd/2], x[..., hd/2:]) -- the 'rotate_half' layout
    used by llama-family checkpoints.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def mrope_angles(positions: jnp.ndarray, sections: Tuple[int, ...],
                 theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL M-RoPE.  positions (3, B, S) for (temporal, h, w); sections
    split head_dim//2.  Returns cos/sin (B, S, head_dim//2): each frequency
    band uses the position stream of its section."""
    dim_half = sum(sections)
    inv = theta ** (-jnp.arange(0, dim_half, dtype=jnp.float32) / dim_half)
    # angles per position stream: (3, B, S, dim_half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    # frequency band i uses the position stream of its section
    parts, off = [], 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., off:off + sec])
        off += sec
    ang_sel = jnp.concatenate(parts, axis=-1)  # (B, S, dim_half)
    return jnp.cos(ang_sel), jnp.sin(ang_sel)


def make_positions(cfg: ModelConfig, batch: int, seq: int,
                   offset: jnp.ndarray | int = 0) -> jnp.ndarray:
    """Default position ids.  For M-RoPE returns (3, B, S) with all three
    streams equal (pure-text behaviour; the VLM frontend stub supplies real
    (t, h, w) grids for image patches via input_specs)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def rope_cos_sin(cfg: ModelConfig, positions: jnp.ndarray,
                 dim_half: Optional[int] = None) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
    if cfg.rope == "none":
        return None
    rot_dim = dim_half or ((cfg.rope_head_dim if cfg.use_mla else cfg.head_dim) // 2)
    if cfg.rope == "mrope":
        return mrope_angles(positions, cfg.mrope_sections, cfg.rope_theta)
    return rope_angles(positions, rot_dim, cfg.rope_theta)
