"""Model substrate: configs, layers, attention, MoE, SSM, transformer."""
from .config import ModelConfig
from .transformer import (decode_step, forward, init_params, loss_fn,
                          make_cache, param_specs, prefill)

__all__ = ["ModelConfig", "decode_step", "forward", "init_params", "loss_fn",
           "make_cache", "param_specs", "prefill"]
