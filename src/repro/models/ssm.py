"""Mamba2 (SSD -- state-space duality, arXiv:2405.21060) block.

The selective state-space recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t
    y_t = C_t^T h_t + D x_t

is computed with the *chunked SSD* algorithm: within chunks of length Q the
quadratic "attention-like" form is used; across chunks the per-chunk final
states are carried by a scan.  This is the TPU-native adaptation: chunk sizes
are chosen so the (Q, Q) intra-chunk matmuls land on the MXU and the
cross-chunk scan is O(S/Q) sequential steps.  A Pallas kernel version of the
intra-chunk compute lives in repro.kernels.ssd_scan.

Layout follows the mamba2 reference: heads of size P = ssm_head_dim,
n_groups B/C groups (we use 1), state size N = ssm_state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rmsnorm


def ssm_params(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    din = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_n_groups
    conv_ch = din + 2 * G * N
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    # in_proj emits [z (din), x (din), B (G*N), C (G*N), dt (H)]
    d_proj = 2 * din + 2 * G * N + H
    p = {
        "in_proj": (jax.random.normal(ks[0], (D, d_proj)) * std).astype(cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) * 0.1).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, H))).astype(jnp.float32),
        "norm": jnp.ones((din,), cfg.pdtype),
        "out_proj": (jax.random.normal(ks[2], (din, D)) * din ** -0.5).astype(cfg.pdtype),
    }
    return p


def _split_proj(zxbcdt: jnp.ndarray, cfg: ModelConfig):
    din, G, N, H = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    x = zxbcdt[..., din:2 * din]
    B = zxbcdt[..., 2 * din:2 * din + G * N]
    C = zxbcdt[..., 2 * din + G * N:2 * din + 2 * G * N]
    dt = zxbcdt[..., 2 * din + 2 * G * N:]
    return z, x, B, C, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  x (B,S,C), w (K,C).  With ``state``
    ((B,K-1,C), decode) prepends it and returns the new state."""
    K = w.shape[0]
    if state is not None:
        xin = jnp.concatenate([state, x], axis=1)
    else:
        xin = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xin[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xin[:, -(K - 1):] if K > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan (pure jnp oracle; the Pallas kernel mirrors this).

    x  (Bt, S, H, P)   inputs per head
    dt (Bt, S, H)      positive step sizes
    A  (H,)            negative decay rates (A = -exp(A_log))
    B  (Bt, S, G, N)   input projections (G groups broadcast over H)
    C  (Bt, S, G, N)   output projections
    h0 optional (Bt, H, P, N) initial state.
    Returns (y (Bt,S,H,P), h_final (Bt,H,P,N)).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0
    rep = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    f32 = jnp.float32
    xq = x.reshape(Bt, nc, Q, H, P).astype(f32)
    dtq = dt.reshape(Bt, nc, Q, H).astype(f32)
    Bq = jnp.repeat(B.reshape(Bt, nc, Q, G, N), rep, axis=3).astype(f32)  # (Bt,nc,Q,H,N)
    Cq = jnp.repeat(C.reshape(Bt, nc, Q, G, N), rep, axis=3).astype(f32)

    dA = dtq * A.astype(f32)                    # (Bt,nc,Q,H) negative
    cums = jnp.cumsum(dA, axis=2)               # within-chunk cumulative
    seg_end = cums[:, :, -1, :]                  # (Bt,nc,H)

    # intra-chunk (quadratic) term: y_intra[t] = sum_{s<=t} C_t.B_s x_s e^{cums_t - cums_s}
    decay = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (Bt,nc,Q,Q,H) t,s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: exp of the (positive) upper triangle overflows and
    # where(tri, inf, 0) poisons the backward pass with inf * 0 = nan
    Lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], decay, -1e30))
    CB = jnp.einsum("bcthn,bcshn->bctsh", Cq, Bq)             # (Bt,nc,Q,Q,H)
    W = CB * Lmat * dtq[:, :, None, :, :]                      # weight on x_s
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", W, xq)

    # chunk-final states: h_c = e^{seg_end} h_{c-1} + sum_s e^{seg_end - cums_s} dt_s B_s x_s^T
    state_in = jnp.einsum(
        "bcsh,bcshn,bcshp->bchpn",
        jnp.exp(seg_end[:, :, None, :] - cums) * dtq, Bq, xq)  # (Bt,nc,H,P,N)

    def scan_chunks(h, inp):
        se, s_in = inp                     # (Bt,H), (Bt,H,P,N)
        h_new = jnp.exp(se)[:, :, None, None] * h + s_in
        return h_new, h                    # emit state *entering* the chunk

    h_init = jnp.zeros((Bt, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_fin, h_enter = jax.lax.scan(
        scan_chunks,
        h_init,
        (jnp.moveaxis(seg_end, 1, 0), jnp.moveaxis(state_in, 1, 0)))
    h_enter = jnp.moveaxis(h_enter, 0, 1)   # (Bt,nc,H,P,N)

    # inter-chunk term: y_inter[t] = C_t e^{cums_t} h_enter
    y_inter = jnp.einsum("bcthn,bchpn->bcthp", Cq * jnp.exp(cums)[..., None], h_enter)

    y = (y_intra + y_inter).reshape(Bt, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), h_fin


def ssd_recurrent_step(xt, dtt, A, Bt_, Ct, h):
    """One decode step.  xt (B,H,P), dtt (B,H), Bt_/Ct (B,G,N), h (B,H,P,N)."""
    G = Bt_.shape[1]
    H = xt.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bt_, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Ct, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dtt.astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    h_new = dA[..., None, None] * h + jnp.einsum(
        "bh,bhn,bhp->bhpn", dtt.astype(jnp.float32), Bh, xt.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h_new)
    return y.astype(xt.dtype), h_new


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state), cfg.cdtype),
    }


def mamba2_block(p: dict, xres: jnp.ndarray, cfg: ModelConfig, mode: str,
                 state: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full Mamba2 mixer.  xres (B, S, D) -> (y (B, S, D), new_state)."""
    Bt, S, D = xres.shape
    dt_ = cfg.cdtype
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_n_groups
    zxbcdt = jnp.einsum("bsd,dp->bsp", xres, p["in_proj"].astype(dt_))
    z, xc, Bv, Cv, dt_raw = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xc, Bv, Cv], axis=-1)
    if mode == "decode":
        conv_out, conv_state = _causal_conv(conv_in, p["conv_w"].astype(dt_),
                                            p["conv_b"].astype(dt_), state["conv"])
    else:
        conv_out, conv_state = _causal_conv(conv_in, p["conv_w"].astype(dt_),
                                            p["conv_b"].astype(dt_))
    din = cfg.d_inner
    xc = conv_out[..., :din]
    Bv = conv_out[..., din:din + G * N].reshape(Bt, S, G, N)
    Cv = conv_out[..., din + G * N:].reshape(Bt, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)
    xh = xc.reshape(Bt, S, H, P)

    if mode == "decode":
        y1, h_new = ssd_recurrent_step(xh[:, 0], dt[:, 0], A, Bv[:, 0], Cv[:, 0],
                                       state["h"])
        y = y1[:, None]
        new_state = {"h": h_new, "conv": conv_state}
    else:
        h0 = state["h"] if state is not None else None
        y, h_fin = ssd_chunked(xh, dt, A, Bv, Cv, cfg.ssm_chunk, h0=h0)
        new_state = {"h": h_fin, "conv": conv_state} if mode == "prefill" else None

    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(Bt, S, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsp,pd->bsd", y, p["out_proj"].astype(dt_))
    return out, new_state
