"""Mesh construction, topology keys, and data-parallel gradients.

This is the ONE mesh module: it owns

* the sweep meshes -- :func:`cell_mesh` (1-D ``("cells",)``) and
  :func:`grid_mesh` (2-D ``("cells", "data")``) used by the sharded sweep
  backend in :mod:`repro.sweep.shard`;
* :func:`mesh_topology`, the hashable token that stands in for a ``Mesh``
  inside program-cache keys (axis names + shape + device kind + process
  count -- NOT object identity, so 1-D/2-D/multi-host variants never share
  an executable while same-topology meshes deliberately do);
* :func:`pmean_grad`, the psum-backed gradient transform that makes a
  per-worker gradient data-parallel across the ``"data"`` mesh axis;
* :func:`maybe_init_distributed`, the ``jax.distributed`` bootstrap behind
  ``ExecutionSpec``'s multi-host knobs;
* the production mesh builders and the parameter/batch/cache sharding
  planner (absorbed from the seed-state ``launch/mesh.py`` and
  ``launch/sharding.py``, which now re-export from here).

Everything is a FUNCTION so importing this module never touches jax device
state (device count is locked at first jax init; dry-runs set XLA_FLAGS
before importing anything else).

psum-axis contract
------------------
``pmean_grad(loss, axis, size)`` is exact (up to one float rounding of the
final ``/ size``) for losses of the form

    mean over a leading sample axis of per-sample terms  +  x-only terms,

which covers both built-in problem classes (``LogRegProblem.worker_loss``
and ``LassoProblem.worker_loss``).  Each shard takes the mean over its
``S / size`` local samples; ``psum / size`` reconstructs the global mean,
and the x-only regulariser -- identical on every shard -- is returned
unchanged (bitwise for power-of-two ``size``).  The sample count ``S`` must
divide by ``size``; anything else raises loudly at trace time rather than
silently dropping samples.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

CELL_AXIS = "cells"
DATA_AXIS = "data"


# ---------------------------------------------------------------------------
# sweep meshes
# ---------------------------------------------------------------------------

def cell_mesh(devices: Optional[Sequence[Any]] = None) -> Mesh:
    """1-D mesh over ``devices`` (default: all local) with axis "cells"."""
    devs = np.array(devices if devices is not None else jax.devices())
    return Mesh(devs, (CELL_AXIS,))


def grid_mesh(mesh_shape: Tuple[int, ...],
              devices: Optional[Sequence[Any]] = None) -> Mesh:
    """Mesh with axes ``("cells",)`` or ``("cells", "data")``.

    ``mesh_shape`` is ``(cells,)`` or ``(cells, data)``.  Uses the first
    ``prod(mesh_shape)`` of ``devices`` (default ``jax.devices()``, which
    spans all processes in a multi-host run); raises if fewer are
    available -- a silent fallback would quietly serialize the data axis.
    """
    shape = tuple(int(s) for s in mesh_shape)
    if not 1 <= len(shape) <= 2 or any(s < 1 for s in shape):
        raise ValueError(
            f"mesh_shape must be (cells,) or (cells, data) with positive "
            f"entries, got {mesh_shape!r}")
    need = int(np.prod(shape))
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < need:
        raise ValueError(
            f"mesh_shape {shape} needs {need} devices, "
            f"only {len(devs)} available")
    axes = (CELL_AXIS,) if len(shape) == 1 else (CELL_AXIS, DATA_AXIS)
    return Mesh(np.array(devs[:need]).reshape(shape), axes)


def cell_axis_size(mesh: Mesh) -> int:
    """Size of the "cells" axis (the grid-partition axis)."""
    if CELL_AXIS not in mesh.axis_names:
        raise ValueError(
            f"sweep meshes need a {CELL_AXIS!r} axis; got axes "
            f"{tuple(mesh.axis_names)} -- build one with cell_mesh() or "
            f"grid_mesh()")
    return int(mesh.shape[CELL_AXIS])


def data_axis_size(mesh: Mesh) -> int:
    """Size of the "data" axis; 1 when the mesh has no data axis."""
    return int(mesh.shape.get(DATA_AXIS, 1)) if DATA_AXIS in mesh.axis_names \
        else 1


def mesh_topology(mesh: Mesh) -> Tuple[Any, ...]:
    """Hashable cache-key token for a mesh: its topology, not its identity.

    ``(tag, axis names, shape, device kind, process count)``.  Two meshes
    over the same device kind with the same axes/shape share executables
    (cells are placement-agnostic); reshaping the same devices from (8,) to
    (4, 2) keys fresh because shape and axis names differ.
    """
    dev = mesh.devices.ravel()[0]
    kind = str(getattr(dev, "device_kind", None) or
               getattr(dev, "platform", "unknown"))
    return ("mesh", tuple(mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape),
            kind, int(jax.process_count()))


# ---------------------------------------------------------------------------
# data-parallel gradients
# ---------------------------------------------------------------------------

def pmean_grad(loss_fn: Callable, axis: str = DATA_AXIS,
               size: int = 1) -> Callable:
    """Data-parallel ``jax.grad(loss_fn)`` for use inside shard_map.

    Returns ``grad_fn(x, *data)`` that slices each data leaf's leading
    sample axis by this shard's ``axis_index``, differentiates the loss on
    the local slice, and ``psum / size``s the result back to the full
    gradient.  Data stays replicated (captured) -- only gradient COMPUTE is
    partitioned, so outputs remain identical on every data shard and the
    cell-axis out_specs need no change.

    See the module docstring for the exactness contract (sample-mean +
    x-only losses; ``S % size == 0`` enforced at trace time).
    """
    grad = jax.grad(loss_fn)
    if size <= 1:
        return grad

    def grad_fn(x, *data):
        i = jax.lax.axis_index(axis)

        def shard(leaf):
            s = int(leaf.shape[0])
            if s % size:
                raise ValueError(
                    f"pmean_grad: leading sample axis ({s}) must divide by "
                    f"the {axis!r} mesh axis size ({size}); pad the worker "
                    f"slices or pick a mesh_shape whose data axis divides "
                    f"the per-worker sample count")
            loc = s // size
            return jax.lax.dynamic_slice_in_dim(leaf, i * loc, loc, axis=0)

        g = grad(x, *[shard(leaf) for leaf in data])
        return jax.tree_util.tree_map(
            lambda leaf: jax.lax.psum(leaf, axis) / size, g)

    return grad_fn


# ---------------------------------------------------------------------------
# multi-host bootstrap
# ---------------------------------------------------------------------------

_DISTRIBUTED_INITIALIZED = False


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int) -> None:
    """``jax.distributed.initialize`` wrapper (idempotent per process)."""
    global _DISTRIBUTED_INITIALIZED
    if _DISTRIBUTED_INITIALIZED:
        return
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=int(num_processes),
        process_id=int(process_id))
    _DISTRIBUTED_INITIALIZED = True


def maybe_init_distributed(execution) -> bool:
    """Bootstrap jax.distributed from an ``ExecutionSpec``-like object.

    No-op (returns False) unless ``execution.coordinator`` is set.  The
    knobs never reach a traced program -- their only cache-key footprint is
    the process count inside :func:`mesh_topology`.
    """
    coordinator = getattr(execution, "coordinator", None)
    if not coordinator:
        return False
    init_distributed(coordinator,
                     getattr(execution, "num_processes", 1),
                     getattr(execution, "process_id", 0))
    return True


# ---------------------------------------------------------------------------
# production meshes (absorbed from launch/mesh.py)
# ---------------------------------------------------------------------------

def make_production_mesh(*, multi_pod: bool = False):
    """Single pod 16x16 ("data","model"); multi-pod 2x16x16 adds "pod"."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (used by reduced-size tests, e.g. (2, 4))."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


# ---------------------------------------------------------------------------
# sharding planner (absorbed from launch/sharding.py)
#
# Rules (divisibility-checked -- any dim not divisible by its axis size is
# left replicated rather than unevenly sharded):
#
# * parameters: the largest divisible feature dim goes to "model" (ties
#   break toward the *later* dim, i.e. column-parallel for up-projections
#   and row-parallel for down-projections); a second divisible dim goes to
#   the data axes (FSDP/ZeRO-3) so the 236B config fits 16 GB/chip.  The
#   leading stacked-layers axis is never sharded (it is scanned over).
# * MoE expert tensors: the expert dim goes to "model" when divisible
#   (expert parallelism, e.g. deepseek's 160 experts on 16-way model axis);
#   otherwise falls back to the feature rule (qwen2-moe's 60 experts).
# * batches: the global-batch dim is sharded over ("pod","data");
#   everything else replicated.  long_500k (batch=1) shards the cache
#   sequence dim over the data axes instead (context parallelism).
# * optimizer state: same rule as its parameter (identical shapes).
# ---------------------------------------------------------------------------

def _key_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(f"#{k.idx}")
    return tuple(names)


def _param_spec(names: Tuple[str, ...], shape: Tuple[int, ...], mesh,
                fsdp: bool = True, small_out_threshold: int = 0) -> P:
    md = model_size(mesh)
    dps = dp_axes(mesh)
    dsz = dp_size(mesh)
    ndim = len(shape)
    spec: list = [None] * ndim

    # leading stacked-layers axis (params under "layers"/"shared" groups are
    # stacked (L, ...) or (G, ...)): never sharded
    start = 1 if ("layers" in names and ndim >= 2) else 0
    cand = list(range(start, ndim))

    # expert parallelism: 4-D (L, E, D, F) expert tensors
    model_dim: Optional[int] = None
    if any("w" in n for n in names) and "moe" in names and ndim >= 4:
        e_dim = start
        if shape[e_dim] % md == 0:
            model_dim = e_dim
    if model_dim is None:
        best = -1
        for i in cand:
            if md > 1 and shape[i] % md == 0 and shape[i] >= md:
                if shape[i] >= best:
                    best = shape[i]
                    model_dim = i
    # §Perf H2: row-parallel sharding of a projection with a SMALL output
    # (e.g. MLA's w_dkv: 5120 -> 576) forces a per-token all-reduce of the
    # partial sums that dwarfs the weight itself -- replicate over "model"
    # (FSDP still shards it over data) instead.
    if (small_out_threshold and model_dim is not None and ndim >= 2 and
            model_dim == ndim - 2 and shape[-1] <= small_out_threshold):
        model_dim = None
    if model_dim is not None and md > 1:
        spec[model_dim] = "model"

    if fsdp and dps:
        best = -1
        fsdp_dim = None
        for i in cand:
            if i == model_dim:
                continue
            if shape[i] % dsz == 0 and shape[i] >= dsz:
                if shape[i] > best:
                    best = shape[i]
                    fsdp_dim = i
        if fsdp_dim is not None:
            spec[fsdp_dim] = dps if len(dps) > 1 else dps[0]
    return P(*spec)


def param_shardings(tree: Any, mesh, fsdp: bool = True,
                    small_out_threshold: int = 0):
    """NamedShardings for a parameter-shaped pytree (params or opt state)."""
    def one(path, leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _param_spec(
            _key_names(path), shape, mesh, fsdp=fsdp,
            small_out_threshold=small_out_threshold))
    return jax.tree_util.tree_map_with_path(one, tree)


def batch_shardings(tree: Any, mesh, global_batch: int):
    """Shard the global-batch dim over ("pod","data")."""
    dps = dp_axes(mesh)
    dsz = dp_size(mesh)
    dp = dps if len(dps) > 1 else (dps[0] if dps else None)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        if global_batch % max(dsz, 1) == 0 and dsz > 1:
            for i, s in enumerate(shape):
                if s == global_batch:
                    spec[i] = dp
                    break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def cache_shardings(tree: Any, mesh, global_batch: int, seq_len: int,
                    context_parallel: bool = False):
    """Decode-cache sharding.

    Baseline: batch dim -> data axes; a KV/feature dim -> "model" when
    divisible; batch=1 -> cache sequence dim -> data axes.

    ``context_parallel=True`` (§Perf H3): the cache *sequence* dim is
    sharded over "model" instead of the feature dim, so the per-token
    attention gathers only O(B*H*S) f32 score statistics instead of the
    whole O(B*S*r) latent / O(B*S*KV*hd) KV cache every step."""
    dps = dp_axes(mesh)
    dsz = dp_size(mesh)
    md = model_size(mesh)
    dp = dps if len(dps) > 1 else (dps[0] if dps else None)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        spec: list = [None] * ndim
        if ndim <= 1:
            return NamedSharding(mesh, P(*spec))
        dp_dim = None
        if dsz > 1 and global_batch % dsz == 0 and global_batch > 1:
            for i in range(1, ndim):
                if shape[i] == global_batch:
                    dp_dim = i
                    spec[i] = dp
                    break
        elif dsz > 1:
            # batch too small: context-parallel the sequence dim over data
            for i in range(1, ndim):
                if shape[i] == seq_len and seq_len % dsz == 0:
                    dp_dim = i
                    spec[i] = dp
                    break
        if md > 1:
            mdim = None
            if context_parallel:
                for i in range(1, ndim):
                    if i != dp_dim and shape[i] == seq_len and \
                            seq_len % md == 0:
                        mdim = i
                        break
            if mdim is None and not context_parallel:
                best = -1
                for i in range(1, ndim):
                    if i == dp_dim or shape[i] == seq_len:
                        continue
                    if shape[i] % md == 0 and shape[i] >= md and shape[i] > best:
                        best = shape[i]
                        mdim = i
            if mdim is not None:
                spec[mdim] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def replicated(tree: Any, mesh):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def describe_shardings(tree, shardings, max_rows: int = 0):
    """Human-readable (path, shape, spec) table for DESIGN/EXPERIMENTS."""
    rows = []
    flat_t = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    for (path, leaf), sh in zip(flat_t, flat_s):
        rows.append(("/".join(_key_names(path)), tuple(leaf.shape),
                     str(sh.spec)))
    if max_rows:
        rows = rows[:max_rows]
    return rows
