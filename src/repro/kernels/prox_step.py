"""Fused proximal update kernel: x <- prox_{gamma R}(x - gamma g).

This is the paper's inner loop (Eq. 4 / Eq. 5).  Unfused, XLA emits
subtract -> scale -> sign/abs/max (4+ HBM round trips for a memory-bound op);
the kernel does one read of (x, g) and one write of x' per element.

TPU mapping: the flattened parameter vector is viewed as (rows, 1024) with
rows tiled in blocks of 8 sublanes x 128 lanes (the VPU-native tile);
``gamma`` (the *delay-adaptive* step-size, a per-event scalar) rides in SMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

LANES = 1024          # columns of the 2-D view (8 x 128 native tiles)
BLOCK_ROWS = 256      # rows per grid step -> 1 MiB f32 per operand block


def _kernel(gamma_ref, x_ref, g_ref, o_ref, *, kind: str, lam: float):
    gamma = gamma_ref[0, 0]
    y = x_ref[...] - gamma * g_ref[...]
    if kind == "none":
        pass
    elif kind == "l1":
        t = gamma * lam
        y = jnp.sign(y) * jnp.maximum(jnp.abs(y) - t, 0.0)
    elif kind == "l2":
        y = y / (1.0 + gamma * lam)
    elif kind == "box":
        y = jnp.clip(y, -lam, lam)
    else:
        raise ValueError(kind)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "lam", "interpret"))
def prox_step(x: jnp.ndarray, g: jnp.ndarray, gamma: jnp.ndarray,
              kind: str = "l1", lam: float = 1e-4,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused prox-gradient update on an arbitrary-shaped array."""
    if interpret is None:
        interpret = default_interpret()
    shape, dtype = x.shape, x.dtype
    n = x.size
    cols = LANES if n >= LANES else 128
    rows = -(-n // cols)
    rows_pad = -(-rows // BLOCK_ROWS) * BLOCK_ROWS if rows > BLOCK_ROWS else rows
    pad = rows_pad * cols - n
    x2 = jnp.pad(x.reshape(-1), (0, pad)).reshape(rows_pad, cols)
    g2 = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows_pad, cols)
    br = min(BLOCK_ROWS, rows_pad)
    grid = (rows_pad // br,)
    out = pl.pallas_call(
        functools.partial(_kernel, kind=kind, lam=lam),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # gamma scalar
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, cols), dtype),
        interpret=interpret,
    )(jnp.asarray(gamma, jnp.float32).reshape(1, 1), x2, g2)
    return out.reshape(-1)[:n].reshape(shape)


def prox_step_tree(params, grads, gamma, kind: str = "l1", lam: float = 1e-4):
    """Apply the fused update leafwise over a pytree."""
    return jax.tree_util.tree_map(
        lambda p, g: prox_step(p, g, gamma, kind=kind, lam=lam), params, grads)
