"""Fused RMSNorm Pallas kernel.

Unfused, XLA emits square -> mean -> rsqrt -> mul -> mul with the (rows, D)
activation crossing HBM multiple times; the kernel computes the row
statistics and the scaled output in one VMEM-resident pass.  Rows are tiled
in blocks of ``BLOCK_ROWS``; D stays whole per block (norm axis must be
resident), which holds for every assigned config (D <= 8192 -> <= 8 MiB f32
per 256-row block operand).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret

BLOCK_ROWS = 256


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (br, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """x (..., D), scale (D,) -> RMSNorm(x) * scale, fused single pass."""
    if interpret is None:
        interpret = default_interpret()
    shape = x.shape
    D = shape[-1]
    rows = x.size // D
    x2 = x.reshape(rows, D)
    br = min(BLOCK_ROWS, rows)
    nr = -(-rows // br)
    pad = nr * br - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(shape)
