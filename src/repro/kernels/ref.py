"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def prox_step_ref(x: jnp.ndarray, g: jnp.ndarray, gamma: jnp.ndarray,
                  kind: str = "l1", lam: float = 1e-4) -> jnp.ndarray:
    """x <- prox_{gamma R}(x - gamma g), elementwise closed forms."""
    y = x - gamma * g
    if kind == "none":
        return y
    if kind == "l1":
        t = gamma * lam
        return jnp.sign(y) * jnp.maximum(jnp.abs(y) - t, 0.0)
    if kind == "l2":
        return y / (1.0 + gamma * lam)
    if kind == "box":
        return jnp.clip(y, -lam, lam)
    raise ValueError(kind)


def flash_attention_ref(q, k, v, qpos, kpos, *, causal: bool,
                        window: Optional[int], scale: float) -> jnp.ndarray:
    """q (BH, Sq, d), k/v (BH, Sk, d), qpos (Sq,), kpos (Sk,) -> (BH, Sq, d).

    Invalid positions are -1; fully-masked query rows return zeros (matching
    the kernel's l == 0 convention)."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = kpos[None, :] >= 0
    if causal:
        valid &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        valid &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(valid[None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)
    return out.astype(v.dtype)


def ssd_intra_ref(x, dt, dA, B, C):
    """Intra-chunk SSD (one chunk).  x (Q,P), dt/dA (Q,), B/C (Q,N) ->
    (y (Q,P), state (N,P)).  All float32."""
    Q = x.shape[0]
    cums = jnp.cumsum(dA)
    decay = cums[:, None] - cums[None, :]
    L = jnp.exp(jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), decay, -1e30))
    W = (C @ B.T) * L * dt[None, :]
    y = W @ x
    w2 = jnp.exp(cums[-1] - cums) * dt
    state = (B * w2[:, None]).T @ x
    return y, state


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """Oracle for kernels.rmsnorm (matches models.layers.rmsnorm)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) *
            scale.astype(jnp.float32)).astype(x.dtype)
