"""Pallas kernels (compiled on tpu/gpu, interpreted on cpu -- see dispatch).

Each standalone kernel has a pure-jnp oracle in ref.py and a jit'd wrapper
in ops.py:
  prox_step        -- fused delay-adaptive prox-gradient update (paper Eq. 4)
  flash_attention  -- blocked online-softmax attention, GQA-native
  ssd_scan         -- Mamba2 SSD intra-chunk compute
  rmsnorm          -- fused single-pass RMSNorm

fused_step holds the sweep engine's fused per-event kernels (policy
window-sum/select/push + prox or server merge in one pallas_call); the
solver scan cores dispatch to them under ``engine='fused'``.
"""
from . import ops, ref
from .dispatch import default_interpret, resolve_interpret
from .fused_step import (fused_policy_buff_step, fused_policy_mix_step,
                         fused_policy_prox_step)
from .ops import (flash_attention, prox_step, prox_step_tree,
                  rmsnorm_fused, ssd_scan_pallas)

__all__ = ["ops", "ref", "flash_attention", "prox_step", "prox_step_tree",
           "rmsnorm_fused", "ssd_scan_pallas", "default_interpret",
           "resolve_interpret", "fused_policy_prox_step",
           "fused_policy_mix_step", "fused_policy_buff_step"]
