"""Pallas TPU kernels (validated with interpret=True on CPU).

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py:
  prox_step        -- fused delay-adaptive prox-gradient update (paper Eq. 4)
  flash_attention  -- blocked online-softmax attention, GQA-native
  ssd_scan         -- Mamba2 SSD intra-chunk compute
  rmsnorm          -- fused single-pass RMSNorm
"""
from . import ops, ref
from .ops import (flash_attention, prox_step, prox_step_tree,
                  rmsnorm_fused, ssd_scan_pallas)

__all__ = ["ops", "ref", "flash_attention", "prox_step", "prox_step_tree",
           "rmsnorm_fused", "ssd_scan_pallas"]
