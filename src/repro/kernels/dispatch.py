"""Backend-aware interpret-mode dispatch for the Pallas kernels.

Every Pallas entry point in this package takes ``interpret=None`` and
resolves the default here.  The old default -- ``interpret = backend !=
"tpu"`` -- sent GPU runs through the slow Pallas interpreter even though
jax lowers Pallas kernels to Triton on GPU; the kernels only ever
*compiled* on TPU.  The corrected rule:

* ``tpu`` / ``gpu``  -> compile (Mosaic / Triton lowering);
* anything else (cpu) -> interpret (jax has no CPU Pallas lowering, but
  interpret mode runs the kernel body as regular jax ops, bitwise-equal
  to the compiled program's arithmetic).

``REPRO_PALLAS_INTERPRET`` is the escape hatch: set it to ``1``/``true``
to force interpret mode everywhere (debugging a kernel on an
accelerator) or ``0``/``false`` to force compilation (surfacing a
lowering error on an unsupported backend instead of silently
interpreting).

The env var is resolved ONCE per process, at the first
:func:`default_interpret` call (i.e. the first kernel trace): every
program cached downstream -- jit trace caches, ``sweep.cache`` entries --
baked that value in as a static argument, so flipping the variable
mid-process would silently apply to *new* traces only while cached
executables kept the old mode.  ``default_interpret`` therefore records
the tri-state it first resolved (forced-on / forced-off / unset) and
raises ``RuntimeError`` if a later call sees the env var changed; set it
before the first kernel runs, or restart the process.
"""
from __future__ import annotations

import os

import jax

__all__ = ["default_interpret", "resolve_interpret"]

_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# backends with a real Pallas lowering: Mosaic (tpu) and Triton (gpu)
COMPILED_BACKENDS = ("tpu", "gpu")

# 1-tuple holding the env tri-state (True/False forced, None unset) seen at
# the first default resolve; None while unarmed.  A tuple so that an armed
# "env unset" state is distinguishable from "never resolved".
_FIRST_RESOLVED: "tuple | None" = None


def _env_state() -> "bool | None":
    """Parse ``REPRO_PALLAS_INTERPRET`` to its tri-state: ``True``/``False``
    when forced, ``None`` when unset/empty; ``ValueError`` on junk."""
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    if env:
        raise ValueError(
            f"{_ENV_VAR}={env!r} not understood; use one of "
            f"{_TRUTHY + _FALSY}")
    return None


def _reset_env_guard() -> None:
    """Forget the recorded first resolution (tests only -- a real process
    must never re-arm, that is exactly the staleness the guard exists
    to surface)."""
    global _FIRST_RESOLVED
    _FIRST_RESOLVED = None


def default_interpret() -> bool:
    """Resolve the interpret-mode default for the current backend.

    Honors the ``REPRO_PALLAS_INTERPRET`` environment variable first;
    otherwise interprets only where no Pallas lowering exists (cpu).
    Raises ``RuntimeError`` if the env var's effective value changed since
    the first resolution in this process (see module docstring): cached
    programs already baked the first value in, so honoring the new one
    would be silently inconsistent.
    """
    global _FIRST_RESOLVED
    state = _env_state()  # parse errors win over the staleness guard
    if _FIRST_RESOLVED is None:
        _FIRST_RESOLVED = (state,)
    elif _FIRST_RESOLVED[0] is not state:
        first = _FIRST_RESOLVED[0]

        def _show(s):
            return "unset" if s is None else f"forced {'on' if s else 'off'}"

        raise RuntimeError(
            f"{_ENV_VAR} changed mid-process: first kernel trace resolved "
            f"it as {_show(first)}, now it is {_show(state)}.  Programs "
            "cached since then baked the first value in (jit trace caches, "
            "sweep.cache executables), so the change cannot take effect "
            "consistently.  Set the variable before the first kernel runs, "
            "or restart the process.")
    if state is not None:
        return state
    return jax.default_backend() not in COMPILED_BACKENDS


def resolve_interpret(interpret: "bool | None") -> bool:
    """``interpret`` if explicitly given, else :func:`default_interpret`."""
    return default_interpret() if interpret is None else bool(interpret)
