"""Backend-aware interpret-mode dispatch for the Pallas kernels.

Every Pallas entry point in this package takes ``interpret=None`` and
resolves the default here.  The old default -- ``interpret = backend !=
"tpu"`` -- sent GPU runs through the slow Pallas interpreter even though
jax lowers Pallas kernels to Triton on GPU; the kernels only ever
*compiled* on TPU.  The corrected rule:

* ``tpu`` / ``gpu``  -> compile (Mosaic / Triton lowering);
* anything else (cpu) -> interpret (jax has no CPU Pallas lowering, but
  interpret mode runs the kernel body as regular jax ops, bitwise-equal
  to the compiled program's arithmetic).

``REPRO_PALLAS_INTERPRET`` is the escape hatch: set it to ``1``/``true``
to force interpret mode everywhere (debugging a kernel on an
accelerator) or ``0``/``false`` to force compilation (surfacing a
lowering error on an unsupported backend instead of silently
interpreting).
"""
from __future__ import annotations

import os

import jax

__all__ = ["default_interpret", "resolve_interpret"]

_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# backends with a real Pallas lowering: Mosaic (tpu) and Triton (gpu)
COMPILED_BACKENDS = ("tpu", "gpu")


def default_interpret() -> bool:
    """Resolve the interpret-mode default for the current backend.

    Honors the ``REPRO_PALLAS_INTERPRET`` environment variable first;
    otherwise interprets only where no Pallas lowering exists (cpu).
    """
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    if env in _TRUTHY:
        return True
    if env in _FALSY:
        return False
    if env:
        raise ValueError(
            f"{_ENV_VAR}={env!r} not understood; use one of "
            f"{_TRUTHY + _FALSY}")
    return jax.default_backend() not in COMPILED_BACKENDS


def resolve_interpret(interpret: "bool | None") -> bool:
    """``interpret`` if explicitly given, else :func:`default_interpret`."""
    return default_interpret() if interpret is None else bool(interpret)
