"""Fused per-event Pallas kernels for the sweep inner loop.

One solver event in the scan cores is four separate XLA ops chained
through the carry: the ``window_sum`` gather from the circular
cumulative-sum buffer, the policy ``lax.switch`` dispatch, the ``_push``
scatter back into the buffer, and the prox/mix/merge update of the
iterate.  Batched over cells that becomes a per-step
``take_along_axis`` / ``put_along_axis`` round trip on the (cells, H)
carry block.  The kernels here fuse all four into ONE ``pallas_call``
per event, so the carry block is read and written exactly once.

Bitwise contract (the repo's standing rule, pinned in
``tests/test_fused_engine.py``): each kernel reconstructs a
``StepsizeState`` from its refs and calls the REAL
``core.stepsize.window_sum`` / ``core.stepsize._push`` on it, and the
prox / server-merge arithmetic is written with the identical expression
the scan cores use -- the fused path is the same dataflow graph, just
launched as a single kernel.  The only structural difference is policy
dispatch: ``lax.switch`` does not lower inside a compiled Pallas body,
so :func:`select_gamma` replicates the six ``ParamPolicy`` branches as a
branch-free ``where`` chain.  Every branch is the exact expression from
``repro.sweep.policies.ParamPolicy.step``; selecting a value computed by
identical ops keeps the result bitwise-equal to the switch.

Carry layout contract (durable -- see ROADMAP): the step-size state
crosses the kernel boundary as four refs ``(k (1,) i32, total (1,) f32,
cumbuf (H,) f32, clipped (1,) i32)`` and the iterate/gradient blocks are
whole-array refs.  Scalars travel as shape-``(1,)`` arrays because Pallas
refs are arrays; ``vmap`` over cells maps each kernel argument on its
leading axis (the Pallas batching rule turns the batch into a grid
axis), which is what lets the batched and sharded runners reuse these
kernels unchanged.

Interpret-vs-compile dispatch follows ``kernels.dispatch``: compiled on
tpu/gpu, interpreted on cpu (where the kernel body runs as plain jax
ops -- still one fused dataflow block, and still bitwise-equal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.stepsize import StepsizeState, _push, window_sum

from .dispatch import resolve_interpret

__all__ = ["select_gamma", "as_policy_params", "fused_leaf",
           "fused_policy_prox_step", "fused_policy_mix_step",
           "fused_policy_buff_step", "boundary_bytes"]


def boundary_bytes(horizon: int, n: int) -> int:
    """Per-event HBM traffic contract of ``fused_policy_prox_step`` on a
    COMPILED backend: the bytes crossing the kernel boundary (operands +
    results).  Refs stream through on-chip memory inside the kernel, so
    nothing between the policy update and the prox write touches HBM --
    this is the quantity the roofline tooling compares against the scan
    engine's per-event HLO bytes.  Interpret mode (CPU) does not honor the
    contract: ref reads materialize whole arrays as ordinary XLA ops.
    All elements are 4-byte (f32/i32)."""
    state = 4 * (1 + 1 + horizon + 1)      # k, total, cumbuf (H,), clipped
    inputs = 4 * 4 + 4 + state + 2 * 4 * n  # params, tau, state, x, g
    outputs = 4 + state + 4 * n             # gamma, new state, x_new
    return inputs + outputs


def select_gamma(policy_id, gamma_prime, c0, c1, ws, tau):
    """Branch-free twin of ``ParamPolicy.step``'s ``lax.switch``.

    Arguments are the four ``PolicyParams`` scalars plus the window sum
    and the (int) delay; each candidate below is the verbatim branch
    expression from ``repro.sweep.policies`` (ids: 0 fixed_like, 1 naive,
    2 adaptive1, 3 adaptive2, 4 hinge, 5 poly).
    """
    t = jnp.asarray(tau, jnp.float32)
    g_fixed = jnp.broadcast_to(c0, ws.shape)
    g_naive = gamma_prime / (t + c0)
    g_ad1 = c0 * jnp.maximum(gamma_prime - ws, 0.0)
    g_ad2 = jnp.where(gamma_prime / (t + 1.0) <= gamma_prime - ws,
                      gamma_prime / (t + 1.0), 0.0)
    g_hinge = gamma_prime * jnp.where(
        t <= c1, 1.0, 1.0 / (c0 * jnp.maximum(t - c1, 0.0) + 1.0))
    g_poly = gamma_prime * jnp.power(t + 1.0, -c0)
    gamma = jnp.where(
        policy_id == 0, g_fixed, jnp.where(
            policy_id == 1, g_naive, jnp.where(
                policy_id == 2, g_ad1, jnp.where(
                    policy_id == 3, g_ad2, jnp.where(
                        policy_id == 4, g_hinge, g_poly)))))
    return jnp.asarray(gamma, jnp.float32)


def as_policy_params(policy):
    """``PolicyParams`` for any policy the fused engine can run.

    ``ParamPolicy`` adapters hand over their traced params; concrete
    ``StepsizePolicy`` dataclasses flatten through ``policy_params``,
    which raises a loud ``TypeError`` for stateful policies
    (``AdaptiveLipschitz``) that the fused kernel cannot express --
    callers fall back to ``engine='scan'`` for those.
    """
    # imported lazily: core modules import this module, and sweep.policies
    # imports core.stepsize -- a module-level import here would cycle
    from repro.sweep.policies import ParamPolicy, policy_params
    if isinstance(policy, ParamPolicy):
        return policy.params
    return policy_params(policy)


def fused_leaf(tree, what: str):
    """The single 1-D leaf the fused kernels operate on, plus its treedef.

    The fused engine moves the iterate through the kernel as one
    whole-array ref, so multi-leaf or multi-dimensional pytrees are
    rejected loudly rather than silently flattened.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if len(leaves) != 1 or leaves[0].ndim != 1:
        raise ValueError(
            f"engine='fused' requires the {what} to be a single 1-D array "
            f"leaf; got {len(leaves)} leaves with shapes "
            f"{[l.shape for l in leaves]} -- use engine='scan'")
    return leaves[0], treedef


def _scalar_i32(v):
    return jnp.asarray(v, jnp.int32).reshape(1)


def _scalar_f32(v):
    return jnp.asarray(v, jnp.float32).reshape(1)


def _read_state(k_ref, total_ref, cumbuf_ref, clip_ref):
    return StepsizeState(k=k_ref[0], total=total_ref[0],
                         cumbuf=cumbuf_ref[...], clipped=clip_ref[0])


def _policy_update(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                   k_ref, total_ref, cumbuf_ref, clip_ref):
    """Shared kernel-body prologue: window-sum gather, policy select,
    cumulative-sum push -- on the real ``core.stepsize`` functions."""
    state = _read_state(k_ref, total_ref, cumbuf_ref, clip_ref)
    tau = tau_ref[0]
    ws, clip = window_sum(state, tau)
    gamma = select_gamma(pid_ref[0], gp_ref[0], c0_ref[0], c1_ref[0], ws, tau)
    return gamma, _push(state, gamma, clip)


def _write_state(state, gamma, k_out, total_out, cumbuf_out, clip_out,
                 gamma_out):
    k_out[0] = state.k
    total_out[0] = state.total
    cumbuf_out[...] = state.cumbuf
    clip_out[0] = state.clipped
    gamma_out[0] = gamma


def _state_outs(horizon: int):
    return [jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((horizon,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32)]


def _state_args(params, tau, state):
    return (_scalar_i32(params.policy_id), _scalar_f32(params.gamma_prime),
            _scalar_f32(params.c0), _scalar_f32(params.c1),
            _scalar_i32(tau), _scalar_i32(state.k), _scalar_f32(state.total),
            state.cumbuf, _scalar_i32(state.clipped))


def _unpack_state(k, total, cumbuf, clipped, gamma):
    return gamma[0], StepsizeState(k=k[0], total=total[0], cumbuf=cumbuf,
                                   clipped=clipped[0])


# ---------------------------------------------------------------------------
# PIAG / BCD: gamma select + prox(x - gamma * g)
# ---------------------------------------------------------------------------

def _prox_kernel(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                 k_ref, total_ref, cumbuf_ref, clip_ref, x_ref, g_ref,
                 k_out, total_out, cumbuf_out, clip_out, gamma_out, x_out,
                 *, prox):
    gamma, state = _policy_update(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                                  k_ref, total_ref, cumbuf_ref, clip_ref)
    # identical expression to the scan cores: prox(x - gamma * g, gamma)
    x_out[...] = prox.prox(x_ref[...] - gamma * g_ref[...], gamma)
    _write_state(state, gamma, k_out, total_out, cumbuf_out, clip_out,
                 gamma_out)


def fused_policy_prox_step(params, prox, state, tau, x, g, *,
                           interpret=None):
    """One fused PIAG/BCD event: ``policy.step`` + ``prox(x - gamma*g)``.

    Returns ``(gamma, new_state, x_new)`` -- bitwise-equal to
    ``gamma, ss = policy.step(state, tau); prox.prox(x - gamma * g, gamma)``.
    The prox operator is static (baked into the kernel body); the policy
    is a runtime ``PolicyParams`` value.
    """
    outs = _state_outs(state.cumbuf.shape[-1])
    outs.append(jax.ShapeDtypeStruct(x.shape, x.dtype))
    res = pl.pallas_call(
        functools.partial(_prox_kernel, prox=prox),
        out_shape=outs, interpret=resolve_interpret(interpret),
    )(*_state_args(params, tau, state), x, g)
    gamma, new_state = _unpack_state(*res[:5])
    return gamma, new_state, res[5]


# ---------------------------------------------------------------------------
# FedAsync: gamma select + server mix x + gamma * (xc - x)
# ---------------------------------------------------------------------------

def _mix_kernel(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                k_ref, total_ref, cumbuf_ref, clip_ref, x_ref, xc_ref,
                k_out, total_out, cumbuf_out, clip_out, gamma_out, x_out):
    gamma, state = _policy_update(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                                  k_ref, total_ref, cumbuf_ref, clip_ref)
    a = x_ref[...]
    x_out[...] = a + gamma * (xc_ref[...] - a)
    _write_state(state, gamma, k_out, total_out, cumbuf_out, clip_out,
                 gamma_out)


def fused_policy_mix_step(params, state, tau, x, xc, *, interpret=None):
    """One fused FedAsync server event: ``policy.step`` + convex mix.

    Returns ``(gamma, new_state, x_new)`` with
    ``x_new = x + gamma * (xc - x)``.
    """
    outs = _state_outs(state.cumbuf.shape[-1])
    outs.append(jax.ShapeDtypeStruct(x.shape, x.dtype))
    res = pl.pallas_call(
        _mix_kernel, out_shape=outs, interpret=resolve_interpret(interpret),
    )(*_state_args(params, tau, state), x, xc)
    gamma, new_state = _unpack_state(*res[:5])
    return gamma, new_state, res[5]


# ---------------------------------------------------------------------------
# FedBuff: gamma select + delta accumulate + buffered apply/decay
# ---------------------------------------------------------------------------

def _buff_kernel(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                 k_ref, total_ref, cumbuf_ref, clip_ref,
                 agg_ref, x_ref, xc_ref, xw_ref, delta_ref,
                 k_out, total_out, cumbuf_out, clip_out, gamma_out,
                 x_out, delta_out, *, scale):
    gamma, state = _policy_update(pid_ref, gp_ref, c0_ref, c1_ref, tau_ref,
                                  k_ref, total_ref, cumbuf_ref, clip_ref)
    agg = agg_ref[0]
    # identical expressions to fedbuff_scan: accumulate against the
    # client's READ snapshot xw, apply scaled by eta/buffer_size on
    # aggregation events, then decay
    delta = delta_ref[...] + gamma * (xc_ref[...] - xw_ref[...])
    x_out[...] = x_ref[...] + agg * scale * delta
    delta_out[...] = (1.0 - agg) * delta
    _write_state(state, gamma, k_out, total_out, cumbuf_out, clip_out,
                 gamma_out)


def fused_policy_buff_step(params, state, tau, x, xc, xw, delta, agg,
                           scale: float, *, interpret=None):
    """One fused FedBuff server event.

    ``scale = eta / buffer_size`` is static; ``agg`` is the traced 0/1
    aggregation flag.  Returns ``(gamma, new_state, x_new, delta_new)``.
    """
    outs = _state_outs(state.cumbuf.shape[-1])
    outs.append(jax.ShapeDtypeStruct(x.shape, x.dtype))
    outs.append(jax.ShapeDtypeStruct(delta.shape, delta.dtype))
    res = pl.pallas_call(
        functools.partial(_buff_kernel, scale=scale),
        out_shape=outs, interpret=resolve_interpret(interpret),
    )(*_state_args(params, tau, state), _scalar_f32(agg), x, xc, xw, delta)
    gamma, new_state = _unpack_state(*res[:5])
    return gamma, new_state, res[5], res[6]
