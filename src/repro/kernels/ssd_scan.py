"""Mamba2 SSD intra-chunk Pallas kernel.

The chunked SSD algorithm (models/ssm.py) has two parts: a sequential
O(S/Q) cross-chunk scan (cheap; left to ``lax.scan``) and the per-chunk
quadratic compute (the FLOPs hot spot):

    y_intra = ((C B^T) .* L .* dt) x        (Q x Q) matmuls -> MXU
    state   = (B .* e^{segsum - cums} dt)^T x

The kernel fuses the decay-matrix construction, masking and both matmuls for
one (batch*chunk, head) grid cell, keeping everything in VMEM: for Q = 256,
P = 64, N = 128 the working set is ~1.1 MiB f32.  The cross-chunk combine
runs on the host graph (ops.ssd_scan_pallas), mirroring models/ssm.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret


def _kernel(x_ref, dt_ref, dA_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    dA = dA_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    B = b_ref[0, :, 0, :].astype(jnp.float32)      # (Q, N)
    C = c_ref[0, :, 0, :].astype(jnp.float32)      # (Q, N)
    Q = x.shape[0]

    cums = jnp.cumsum(dA)
    decay = cums[:, None] - cums[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(col <= row, decay, -1e30))  # mask before exp
    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)
    W = CB * L * dt[None, :]
    y_ref[0, :, 0, :] = jnp.dot(W, x, preferred_element_type=jnp.float32
                                ).astype(y_ref.dtype)
    w2 = jnp.exp(cums[-1] - cums) * dt
    st = jnp.dot((B * w2[:, None]).T, x, preferred_element_type=jnp.float32)
    st_ref[0, 0, :, :] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, dt, dA, B, C, interpret: Optional[bool] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-chunk SSD compute.

    x  (BC, Q, H, P) -- batch*chunks flattened
    dt (BC, Q, H)    -- positive step sizes
    dA (BC, Q, H)    -- dt * A (negative)
    B  (BC, Q, G, N), C (BC, Q, G, N) -- G groups broadcast over heads
    Returns y (BC, Q, H, P) float32 and state (BC, H, N, P) float32.
    """
    if interpret is None:
        interpret = default_interpret()
    BC, Q, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G

    y, st = pl.pallas_call(
        _kernel,
        grid=(BC, H),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, 1), lambda b, h: (b, 0, h)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, _rep=rep: (b, 0, h // _rep, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, _rep=rep: (b, 0, h // _rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, dA, B, C)
    return y, st
