"""Blocked flash attention (online softmax) Pallas kernel.

Grid (batch*kv_head, q_blocks, k_blocks); the k axis is the innermost
(sequential) dimension, carrying the running max / normalizer / accumulator
in VMEM scratch -- the canonical flash schedule.  GQA is handled without
repeating KV: the wrapper folds the per-group query heads into extra query
*rows* (all heads of a group share the same K/V), so q arrives as
(B*KV, G*Sq, d) and the kernel never sees head replication.

Masking is position-based (absolute positions as int32 inputs): supports
causal, bidirectional and sliding-window in one kernel; slots with position
-1 (ring-cache holes, padding) are masked out.  Fully-masked query rows
return zeros.

Block sizes default to (128, 512): q/k/v tiles of 128x128 feed the MXU, and
the f32 accumulator (block_q x d) stays well inside the ~16 MiB v5e VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 512


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, causal: bool,
            window: Optional[int], nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)          # (bk, d)
    qp = qpos_ref[...]                        # (bq,)
    kp = kpos_ref[...]                        # (bk,)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    valid = (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        valid &= kp[None, :] <= qp[:, None]
    if window is not None:
        valid &= kp[None, :] > qp[:, None] - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                       # (bq, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.where(m_prev[:, 0] > NEG_INF / 2,
                      jnp.exp(m_prev[:, 0] - m_new), 0.0)
    l_new = alpha * l_scr[:, 0] + jnp.sum(p, axis=-1)
    acc = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, qpos, kpos, *, causal: bool = True,
                         window: Optional[int] = None, scale: float = 1.0,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
    """q (BH, Sq, d), k/v (BH, Sk, d), qpos (Sq,), kpos (Sk,) int32."""
    if interpret is None:
        interpret = default_interpret()
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    pq, pk = nq * bq - Sq, nk * bk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
        qpos = jnp.pad(qpos, (0, pq), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
        kpos = jnp.pad(kpos, (0, pk), constant_values=-1)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((bq,), lambda b, iq, ik: (iq,)),
            pl.BlockSpec((bk,), lambda b, iq, ik: (ik,)),
            pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, d), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v)
    return out[:, :Sq]
