"""jit'd dispatch wrappers around the Pallas kernels.

* ``flash_attention``     -- model-facing GQA attention (folds query groups
                             into rows; no KV replication).
* ``ssd_scan_pallas``     -- full chunked SSD using the intra-chunk kernel +
                             host cross-chunk combine.
* ``prox_step`` / ``prox_step_tree`` re-exported from kernels.prox_step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .prox_step import prox_step, prox_step_tree  # re-export
from .rmsnorm import rmsnorm as rmsnorm_fused  # re-export
from .ssd_scan import ssd_intra_chunk

__all__ = ["flash_attention", "ssd_scan_pallas", "prox_step",
           "prox_step_tree", "rmsnorm_fused"]


def flash_attention(q, k, v, qpos, kpos, *, causal: bool = True,
                    window: Optional[int] = None, scale: float = 1.0,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Model-facing wrapper.  q (B,Sq,H,d), k/v (B,Sk,KV,d) -> (B,Sq,H,dv).

    GQA: the G = H/KV heads of a group share K/V, so their queries are folded
    into extra query rows of the (B*KV)-indexed kernel batch.
    """
    B, Sq, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    # (B, Sq, KV, G, d) -> (B, KV, G, Sq, d) -> (B*KV, G*Sq, d)
    qf = q.reshape(B, Sq, KV, G, d).transpose(0, 2, 3, 1, 4).reshape(B * KV, G * Sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, d)
    qpos_f = jnp.tile(qpos, (G,))
    out = flash_attention_bhsd(qf, kf, vf, qpos_f, kpos, causal=causal,
                               window=window, scale=scale, interpret=interpret)
    out = out.reshape(B, KV, G, Sq, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, d)


def ssd_scan_pallas(x, dt, A, B, C, chunk: int, h0=None,
                    interpret: Optional[bool] = None):
    """Drop-in replacement for models.ssm.ssd_chunked using the kernel.

    Shapes follow ssd_chunked: x (Bt,S,H,P), dt (Bt,S,H), A (H,),
    B/C (Bt,S,G,N).  Returns (y (Bt,S,H,P), h_final (Bt,H,P,N)).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    f32 = jnp.float32
    dA = dt.astype(f32) * A.astype(f32)                       # (Bt, S', H)
    BC_ = Bt * nc
    xq = x.reshape(BC_, Q, H, P)
    dtq = dt.reshape(BC_, Q, H).astype(f32)
    dAq = dA.reshape(BC_, Q, H)
    Bq = B.reshape(BC_, Q, G, N).astype(f32)
    Cq = C.reshape(BC_, Q, G, N).astype(f32)

    y_intra, st_in = ssd_intra_chunk(xq, dtq, dAq, Bq, Cq, interpret=interpret)
    y_intra = y_intra.reshape(Bt, nc, Q, H, P)
    st_in = st_in.reshape(Bt, nc, H, N, P).transpose(0, 1, 2, 4, 3)  # (Bt,nc,H,P,N)

    cums = jnp.cumsum(dAq.reshape(Bt, nc, Q, H), axis=2)
    seg_end = cums[:, :, -1, :]                                # (Bt,nc,H)

    def scan_chunks(h, inp):
        se, s_in = inp
        h_new = jnp.exp(se)[:, :, None, None] * h + s_in
        return h_new, h

    h_init = jnp.zeros((Bt, H, P, N), f32) if h0 is None else h0.astype(f32)
    h_fin, h_enter = jax.lax.scan(
        scan_chunks, h_init,
        (jnp.moveaxis(seg_end, 1, 0), jnp.moveaxis(st_in, 1, 0)))
    h_enter = jnp.moveaxis(h_enter, 0, 1)                      # (Bt,nc,H,P,N)

    rep = H // G
    Cfull = jnp.repeat(C.reshape(Bt, nc, Q, G, N), rep, axis=3).astype(f32)
    y_inter = jnp.einsum("bcthn,bchpn->bcthp",
                         Cfull * jnp.exp(cums)[..., None], h_enter)
    y = (y_intra + y_inter).reshape(Bt, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), h_fin
