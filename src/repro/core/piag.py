"""PIAG (Proximal Incremental Aggregated Gradient) with delay tracking.

Implements the paper's Algorithm 1 / Eqs. (3)-(4):

    g_k     = (1/n) sum_i grad f_i(x_{k - tau_k^(i)})
    x_{k+1} = prox_{gamma_k R}(x_k - gamma_k g_k)

as a fully-jitted ``lax.scan`` over a write-event trace (core.engine).  The
master state carries the aggregated gradient table g^(i), the iterate
snapshot each worker is computing on, and the delay-adaptive step-size state;
delays are the trace's write-event staleness, exactly Algorithm 1's
``tau_k^(i) = k - s^(i)`` bookkeeping.

The solver is generic over pytree iterates and any per-worker loss
``worker_loss(x, worker_data...)``; ``run_piag_logreg`` specializes it to the
paper's §4 workload.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

import numpy as np

from typing import Any

from .engine import EventTrace, strided_scan
from .prox import ProxOp
from .stepsize import (StepsizePolicy, StepsizeState, auto_horizon, clip_delta,
                       clipped_count as _clipped_of)
from ..telemetry.accumulators import (TelemetryConfig, init_telemetry,
                                      observe, emit_window, finalize)
from ..faults.spec import CODE_CORRUPT, FaultSpec, normalize_faults
from ..faults.inject import corrupt_value, update_fault_codes
from ..faults.guards import (guard_event, guarded_gamma, init_faults,
                             payload_finite)

__all__ = ["PIAGResult", "piag_scan", "run_piag", "run_piag_logreg"]


class PIAGResult(NamedTuple):
    x: jnp.ndarray            # final iterate (pytree)
    objective: jnp.ndarray    # (K,) P(x_{k+1}) after each write event
    gammas: jnp.ndarray       # (K,) emitted step-sizes
    taus: jnp.ndarray         # (K,) tau_k = max_i tau_k^(i) fed to the policy
    opt_residual: jnp.ndarray  # (K,) ||x_{k+1} - x_k|| / gamma_k (prox-grad map)
    clipped: jnp.ndarray = 0  # plain-int default: no jax init at import time
    # ^ final StepsizeState.clipped: number of events whose delay exceeded the
    #   policy horizon (H - 1 cap) -- nonzero means the horizon was undersized
    #   and window sums were silently truncated; see ROADMAP.
    telemetry: Any = None     # DelayTelemetry when telemetry= was passed
    # ^ trailing optional field: existing positional construction and the
    #   bitwise row-equivalence pins over the other leaves are unaffected.
    faults: Any = None        # FaultState counters when faults= was passed


def piag_scan(
    worker_loss: Callable,      # (x, *worker_data_slice) -> scalar, f_i
    x0,                         # pytree initial iterate
    worker_data,                # pytree, each leaf (n_workers, ...)
    events,                     # (worker (K,) i32, tau (K,) i32) jnp arrays
    policy: StepsizePolicy,
    prox: ProxOp,
    objective: Callable | None = None,  # P(x); defaults to mean worker loss + R
    horizon: int = 4096,
    active: jnp.ndarray | None = None,  # (n,) bool; ragged-bucket worker mask
    record_every: int = 1,
    telemetry: TelemetryConfig | None = None,
    engine: str = "scan",
    faults: FaultSpec | None = None,
    fault_codes: jnp.ndarray | None = None,
    grad_fn: Callable | None = None,  # (x, *worker_data_slice) -> grad pytree
) -> PIAGResult:
    """The traceable PIAG core: Algorithm 1 as a pure ``lax.scan``.

    Everything is a function of jnp values, so the SAME step code serves the
    solo path (``run_piag`` jits it directly) and the batched path
    (``repro.sweep.sweep_piag`` vmaps it over stacked events and policy
    parameters) -- which is what makes per-row equivalence between the two
    exact rather than approximate.

    ``active`` supports ragged worker-count sweeps: a bucketed cell pads its
    gradient table to the bucket width, and the mask turns the aggregation
    into a mean over ACTIVE rows only, so padded workers never contribute
    gradients (their table rows are multiplied by an exact 0.0; padded
    ``worker_data`` rows therefore only need to be finite).  The trace must
    be masked consistently (``engine.trace_scan(T, active=...)``) so padded
    workers never appear in ``events`` either.

    ``record_every=s`` decimates the recorded trajectory: only every s-th
    event's (objective, gamma, tau, residual) row is materialized -- and the
    objective/residual are only COMPUTED on those events -- so big sweeps
    stop paying an O(K) objective evaluation and an O(B, K) output for
    trajectories they will subsample anyway.  The iterate path is unchanged
    (recorded rows are bitwise rows ``s-1, 2s-1, ...`` of a stride-1 run);
    K must be a multiple of s.

    ``telemetry=TelemetryConfig(...)`` threads an in-scan accumulator
    (delay histogram, tau/gamma moments, per-window clip counts) through the
    carry and returns it finalized on ``result.telemetry``.  The accumulator
    observes EVERY event -- decimated steps included -- so its aggregates
    are exact under any ``record_every``, and it is bitwise-neutral: no
    solver leaf depends on it.

    ``engine='fused'`` launches line 16 + line 17 (window-sum gather, policy
    select, cumulative-sum push, prox step) as ONE Pallas kernel per event
    (``repro.kernels.fused_step``) instead of chained XLA ops -- bitwise-
    equal to ``engine='scan'`` and telemetry-neutral (the accumulator rides
    the same carry either way).  Requires a single-1-D-leaf iterate and a
    ``PolicyParams``-expressible policy; both are checked loudly.

    ``faults=FaultSpec(...)`` (with a ``fault_codes`` event column from
    ``repro.faults.update_fault_codes``) switches in the guarded step:
    drop/dup/corrupt codes are applied to the returning worker's gradient,
    non-finite or over-stale payloads are rejected (skip-and-count; the
    gradient table keeps its previous row so one corrupt worker never
    poisons the aggregate), horizon overflow degrades to the
    worst-case-bound ``gamma'/(tau+1)``, and a ``FaultState`` counter tuple
    rides the carry onto ``result.faults``.  ``faults=None`` is bitwise the
    pre-fault jaxpr -- the guarded body is a SEPARATE code path, not a
    predicated version of the old one.
    """
    if engine not in ("scan", "fused"):
        raise ValueError(f"engine must be 'scan' or 'fused', got {engine!r}")
    faults = normalize_faults(faults)
    if faults is not None:
        if engine == "fused":
            raise TypeError("engine='fused' does not support fault "
                            "injection; use engine='scan'")
        if fault_codes is None:
            raise ValueError("faults is set but fault_codes is None; build "
                             "the event codes with "
                             "repro.faults.update_fault_codes")
    if engine == "fused":
        from ..kernels.fused_step import (as_policy_params, fused_leaf,
                                          fused_policy_prox_step)
        fparams = as_policy_params(policy)
        _, x_treedef = fused_leaf(x0, "PIAG iterate")
    n = jax.tree_util.tree_leaves(worker_data)[0].shape[0]
    # grad_fn is the data-parallel seam: the 2-D sharded backend injects
    # repro.mesh.pmean_grad(worker_loss, "data", D) so each mesh data shard
    # differentiates its slice of the samples and psums back the full
    # gradient.  grad_fn=None is bitwise the old jaxpr (off-is-absent).
    grad_i = jax.grad(worker_loss) if grad_fn is None else grad_fn

    if active is None:
        def aggregate(buf):
            return jnp.mean(buf, axis=0)
    else:
        amask = jnp.asarray(active, jnp.float32)
        n_active = jnp.sum(amask)

        def aggregate(buf):
            w = amask.reshape((n,) + (1,) * (buf.ndim - 1))
            return jnp.sum(buf * w, axis=0) / n_active

    def data_at(w):
        return jax.tree_util.tree_map(lambda leaf: leaf[w], worker_data)

    if objective is None:
        def objective(x):
            losses = jax.vmap(lambda i: worker_loss(x, *jax.tree_util.tree_leaves(data_at(i))))
            # note: assumes worker_data leaves order == worker_loss arg order
            idx = jnp.arange(n)
            return aggregate(losses(idx)) + prox.value(x)

    # Algorithm 1 line 3: g^(i) <- grad f_i(x_0)
    def init_grad(w):
        return grad_i(x0, *jax.tree_util.tree_leaves(data_at(w)))

    g_table = jax.vmap(init_grad)(jnp.arange(n))
    x_read0 = jax.tree_util.tree_map(lambda leaf: jnp.broadcast_to(leaf, (n,) + leaf.shape), x0)

    def make_step(emit):
        if faults is not None:
            return _make_fault_step(emit)

        def step(carry, event):
            x, gtab, x_read, ss = carry[:4]
            w, tau = event
            # worker w returns grad f_w(x_read[w])  (Algorithm 1 line 12)
            xw = jax.tree_util.tree_map(lambda leaf: leaf[w], x_read)
            gw = grad_i(xw, *jax.tree_util.tree_leaves(data_at(w)))
            gtab = jax.tree_util.tree_map(lambda buf, gnew: buf.at[w].set(gnew), gtab, gw)
            # line 14: aggregate; line 16: delay-adaptive gamma; line 17: prox step
            g = jax.tree_util.tree_map(aggregate, gtab)
            ss_old = ss
            if engine == "fused":
                gamma, ss, x_leaf = fused_policy_prox_step(
                    fparams, prox, ss, tau,
                    jax.tree_util.tree_leaves(x)[0],
                    jax.tree_util.tree_leaves(g)[0])
                x_new = jax.tree_util.tree_unflatten(x_treedef, [x_leaf])
            else:
                gamma, ss = policy.step(ss, tau)
                x_new = prox.prox(
                    jax.tree_util.tree_map(
                        lambda xv, gv: xv - gamma * gv, x, g), gamma)
            # line 20: hand x_{k+1} to the returning worker
            x_read = jax.tree_util.tree_map(
                lambda buf, xv: buf.at[w].set(xv), x_read, x_new)
            if telemetry is None:
                if not emit:  # decimated step: carry advances, nothing recorded
                    return (x_new, gtab, x_read, ss), None
            else:
                tel = observe(carry[4], tau, gamma, clip_delta(ss_old, ss))
                if not emit:
                    return (x_new, gtab, x_read, ss, tel), None
                tel, wclip = emit_window(tel)
            dx = jnp.sqrt(sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                jax.tree_util.tree_leaves(x_new), jax.tree_util.tree_leaves(x))))
            res = jnp.where(gamma > 0, dx / jnp.maximum(gamma, 1e-30), 0.0)
            out = (objective(x_new), gamma, tau, res)
            if telemetry is None:
                return (x_new, gtab, x_read, ss), out
            return (x_new, gtab, x_read, ss, tel), out + (wclip,)
        return step

    # Index of the FaultState in the carry (after the optional telemetry).
    fi = 5 if telemetry is not None else 4

    def _make_fault_step(emit):
        poison = corrupt_value(faults)

        def step(carry, event):
            x, gtab, x_read, ss = carry[:4]
            fs = carry[fi]
            w, tau, code = event
            xw = jax.tree_util.tree_map(lambda leaf: leaf[w], x_read)
            gw = grad_i(xw, *jax.tree_util.tree_leaves(data_at(w)))
            # update-level corruption: poison the payload BEFORE the guard
            gw = jax.tree_util.tree_map(
                lambda a: (a + jnp.where(code == CODE_CORRUPT, poison,
                                         jnp.float32(0.0))).astype(a.dtype),
                gw)
            finite = payload_finite(gw) if faults.guard_nonfinite \
                else jnp.ones((), jnp.bool_)
            accept, mult, fs = guard_event(faults, code, tau, finite, fs)
            # rejected updates keep the worker's PREVIOUS table row: one
            # corrupt gradient must never poison the aggregate
            gtab = jax.tree_util.tree_map(
                lambda buf, gnew: buf.at[w].set(
                    jnp.where(accept, gnew, buf[w])), gtab, gw)
            g = jax.tree_util.tree_map(aggregate, gtab)
            ss_old = ss
            gamma, ss, fs = guarded_gamma(policy, ss, tau, mult, faults, fs)
            x_cand = prox.prox(
                jax.tree_util.tree_map(
                    lambda xv, gv: xv - gamma * gv, x, g), gamma)
            x_new = jax.tree_util.tree_map(
                lambda cnd, old: jnp.where(accept, cnd, old), x_cand, x)
            # the worker refetches the latest iterate either way (a rejected
            # worker rejoins on fresh state, shrinking its next staleness)
            x_read = jax.tree_util.tree_map(
                lambda buf, xv: buf.at[w].set(xv), x_read, x_new)
            tel = None
            if telemetry is not None:
                tel = observe(carry[4], tau, gamma, clip_delta(ss_old, ss))
            extras = ((tel,) if telemetry is not None else ()) + (fs,)
            if not emit:
                return (x_new, gtab, x_read, ss) + extras, None
            wtail = ()
            if telemetry is not None:
                tel, wclip = emit_window(tel)
                extras = (tel, fs)
                wtail = (wclip,)
            dx = jnp.sqrt(sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                jax.tree_util.tree_leaves(x_new),
                jax.tree_util.tree_leaves(x))))
            res = jnp.where(gamma > 0, dx / jnp.maximum(gamma, 1e-30), 0.0)
            out = (objective(x_new), gamma, tau, res) + wtail
            return (x_new, gtab, x_read, ss) + extras, out
        return step

    if faults is not None:
        events = tuple(events) + (jnp.asarray(fault_codes, jnp.int32),)
    carry0 = (x0, g_table, x_read0, policy.init(horizon))
    if telemetry is not None:
        carry0 = carry0 + (init_telemetry(telemetry),)
    if faults is not None:
        carry0 = carry0 + (init_faults(),)
    carry_fin, outs = strided_scan(make_step, carry0, events, record_every)
    x_fin, ss_fin = carry_fin[0], carry_fin[3]
    obj, gam, taus, res = outs[:4]
    tel_out = None
    if telemetry is not None:
        tel_out = finalize(carry_fin[4], outs[4])
    faults_out = carry_fin[fi] if faults is not None else None
    return PIAGResult(x=x_fin, objective=obj, gammas=gam, taus=taus,
                      opt_residual=res, clipped=_clipped_of(ss_fin),
                      telemetry=tel_out, faults=faults_out)


def run_piag(
    worker_loss: Callable,
    x0,
    worker_data,
    trace: EventTrace,
    policy: StepsizePolicy,
    prox: ProxOp,
    objective: Callable | None = None,
    horizon: int | str = 4096,
    use_tau_max: bool = True,
    record_every: int = 1,
    telemetry: TelemetryConfig | None = None,
    engine: str = "scan",
    faults: FaultSpec | None = None,
    fault_seed: int = 0,
) -> PIAGResult:
    """Run PIAG over a write-event trace; everything under one jit.

    ``horizon='auto'`` sizes the step-size window buffer from the trace's
    own measured delays (``auto_horizon``) instead of the 4096 worst-case
    default -- bitwise-identical output, a fraction of the scan carry.
    ``engine='fused'`` routes the per-event policy + prox update through
    the fused Pallas kernel (see ``piag_scan``).  ``faults`` enables the
    guarded step (``piag_scan``); the per-event drop/dup/corrupt codes are
    drawn inside the jit from ``fault_seed`` (the cell seed), so solo runs
    match the batched sweep bitwise under faults."""
    taus = trace.tau_max if use_tau_max else trace.tau
    if horizon == "auto":
        horizon = auto_horizon(int(np.max(taus, initial=0)))
    events = (
        jnp.asarray(trace.worker, jnp.int32),
        jnp.asarray(taus, jnp.int32),
    )
    faults = normalize_faults(faults)

    if faults is None:
        @jax.jit
        def run(events):
            return piag_scan(worker_loss, x0, worker_data, events, policy,
                             prox, objective=objective, horizon=horizon,
                             record_every=record_every, telemetry=telemetry,
                             engine=engine)

        return run(events)

    n_events = int(events[0].shape[0])

    @jax.jit
    def run_faulted(events, fseed):
        codes = update_fault_codes(faults, n_events, fseed)
        return piag_scan(worker_loss, x0, worker_data, events, policy, prox,
                         objective=objective, horizon=horizon,
                         record_every=record_every, telemetry=telemetry,
                         engine=engine, faults=faults, fault_codes=codes)

    return run_faulted(events, jnp.int32(fault_seed))


def run_piag_lipschitz(problem, trace, prox, h: float = 0.9,
                       alpha: float = 0.9, gamma0: float = 1.0,
                       horizon: int = 4096) -> PIAGResult:
    """BEYOND-PAPER: PIAG needing neither the delay bound nor L.

    Uses core.stepsize.AdaptiveLipschitz: per write event, the returning
    worker's (old grad, new grad, old iterate, new iterate) quadruple yields
    a secant curvature sample ||dg||/||dx||; the running max estimates L and
    sets the Eq.-(8) budget gamma' = h / L_est on-line (the paper's §5
    future work, made concrete)."""
    from .stepsize import AdaptiveLipschitz

    Aw, bw = problem.worker_slices()
    n = Aw.shape[0]
    grad_i = jax.grad(lambda x, A, b: problem.worker_loss(x, A, b))
    pol = AdaptiveLipschitz(gamma_prime=gamma0, h=h, alpha=alpha)
    x0 = jnp.zeros((problem.dim,), jnp.float32)

    g_table = jax.vmap(lambda i: grad_i(x0, Aw[i], bw[i]))(jnp.arange(n))
    x_read0 = jnp.broadcast_to(x0, (n,) + x0.shape)
    events = (jnp.asarray(trace.worker, jnp.int32),
              jnp.asarray(trace.tau_max, jnp.int32))

    def step(carry, event):
        x, gtab, x_read, x_prev, lip = carry
        w, tau = event
        xw = x_read[w]
        gw = grad_i(xw, Aw[w], bw[w])
        # secant curvature sample from worker w's consecutive gradients
        dg = jnp.linalg.norm(gw - gtab[w])
        dx = jnp.linalg.norm(xw - x_prev[w])
        lip = pol.observe_curvature(lip, dg, dx)
        gtab = gtab.at[w].set(gw)
        x_prev = x_prev.at[w].set(xw)
        g = jnp.mean(gtab, axis=0)
        gamma, lip = pol.step(lip, tau)
        x_new = prox.prox(x - gamma * g, gamma)
        x_read = x_read.at[w].set(x_new)
        return (x_new, gtab, x_read, x_prev, lip), (
            problem.P(x_new), gamma, tau, lip.L_est)

    @jax.jit
    def run(carry0, events):
        return jax.lax.scan(step, carry0, events)

    carry0 = (x0, g_table, x_read0, x_read0, pol.init(horizon))
    (x_fin, _, _, _, lip_fin), (obj, gam, taus, L_est) = run(carry0, events)
    return PIAGResult(x=x_fin, objective=obj, gammas=gam, taus=taus,
                      opt_residual=L_est, clipped=_clipped_of(lip_fin))


def run_piag_logreg(problem, trace, policy, prox, horizon: int = 4096) -> PIAGResult:
    """PIAG on the paper's l1-regularized logistic regression (§4.1)."""
    Aw, bw = problem.worker_slices()

    def worker_loss(x, A, b):
        return problem.worker_loss(x, A, b)

    def objective(x):
        return problem.P(x)

    x0 = jnp.zeros((problem.dim,), jnp.float32)
    return run_piag(worker_loss, x0, (Aw, bw), trace, policy, prox,
                    objective=objective, horizon=horizon)
