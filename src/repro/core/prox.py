"""Proximal operators for the composite objective P(x) = f(x) + R(x).

Each operator is a ``ProxOp`` with ``value(x) = R(x)`` and
``prox(x, gamma) = argmin_y R(y) + ||y - x||^2 / (2 gamma)``.  All are exact
closed forms, jit-compatible, and work on arbitrary pytrees (applied leafwise
where separability permits; group-l2 treats each leaf as one group).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def _tree_map(fn, x):
    return jax.tree_util.tree_map(fn, x)


def _tree_sum(fn, x):
    return sum(jnp.sum(fn(leaf)) for leaf in jax.tree_util.tree_leaves(x))


@dataclasses.dataclass(frozen=True)
class ProxOp:
    def value(self, x: Pytree) -> jnp.ndarray:
        raise NotImplementedError

    def prox(self, x: Pytree, gamma: jnp.ndarray) -> Pytree:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Zero(ProxOp):
    """R = 0 (smooth problems)."""

    def value(self, x):
        return jnp.zeros((), jnp.float32)

    def prox(self, x, gamma):
        return x


@dataclasses.dataclass(frozen=True)
class L1(ProxOp):
    """R(x) = lam * ||x||_1; prox = soft threshold."""

    lam: float = 1e-4

    def value(self, x):
        return self.lam * _tree_sum(jnp.abs, x)

    def prox(self, x, gamma):
        t = gamma * self.lam
        return _tree_map(lambda v: jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0), x)


@dataclasses.dataclass(frozen=True)
class L2Squared(ProxOp):
    """R(x) = (lam/2)||x||^2; prox = shrink by 1/(1 + gamma lam)."""

    lam: float = 1e-4

    def value(self, x):
        return 0.5 * self.lam * _tree_sum(jnp.square, x)

    def prox(self, x, gamma):
        return _tree_map(lambda v: v / (1.0 + gamma * self.lam), x)


@dataclasses.dataclass(frozen=True)
class ElasticNet(ProxOp):
    """R(x) = lam1 ||x||_1 + (lam2/2)||x||^2."""

    lam1: float = 1e-4
    lam2: float = 1e-4

    def value(self, x):
        return self.lam1 * _tree_sum(jnp.abs, x) + 0.5 * self.lam2 * _tree_sum(jnp.square, x)

    def prox(self, x, gamma):
        t = gamma * self.lam1
        s = 1.0 + gamma * self.lam2
        return _tree_map(lambda v: jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0) / s, x)


@dataclasses.dataclass(frozen=True)
class Box(ProxOp):
    """Indicator of the box [lo, hi]^d; prox = projection (clip)."""

    lo: float = -1.0
    hi: float = 1.0

    def value(self, x):
        viol = sum(
            jnp.sum(jnp.maximum(self.lo - leaf, 0.0) + jnp.maximum(leaf - self.hi, 0.0))
            for leaf in jax.tree_util.tree_leaves(x)
        )
        return jnp.where(viol > 0, jnp.inf, 0.0).astype(jnp.float32)

    def prox(self, x, gamma):
        del gamma  # projection is step-size independent
        return _tree_map(lambda v: jnp.clip(v, self.lo, self.hi), x)


@dataclasses.dataclass(frozen=True)
class GroupL2(ProxOp):
    """R(x) = lam * sum_g ||x_g||_2 with each pytree leaf a group (block
    soft-threshold) -- the separable-R structure Async-BCD requires."""

    lam: float = 1e-4

    def value(self, x):
        return self.lam * sum(
            jnp.linalg.norm(leaf) for leaf in jax.tree_util.tree_leaves(x)
        )

    def prox(self, x, gamma):
        t = gamma * self.lam

        def blk(v):
            n = jnp.linalg.norm(v)
            scale = jnp.maximum(1.0 - t / jnp.maximum(n, 1e-30), 0.0)
            return scale * v

        return _tree_map(blk, x)


PROX_OPS = {
    "none": Zero,
    "l1": L1,
    "l2": L2Squared,
    "elastic_net": ElasticNet,
    "box": Box,
    "group_l2": GroupL2,
}


def make_prox(name: str, **kwargs) -> ProxOp:
    try:
        cls = PROX_OPS[name]
    except KeyError as e:
        raise ValueError(f"unknown prox {name!r}; options: {sorted(PROX_OPS)}") from e
    return cls(**kwargs)
