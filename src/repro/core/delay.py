"""Delay models, traces and the write-event delay tracker (paper §2).

Delays in asynchronous optimization are measured in *write events* -- the
number of master updates between the iterate snapshot a gradient was computed
on and the update that consumes it (paper §2, [Leblond et al. '18]).  This
module provides

* the three delay models used in the paper's Figure 1 (constant / uniform
  random / burst), plus a Markov-modulated model and a heterogeneous-worker
  service-time model for richer experiments;
* ``DelayTracker`` -- the timestamping bookkeeping from Algorithms 1-2: the
  master stamps the outgoing iterate with its version ``k``; returning
  gradients carry the stamp; delay = current ``k`` minus stamp.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = [
    "constant_delays",
    "random_delays",
    "burst_delays",
    "markov_delays",
    "DelayTracker",
    "DELAY_MODELS",
    "make_delays",
]


def constant_delays(n_steps: int, tau: int, seed: int = 0) -> np.ndarray:
    """Model 1 (Fig. 1): tau_k = tau, except the ramp-in (tau_k <= k)."""
    t = np.full((n_steps,), tau, dtype=np.int32)
    ramp = np.minimum(np.arange(n_steps), tau)
    return np.minimum(t, ramp).astype(np.int32)


def random_delays(n_steps: int, tau: int, seed: int = 0) -> np.ndarray:
    """Model 2 (Fig. 1): tau_k ~ Uniform{0..tau}."""
    rng = np.random.default_rng(seed)
    t = rng.integers(0, tau + 1, size=n_steps).astype(np.int32)
    return np.minimum(t, np.arange(n_steps)).astype(np.int32)


def burst_delays(n_steps: int, tau: int, period: int = 100, seed: int = 0) -> np.ndarray:
    """Model 3 (Fig. 1): tau_k = tau once per epoch (period), else 0."""
    t = np.zeros((n_steps,), dtype=np.int32)
    t[period::period] = tau
    return np.minimum(t, np.arange(n_steps)).astype(np.int32)


def markov_delays(n_steps: int, tau: int, p_slow: float = 0.05,
                  p_recover: float = 0.3, seed: int = 0) -> np.ndarray:
    """Two-state Markov-modulated delays: a 'congested' state emits delays
    near tau, the 'fast' state emits near-zero delays.  Models stragglers with
    temporal correlation (beyond the paper's three models)."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n_steps,), dtype=np.int32)
    slow = False
    for k in range(n_steps):
        if slow:
            out[k] = rng.integers(max(tau // 2, 1), tau + 1)
            slow = rng.random() >= p_recover
        else:
            out[k] = rng.integers(0, max(tau // 8, 1) + 1)
            slow = rng.random() < p_slow
    return np.minimum(out, np.arange(n_steps)).astype(np.int32)


DELAY_MODELS = {
    "constant": constant_delays,
    "random": random_delays,
    "burst": burst_delays,
    "markov": markov_delays,
}


def make_delays(model: str, n_steps: int, tau: int, seed: int = 0, **kw) -> np.ndarray:
    return DELAY_MODELS[model](n_steps, tau, seed=seed, **kw)


@dataclasses.dataclass
class DelayTracker:
    """Write-event timestamping (Algorithm 1 lines 12/15; Algorithm 2 lines 5/10).

    The master (or shared memory) holds a monotone iterate-version counter
    ``k``.  ``stamp()`` records the version a worker read; ``delay()`` returns
    the current staleness of that worker's data.  Thread-safety is the
    caller's concern (core.runtime wraps access in the master loop / the
    shared-memory critical section, exactly as the paper's algorithms do).
    """

    k: int = 0
    stamps: Dict[int, int] = dataclasses.field(default_factory=dict)
    max_seen: int = 0

    def stamp(self, worker: int, version: Optional[int] = None) -> int:
        v = self.k if version is None else version
        self.stamps[worker] = v
        return v

    def delay(self, worker: int) -> int:
        """Current staleness of ``worker``'s data.

        Raises ``KeyError`` for a worker that was never stamped: silently
        assuming stamp 0 would report staleness ``k`` -- an arbitrarily large
        delay that crushes any delay-adaptive step-size to zero and is
        indistinguishable from a real straggler.  Callers must ``stamp()``
        each worker when handing it the initial iterate (Algorithm 1 line 3).
        """
        if worker not in self.stamps:
            raise KeyError(
                f"worker {worker} has no stamp; call stamp({worker}, version) "
                "when it first reads the iterate (Algorithm 1 line 3)")
        tau = self.k - self.stamps[worker]
        self.max_seen = max(self.max_seen, tau)
        return tau

    def delays(self) -> Dict[int, int]:
        return {w: self.k - s for w, s in self.stamps.items()}

    def max_delay(self) -> int:
        return max(self.delays().values(), default=0)

    def advance(self) -> int:
        self.k += 1
        return self.k
