"""Event-driven asynchrony simulator.

JAX/XLA is a single-controller SPMD runtime: a compiled program step is
synchronous by construction.  To study the paper's *asynchronous* algorithms
at pod scale we therefore separate mechanism from policy:

* this module simulates the *event structure* of an asynchronous system --
  which worker's gradient arrives at each master write event, and how stale
  it is -- from per-worker service-time models (stragglers, heterogeneous
  speeds, network jitter);
* the solvers (core.piag / core.bcd / core.async_sgd) consume the resulting
  integer event trace inside a fully-jitted ``lax.scan``, computing real
  gradients and real delay-adaptive step-sizes.

Because the paper measures delays in write events (not wall time), a solver
driven by a simulated event trace is *exactly* the paper's algorithm for that
realization of worker timings.  ``core.runtime`` provides genuinely-threaded
execution for the paper-scale experiments; this module provides determinism
and scale.

Two trace paths
---------------

There are two interchangeable implementations of the event structure:

* the **reference path** -- ``simulate_parameter_server`` /
  ``simulate_shared_memory`` -- a Python ``heapq`` discrete-event loop.
  Simple, obviously correct, and the ground truth every other path is
  tested against; but it costs Python time per event and cannot be
  batched.
* the **jitted path** -- ``trace_scan`` / ``generate_trace`` -- the same
  event structure computed inside a ``lax.scan`` from a pre-sampled
  per-worker service-time matrix (``sample_service_times``).  It jits,
  and, crucially, it ``vmap``s: ``repro.sweep`` stacks one service-time
  matrix per grid cell and runs whole policy x seed x topology sweeps as
  one XLA program.

The two paths agree *bitwise* (same (worker, read_at, tau) sequence, same
float32 wall-clock) when driven by the same service-time matrix: both
accumulate completion times in float32 and both break completion-time ties
by push order ((time, seq) -- workers 0..n-1 first, then one push per
event).  ``tests/test_sweep.py`` pins this equivalence.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["WorkerModel", "EventTrace", "EventHeap", "simulate_parameter_server",
           "simulate_shared_memory", "sample_service_times", "trace_scan",
           "generate_trace", "strided_scan"]


def strided_scan(make_step, carry, xs, record_every: int = 1):
    """``lax.scan`` with decimated recording: keep every s-th output.

    ``make_step(emit)`` returns the scan step; with ``emit=False`` it must
    return ``(new_carry, None)`` and may SKIP output-only work (objective
    evaluations, residual norms) -- the carry evolution must be identical
    either way, which is what makes the recorded samples of a strided run
    bitwise-equal to the corresponding rows of a stride-1 run.

    ``record_every=1`` is exactly ``lax.scan(make_step(True), ...)`` (same
    program, bitwise).  For s > 1 the trace is processed in chunks of s
    events: the first s-1 advance the carry silently, the s-th emits, so the
    recorded rows are events ``s-1, 2s-1, ..., K-1`` and output buffers
    shrink by s.  ``K`` must be a multiple of s.

    Carry-borne accumulators (``repro.telemetry.accumulators``) update on
    BOTH silent and loud steps -- the carry advances through every event --
    which is why in-scan aggregate statistics stay exact under decimation
    with no change to this function.
    """
    every = int(record_every)
    if every < 1:
        raise ValueError(f"record_every must be >= 1, got {record_every}")
    if every == 1:
        return jax.lax.scan(make_step(True), carry, xs)
    tmap = jax.tree_util.tree_map
    K = int(jax.tree_util.tree_leaves(xs)[0].shape[0])
    if K % every:
        raise ValueError(
            f"record_every={every} must divide the trace length {K}")
    xs_r = tmap(lambda e: e.reshape((K // every, every) + e.shape[1:]), xs)
    silent, loud = make_step(False), make_step(True)

    def chunk(c, xc):
        def drop(cc, e):
            cc, _ = silent(cc, e)
            return cc, None

        c, _ = jax.lax.scan(drop, c, tmap(lambda e: e[:every - 1], xc))
        return loud(c, tmap(lambda e: e[every - 1], xc))

    return jax.lax.scan(chunk, carry, xs_r)


@dataclasses.dataclass(frozen=True)
class WorkerModel:
    """Lognormal service time with occasional straggler events.

    mean:        mean compute time (arbitrary units).
    sigma:       lognormal shape (jitter).
    p_straggle:  probability a task is hit by a straggler event.
    straggle_x:  multiplicative slowdown of straggler tasks.
    """

    mean: float = 1.0
    sigma: float = 0.25
    p_straggle: float = 0.0
    straggle_x: float = 10.0

    def sample(self, rng: np.random.Generator) -> float:
        # lognormal with E[t] = mean
        mu = np.log(self.mean) - 0.5 * self.sigma**2
        t = float(rng.lognormal(mu, self.sigma))
        if self.p_straggle > 0 and rng.random() < self.p_straggle:
            t *= self.straggle_x
        return t

    def sample_n(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Vectorized draw of ``n`` task durations (own stream, task order)."""
        mu = np.log(self.mean) - 0.5 * self.sigma**2
        t = rng.lognormal(mu, self.sigma, size=n)
        if self.p_straggle > 0:
            t = np.where(rng.random(n) < self.p_straggle,
                         t * self.straggle_x, t)
        return t


def heterogeneous_workers(n: int, spread: float = 2.0, seed: int = 0,
                          p_straggle: float = 0.02, straggle_x: float = 8.0) -> list:
    """n workers with mean speeds log-spaced over [1, spread] (the paper's
    Figure 3 shows per-worker max delays varying ~2.4x)."""
    rng = np.random.default_rng(seed)
    means = np.geomspace(1.0, spread, n)
    rng.shuffle(means)
    return [WorkerModel(mean=float(m), p_straggle=p_straggle, straggle_x=straggle_x)
            for m in means]


def sample_service_times(workers: Sequence[WorkerModel], n_tasks: int,
                         seed: int = 0) -> np.ndarray:
    """Pre-sample the full service-time matrix ``T[i, j]`` (float32).

    ``T[i, j]`` is the duration of worker ``i``'s ``j``-th task.  Each worker
    draws from its own counter-based substream ``default_rng([seed, i])``, so
    the matrix is independent of event order -- the property that lets the
    heapq reference and the ``lax.scan`` path consume identical randomness.
    Durations are rounded to float32 because the jitted path accumulates
    completion times in float32 (x64 is disabled under JAX defaults); the
    reference path does the same when handed a matrix, keeping event *order*
    (ties included) bitwise-identical across paths.
    """
    out = np.empty((len(workers), n_tasks), np.float32)
    for i, w in enumerate(workers):
        rng = np.random.default_rng([seed, i])
        out[i] = w.sample_n(rng, n_tasks).astype(np.float32)
    return out


class EventHeap:
    """Deterministic discrete-event queue of in-flight tasks.

    The mechanism shared by every simulator in this codebase: push a task
    with its completion time and an arbitrary payload, pop the earliest.
    A monotone tiebreak makes pops deterministic under equal completion
    times (insertion order wins), so traces are reproducible bit-for-bit.
    Used here for the paper's parameter-server / shared-memory event
    structures and by ``repro.federated.events`` for round-trip federated
    clients (multi-event lifecycles: start, dropout/rejoin, upload).
    """

    def __init__(self):
        self._heap: list = []
        self._tie = 0

    def push(self, t: float, *payload) -> None:
        heapq.heappush(self._heap, (t, self._tie) + payload)
        self._tie += 1

    def pop(self):
        """Return ``(t, *payload)`` of the earliest task."""
        item = heapq.heappop(self._heap)
        return (item[0],) + item[2:]

    def __len__(self) -> int:
        return len(self._heap)


class EventTrace(NamedTuple):
    """One master write event per row.

    worker:   (K,) int32 -- which worker's gradient is consumed at event k.
    read_at:  (K,) int32 -- iterate version that worker had read.
    tau:      (K,) int32 -- staleness of *that* worker's gradient, k - read_at.
    tau_max:  (K,) int32 -- max staleness across the whole gradient table at k
                            (the tau_k that the PIAG analysis uses).
    t_wall:   (K,) float64 -- simulated wall-clock time of the event.
    """

    worker: np.ndarray
    read_at: np.ndarray
    tau: np.ndarray
    tau_max: np.ndarray
    t_wall: np.ndarray

    @property
    def n_events(self) -> int:
        return int(self.worker.shape[0])

    def max_delay(self) -> int:
        return int(self.tau_max.max(initial=0))


def _next_time(t: float, workers, i: int, rng, service_times, next_task):
    """Completion time of worker i's next task.

    With a pre-sampled matrix, accumulate in float32 (matching ``trace_scan``
    bit-for-bit); otherwise sample on the fly in float64 (legacy behavior,
    kept so existing seeded traces are unchanged).
    """
    if service_times is None:
        return t + workers[i].sample(rng)
    j = next_task[i]
    next_task[i] += 1
    return np.float32(t) + service_times[i, j]


def simulate_parameter_server(
    n_workers: int,
    n_events: int,
    workers: Optional[Sequence[WorkerModel]] = None,
    seed: int = 0,
    service_times: Optional[np.ndarray] = None,
) -> EventTrace:
    """Simulate Algorithm 1's event structure with |R| = 1.

    Each worker computes on the newest iterate it was handed; when it returns,
    the master performs one write event (k += 1) and hands the worker the new
    iterate.  Staleness of worker i's table entry at event k is k - s[i],
    where s[i] is the version it last read -- the paper's delay definition.

    ``service_times`` (n_workers, >= n_events + 1) float32, if given, replaces
    on-the-fly sampling: worker i's j-th task takes ``service_times[i, j]``
    and completion times accumulate in float32 -- the reference against which
    the jitted ``trace_scan`` is bitwise-tested.
    """
    if workers is None:
        workers = heterogeneous_workers(n_workers, seed=seed)
    assert len(workers) == n_workers
    rng = np.random.default_rng(seed + 1)
    next_task = np.zeros((n_workers,), np.int64)

    heap = EventHeap()  # payload: (worker, version_read)
    for i, w in enumerate(workers):
        heap.push(_next_time(0.0, workers, i, rng, service_times, next_task), i, 0)
    s = np.zeros((n_workers,), np.int64)  # version each table entry was computed on

    worker = np.zeros((n_events,), np.int32)
    read_at = np.zeros((n_events,), np.int32)
    tau = np.zeros((n_events,), np.int32)
    tau_max = np.zeros((n_events,), np.int32)
    t_wall = np.zeros((n_events,), np.float64)

    for k in range(n_events):
        t, i, v = heap.pop()
        s[i] = v
        worker[k] = i
        read_at[k] = v
        tau[k] = k - v
        tau_max[k] = k - int(s.min())
        t_wall[k] = t
        # master writes x_{k+1} (version k+1) and hands it to worker i
        heap.push(_next_time(t, workers, i, rng, service_times, next_task), i, k + 1)
    return EventTrace(worker, read_at, tau, tau_max, t_wall)


def simulate_shared_memory(
    n_workers: int,
    n_events: int,
    n_blocks: int,
    workers: Optional[Sequence[WorkerModel]] = None,
    seed: int = 0,
    service_times: Optional[np.ndarray] = None,
) -> "EventTrace":
    """Simulate Algorithm 2's event structure.

    Workers repeatedly: read the shared iterate (recording the counter s),
    compute a block gradient, then perform one atomic write event.  The block
    index is sampled uniformly by the solver (kept out of the trace so the
    trace is model-independent); tau_k = k - s_{i_k}.

    ``service_times`` works exactly as in ``simulate_parameter_server``.
    """
    if workers is None:
        workers = heterogeneous_workers(n_workers, seed=seed)
    rng = np.random.default_rng(seed + 2)
    next_task = np.zeros((n_workers,), np.int64)

    heap = EventHeap()  # payload: (worker, counter_read)
    for i, w in enumerate(workers):
        heap.push(_next_time(0.0, workers, i, rng, service_times, next_task), i, 0)

    worker = np.zeros((n_events,), np.int32)
    read_at = np.zeros((n_events,), np.int32)
    tau = np.zeros((n_events,), np.int32)
    t_wall = np.zeros((n_events,), np.float64)

    for k in range(n_events):
        t, i, s_read = heap.pop()
        worker[k] = i
        read_at[k] = s_read
        tau[k] = k - s_read
        t_wall[k] = t
        # worker i re-reads immediately after its write (version k+1)
        heap.push(_next_time(t, workers, i, rng, service_times, next_task), i, k + 1)
    return EventTrace(worker, read_at, tau, tau.copy(), t_wall)


class TraceArrays(NamedTuple):
    """``EventTrace`` columns as jnp arrays -- the jit/vmap-side twin.

    Identical field meaning to ``EventTrace``; ``t_wall`` is float32 (the
    accumulation dtype of the jitted path).  ``tau_max`` is the
    parameter-server table staleness; shared-memory consumers use ``tau``.
    """

    worker: jnp.ndarray
    read_at: jnp.ndarray
    tau: jnp.ndarray
    tau_max: jnp.ndarray
    t_wall: jnp.ndarray


def trace_scan(service_times: jnp.ndarray,
               active: Optional[jnp.ndarray] = None) -> TraceArrays:
    """The jitted/vmappable event-structure kernel.

    ``service_times`` is a (n_workers, n_events + 1) float32 matrix
    (``sample_service_times``); the extra column covers the worst case of one
    worker consuming every event.  Emits ``n_events = service_times.shape[1]
    - 1`` write events: per event, the in-flight task with the smallest
    (completion_time, push_seq) key completes -- the exact pop order of the
    ``EventHeap`` reference (initial tasks carry seq 0..n-1 in worker order;
    the task pushed at event k carries seq n + k), so simultaneous arrivals
    resolve identically in both paths.

    ``active`` is an optional (n_workers,) bool mask for RAGGED batches: a
    grid bucket pads every cell's matrix to a common worker count, and the
    mask guarantees padded rows never win the (time, seq) event race and
    never enter the staleness table minimum, so a padded cell's trace is
    bitwise-identical to its exact-width run (``repro.sweep`` pads service
    times with +inf as a second line of defense, but only the mask keeps
    ``tau_max`` correct -- an unmasked padded row would freeze ``s`` at 0
    and make the table staleness grow without bound).

    Pure function of its arguments: ``jax.vmap(trace_scan)`` over a stacked
    batch of matrices generates a whole sweep's traces in one program, and
    ``repro.sweep`` composes it with the solver scans under a single jit.
    """
    T = jnp.asarray(service_times, jnp.float32)
    n, n_tasks = T.shape
    n_events = n_tasks - 1
    i32 = jnp.int32
    act = None if active is None else jnp.asarray(active, jnp.bool_)

    init = (
        T[:, 0],                        # t: completion time of in-flight task
        jnp.arange(n, dtype=i32),       # seq: push order of in-flight task
        jnp.ones((n,), i32),            # next_task: per-worker task cursor
        jnp.zeros((n,), i32),           # ver: version the in-flight task read
        jnp.zeros((n,), i32),           # s: version of each table entry
    )

    def step(carry, k):
        t, seq, task, ver, s = carry
        # pop: lexicographic argmin over (t, seq) == EventHeap order
        t_race = t if act is None else jnp.where(act, t, jnp.inf)
        at_min = t_race == jnp.min(t_race)
        i = jnp.argmin(jnp.where(at_min, seq, jnp.iinfo(i32).max)).astype(i32)
        v = ver[i]
        s = s.at[i].set(v)
        s_race = s if act is None else jnp.where(act, s, jnp.iinfo(i32).max)
        out = (i, v, k - v, k - jnp.min(s_race), t[i])
        # push: worker i starts its next task at the write it just triggered
        t = t.at[i].add(T[i, task[i]])
        task = task.at[i].add(1)
        ver = ver.at[i].set(k + 1)
        seq = seq.at[i].set(n + k)
        return (t, seq, task, ver, s), out

    _, (worker, read_at, tau, tau_max, t_wall) = jax.lax.scan(
        step, init, jnp.arange(n_events, dtype=i32))
    return TraceArrays(worker, read_at, tau, tau_max, t_wall)


@jax.jit
def _trace_scan_jit(service_times):
    return trace_scan(service_times)


def generate_trace(service_times: np.ndarray,
                   kind: str = "parameter_server") -> EventTrace:
    """Host-side wrapper: run ``trace_scan`` jitted and return an ``EventTrace``.

    Drop-in replacement for ``simulate_parameter_server`` /
    ``simulate_shared_memory`` driven by a pre-sampled matrix -- bitwise-equal
    traces at a fraction of the Python cost.  ``kind='shared_memory'`` only
    changes the ``tau_max`` column (shared-memory staleness is per-write,
    ``tau_max == tau``), exactly as in the reference pair.
    """
    if kind not in ("parameter_server", "shared_memory"):
        raise ValueError(f"unknown trace kind {kind!r}")
    out = jax.device_get(_trace_scan_jit(np.asarray(service_times, np.float32)))
    tau_max = out.tau_max if kind == "parameter_server" else out.tau.copy()
    return EventTrace(out.worker, out.read_at, out.tau, tau_max,
                      out.t_wall.astype(np.float64))
