"""Event-driven asynchrony simulator.

JAX/XLA is a single-controller SPMD runtime: a compiled program step is
synchronous by construction.  To study the paper's *asynchronous* algorithms
at pod scale we therefore separate mechanism from policy:

* this module simulates the *event structure* of an asynchronous system --
  which worker's gradient arrives at each master write event, and how stale
  it is -- from per-worker service-time models (stragglers, heterogeneous
  speeds, network jitter);
* the solvers (core.piag / core.bcd / core.async_sgd) consume the resulting
  integer event trace inside a fully-jitted ``lax.scan``, computing real
  gradients and real delay-adaptive step-sizes.

Because the paper measures delays in write events (not wall time), a solver
driven by a simulated event trace is *exactly* the paper's algorithm for that
realization of worker timings.  ``core.runtime`` provides genuinely-threaded
execution for the paper-scale experiments; this module provides determinism
and scale.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import NamedTuple, Optional, Sequence

import numpy as np

__all__ = ["WorkerModel", "EventTrace", "EventHeap", "simulate_parameter_server",
           "simulate_shared_memory"]


@dataclasses.dataclass(frozen=True)
class WorkerModel:
    """Lognormal service time with occasional straggler events.

    mean:        mean compute time (arbitrary units).
    sigma:       lognormal shape (jitter).
    p_straggle:  probability a task is hit by a straggler event.
    straggle_x:  multiplicative slowdown of straggler tasks.
    """

    mean: float = 1.0
    sigma: float = 0.25
    p_straggle: float = 0.0
    straggle_x: float = 10.0

    def sample(self, rng: np.random.Generator) -> float:
        # lognormal with E[t] = mean
        mu = np.log(self.mean) - 0.5 * self.sigma**2
        t = float(rng.lognormal(mu, self.sigma))
        if self.p_straggle > 0 and rng.random() < self.p_straggle:
            t *= self.straggle_x
        return t


def heterogeneous_workers(n: int, spread: float = 2.0, seed: int = 0,
                          p_straggle: float = 0.02, straggle_x: float = 8.0) -> list:
    """n workers with mean speeds log-spaced over [1, spread] (the paper's
    Figure 3 shows per-worker max delays varying ~2.4x)."""
    rng = np.random.default_rng(seed)
    means = np.geomspace(1.0, spread, n)
    rng.shuffle(means)
    return [WorkerModel(mean=float(m), p_straggle=p_straggle, straggle_x=straggle_x)
            for m in means]


class EventHeap:
    """Deterministic discrete-event queue of in-flight tasks.

    The mechanism shared by every simulator in this codebase: push a task
    with its completion time and an arbitrary payload, pop the earliest.
    A monotone tiebreak makes pops deterministic under equal completion
    times (insertion order wins), so traces are reproducible bit-for-bit.
    Used here for the paper's parameter-server / shared-memory event
    structures and by ``repro.federated.events`` for round-trip federated
    clients (multi-event lifecycles: start, dropout/rejoin, upload).
    """

    def __init__(self):
        self._heap: list = []
        self._tie = 0

    def push(self, t: float, *payload) -> None:
        heapq.heappush(self._heap, (t, self._tie) + payload)
        self._tie += 1

    def pop(self):
        """Return ``(t, *payload)`` of the earliest task."""
        item = heapq.heappop(self._heap)
        return (item[0],) + item[2:]

    def __len__(self) -> int:
        return len(self._heap)


class EventTrace(NamedTuple):
    """One master write event per row.

    worker:   (K,) int32 -- which worker's gradient is consumed at event k.
    read_at:  (K,) int32 -- iterate version that worker had read.
    tau:      (K,) int32 -- staleness of *that* worker's gradient, k - read_at.
    tau_max:  (K,) int32 -- max staleness across the whole gradient table at k
                            (the tau_k that the PIAG analysis uses).
    t_wall:   (K,) float64 -- simulated wall-clock time of the event.
    """

    worker: np.ndarray
    read_at: np.ndarray
    tau: np.ndarray
    tau_max: np.ndarray
    t_wall: np.ndarray

    @property
    def n_events(self) -> int:
        return int(self.worker.shape[0])

    def max_delay(self) -> int:
        return int(self.tau_max.max(initial=0))


def simulate_parameter_server(
    n_workers: int,
    n_events: int,
    workers: Optional[Sequence[WorkerModel]] = None,
    seed: int = 0,
) -> EventTrace:
    """Simulate Algorithm 1's event structure with |R| = 1.

    Each worker computes on the newest iterate it was handed; when it returns,
    the master performs one write event (k += 1) and hands the worker the new
    iterate.  Staleness of worker i's table entry at event k is k - s[i],
    where s[i] is the version it last read -- the paper's delay definition.
    """
    if workers is None:
        workers = heterogeneous_workers(n_workers, seed=seed)
    assert len(workers) == n_workers
    rng = np.random.default_rng(seed + 1)

    heap = EventHeap()  # payload: (worker, version_read)
    for i, w in enumerate(workers):
        heap.push(w.sample(rng), i, 0)
    s = np.zeros((n_workers,), np.int64)  # version each table entry was computed on

    worker = np.zeros((n_events,), np.int32)
    read_at = np.zeros((n_events,), np.int32)
    tau = np.zeros((n_events,), np.int32)
    tau_max = np.zeros((n_events,), np.int32)
    t_wall = np.zeros((n_events,), np.float64)

    for k in range(n_events):
        t, i, v = heap.pop()
        s[i] = v
        worker[k] = i
        read_at[k] = v
        tau[k] = k - v
        tau_max[k] = k - int(s.min())
        t_wall[k] = t
        # master writes x_{k+1} (version k+1) and hands it to worker i
        heap.push(t + workers[i].sample(rng), i, k + 1)
    return EventTrace(worker, read_at, tau, tau_max, t_wall)


def simulate_shared_memory(
    n_workers: int,
    n_events: int,
    n_blocks: int,
    workers: Optional[Sequence[WorkerModel]] = None,
    seed: int = 0,
) -> "EventTrace":
    """Simulate Algorithm 2's event structure.

    Workers repeatedly: read the shared iterate (recording the counter s),
    compute a block gradient, then perform one atomic write event.  The block
    index is sampled uniformly by the solver (kept out of the trace so the
    trace is model-independent); tau_k = k - s_{i_k}.
    """
    if workers is None:
        workers = heterogeneous_workers(n_workers, seed=seed)
    rng = np.random.default_rng(seed + 2)

    heap = EventHeap()  # payload: (worker, counter_read)
    for i, w in enumerate(workers):
        heap.push(w.sample(rng), i, 0)

    worker = np.zeros((n_events,), np.int32)
    read_at = np.zeros((n_events,), np.int32)
    tau = np.zeros((n_events,), np.int32)
    t_wall = np.zeros((n_events,), np.float64)

    for k in range(n_events):
        t, i, s_read = heap.pop()
        worker[k] = i
        read_at[k] = s_read
        tau[k] = k - s_read
        t_wall[k] = t
        # worker i re-reads immediately after its write (version k+1)
        heap.push(t + workers[i].sample(rng), i, k + 1)
    return EventTrace(worker, read_at, tau, tau.copy(), t_wall)
