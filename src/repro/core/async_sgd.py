"""Delay-adaptive Asynchronous (Prox-)SGD -- the paper's §5 extension, used
as the pod-scale trainer's update rule.

PIAG's gradient table costs n x |params| memory, which is infeasible for the
multi-billion-parameter assigned architectures (see DESIGN.md §3).  The
table-free variant applies each arriving (delayed) gradient directly:

    gamma_k   chosen delay-adaptively from tau_k   (core.stepsize)
    x_{k+1} = prox_{gamma_k R}(x_k - gamma_k d_k)

where ``d_k`` is the (optionally momentum-filtered, weight-decayed) update
direction built from the stale gradient.  The step-size principle (8) is
identical; only the gradient estimator changes.  The same state is what
``launch/train.py`` lowers for the multi-pod dry-run, so the compiled HLO
contains the paper's delay-tracking + adaptive-gamma scalar program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .prox import ProxOp, Zero
from .stepsize import StepsizePolicy, StepsizeState

Pytree = Any

__all__ = ["AsyncOptState", "AsyncSGD", "tree_scale_add"]


def tree_scale_add(x: Pytree, y: Pytree, alpha) -> Pytree:
    return jax.tree_util.tree_map(lambda a, b: a + alpha * b, x, y)


class AsyncOptState(NamedTuple):
    step: jnp.ndarray           # master write-event counter k (int32)
    ss: StepsizeState           # delay-adaptive step-size state
    momentum: Optional[Pytree]  # momentum buffer (None if beta == 0)
    worker_stamp: jnp.ndarray   # (n_workers,) iterate version each worker read


@dataclasses.dataclass(frozen=True)
class AsyncSGD:
    """Delay-adaptive async SGD/momentum with composite prox step.

    ``lr_scale`` rescales the emitted gamma (the theory's gamma' already
    encodes 1/L; for deep nets L is unknown so gamma' is a tuned base LR and
    the *relative* delay adaptation is what the paper contributes).
    """

    policy: StepsizePolicy
    prox: ProxOp = Zero()
    beta: float = 0.0            # momentum
    weight_decay: float = 0.0    # decoupled weight decay
    lr_scale: float = 1.0
    n_workers: int = 1
    horizon: int = 4096

    def init(self, params: Pytree) -> AsyncOptState:
        mom = None
        if self.beta > 0:
            mom = jax.tree_util.tree_map(jnp.zeros_like, params)
        return AsyncOptState(
            step=jnp.zeros((), jnp.int32),
            ss=self.policy.init(self.horizon),
            momentum=mom,
            worker_stamp=jnp.zeros((self.n_workers,), jnp.int32),
        )

    def observe(self, state: AsyncOptState, worker: jnp.ndarray) -> Tuple[jnp.ndarray, AsyncOptState]:
        """Algorithm-1-style delay bookkeeping: the arriving gradient from
        ``worker`` was computed at version worker_stamp[worker]; the worker
        then picks up the new iterate (version k+1)."""
        tau = state.step - state.worker_stamp[worker]
        stamps = state.worker_stamp.at[worker].set(state.step + 1)
        return tau, state._replace(worker_stamp=stamps)

    def apply(self, params: Pytree, grads: Pytree, state: AsyncOptState,
              tau: jnp.ndarray) -> Tuple[Pytree, AsyncOptState, jnp.ndarray]:
        """One master write event: delay-adaptive gamma, momentum, prox."""
        gamma, ss = self.policy.step(state.ss, tau)
        lr = self.lr_scale * gamma
        if self.beta > 0:
            mom = jax.tree_util.tree_map(
                lambda m, g: self.beta * m + g, state.momentum, grads)
            direction = mom
        else:
            mom = state.momentum
            direction = grads
        if self.weight_decay > 0:
            direction = jax.tree_util.tree_map(
                lambda d, p: d + self.weight_decay * p, direction, params)
        shifted = jax.tree_util.tree_map(lambda p, d: p - lr * d, params, direction)
        new_params = self.prox.prox(shifted, lr)
        new_state = AsyncOptState(step=state.step + 1, ss=ss, momentum=mom,
                                  worker_stamp=state.worker_stamp)
        return new_params, new_state, gamma

    def update(self, params: Pytree, grads: Pytree, state: AsyncOptState,
               worker: jnp.ndarray) -> Tuple[Pytree, AsyncOptState, jnp.ndarray, jnp.ndarray]:
        """observe + apply in one call (what the trainer jits)."""
        tau, state = self.observe(state, worker)
        params, state, gamma = self.apply(params, grads, state, tau)
        return params, state, gamma, tau
