"""Async-BCD (asynchronous proximal block-coordinate descent) with delay
tracking -- the paper's Algorithm 2 / Eq. (5):

    x_{k+1}^(j) = prox_{gamma_k R_j}(x_k^(j) - gamma_k grad_j f(xhat_k))

run as a jitted ``lax.scan`` over a shared-memory write-event trace.  The
variable is partitioned into ``m`` equal blocks (the paper splits "almost
evenly"; we pad the tail).  Each event k: worker i_k contributes the block-j_k
partial gradient evaluated at the iterate snapshot it read ``tau_k`` write
events ago; the step-size is chosen delay-adaptively (Algorithm 2 line 6)
inside the same critical section as the write, exactly as the paper requires.

Consistent-but-stale reads are simulated here (J_k = [k - tau_k, k-1], the
worst case the analysis covers); genuinely inconsistent reads occur in the
threaded runtime (core.runtime.SharedMemoryBCD).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .engine import EventTrace, strided_scan
from .prox import ProxOp
from .stepsize import StepsizePolicy, auto_horizon, clip_delta, clipped_count
from ..telemetry.accumulators import (TelemetryConfig, init_telemetry,
                                      observe, emit_window, finalize)
from ..faults.spec import CODE_CORRUPT, FaultSpec, normalize_faults
from ..faults.inject import corrupt_value, update_fault_codes
from ..faults.guards import guard_event, guarded_gamma, init_faults

__all__ = ["BCDResult", "bcd_scan", "run_async_bcd", "run_bcd_logreg",
           "sample_blocks"]


class BCDResult(NamedTuple):
    x: jnp.ndarray            # final iterate, (d,) (padding stripped)
    objective: jnp.ndarray    # (K,)
    gammas: jnp.ndarray       # (K,)
    taus: jnp.ndarray         # (K,)
    blocks: jnp.ndarray       # (K,) block index updated at each event
    clipped: jnp.ndarray = 0  # plain-int default: no jax init at import time
    # ^ final StepsizeState.clipped: events whose delay exceeded the policy
    #   horizon (H - 1 cap); nonzero flags an undersized horizon per cell.
    telemetry: Any = None     # DelayTelemetry when telemetry= was passed
    faults: Any = None        # FaultState counters when faults= was passed


def _blockify(x: jnp.ndarray, m: int):
    d = x.shape[0]
    db = -(-d // m)  # ceil
    pad = m * db - d
    return jnp.pad(x, (0, pad)).reshape(m, db), d


def bcd_scan(
    grad_f: Callable,           # full gradient of the smooth part, (d_pad,) -> (d_pad,)
    objective: Callable,        # P(x) on the unpadded vector
    x0: jnp.ndarray,            # (d,)
    m: int,
    n_workers: int,
    events,                     # (worker, tau, block) (K,) i32 jnp arrays each
    policy: StepsizePolicy,
    prox: ProxOp,
    horizon: int = 4096,
    record_every: int = 1,
    telemetry: TelemetryConfig | None = None,
    engine: str = "scan",
    faults: FaultSpec | None = None,
    fault_codes: jnp.ndarray | None = None,
) -> BCDResult:
    """The traceable Async-BCD core (Algorithm 2 as a pure ``lax.scan``);
    shared verbatim by the solo ``run_async_bcd`` jit and the vmapped
    ``repro.sweep.sweep_bcd`` batch.  ``record_every=s`` materializes (and
    computes the objective for) only every s-th event row, bitwise rows
    ``s-1, 2s-1, ...`` of the stride-1 run (see ``engine.strided_scan``).

    Ragged worker-count buckets need NO active-worker mask here (unlike
    ``piag_scan``): there is no cross-worker reduction -- each event touches
    only the returning worker's snapshot row -- so as long as the trace is
    masked (``engine.trace_scan(T, active=...)``), padded workers never
    appear in ``events`` and their ``x_read`` rows are dead weight; passing
    ``n_workers`` = the bucket width is sufficient and exact.

    ``engine='fused'`` launches lines 6-7 (policy window-sum/select/push +
    the block prox step) as one Pallas kernel per event over the active
    block row -- bitwise-equal to ``engine='scan'``; the block extract /
    scatter stays outside the kernel.

    ``faults``/``fault_codes`` switch in the guarded step (see
    ``piag_scan``): the updated block gradient is the guarded payload --
    corrupt events poison ``gj``, non-finite / over-stale payloads skip the
    block write entirely -- and ``faults=None`` is bitwise the pre-fault
    jaxpr (a separate step body, not a predicated one)."""
    if engine not in ("scan", "fused"):
        raise ValueError(f"engine must be 'scan' or 'fused', got {engine!r}")
    faults = normalize_faults(faults)
    if faults is not None:
        if engine == "fused":
            raise TypeError("engine='fused' does not support fault "
                            "injection; use engine='scan'")
        if fault_codes is None:
            raise ValueError("faults is set but fault_codes is None; build "
                             "the event codes with "
                             "repro.faults.update_fault_codes")
    if engine == "fused":
        from ..kernels.fused_step import (as_policy_params,
                                          fused_policy_prox_step)
        fparams = as_policy_params(policy)
    xb0, d = _blockify(jnp.asarray(x0, jnp.float32), m)
    db = xb0.shape[1]

    def unpad(xb):
        return xb.reshape(-1)[:d]

    # snapshots each worker last read (consistent-but-stale reads)
    x_read0 = jnp.broadcast_to(xb0, (n_workers,) + xb0.shape)

    def make_step(emit):
        if faults is not None:
            return _make_fault_step(emit)

        def step(carry, event):
            xb, x_read, ss = carry[:3]
            w, tau, j = event
            xhat = x_read[w]                                 # Algorithm 2 line 4
            g = grad_f(unpad(xhat))                          # grad at the stale read
            gpad = jnp.pad(g, (0, m * db - d)).reshape(m, db)
            gj = gpad[j]                                     # grad_j f(xhat)
            ss_old = ss
            if engine == "fused":                            # lines 6-7 fused
                gamma, ss, xj_new = fused_policy_prox_step(
                    fparams, prox, ss, tau, xb[j], gj)
            else:
                gamma, ss = policy.step(ss, tau)             # line 6 (delay-adaptive)
                xj_new = prox.prox(xb[j] - gamma * gj, gamma)  # line 7, Eq. (5)
            xb_new = xb.at[j].set(xj_new)                    # line 8 (atomic write)
            x_read = x_read.at[w].set(xb_new)                # line 10 (re-read)
            if telemetry is None:
                if not emit:
                    return (xb_new, x_read, ss), None
                return (xb_new, x_read, ss), (objective(unpad(xb_new)), gamma,
                                              tau, j)
            tel = observe(carry[3], tau, gamma, clip_delta(ss_old, ss))
            if not emit:
                return (xb_new, x_read, ss, tel), None
            tel, wclip = emit_window(tel)
            return (xb_new, x_read, ss, tel), (objective(unpad(xb_new)), gamma,
                                               tau, j, wclip)
        return step

    fi = 4 if telemetry is not None else 3

    def _make_fault_step(emit):
        poison = corrupt_value(faults)

        def step(carry, event):
            xb, x_read, ss = carry[:3]
            fs = carry[fi]
            w, tau, j, code = event
            xhat = x_read[w]
            g = grad_f(unpad(xhat))
            gpad = jnp.pad(g, (0, m * db - d)).reshape(m, db)
            gj = gpad[j] + jnp.where(code == CODE_CORRUPT, poison,
                                     jnp.float32(0.0))
            finite = jnp.all(jnp.isfinite(gj)) if faults.guard_nonfinite \
                else jnp.ones((), jnp.bool_)
            accept, mult, fs = guard_event(faults, code, tau, finite, fs)
            ss_old = ss
            gamma, ss, fs = guarded_gamma(policy, ss, tau, mult, faults, fs)
            xj_cand = prox.prox(xb[j] - gamma * gj, gamma)
            xj_new = jnp.where(accept, xj_cand, xb[j])
            xb_new = xb.at[j].set(xj_new)
            x_read = x_read.at[w].set(xb_new)
            tel = None
            if telemetry is not None:
                tel = observe(carry[3], tau, gamma, clip_delta(ss_old, ss))
            extras = ((tel,) if telemetry is not None else ()) + (fs,)
            if not emit:
                return (xb_new, x_read, ss) + extras, None
            wtail = ()
            if telemetry is not None:
                tel, wclip = emit_window(tel)
                extras = (tel, fs)
                wtail = (wclip,)
            out = (objective(unpad(xb_new)), gamma, tau, j) + wtail
            return (xb_new, x_read, ss) + extras, out
        return step

    if faults is not None:
        events = tuple(events) + (jnp.asarray(fault_codes, jnp.int32),)
    carry0 = (xb0, x_read0, policy.init(horizon))
    if telemetry is not None:
        carry0 = carry0 + (init_telemetry(telemetry),)
    if faults is not None:
        carry0 = carry0 + (init_faults(),)
    carry_fin, outs = strided_scan(make_step, carry0, events, record_every)
    xb_fin, ss_fin = carry_fin[0], carry_fin[2]
    obj, gam, taus, blk = outs[:4]
    tel_out = finalize(carry_fin[3], outs[4]) if telemetry is not None else None
    faults_out = carry_fin[fi] if faults is not None else None
    return BCDResult(x=unpad(xb_fin), objective=obj, gammas=gam, taus=taus,
                     blocks=blk, clipped=clipped_count(ss_fin),
                     telemetry=tel_out, faults=faults_out)


def run_async_bcd(
    grad_f: Callable,
    objective: Callable,
    x0: jnp.ndarray,
    m: int,
    trace: EventTrace,
    blocks: np.ndarray,         # (K,) int32 block choices (uniform at random)
    policy: StepsizePolicy,
    prox: ProxOp,
    horizon: int | str = 4096,
    record_every: int = 1,
    telemetry: TelemetryConfig | None = None,
    engine: str = "scan",
    faults: FaultSpec | None = None,
    fault_seed: int = 0,
) -> BCDResult:
    n = int(trace.worker.max()) + 1 if trace.n_events else 1
    if horizon == "auto":  # measured-delay sizing off the trace itself
        horizon = auto_horizon(int(np.max(trace.tau, initial=0)))
    events = (
        jnp.asarray(trace.worker, jnp.int32),
        jnp.asarray(trace.tau, jnp.int32),
        jnp.asarray(blocks, jnp.int32),
    )
    faults = normalize_faults(faults)

    if faults is None:
        @jax.jit
        def run(events):
            return bcd_scan(grad_f, objective, x0, m, n, events, policy, prox,
                            horizon=horizon, record_every=record_every,
                            telemetry=telemetry, engine=engine)

        return run(events)

    n_events = int(events[0].shape[0])

    @jax.jit
    def run_faulted(events, fseed):
        codes = update_fault_codes(faults, n_events, fseed)
        return bcd_scan(grad_f, objective, x0, m, n, events, policy, prox,
                        horizon=horizon, record_every=record_every,
                        telemetry=telemetry, engine=engine,
                        faults=faults, fault_codes=codes)

    return run_faulted(events, jnp.int32(fault_seed))


def sample_blocks(m: int, n_events: int, seed: int = 0) -> np.ndarray:
    """The uniform block choices of Algorithm 2 line 5 (shared by the solo
    ``run_bcd_logreg`` and the sweep path so rows stay comparable)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, m, size=n_events).astype(np.int32)


def run_bcd_logreg(problem, trace, policy, prox, m: int = 20,
                   seed: int = 0, horizon: int = 4096) -> BCDResult:
    """Async-BCD on the paper's l1-regularized logistic regression (§4.2)."""
    blocks = sample_blocks(m, trace.n_events, seed=seed)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    return run_async_bcd(problem.grad_f, problem.P, x0, m, trace, blocks,
                         policy, prox, horizon=horizon)
