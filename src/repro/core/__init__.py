"""Core library: the paper's delay-adaptive step-size machinery.

Public API::

    from repro.core import (
        make_policy, Adaptive1, Adaptive2, FixedStepSize, NaiveAdaptive,
        make_prox, run_piag, run_async_bcd, AsyncSGD,
        simulate_parameter_server, simulate_shared_memory, make_delays,
    )
"""
from .async_sgd import AsyncOptState, AsyncSGD
from .bcd import (BCDResult, bcd_scan, run_async_bcd, run_bcd_logreg,
                  sample_blocks)
from .delay import DelayTracker, make_delays, DELAY_MODELS
from .engine import (EventHeap, EventTrace, TraceArrays, WorkerModel,
                     generate_trace, heterogeneous_workers,
                     sample_service_times, simulate_parameter_server,
                     simulate_shared_memory, trace_scan)
from .piag import (PIAGResult, piag_scan, run_piag, run_piag_lipschitz,
                   run_piag_logreg)
from .problems import (LassoProblem, LogRegProblem, Quadratic, make_lasso,
                       make_logreg, solve_centralized)
from .prox import (PROX_OPS, Box, ElasticNet, GroupL2, L1, L2Squared, ProxOp,
                   Zero, make_prox)
from .runtime import PIAGServer, RunLog, SharedMemoryBCD
from .stepsize import (POLICIES, Adaptive1, Adaptive2, AdaptiveLipschitz, DavisFixed,
                       FixedStepSize, HingeWeight, NaiveAdaptive, PolyWeight,
                       StepsizePolicy, StepsizeState, SunDengFixed, init_state,
                       make_policy, window_sum)
from .theory import (check_principle, example1, example1_divergence_threshold,
                     prop1_lower_bounds, verify_theorem1)

__all__ = [k for k in dir() if not k.startswith("_")]
