"""Genuinely-asynchronous runtimes (threads), mirroring the paper's §4 setup.

Two runtimes:

* ``PIAGServer``       -- Algorithm 1 verbatim: a master thread owns the
  iterate and the gradient table; n worker threads receive (x_k, k) over
  per-worker queues, compute their shard gradient (a jitted JAX call that
  releases the GIL), and send (grad, k) back.  The master processes one
  return at a time (|R| = 1, as in §4.1), tracks write-event delays with
  ``DelayTracker``, picks the delay-adaptive step-size, and applies the prox
  update.
* ``SharedMemoryBCD``  -- Algorithm 2: workers share a numpy iterate.  Reads
  are deliberately NOT locked (inconsistent reads, Eq. 6); steps 5-9 (delay,
  step-size, block prox update, write, counter bump) run inside one lock,
  exactly the critical section the paper assumes.

These produce the paper's Figure 2-4 style traces with *real* asynchrony on
this container's cores.  Determinism is not guaranteed (that is the point);
the event-driven engine (core.engine) is the deterministic twin.

Resilience contract (the chaos-tested layer)
--------------------------------------------

Real threads really die, so both runtimes are hardened:

* the PIAG master never blocks forever on ``out_q.get``: it polls with a
  short timeout, re-raises a crashed worker's exception (chained) within
  ``heartbeat`` seconds, and raises ``RuntimeError`` when every worker is
  dead or ``TimeoutError`` when live workers produce nothing for a full
  heartbeat;
* worker crashes are counted (``RunLog.crashes``) and -- with
  ``respawn=True`` -- the master respawns the worker, RE-STAMPS its
  ``DelayTracker`` entry at the current write count (a rejoining worker
  must not carry its pre-crash staleness), re-sends the current iterate,
  and counts the respawn (``RunLog.respawns``);
* queues are bounded (no unbounded buildup when one side stalls) and
  shutdown drains ``out_q`` so no worker is left blocked on a full queue;
* ``join(timeout)`` failures are no longer silent: each leaked thread
  emits a warning and bumps ``RunLog.join_failures``;
* ``SharedMemoryBCD`` propagates worker exceptions to the master (which
  otherwise spins forever on the write counter) and applies the same
  join accounting.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import warnings
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .delay import DelayTracker
from .prox import ProxOp
from .stepsize import StepsizePolicy

__all__ = ["PIAGServer", "SharedMemoryBCD", "RunLog", "WorkerCrash"]


class WorkerCrash(RuntimeError):
    """A worker thread died and the runtime surfaced it (original exception
    chained as ``__cause__``)."""


@dataclasses.dataclass
class RunLog:
    objective: List[float] = dataclasses.field(default_factory=list)
    gammas: List[float] = dataclasses.field(default_factory=list)
    taus: List[int] = dataclasses.field(default_factory=list)
    taus_per_worker: List[np.ndarray] = dataclasses.field(default_factory=list)
    wall: List[float] = dataclasses.field(default_factory=list)
    # resilience accounting (see module docstring)
    crashes: int = 0          # worker threads that died mid-run
    respawns: int = 0         # crashed workers revived (respawn=True)
    join_failures: int = 0    # threads still alive after join(timeout)

    def as_arrays(self):
        return (np.array(self.objective), np.array(self.gammas),
                np.array(self.taus), np.array(self.wall))


class PIAGServer:
    """Threaded parameter server running PIAG with delay-adaptive step-sizes."""

    def __init__(self, problem, policy: StepsizePolicy, prox: ProxOp,
                 n_workers: Optional[int] = None, record_every: int = 1,
                 worker_sleep: Optional[Callable[[int], float]] = None,
                 heartbeat: float = 5.0, respawn: bool = False,
                 max_respawns: int = 2):
        self.problem = problem
        self.policy = policy
        self.prox = prox
        self.n = n_workers or problem.n_workers
        self.record_every = record_every
        self.worker_sleep = worker_sleep  # optional artificial heterogeneity
        # resilience knobs: heartbeat bounds how long the master waits for
        # ANY worker result before declaring the run wedged; respawn revives
        # crashed workers (up to max_respawns each) instead of aborting
        self.heartbeat = float(heartbeat)
        self.respawn = bool(respawn)
        self.max_respawns = int(max_respawns)
        Aw, bw = problem.worker_slices()
        self._Aw = [np.asarray(Aw[i]) for i in range(self.n)]
        self._bw = [np.asarray(bw[i]) for i in range(self.n)]
        self._grad_i = jax.jit(jax.grad(problem.worker_loss))
        self._P = jax.jit(problem.P)
        # step-size state lives on host: tiny scalars, master-only access
        self._ss = policy.init()
        self._ss_step = jax.jit(policy.step)

    def run(self, n_events: int, x0: Optional[np.ndarray] = None) -> RunLog:
        d = self.problem.dim
        x = jnp.zeros((d,), jnp.float32) if x0 is None else jnp.asarray(x0)
        # bounded queues: a master-sent iterate per worker plus slack on the
        # return path -- a stalled peer can never grow a queue without bound
        in_q = [queue.Queue(maxsize=2) for _ in range(self.n)]
        out_q = queue.Queue(maxsize=2 * self.n + 1)
        stop = threading.Event()
        tracker = DelayTracker()
        errors: dict = {}        # worker index -> boxed exception
        log = RunLog()

        def worker(i: int):
            try:
                while not stop.is_set():
                    try:
                        xk, k = in_q[i].get(timeout=0.1)
                    except queue.Empty:
                        continue
                    if self.worker_sleep is not None:
                        time.sleep(self.worker_sleep(i))
                    g = self._grad_i(xk, self._Aw[i], self._bw[i])
                    g.block_until_ready()   # compute outside the master's loop
                    out_q.put((i, g, k))
            except BaseException as exc:    # box it; master re-raises
                errors[i] = exc
                try:
                    out_q.put_nowait(("__crash__", i, exc))  # wake the master
                except queue.Full:
                    pass

        def spawn(i: int) -> threading.Thread:
            t = threading.Thread(target=worker, args=(i,), daemon=True)
            t.start()
            return t

        threads = [spawn(i) for i in range(self.n)]

        def get_result(k: int):
            """out_q.get with a heartbeat: surfaces crashed workers instead
            of blocking forever (the old master deadlocked here)."""
            waited = 0.0
            while True:
                try:
                    msg = out_q.get(timeout=0.25)
                except queue.Empty:
                    waited += 0.25
                    live = [t.is_alive() for t in threads]
                    if errors and not self.respawn:
                        i = next(iter(errors))
                        raise WorkerCrash(
                            f"worker {i} died at write event {k}"
                        ) from errors[i]
                    if not any(live):
                        raise WorkerCrash(
                            f"all {self.n} workers dead at write event {k}")
                    if waited >= self.heartbeat:
                        raise TimeoutError(
                            f"no worker result within heartbeat="
                            f"{self.heartbeat}s at write event {k} "
                            f"({sum(live)}/{self.n} workers alive)")
                    continue
                if msg[0] == "__crash__":
                    _, i, exc = msg
                    log.crashes += 1
                    if self.respawn and self._respawn_budget[i] > 0:
                        self._respawn_budget[i] -= 1
                        log.respawns += 1
                        # rejoin semantics: the revived worker restarts from
                        # the CURRENT iterate/version -- re-stamp its tracker
                        # entry so it does not carry pre-crash staleness
                        tracker.stamp(i, k)
                        threads[i] = spawn(i)
                        errors.pop(i, None)
                        in_q[i].put((self._x_live, k))
                        continue
                    raise WorkerCrash(
                        f"worker {i} died at write event {k}") from exc
                return msg

        self._respawn_budget = {i: self.max_respawns for i in range(self.n)}

        # Algorithm 1 init: g^(i) = grad f_i(x_0)
        g_table = [self._grad_i(x, self._Aw[i], self._bw[i]) for i in range(self.n)]
        g_sum = sum(g_table[1:], g_table[0])
        for i in range(self.n):
            tracker.stamp(i, 0)
            in_q[i].put((x, 0))

        t0 = time.perf_counter()
        ss = self._ss
        self._x_live = x
        try:
            for k in range(n_events):
                i, g_new, s_read = get_result(k)
                # lines 11-13: replace worker i's table entry, stamp s^(i)
                g_sum = g_sum - g_table[i] + g_new
                g_table[i] = g_new
                tracker.k = k
                tracker.stamp(i, s_read)
                # line 15: tau_k^(i) = k - s^(i); policy consumes max tau_k^(i)
                delays = tracker.delays()
                tau = max(delays.values())
                gamma, ss = self._ss_step(ss, jnp.int32(tau))
                gamma_f = float(gamma)
                # line 17: x_{k+1} = prox_{gamma R}(x_k - gamma g_k)
                x = self.prox.prox(x - gamma * (g_sum / self.n), gamma)
                self._x_live = x
                # line 20: send x_{k+1} (version k+1) back to the idle worker
                tracker.stamp(i, k + 1)
                in_q[i].put((x, k + 1))
                if k % self.record_every == 0:
                    log.objective.append(float(self._P(x)))
                    log.gammas.append(gamma_f)
                    log.taus.append(int(tau))
                    log.taus_per_worker.append(np.array(sorted(delays.values())))
                    log.wall.append(time.perf_counter() - t0)
        finally:
            stop.set()
            # drain the bounded return queue so no worker stays blocked on a
            # full out_q.put while we try to join it
            try:
                while True:
                    out_q.get_nowait()
            except queue.Empty:
                pass
            for i, t in enumerate(threads):
                t.join(timeout=1.0)
                if t.is_alive():
                    log.join_failures += 1
                    warnings.warn(
                        f"PIAGServer worker {i} did not exit within 1s of "
                        "stop; thread leaked (daemon -- it dies with the "
                        "process)", RuntimeWarning, stacklevel=2)
        self.x_final = np.asarray(x)
        return log


class SharedMemoryBCD:
    """Threaded shared-memory Async-BCD with inconsistent reads."""

    def __init__(self, problem, policy: StepsizePolicy, prox: ProxOp,
                 n_workers: int = 8, m_blocks: int = 20, record_every: int = 1,
                 seed: int = 0):
        self.problem = problem
        self.policy = policy
        self.prox = prox
        self.n = n_workers
        self.m = m_blocks
        self.record_every = record_every
        self.seed = seed
        d = problem.dim
        self.db = -(-d // m_blocks)
        self._grad = jax.jit(problem.grad_f)
        self._P = jax.jit(problem.P)
        self._ss_step = jax.jit(policy.step)

    def run(self, n_events: int, x0: Optional[np.ndarray] = None) -> RunLog:
        d = self.problem.dim
        # shared iterate: plain numpy => unlocked reads are inconsistent (Eq. 6)
        x = np.zeros((d,), np.float32) if x0 is None else np.array(x0, np.float32)
        lock = threading.Lock()
        counter = {"k": 0}
        ss_box = {"ss": self.policy.init()}
        log = RunLog()
        objectives: dict = {}   # write event k -> P(x) (filled outside the lock)
        t0 = time.perf_counter()
        stop = threading.Event()

        errors: dict = {}        # worker index -> boxed exception

        def worker(i: int):
            try:
                self._bcd_loop(i, n_events, x, lock, counter, ss_box, log,
                               objectives, stop, t0, d)
            except BaseException as exc:
                errors[i] = exc

        threads = [threading.Thread(target=worker, args=(i,), daemon=True)
                   for i in range(self.n)]
        for t in threads:
            t.start()
        # the master owns no events here (Algorithm 2 is fully decentralized)
        # -- it only waits for the write counter.  The old loop spun forever
        # if every worker died; now dead/excepted workers surface.
        while counter["k"] < n_events:
            time.sleep(0.01)
            if errors:
                stop.set()
                i = next(iter(errors))
                raise WorkerCrash(
                    f"SharedMemoryBCD worker {i} died at write event "
                    f"{counter['k']}/{n_events}") from errors[i]
            if not any(t.is_alive() for t in threads):
                if counter["k"] >= n_events:
                    break   # workers finished between the two checks
                raise WorkerCrash(
                    f"all {self.n} BCD workers exited at write event "
                    f"{counter['k']}/{n_events} without finishing")
        stop.set()
        for i, t in enumerate(threads):
            t.join(timeout=5.0)
            if t.is_alive():
                log.join_failures += 1
                warnings.warn(
                    f"SharedMemoryBCD worker {i} did not exit within 5s of "
                    "stop; thread leaked (daemon -- it dies with the "
                    "process)", RuntimeWarning, stacklevel=2)
        log.crashes = len(errors)
        # scalar rows were appended in write-event order under the lock;
        # reassemble the objective column in the same order.  If a straggler
        # thread outlived the join with its deferred P(x) still pending, trim
        # the scalar columns so all four stay aligned.
        obj_sorted = [objectives[k] for k in sorted(objectives)]
        n_rows = len(obj_sorted)
        if n_rows < len(log.gammas):
            del log.gammas[n_rows:], log.taus[n_rows:], log.wall[n_rows:]
        log.objective.extend(obj_sorted)
        self.x_final = x.copy()
        return log

    def _bcd_loop(self, i: int, n_events: int, x, lock, counter, ss_box,
                  log, objectives, stop, t0, d):
        rng = np.random.default_rng(self.seed + i)
        while not stop.is_set():
            s_read = counter["k"]            # Algorithm 2 line 10 (stamp)
            xhat = x.copy()                  # unlocked read -> inconsistent
            j = int(rng.integers(0, self.m))  # line 3
            g = np.asarray(self._grad(jnp.asarray(xhat)))  # line 4
            lo, hi = j * self.db, min((j + 1) * self.db, d)
            gj = g[lo:hi]
            x_snap = None
            with lock:                        # lines 5-9 critical section
                k = counter["k"]
                if k >= n_events:
                    return
                tau = k - s_read              # line 5
                gamma, ss_box["ss"] = self._ss_step(ss_box["ss"], jnp.int32(tau))
                gamma_f = float(gamma)        # line 6
                xj = x[lo:hi] - gamma_f * gj
                x[lo:hi] = np.asarray(self.prox.prox(jnp.asarray(xj), gamma_f))
                counter["k"] = k + 1          # line 9 (write event)
                if k % self.record_every == 0:
                    # record scalars + an iterate snapshot inside the
                    # lock; the O(Nd) objective matvec runs OUTSIDE it so
                    # workers are not serialized on a jitted dense matvec
                    # every record_every events
                    log.gammas.append(gamma_f)
                    log.taus.append(int(tau))
                    log.wall.append(time.perf_counter() - t0)
                    x_snap = (k, x.copy())
            if x_snap is not None:
                k_rec, xs = x_snap
                objectives[k_rec] = float(self._P(jnp.asarray(xs)))
