"""Benchmark problems: regularized logistic regression (the paper's §4
workload, with rcv1-like sparse and MNIST-like dense synthetic generators)
and quadratics with known curvature (for exactness tests / Example 1)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def worker_rms_smoothness(A: np.ndarray, n_workers: int, denom_scale: float,
                          shift: float = 0.0) -> float:
    """RMS of per-shard smoothness constants over an n-way contiguous sample
    split: L_i = lambda_max(A_i^T A_i) / (denom_scale * N_i) + shift, returned
    as sqrt(mean L_i^2) -- the worker-split L both convex problems use
    (denom_scale=4 for logistic, 1 for least squares)."""
    n = n_workers
    N = (A.shape[0] // n) * n
    shards = A[:N].reshape(n, -1, A.shape[1])
    Ls = [power_iteration_sq(shards[i]) / (denom_scale * shards[i].shape[0]) + shift
          for i in range(n)]
    return float(np.sqrt(np.mean(np.square(Ls))))


def power_iteration_sq(A: np.ndarray, iters: int = 200, seed: int = 0) -> float:
    """lambda_max(A^T A) via power iteration (no scipy dependency needed)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(A.shape[1],))
    v /= np.linalg.norm(v)
    lam = 0.0
    for _ in range(iters):
        w = A.T @ (A @ v)
        lam = float(np.linalg.norm(w))
        if lam == 0.0:
            return 0.0
        v = w / lam
    return lam


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """f(x) = (1/N) sum_i log(1 + exp(-b_i a_i^T x)) + (lam2/2)||x||^2,
    R(x) = lam1 ||x||_1  -- the paper's experimental setup."""

    A: jnp.ndarray          # (N, d)
    b: jnp.ndarray          # (N,) in {-1, +1}
    lam1: float
    lam2: float
    L: float                # sqrt((1/n) sum L_i^2) over the worker split
    Lhat: float             # block-coordinate smoothness (Assumption 1)
    n_workers: int

    @property
    def dim(self) -> int:
        return int(self.A.shape[1])

    # -- smooth part -------------------------------------------------------
    def f(self, x: jnp.ndarray) -> jnp.ndarray:
        z = self.b * (self.A @ x)
        return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * self.lam2 * jnp.sum(x * x)

    def grad_f(self, x: jnp.ndarray) -> jnp.ndarray:
        z = self.b * (self.A @ x)
        s = -self.b * jax.nn.sigmoid(-z)  # d/dz logaddexp(0,-z) * b
        return self.A.T @ s / self.A.shape[0] + self.lam2 * x

    # -- per-worker pieces: f = (1/n) sum_i f_i ----------------------------
    def worker_slices(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Split samples into n contiguous equal shards -> (n, N/n, d), (n, N/n)."""
        n = self.n_workers
        N = (self.A.shape[0] // n) * n
        return (self.A[:N].reshape(n, -1, self.A.shape[1]),
                self.b[:N].reshape(n, -1))

    def worker_loss(self, x: jnp.ndarray, Aw: jnp.ndarray, bw: jnp.ndarray) -> jnp.ndarray:
        """f_i: full-objective-scale loss on shard i (so that (1/n) sum f_i = f)."""
        z = bw * (Aw @ x)
        return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * self.lam2 * jnp.sum(x * x)

    # -- composite objective ----------------------------------------------
    def P(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.f(x) + self.lam1 * jnp.sum(jnp.abs(x))

    def full_smoothness(self) -> float:
        """Smoothness constant of the FULL objective f (not the worker RMS):
        lambda_max(A^T A)/(4N) + lam2."""
        A = np.asarray(self.A)
        return float(power_iteration_sq(A) / (4.0 * A.shape[0]) + self.lam2)

    def block_smoothness(self, m: int) -> float:
        """Assumption 1's block-wise constant Lhat for an m-block partition:
        max_J lambda_max(A_{:,J}^T A_{:,J}) / (4N) + lam2.

        The coordinate-wise ``self.Lhat`` under-estimates this whenever
        columns within a block are correlated (dense MNIST-like data) --
        using it as 1/gamma' makes Async-BCD oscillate."""
        A = np.asarray(self.A)
        N, d = A.shape
        db = -(-d // m)
        worst = 0.0
        for j in range(m):
            blk = A[:, j * db:(j + 1) * db]
            if blk.shape[1] == 0:
                continue
            worst = max(worst, power_iteration_sq(blk, seed=j))
        return float(worst / (4.0 * N) + self.lam2)


def make_logreg(
    n_samples: int = 2000,
    dim: int = 200,
    n_workers: int = 10,
    sparse_like: bool = True,
    lam1: float = 1e-5,
    lam2: float = 1e-4,
    seed: int = 0,
) -> LogRegProblem:
    """Synthetic classification data.

    ``sparse_like=True`` mimics rcv1 (high-dim, ~1% dense, normalized rows);
    ``False`` mimics MNIST (dense, bounded features).  Offline container ->
    synthetic stand-ins with matched statistics; lam defaults follow §4.
    """
    rng = np.random.default_rng(seed)
    x_star = rng.normal(size=(dim,)) / np.sqrt(dim)
    if sparse_like:
        density = 0.05
        mask = rng.random((n_samples, dim)) < density
        A = rng.normal(size=(n_samples, dim)) * mask
        norms = np.linalg.norm(A, axis=1, keepdims=True)
        A = A / np.maximum(norms, 1e-12)  # rcv1 rows are l2-normalized
    else:
        A = np.abs(rng.normal(size=(n_samples, dim))) * (rng.random((n_samples, dim)) < 0.25)
        A = A / max(np.abs(A).max(), 1e-12)
    logits = A @ x_star + 0.3 * rng.normal(size=(n_samples,))
    b = np.where(logits >= 0, 1.0, -1.0)

    # Worker-wise smoothness: f_i is the mean loss over shard i, so
    # L_i <= lambda_max(A_i^T A_i)/(4 N_i) + lam2.
    L = worker_rms_smoothness(A, n_workers, denom_scale=4.0, shift=lam2)
    # Block smoothness (Assumption 1): Lhat <= max_j ||A_{:,j}||^2/(4N) + lam2
    col_sq = (A * A).sum(axis=0)
    Lhat = float(col_sq.max() / (4.0 * n_samples) + lam2)

    return LogRegProblem(
        A=jnp.asarray(A, jnp.float32), b=jnp.asarray(b, jnp.float32),
        lam1=lam1, lam2=lam2, L=L, Lhat=Lhat, n_workers=n_workers,
    )


@dataclasses.dataclass(frozen=True)
class LassoProblem:
    """f(x) = (1/2N) ||A x - y||^2, R(x) = lam1 ||x||_1 -- the classic lasso,
    shardable over samples exactly like ``LogRegProblem`` (f = (1/n) sum f_i
    with f_i the full-scale loss on shard i), so it plugs into PIAG and the
    federated servers unchanged."""

    A: jnp.ndarray          # (N, d)
    y: jnp.ndarray          # (N,)
    lam1: float
    L: float                # smoothness over the worker split
    n_workers: int

    @property
    def dim(self) -> int:
        return int(self.A.shape[1])

    def f(self, x: jnp.ndarray) -> jnp.ndarray:
        r = self.A @ x - self.y
        return 0.5 * jnp.mean(r * r)

    def grad_f(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.A.T @ (self.A @ x - self.y) / self.A.shape[0]

    def worker_slices(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        n = self.n_workers
        N = (self.A.shape[0] // n) * n
        return (self.A[:N].reshape(n, -1, self.A.shape[1]),
                self.y[:N].reshape(n, -1))

    def worker_loss(self, x: jnp.ndarray, Aw: jnp.ndarray, yw: jnp.ndarray) -> jnp.ndarray:
        r = Aw @ x - yw
        return 0.5 * jnp.mean(r * r)

    def P(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.f(x) + self.lam1 * jnp.sum(jnp.abs(x))

    def full_smoothness(self) -> float:
        A = np.asarray(self.A)
        return float(power_iteration_sq(A) / A.shape[0])


def make_lasso(
    n_samples: int = 1000,
    dim: int = 100,
    n_workers: int = 10,
    density: float = 0.1,
    lam1: float = 1e-3,
    noise: float = 0.01,
    seed: int = 0,
) -> LassoProblem:
    """Sparse-ground-truth least squares: y = A x* + noise with x* ``density``
    -sparse; lam1 defaults near the support-recovery regime."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_samples, dim)) / np.sqrt(n_samples)
    x_star = np.where(rng.random(dim) < density, rng.normal(size=dim), 0.0)
    y = A @ x_star + noise * rng.normal(size=n_samples)

    L = worker_rms_smoothness(A, n_workers, denom_scale=1.0)
    return LassoProblem(A=jnp.asarray(A, jnp.float32),
                        y=jnp.asarray(y, jnp.float32),
                        lam1=lam1, L=L, n_workers=n_workers)


def solve_centralized(problem, prox, iters: int = 3000):
    """Reference minimizer of P = f + R by (accelerated) proximal gradient
    descent on the FULL data -- the centralized optimum that asynchronous /
    federated runs are measured against.

    Returns ``(x_star, P_trace)``; ``P_trace[-1]`` is the best available
    estimate of P*.  FISTA momentum with lr = 1/L_full, jitted end-to-end.
    """
    lr = 1.0 / problem.full_smoothness()
    x0 = jnp.zeros((problem.dim,), jnp.float32)

    def step(carry, _):
        x, z, t = carry
        g = problem.grad_f(z)
        x_new = prox.prox(z - lr * g, lr)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = x_new + ((t - 1.0) / t_new) * (x_new - x)
        return (x_new, z_new, t_new), problem.P(x_new)

    @jax.jit
    def run(carry0):
        return jax.lax.scan(step, carry0, None, length=iters)

    (x_fin, _, _), objs = run((x0, x0, jnp.ones((), jnp.float32)))
    return x_fin, objs


@dataclasses.dataclass(frozen=True)
class Quadratic:
    """f(x) = 0.5 ||x||^2 scaled -- Example 1's problem (n = d = 1 scalar)."""

    curvature: float = 1.0

    def f(self, x):
        return 0.5 * self.curvature * jnp.sum(x * x)

    def grad_f(self, x):
        return self.curvature * x

    @property
    def L(self):
        return self.curvature
