"""Benchmark problems: regularized logistic regression (the paper's §4
workload, with rcv1-like sparse and MNIST-like dense synthetic generators)
and quadratics with known curvature (for exactness tests / Example 1)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def power_iteration_sq(A: np.ndarray, iters: int = 200, seed: int = 0) -> float:
    """lambda_max(A^T A) via power iteration (no scipy dependency needed)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(A.shape[1],))
    v /= np.linalg.norm(v)
    lam = 0.0
    for _ in range(iters):
        w = A.T @ (A @ v)
        lam = float(np.linalg.norm(w))
        if lam == 0.0:
            return 0.0
        v = w / lam
    return lam


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """f(x) = (1/N) sum_i log(1 + exp(-b_i a_i^T x)) + (lam2/2)||x||^2,
    R(x) = lam1 ||x||_1  -- the paper's experimental setup."""

    A: jnp.ndarray          # (N, d)
    b: jnp.ndarray          # (N,) in {-1, +1}
    lam1: float
    lam2: float
    L: float                # sqrt((1/n) sum L_i^2) over the worker split
    Lhat: float             # block-coordinate smoothness (Assumption 1)
    n_workers: int

    @property
    def dim(self) -> int:
        return int(self.A.shape[1])

    # -- smooth part -------------------------------------------------------
    def f(self, x: jnp.ndarray) -> jnp.ndarray:
        z = self.b * (self.A @ x)
        return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * self.lam2 * jnp.sum(x * x)

    def grad_f(self, x: jnp.ndarray) -> jnp.ndarray:
        z = self.b * (self.A @ x)
        s = -self.b * jax.nn.sigmoid(-z)  # d/dz logaddexp(0,-z) * b
        return self.A.T @ s / self.A.shape[0] + self.lam2 * x

    # -- per-worker pieces: f = (1/n) sum_i f_i ----------------------------
    def worker_slices(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Split samples into n contiguous equal shards -> (n, N/n, d), (n, N/n)."""
        n = self.n_workers
        N = (self.A.shape[0] // n) * n
        return (self.A[:N].reshape(n, -1, self.A.shape[1]),
                self.b[:N].reshape(n, -1))

    def worker_loss(self, x: jnp.ndarray, Aw: jnp.ndarray, bw: jnp.ndarray) -> jnp.ndarray:
        """f_i: full-objective-scale loss on shard i (so that (1/n) sum f_i = f)."""
        z = bw * (Aw @ x)
        return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * self.lam2 * jnp.sum(x * x)

    # -- composite objective ----------------------------------------------
    def P(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.f(x) + self.lam1 * jnp.sum(jnp.abs(x))

    def block_smoothness(self, m: int) -> float:
        """Assumption 1's block-wise constant Lhat for an m-block partition:
        max_J lambda_max(A_{:,J}^T A_{:,J}) / (4N) + lam2.

        The coordinate-wise ``self.Lhat`` under-estimates this whenever
        columns within a block are correlated (dense MNIST-like data) --
        using it as 1/gamma' makes Async-BCD oscillate."""
        A = np.asarray(self.A)
        N, d = A.shape
        db = -(-d // m)
        worst = 0.0
        for j in range(m):
            blk = A[:, j * db:(j + 1) * db]
            if blk.shape[1] == 0:
                continue
            worst = max(worst, power_iteration_sq(blk, seed=j))
        return float(worst / (4.0 * N) + self.lam2)


def make_logreg(
    n_samples: int = 2000,
    dim: int = 200,
    n_workers: int = 10,
    sparse_like: bool = True,
    lam1: float = 1e-5,
    lam2: float = 1e-4,
    seed: int = 0,
) -> LogRegProblem:
    """Synthetic classification data.

    ``sparse_like=True`` mimics rcv1 (high-dim, ~1% dense, normalized rows);
    ``False`` mimics MNIST (dense, bounded features).  Offline container ->
    synthetic stand-ins with matched statistics; lam defaults follow §4.
    """
    rng = np.random.default_rng(seed)
    x_star = rng.normal(size=(dim,)) / np.sqrt(dim)
    if sparse_like:
        density = 0.05
        mask = rng.random((n_samples, dim)) < density
        A = rng.normal(size=(n_samples, dim)) * mask
        norms = np.linalg.norm(A, axis=1, keepdims=True)
        A = A / np.maximum(norms, 1e-12)  # rcv1 rows are l2-normalized
    else:
        A = np.abs(rng.normal(size=(n_samples, dim))) * (rng.random((n_samples, dim)) < 0.25)
        A = A / max(np.abs(A).max(), 1e-12)
    logits = A @ x_star + 0.3 * rng.normal(size=(n_samples,))
    b = np.where(logits >= 0, 1.0, -1.0)

    # Worker-wise smoothness: f_i is the mean loss over shard i, so
    # L_i <= lambda_max(A_i^T A_i)/(4 N_i) + lam2.
    n = n_workers
    N = (n_samples // n) * n
    Ls = []
    for i in range(n):
        Ai = A[:N].reshape(n, -1, dim)[i]
        Ls.append(power_iteration_sq(Ai) / (4.0 * Ai.shape[0]) + lam2)
    L = float(np.sqrt(np.mean(np.square(Ls))))
    # Block smoothness (Assumption 1): Lhat <= max_j ||A_{:,j}||^2/(4N) + lam2
    col_sq = (A * A).sum(axis=0)
    Lhat = float(col_sq.max() / (4.0 * n_samples) + lam2)

    return LogRegProblem(
        A=jnp.asarray(A, jnp.float32), b=jnp.asarray(b, jnp.float32),
        lam1=lam1, lam2=lam2, L=L, Lhat=Lhat, n_workers=n_workers,
    )


@dataclasses.dataclass(frozen=True)
class Quadratic:
    """f(x) = 0.5 ||x||^2 scaled -- Example 1's problem (n = d = 1 scalar)."""

    curvature: float = 1.0

    def f(self, x):
        return 0.5 * self.curvature * jnp.sum(x * x)

    def grad_f(self, x):
        return self.curvature * x

    @property
    def L(self):
        return self.curvature
