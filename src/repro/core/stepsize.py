"""Delay-adaptive step-size policies (Wu et al., 2022).

Implements the general step-size principle (Eq. 8)

    0 <= gamma_k <= max(0, gamma' - sum_{t=k-tau_k}^{k-1} gamma_t)

and the concrete policies from the paper:

* ``FixedStepSize``      -- gamma_k = gamma' / (tau_bound + 1)  (state of the art
                            fixed policy used as the paper's baseline; needs the
                            *worst-case* delay bound).
* ``SunDengFixed``       -- gamma_k = h / (L (tau_bound + 1/2))  [Sun'19, Deng'20].
* ``DavisFixed``         -- gamma_k = h / (Lhat + 2 L tau / sqrt(m)) [Davis'16],
                            the Async-BCD baseline.
* ``NaiveAdaptive``      -- gamma_k = c / (tau_k + b)  (Eq. 7) which *diverges*
                            (Example 1); kept to reproduce the failure mode.
* ``Adaptive1``          -- gamma_k = alpha * max(gamma' - window_sum, 0)  (Eq. 13).
* ``Adaptive2``          -- gamma_k = gamma'/(tau_k+1) when it fits the remaining
                            window budget, else 0  (Eq. 14).
* ``HingeWeight``        -- gamma' * s(tau), hinge staleness discount
                            [FedAsync, Xie'19]: the federated mixing weight.
* ``PolyWeight``         -- gamma' * (tau+1)^(-a), polynomial staleness
                            discount [FedAsync, Xie'19].

All policies are pure-functional and jit/scan-compatible.  The window sum
``sum_{t=k-tau_k}^{k-1} gamma_t`` is computed in O(1) from a circular buffer of
cumulative sums: ``buf[(j-1) % H]`` stores ``S_j = sum_{t<j} gamma_t`` so that
``window_sum(k, tau) = S_k - S_{k-tau}``.  ``H`` caps the largest observable
delay; delays beyond the horizon are clipped (and flagged).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import ClassVar, NamedTuple, Tuple

import jax
import jax.numpy as jnp

DEFAULT_HORIZON = 4096


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1)."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def auto_horizon(tau_bar: int, slack: int = 1) -> int:
    """Measured-delay horizon sizing: the smallest power-of-two buffer that
    represents every observed delay with ``slack`` headroom.

    The largest delay ``window_sum`` can represent is ``H - 1``, so any
    ``H >= tau_bar + 1`` reproduces the default ``DEFAULT_HORIZON = 4096``
    run *bitwise* (the circular cumulative-sum buffer reads the same
    ``S_{k-tau}`` values whenever no delay clips).  Sizing by the measured
    tau-bar instead of the worst-case default is the engine-level analogue of
    the paper's thesis -- pay for the delays you *measure*, not the bound you
    fear -- and shrinks the per-cell scan carry by ``4096 / H``.

    ``slack`` (>= 1) is headroom above the measurement; the ``clipped``
    counter stays as the runtime safety net for delays beyond it.
    """
    if slack < 1:
        raise ValueError(f"auto-horizon slack must be >= 1, got {slack}")
    return max(2, next_pow2(int(tau_bar) + int(slack)))


class StepsizeState(NamedTuple):
    """Carry for a step-size policy inside ``lax.scan``/``jit``.

    Attributes:
      k:        current iteration counter (int32 scalar).
      total:    S_k = sum of all step-sizes emitted so far (float32 scalar).
      cumbuf:   circular buffer of cumulative sums; ``cumbuf[(j-1) % H] = S_j``.
      clipped:  number of times a delay exceeded the horizon (diagnostic).
    """

    k: jnp.ndarray
    total: jnp.ndarray
    cumbuf: jnp.ndarray
    clipped: jnp.ndarray

    @property
    def horizon(self) -> int:
        return self.cumbuf.shape[-1]


def init_state(horizon: int = DEFAULT_HORIZON,
               batch_shape: Tuple[int, ...] = ()) -> StepsizeState:
    """Fresh policy state; ``batch_shape`` prepends grid dimensions.

    A batched state steps directly: ``window_sum`` / ``_push`` gather and
    scatter along the last (horizon) axis, so ``policy.step(state, taus)``
    with a ``batch_shape`` state and a matching batch of delays advances
    every cell's independent circular buffer in one call -- no ``vmap``
    required (``repro.sweep`` vmaps whole solver scans instead, where the
    per-cell state is scalar; this path serves host-side batched policy
    experiments).
    """
    return StepsizeState(
        k=jnp.zeros(batch_shape, jnp.int32),
        total=jnp.zeros(batch_shape, jnp.float32),
        cumbuf=jnp.zeros(batch_shape + (horizon,), jnp.float32),
        clipped=jnp.zeros(batch_shape, jnp.int32),
    )


def window_sum(state: StepsizeState, tau: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (sum_{t=k-tau}^{k-1} gamma_t, was_clipped).

    ``tau`` is clipped to ``[0, min(k, H-1)]``; clipping beyond the horizon
    only ever *under-estimates* the window sum, which would be unsafe, so we
    also return a flag the caller accumulates (in practice H is chosen > any
    system delay; the dry-run configs use H=4096).

    The cap is ``H - 1``, not ``H``: we need ``S_{k-tau}``, which lives in
    buffer slot ``(k - tau - 1) % H``, and at ``tau = H`` that slot collides
    with ``(k - 1) % H`` -- just overwritten with ``S_k`` -- so the window
    sum would silently read as zero (regression pinned in
    ``tests/test_stepsize_properties.py::test_window_sum_horizon_clipping_edge``).
    """
    H = state.horizon
    k = state.k
    tau = jnp.asarray(tau, jnp.int32)
    cap = jnp.minimum(k, H - 1)
    tau_c = jnp.clip(tau, 0, cap)
    was_clipped = (tau > cap).astype(jnp.int32)
    j = k - tau_c  # we need S_j
    if state.cumbuf.ndim == 1:
        s_read = state.cumbuf[(j - 1) % H]
    else:  # batched state (init_state(batch_shape=...)): gather per cell
        s_read = jnp.take_along_axis(
            state.cumbuf, (((j - 1) % H)[..., None]), axis=-1)[..., 0]
    s_j = jnp.where(j <= 0, 0.0, s_read)
    return state.total - s_j, was_clipped


def _push(state: StepsizeState, gamma: jnp.ndarray, was_clipped: jnp.ndarray) -> StepsizeState:
    H = state.horizon
    new_total = state.total + gamma
    if state.cumbuf.ndim == 1:
        cumbuf = state.cumbuf.at[state.k % H].set(new_total)
    else:  # batched state: indexed scatter of each cell's slot, mirroring
        # the take_along_axis gather in window_sum (a boolean-mask + where
        # here would materialize an O(H) write per step)
        cumbuf = jnp.put_along_axis(
            state.cumbuf, (state.k % H)[..., None], new_total[..., None],
            axis=-1, inplace=False)
    return StepsizeState(
        k=state.k + 1,
        total=new_total,
        cumbuf=cumbuf,
        clipped=state.clipped + was_clipped,
    )


@dataclasses.dataclass(frozen=True)
class StepsizePolicy:
    """Base class.  ``gamma_prime`` is gamma' = h/L (or h/Lhat for BCD)."""

    gamma_prime: float

    # True on policies whose gamma CONSUMES the window sum (adaptive1/2):
    # for those, a clipped delay in ``run`` is worth reporting; the
    # fixed/naive/weight families call ``window_sum`` only for uniform
    # buffer diagnostics and stay quiet.
    uses_window: ClassVar[bool] = False

    def init(self, horizon: int = DEFAULT_HORIZON) -> StepsizeState:
        return init_state(horizon)

    def _gamma(self, state: StepsizeState, tau: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError

    def step(self, state: StepsizeState, tau: jnp.ndarray) -> Tuple[jnp.ndarray, StepsizeState]:
        """Consume the observed delay ``tau_k`` and emit ``gamma_k``."""
        gamma, was_clipped = self._gamma(state, tau)
        gamma = jnp.asarray(gamma, jnp.float32)
        return gamma, _push(state, gamma, was_clipped)

    # Convenience for numpy-land experiments / benchmarks.
    def run(self, taus) -> jnp.ndarray:
        """Emit the full step-size sequence for a delay trace (jit-scanned).

        The buffer is sized from the trace's own largest delay
        (``auto_horizon(max(taus))``), so a window sum is never silently
        truncated by an undersized horizon -- the old
        ``min(DEFAULT_HORIZON, len(taus))`` sizing clipped any trace longer
        than 4096 events that carried a delay >= 4096.  Delays that still
        exceed the available history (``tau > k``: asking for more steps
        than have happened; exact only because ``window_sum`` clamps to the
        full recorded sum) are counted and reported via ``RuntimeWarning``
        -- undersizing is loud, never silent (ROADMAP durable semantics).
        """
        taus = jnp.asarray(taus, jnp.int32)

        def body(state, tau):
            g, state = self.step(state, tau)
            return state, g

        horizon = _run_horizon(taus)
        state, gammas = jax.lax.scan(body, self.init(horizon), taus)
        if self.uses_window:
            _warn_clipped(state, type(self).__name__)
        return gammas


@dataclasses.dataclass(frozen=True)
class FixedStepSize(StepsizePolicy):
    """gamma_k = gamma' / (tau_bound + 1).  Requires the worst-case bound."""

    tau_bound: int = 0

    def _gamma(self, state, tau):
        _, clip = window_sum(state, tau)  # keep the buffer diagnostics uniform
        return jnp.full((), self.gamma_prime / (self.tau_bound + 1), jnp.float32), clip


@dataclasses.dataclass(frozen=True)
class SunDengFixed(StepsizePolicy):
    """gamma_k = h/(L (tau + 1/2)) per [Sun et al. '19; Deng et al. '20].

    Construct with gamma_prime = h/L; the policy divides by (tau_bound + 1/2).
    """

    tau_bound: int = 0

    def _gamma(self, state, tau):
        _, clip = window_sum(state, tau)
        return jnp.full((), self.gamma_prime / (self.tau_bound + 0.5), jnp.float32), clip


@dataclasses.dataclass(frozen=True)
class DavisFixed(StepsizePolicy):
    """Async-BCD baseline gamma_k = h / (Lhat + 2 L tau / sqrt(m)) [Davis'16].

    ``gamma_prime`` must be h/Lhat; ``ratio`` is (2 L / (Lhat sqrt(m))).
    """

    tau_bound: int = 0
    ratio: float = 2.0

    def _gamma(self, state, tau):
        _, clip = window_sum(state, tau)
        g = self.gamma_prime / (1.0 + self.ratio * self.tau_bound)
        return jnp.full((), g, jnp.float32), clip


@dataclasses.dataclass(frozen=True)
class NaiveAdaptive(StepsizePolicy):
    """The *failing* natural extension gamma_k = c/(tau_k + b)  (Eq. 7)."""

    b: float = 1.0

    def _gamma(self, state, tau):
        _, clip = window_sum(state, tau)
        return self.gamma_prime / (jnp.asarray(tau, jnp.float32) + self.b), clip


@dataclasses.dataclass(frozen=True)
class Adaptive1(StepsizePolicy):
    """Eq. (13): gamma_k = alpha * max(gamma' - window_sum, 0)."""

    alpha: float = 0.9
    uses_window: ClassVar[bool] = True

    def _gamma(self, state, tau):
        ws, clip = window_sum(state, tau)
        return self.alpha * jnp.maximum(self.gamma_prime - ws, 0.0), clip


@dataclasses.dataclass(frozen=True)
class Adaptive2(StepsizePolicy):
    """Eq. (14): gamma'/(tau_k+1) gated by the remaining window budget."""

    uses_window: ClassVar[bool] = True

    def _gamma(self, state, tau):
        ws, clip = window_sum(state, tau)
        cand = self.gamma_prime / (jnp.asarray(tau, jnp.float32) + 1.0)
        budget = self.gamma_prime - ws
        return jnp.where(cand <= budget, cand, 0.0), clip


@dataclasses.dataclass(frozen=True)
class HingeWeight(StepsizePolicy):
    """FedAsync hinge staleness weight [Xie et al. '19]:

        gamma_k = gamma' * s(tau_k),  s(tau) = 1                      tau <= b
                                              1 / (a (tau - b) + 1)  otherwise.

    In the federated server ``gamma'`` plays the role of the base mixing
    weight alpha; s(tau) down-weights stale client models exactly as the
    paper's gamma(tau) down-weights stale gradients.  The ``+1`` keeps
    s continuous at the knee, monotone nonincreasing in tau, and <= 1 for
    EVERY a > 0 (without it, a < 1 would up-weight a stale model above the
    fresh weight).
    """

    a: float = 10.0
    b: float = 4.0

    def _gamma(self, state, tau):
        _, clip = window_sum(state, tau)  # keep buffer diagnostics uniform
        t = jnp.asarray(tau, jnp.float32)
        s = jnp.where(t <= self.b, 1.0,
                      1.0 / (self.a * jnp.maximum(t - self.b, 0.0) + 1.0))
        return self.gamma_prime * s, clip


@dataclasses.dataclass(frozen=True)
class PolyWeight(StepsizePolicy):
    """FedAsync polynomial staleness weight [Xie et al. '19]:

        gamma_k = gamma' * (tau_k + 1)^(-a).

    Monotone decreasing in tau; ``a = 0`` reduces to the constant weight
    (FedAvg-style mixing, no staleness discount).
    """

    a: float = 0.5

    def _gamma(self, state, tau):
        _, clip = window_sum(state, tau)
        t = jnp.asarray(tau, jnp.float32)
        return self.gamma_prime * jnp.power(t + 1.0, -self.a), clip


class LipschitzState(NamedTuple):
    """StepsizeState extended with an on-line curvature estimate."""

    ss: StepsizeState
    L_est: jnp.ndarray       # running max of ||g_k - g_{k-1}|| / ||x_k - x_{k-1}||
    have_prev: jnp.ndarray   # bool


@dataclasses.dataclass(frozen=True)
class AdaptiveLipschitz(StepsizePolicy):
    """BEYOND-PAPER (the paper's §5 future work): estimate the smoothness
    constant on-line and combine it with the delay-adaptive principle.

    gamma' is replaced by h / L_est where L_est is a running (decayed) max of
    secant curvature estimates ||g_k - g_{k-1}|| / ||x_k - x_{k-1}|| supplied
    by the caller via ``observe_curvature``; the window budget of Eq. (8) is
    enforced against the CURRENT h/L_est, so the policy needs neither the
    delay bound NOR the Lipschitz constant.  ``gamma_prime`` acts as the
    initial (optimistic) budget; ``h`` is the safety factor.
    """

    h: float = 0.9
    alpha: float = 0.9
    decay: float = 1.0       # 1.0 = hard max; <1 forgets old curvature
    uses_window: ClassVar[bool] = True

    def init(self, horizon: int = DEFAULT_HORIZON) -> LipschitzState:  # type: ignore[override]
        return LipschitzState(
            ss=init_state(horizon),
            L_est=jnp.asarray(self.h / max(self.gamma_prime, 1e-30), jnp.float32),
            have_prev=jnp.zeros((), jnp.bool_),
        )

    def observe_curvature(self, state: LipschitzState, dg_norm, dx_norm
                          ) -> LipschitzState:
        """Feed ||g_k - g_{k-1}|| and ||x_k - x_{k-1}|| (any worker pair)."""
        sec = jnp.where(dx_norm > 1e-30, dg_norm / jnp.maximum(dx_norm, 1e-30),
                        0.0)
        L_new = jnp.maximum(state.L_est * self.decay, sec)
        return state._replace(L_est=jnp.maximum(L_new, 1e-30),
                              have_prev=jnp.ones((), jnp.bool_))

    def step(self, state: LipschitzState, tau):  # type: ignore[override]
        gp = self.h / state.L_est
        ws, clip = window_sum(state.ss, tau)
        gamma = self.alpha * jnp.maximum(gp - ws, 0.0)
        gamma = jnp.asarray(gamma, jnp.float32)
        return gamma, state._replace(ss=_push(state.ss, gamma, clip))

    def run(self, taus) -> jnp.ndarray:  # curvature-free trace (L fixed at init)
        taus = jnp.asarray(taus, jnp.int32)

        def body(state, tau):
            g, state = self.step(state, tau)
            return state, g

        # sized from the measured delays (NOT the trace length -- a short
        # trace with one large delay used to clip silently); see
        # StepsizePolicy.run
        state, gammas = jax.lax.scan(body, self.init(_run_horizon(taus)),
                                     taus)
        _warn_clipped(state, type(self).__name__)
        return gammas


def _run_horizon(taus: jnp.ndarray) -> int:
    """Buffer sizing for the host-side ``policy.run`` convenience: the
    ``auto_horizon`` of the trace's own largest delay, so every observed
    delay is representable (``H - 1 >= max(taus)``)."""
    tau_max = int(jnp.max(taus)) if int(taus.shape[0]) else 0
    return auto_horizon(max(tau_max, 0))


def _warn_clipped(state, name: str) -> None:
    """Loudness half of the run-sizing contract: report (never swallow) the
    final ``clipped`` count.  With the horizon sized by ``_run_horizon``,
    clips can only come from ``tau > k`` -- a delay claiming more steps than
    have happened -- where ``window_sum`` clamps to the full recorded sum."""
    n = int(clipped_count(state))
    if n:
        warnings.warn(
            f"{name}.run: {n} event(s) carried a delay exceeding the "
            f"available history (tau > min(k, H - 1)); their window sums "
            f"were clamped to the full recorded sum",
            RuntimeWarning, stacklevel=3)


def clipped_count(state) -> jnp.ndarray:
    """The horizon-clip diagnostic of a final policy state (int32 scalar).

    Works for both ``StepsizeState`` and the extended ``LipschitzState``;
    solvers thread this into their result tuples so a sweep can see which
    cells silently truncated window sums (delay > H - 1) instead of having
    to re-run with a bigger horizon to find out.
    """
    if isinstance(state, LipschitzState):
        state = state.ss
    return state.clipped


def clip_delta(old, new) -> jnp.ndarray:
    """Per-event horizon-clip flag: 1 iff the ``policy.step`` transition
    ``old -> new`` clipped its window sum at H - 1 (int32 scalar, traceable).

    The clip counter is monotone and bumps at most once per step, so the
    delta IS the flag; the telemetry accumulators fold it into their
    per-window clip counts (``repro.telemetry.accumulators.observe``).
    """
    return clipped_count(new) - clipped_count(old)


POLICIES = {
    "fixed": FixedStepSize,
    "constant": FixedStepSize,   # tau_bound=0 -> gamma_k = gamma' (FedAvg mixing)
    "sun_deng": SunDengFixed,
    "davis": DavisFixed,
    "naive": NaiveAdaptive,
    "adaptive1": Adaptive1,
    "adaptive2": Adaptive2,
    "adaptive_lipschitz": AdaptiveLipschitz,
    "hinge": HingeWeight,
    "poly": PolyWeight,
}


def make_policy(name: str, gamma_prime: float, **kwargs) -> StepsizePolicy:
    try:
        cls = POLICIES[name]
    except KeyError as e:
        raise ValueError(f"unknown step-size policy {name!r}; options: {sorted(POLICIES)}") from e
    return cls(gamma_prime=gamma_prime, **kwargs)
