"""Numerical instruments for the paper's theory.

* ``check_principle``      -- verify a (gamma, tau) trace satisfies Eq. (8).
* ``verify_theorem1``      -- check the premises (9)-(10) of Theorem 1 on a
                              concrete sequence realization and verify the
                              conclusions (11)-(12).
* ``example1``             -- the paper's Example 1: the naive step-size (7)
                              diverges on f(x) = x^2/2 with tau_k = k mod T.
* ``prop1_lower_bounds``   -- Proposition 1's step-size-integral bounds.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .stepsize import Adaptive1, Adaptive2, NaiveAdaptive, StepsizePolicy

__all__ = [
    "check_principle", "verify_theorem1", "Theorem1Report",
    "example1", "prop1_lower_bounds",
]


def check_principle(gammas, taus, gamma_prime: float,
                    atol: float = None) -> bool:
    """Eq. (8): 0 <= gamma_k <= max(0, gamma' - sum_{t=k-tau_k}^{k-1} gamma_t).

    Default tolerance scales with gamma' to absorb float32 window-sum
    round-off (the policies are exact in their own f32 arithmetic)."""
    if atol is None:
        atol = 1e-5 * max(gamma_prime, 1.0)
    g = np.asarray(gammas, np.float64)
    t = np.asarray(taus, np.int64)
    cum = np.concatenate([[0.0], np.cumsum(g)])  # cum[j] = S_j
    for k in range(len(g)):
        tau = min(int(t[k]), k)
        wsum = cum[k] - cum[k - tau]
        ub = max(0.0, gamma_prime - wsum)
        if g[k] < -atol or g[k] > ub + atol:
            return False
    return True


class Theorem1Report(NamedTuple):
    premises_hold: bool      # (9) with the given sequences and (10)
    conclusion_V: bool       # V_k <= Q_k V_0 for all k       (Eq. 11)
    conclusion_X: bool       # sum_k X_k / Q_k <= V_0          (Eq. 12)


def verify_theorem1(V, X, W, p, r, q, taus, atol: float = 1e-9) -> Theorem1Report:
    """Check Theorem 1 on concrete non-negative sequences.

    All arrays have length K (V has K+1).  Returns which premises hold and
    whether the conclusions then hold -- used by property tests to probe the
    theorem numerically over random instances.
    """
    V = np.asarray(V, np.float64)
    X = np.asarray(X, np.float64)
    W = np.asarray(W, np.float64)
    p = np.asarray(p, np.float64)
    r = np.asarray(r, np.float64)
    q = np.asarray(q, np.float64)
    taus = np.asarray(taus, np.int64)
    K = len(p)

    Q = np.concatenate([[1.0], np.cumprod(q)])  # Q[k] = prod_{j<k} q_j

    prem = True
    for k in range(K):
        tau = min(int(taus[k]), k)
        lhs = X[k + 1] + V[k + 1]
        rhs = q[k] * V[k] + p[k] * W[k - tau:k].sum() - r[k] * W[k]
        if lhs > rhs + atol:
            prem = False
            break
        if p[k] > 0:
            for l in range(k - tau, k + 1):
                bound = r[l] / Q[l + 1] - sum(p[t] / Q[t + 1] for t in range(l + 1, k))
                if p[k] / Q[k + 1] > bound + atol:
                    prem = False
                    break
        if not prem:
            break

    conc_V = bool(np.all(V[1:] <= Q[1:len(V)] * V[0] + atol))
    conc_X = bool(np.sum(X[1:] / Q[1:len(X)]) <= V[0] + atol)
    return Theorem1Report(prem, conc_V, conc_X)


def example1(policy: StepsizePolicy, T: int, n_periods: int = 40,
              x0: float = 1.0):
    """Run x_{k+1} = x_k - gamma_k x_{T floor(k/T)} (PIAG/BCD on f = x^2/2
    with tau_k = k mod T) and return |x_{kT}| at period boundaries."""
    K = T * n_periods
    taus = np.arange(K) % T
    import jax.numpy as jnp
    gammas = np.asarray(policy.run(taus))
    x = float(x0)
    xs = [x]
    for period in range(n_periods):
        s = gammas[period * T:(period + 1) * T].sum()
        x = (1.0 - s) * x
        xs.append(x)
    return np.abs(np.array(xs)), gammas, taus


def example1_divergence_threshold(c: float, b: float) -> int:
    """Example 1 requires T > b (e^{2/c} - 1) for divergence of the naive
    policy gamma_k = c/(tau_k + b)."""
    return int(np.ceil(b * (np.exp(2.0 / c) - 1.0))) + 1


def prop1_lower_bounds(gammas, taus, gamma_prime: float, alpha: float,
                        tau_bound: int):
    """Return (lhs, adaptive1_bound, adaptive2_bound) per Proposition 1:
    sum_{t<=k} gamma_t >= (k+1) alpha gamma'/(tau+1)        (Eq. 15)
    sum_{t<=k} gamma_t >= (k+1) tau gamma'/(tau+1)^2        (Eq. 16)."""
    g = np.asarray(gammas, np.float64)
    k1 = np.arange(1, len(g) + 1)
    lhs = np.cumsum(g)
    b1 = k1 * alpha * gamma_prime / (tau_bound + 1)
    b2 = k1 * tau_bound * gamma_prime / (tau_bound + 1) ** 2
    return lhs, b1, b2
