"""The declarative experiment-spec family (`repro.api`).

Every experiment in this repo has one shape -- (problem, solver, delay
model / topology, step-size policy grid) -> convergence traces -- but the
runners that execute it are scattered across layers (solo ``run_*`` jits,
batched ``sweep_*`` programs, ``shard_map`` mega-grids, federated fused
scans).  The spec family expresses the WHOLE experiment as data:

* ``ProblemSpec``    -- which convex problem (or a prebuilt one) + prox.
* ``SolverSpec``     -- piag | bcd | fedasync | fedbuff + solver knobs.
* ``TopologySpec``   -- worker/client population regimes x worker counts.
* ``DelaySpec``      -- how delays are measured (tau vs tau_max) and the
                        delay model's expected maximum (horizon validation).
* ``PolicyGridSpec`` -- the step-size policy x seed axes of the grid.
* ``ExecutionSpec``  -- backend = solo | batched | sharded + device knobs.
* ``ExperimentSpec`` -- the product; ``repro.api.run(spec)`` compiles it
                        down to the existing scans and returns a unified
                        ``Results`` table.

The contract of the redesign is **bitwise fidelity**: a spec-routed run
reproduces the rows of the runner it dispatches to exactly (pinned in
``tests/test_api.py`` across all four solvers and all three backends) --
the spec layer only *routes*, it never re-implements numerics.

Specs are plain frozen dataclasses: hashable-free config containers that
compare by value and ``dataclasses.replace`` cleanly (sweep one axis by
replacing one field).  Build-time validation catches horizon misconfigs
early: a declared ``DelaySpec.expected_max_delay`` that the solver horizon
cannot represent (the ``window_sum`` H - 1 cap) raises at CONSTRUCTION,
and a measured delay bound that exceeds it raises at resolve time --
instead of relying on the post-hoc per-row ``clipped`` counter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple, Union

__all__ = ["ProblemSpec", "SolverSpec", "TopologySpec", "DelaySpec",
           "PolicyGridSpec", "ExecutionSpec", "ExperimentSpec",
           "SOLVERS", "BACKENDS", "FIXED_FAMILY", "SPEC_FAMILY"]

SOLVERS = ("piag", "bcd", "fedasync", "fedbuff")
BACKENDS = ("solo", "batched", "sharded")

# policy names whose constructor takes the worst-case delay bound; the grid
# resolver injects the measured (or declared) tau-bar for these
FIXED_FAMILY = ("fixed", "sun_deng", "davis")


def _freeze(seq) -> Tuple:
    return tuple(seq) if seq is not None else None


def check_horizon(horizon, expected_max_delay: Optional[int]) -> None:
    """The one home of the horizon-representability rule: ``window_sum``
    caps delays at H - 1, so an expected max delay beyond that silently
    truncates window sums.  Shared by spec construction (declared bounds)
    and resolve (measured tau-bar).  ``horizon='auto'`` is exempt: the
    resolver sizes it FROM the measured/declared bound, so it represents
    every expected delay by construction."""
    if horizon == "auto":
        return
    exp = expected_max_delay
    if exp is not None and exp > horizon - 1:
        raise ValueError(
            f"horizon {horizon} cannot represent the delay model's "
            f"expected max delay {exp}: window sums clip at H - 1 = "
            f"{horizon - 1} (core.stepsize.window_sum); raise "
            f"SolverSpec.horizon to at least {exp + 1} or declare a "
            "smaller DelaySpec.expected_max_delay")


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """Which problem the experiment optimizes, plus its prox operator.

    ``kind``:   ``"logreg"`` | ``"lasso"`` (built via ``core.problems.make_*``
                with ``params`` forwarded and ``n_workers`` taken from the
                topology's widest cell) or ``"custom"`` (use ``problem``).
    ``params``: forwarded verbatim to ``make_logreg`` / ``make_lasso``.
    ``prox``:   name from ``core.prox.PROX_OPS``; ``prox_params`` forwarded.
                Default ``"l1"`` with ``lam = problem.lam1``.
    ``problem`` / ``prox_op``: prebuilt objects (the component escape hatch
                the legacy shims use); they bypass the declarative build.
    """

    kind: str = "logreg"
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    prox: str = "l1"
    prox_params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    problem: Any = None
    prox_op: Any = None

    def __post_init__(self):
        if self.problem is None and self.kind not in ("logreg", "lasso"):
            raise ValueError(
                f"unknown problem kind {self.kind!r} (logreg | lasso | "
                "pass a prebuilt `problem`)")


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Which solver consumes the event trace, and its knobs.

    ``m`` is the Async-BCD block count; ``eta`` / ``buffer_size`` are the
    FedBuff server rate and |R| (FedAsync forces ``buffer_size = 1``);
    ``local_lr`` is the federated clients' local prox-SGD rate (``None`` ->
    ``0.9 / L``); ``n_steps`` is the federated trace-scan pop budget
    (``None`` -> ``default_fed_steps``).  ``horizon`` is the step-size
    window-sum horizon H -- the largest representable delay is H - 1 --
    or ``'auto'``: size H to ``next_pow2(measured tau-bar + slack)`` at
    resolve time (``DelaySpec.horizon_slack``), bitwise-identical to the
    4096 default whenever delays fit, at a fraction of the scan carry.
    """

    name: str = "piag"
    horizon: Union[int, str] = 4096
    m: int = 20
    eta: float = 1.0
    buffer_size: int = 1
    local_lr: Optional[float] = None
    n_steps: Optional[int] = None

    def __post_init__(self):
        if self.name not in SOLVERS:
            raise ValueError(f"unknown solver {self.name!r}; one of {SOLVERS}")
        if isinstance(self.horizon, str):
            if self.horizon != "auto":
                raise ValueError(
                    f"horizon must be an int >= 2 or 'auto', "
                    f"got {self.horizon!r}")
        elif self.horizon < 2:
            raise ValueError(f"horizon must be >= 2, got {self.horizon}")
        if self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")

    @property
    def federated(self) -> bool:
        return self.name in ("fedasync", "fedbuff")


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """The worker/client population axis of the grid.

    ``kind``:      ``"standard"`` -- the four worker regimes of
                   ``sweep.standard_topology_factories`` (PIAG/BCD);
                   ``"edge"``     -- heterogeneous federated clients
                   (``federated.events.heterogeneous_clients`` with
                   ``params`` forwarded);
                   ``"custom"``   -- use ``topologies`` directly.
    ``names``:     optional subset of the regime names.
    ``n_workers``: worker counts; more than one grows the ragged
                   worker-count axis (bucketed sweeps).  ``None`` is only
                   valid for ``custom`` topologies given as concrete worker
                   lists (the PR 2 grid form).
    ``topologies``: custom mapping name -> width factory (or concrete list
                   when ``n_workers`` is None).
    """

    kind: str = "standard"
    names: Optional[Tuple[str, ...]] = None
    n_workers: Optional[Tuple[int, ...]] = (8,)
    seed: int = 0
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    topologies: Optional[Mapping[str, Any]] = None

    def __post_init__(self):
        if self.kind not in ("standard", "edge", "custom"):
            raise ValueError(f"unknown topology kind {self.kind!r}")
        if self.kind == "custom" and self.topologies is None:
            raise ValueError("custom topology needs `topologies`")
        object.__setattr__(self, "names", _freeze(self.names))
        object.__setattr__(self, "n_workers", _freeze(self.n_workers))
        if self.n_workers is not None and not self.n_workers:
            raise ValueError("n_workers must be non-empty or None")
        if self.n_workers is None:
            bad = [] if self.topologies is None else \
                [n for n, v in self.topologies.items() if callable(v)]
            if self.kind != "custom" or bad:
                raise ValueError(
                    "n_workers=None needs custom topologies given as "
                    "concrete worker lists" +
                    (f" (factories: {bad})" if bad else ""))

    @property
    def width_max(self) -> int:
        if self.n_workers is not None:
            return max(int(w) for w in self.n_workers)
        widths = {len(ws) for ws in self.topologies.values()}
        return max(widths)


@dataclasses.dataclass(frozen=True)
class DelaySpec:
    """How delays are measured and what the delay model is expected to do.

    ``use_tau_max``:       PIAG feeds the table-wide max staleness (the
                           paper's tau_k) when True, the returning worker's
                           own staleness when False.
    ``expected_max_delay``: a declared bound on the delay model's maximum
                           delay.  If set, spec CONSTRUCTION fails when the
                           solver horizon cannot represent it (H - 1 cap).
    ``measure``:           when no bound is declared, measure tau-bar from
                           the grid's own traces at resolve time (PIAG/BCD)
                           and validate the horizon against it.
    ``horizon_slack``:     headroom (>= 1) added to the measured/declared
                           bound when ``SolverSpec.horizon='auto'`` sizes
                           the window buffer (``stepsize.auto_horizon``).
    """

    use_tau_max: bool = True
    expected_max_delay: Optional[int] = None
    measure: bool = True
    horizon_slack: int = 1

    def __post_init__(self):
        if self.horizon_slack < 1:
            raise ValueError(
                f"horizon_slack must be >= 1, got {self.horizon_slack}")


@dataclasses.dataclass(frozen=True)
class PolicyGridSpec:
    """The step-size policy x seed axes.

    ``names``:        policy names from ``core.stepsize.POLICIES``; the
                      fixed family (``fixed`` / ``sun_deng`` / ``davis``)
                      gets ``tau_bound`` injected (measured tau-bar when
                      ``tau_bound`` is None -- the paper's tuning protocol).
    ``gamma_prime``:  gamma' = h/L.  ``None`` -> auto: ``0.99 / L`` (PIAG),
                      ``0.99 / block_smoothness(m)`` (BCD), ``0.6`` (the
                      federated base mixing weight).
    ``policy_kwargs``: per-name extra constructor kwargs.
    ``policies``:     escape hatch: concrete name -> ``StepsizePolicy``.
    """

    names: Tuple[str, ...] = ("adaptive1", "adaptive2", "fixed")
    seeds: Tuple[int, ...] = (0, 1, 2, 3)
    gamma_prime: Optional[float] = None
    tau_bound: Optional[int] = None
    policy_kwargs: Mapping[str, Mapping[str, Any]] = dataclasses.field(
        default_factory=dict)
    policies: Optional[Mapping[str, Any]] = None

    def __post_init__(self):
        object.__setattr__(self, "names", _freeze(self.names))
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))
        if not self.seeds:
            raise ValueError("need at least one seed")


@dataclasses.dataclass(frozen=True)
class ExecutionSpec:
    """Where and how the grid executes.

    ``backend``: ``"solo"``    -- one jitted run per cell (the pre-sweep
                 per-cell path; the reference semantics);
                 ``"batched"`` -- one vmapped XLA program per bucket
                 (``repro.sweep`` runners);
                 ``"sharded"`` -- the batched program with the cell axis
                 partitioned across a device mesh (``repro.sweep.shard``).
    ``devices``: use the first N devices for the sharded mesh (None = all).
    ``mesh``:    a prebuilt ``jax.sharding.Mesh`` (overrides ``devices``).
    ``mesh_shape``: build a ``(cells,)`` or 2-D ``(cells, data)`` mesh over
                 the first ``prod(mesh_shape)`` devices
                 (``repro.mesh.grid_mesh``).  A data axis > 1 computes each
                 cell's per-worker gradients data-parallel (``pmean_grad``
                 psums partial gradients over "data"); rows stay
                 bitwise-equal on integer leaves to the 1-D and solo paths.
                 Requires the per-worker sample count to divide by the data
                 axis size.  Mutually exclusive with ``mesh``.
    ``coordinator`` / ``num_processes`` / ``process_id``: multi-host
                 bootstrap -- when ``coordinator`` ("host:port") is set the
                 sharded backend calls ``jax.distributed.initialize`` once
                 before building the mesh, so ``jax.devices()`` (and hence
                 ``mesh_shape``) spans every process.  The knobs never reach
                 a traced program; their only cache-key footprint is the
                 process count inside ``repro.mesh.mesh_topology``.
    ``bucket_widths``: explicit ragged-bucket width menu (None = pow-2).
    ``reference``: federated sweeps only -- route trace generation through
                 the Python heapq reference twin instead of the fused scan.
    ``record_every``: decimated trace recording -- materialize (and compute
                 the objective for) only every s-th event row; stride 1 is
                 bitwise today's behavior, stride s keeps bitwise rows
                 ``s-1, 2s-1, ...`` and shrinks the (B, K) outputs by s.
                 Must divide ``n_events``.
    ``telemetry``: thread the in-scan delay/step-size accumulators
                 (``repro.telemetry``) through the solver carry.  Bitwise-
                 neutral on every solver leaf; adds a ``DelayTelemetry``
                 pytree on ``Results.raw.telemetry`` and exact aggregates
                 to the run's ``RunRecord`` even under decimation.
    ``telemetry_bins``: delay-histogram buckets (last bin = overflow,
                 counting every ``tau >= bins - 1``).
    ``engine``:  per-event inner-loop implementation inside the solver
                 scans.  ``"scan"`` (default) is the pure-XLA path;
                 ``"fused"`` launches the policy update (window-sum /
                 select / push) and the iterate step as ONE Pallas kernel
                 per event (``repro.kernels.fused_step``) -- bitwise-equal
                 on every backend, compiled on TPU/GPU and interpreted on
                 CPU (``repro.kernels.dispatch``).  Not supported for
                 ``AdaptiveLipschitz`` (backtracking is host-side).
    """

    backend: str = "batched"
    devices: Optional[int] = None
    mesh: Any = None
    mesh_shape: Optional[Tuple[int, ...]] = None
    coordinator: Optional[str] = None
    num_processes: int = 1
    process_id: int = 0
    bucket_widths: Optional[Tuple[int, ...]] = None
    reference: bool = False
    record_every: int = 1
    telemetry: bool = False
    telemetry_bins: int = 64
    engine: str = "scan"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; one of {BACKENDS}")
        if self.engine not in ("scan", "fused"):
            raise ValueError(
                f"engine must be 'scan' or 'fused', got {self.engine!r}")
        if self.record_every < 1:
            raise ValueError(
                f"record_every must be >= 1, got {self.record_every}")
        if self.telemetry_bins < 2:
            raise ValueError(
                f"telemetry_bins must be >= 2, got {self.telemetry_bins}")
        if self.mesh_shape is not None:
            if self.mesh is not None:
                raise ValueError(
                    "mesh and mesh_shape are mutually exclusive: a prebuilt "
                    "mesh already fixes the topology")
            shape = tuple(int(s) for s in self.mesh_shape)
            if not 1 <= len(shape) <= 2 or any(s < 1 for s in shape):
                raise ValueError(
                    f"mesh_shape must be (cells,) or (cells, data) with "
                    f"positive entries, got {self.mesh_shape!r}")
            object.__setattr__(self, "mesh_shape", shape)
            if self.backend != "sharded":
                raise ValueError(
                    f"mesh_shape requires backend='sharded', got "
                    f"{self.backend!r}")
        if self.num_processes < 1:
            raise ValueError(
                f"num_processes must be >= 1, got {self.num_processes}")
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(
                f"process_id must be in [0, num_processes), got "
                f"{self.process_id} with num_processes={self.num_processes}")
        if self.coordinator is not None and self.backend != "sharded":
            raise ValueError(
                "coordinator (multi-host init) requires backend='sharded'")
        object.__setattr__(self, "bucket_widths", _freeze(self.bucket_widths))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One declarative experiment: the product of the five axes above.

    ``n_events`` is the trace length K (write events for PIAG/BCD, uploads
    for the federated servers).  ``grid`` is a component escape hatch: a
    prebuilt ``sweep.SweepGrid`` bypasses the declarative topology/policy
    build entirely (used by the legacy shims).  ``validate_horizon``
    controls resolve-time horizon validation (see ``DelaySpec``).

    ``faults`` (a ``repro.faults.FaultSpec``, or None) injects deterministic
    fault processes -- crash/rejoin chains and straggler spikes into the
    delay traces, drop/duplicate/corrupt codes into the server updates --
    and arms the in-scan guards (NaN/Inf rejection, staleness cutoff,
    horizon-overflow degradation).  ``faults=None`` (or a disabled spec) is
    BITWISE the pre-fault program on every solver and backend; a set spec
    rides every sweep-program cache key.
    """

    problem: ProblemSpec = dataclasses.field(default_factory=ProblemSpec)
    solver: SolverSpec = dataclasses.field(default_factory=SolverSpec)
    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)
    policies: PolicyGridSpec = dataclasses.field(
        default_factory=PolicyGridSpec)
    delay: DelaySpec = dataclasses.field(default_factory=DelaySpec)
    execution: ExecutionSpec = dataclasses.field(default_factory=ExecutionSpec)
    n_events: int = 1000
    grid: Any = None
    validate_horizon: bool = True
    faults: Any = None

    def __post_init__(self):
        if self.n_events < 1:
            raise ValueError("n_events must be >= 1")
        if self.solver.federated and self.execution.reference \
                and self.execution.backend == "sharded":
            raise ValueError(
                "reference=True (heapq twin) cannot shard; use backend="
                "'batched'")
        if self.n_events % self.execution.record_every:
            raise ValueError(
                f"record_every={self.execution.record_every} must divide "
                f"n_events={self.n_events}")
        check_horizon(self.solver.horizon, self.delay.expected_max_delay)
        if self.faults is not None:
            from repro.faults.spec import normalize_faults
            object.__setattr__(self, "faults", normalize_faults(self.faults))
        if self.faults is not None:
            if self.execution.engine == "fused":
                raise ValueError(
                    "engine='fused' does not support fault injection; use "
                    "engine='scan'")
            if self.execution.reference:
                raise ValueError(
                    "reference=True (heapq twin) does not support fault "
                    "injection; use the fused federated trace path")

    def validate(self) -> "ExperimentSpec":
        """Resolve problem + grid and run the horizon validation without
        executing anything; returns self for chaining."""
        from .run import resolve
        resolve(self)
        return self

    def replace(self, **kwargs) -> "ExperimentSpec":
        return dataclasses.replace(self, **kwargs)


# The authoritative enumeration of spec dataclasses whose fields are program
# knobs.  ``repro.staticcheck.cachekey`` walks every field of every class
# here (plus FaultSpec and TelemetryConfig, which live in their own
# packages) and refuses to pass until each has a registered perturbation or
# an explicit skip-with-reason -- so a knob added to any of these classes
# without cache-key/staticcheck coverage fails CI rather than silently
# risking stale-executable reuse.
SPEC_FAMILY = (ExperimentSpec, ProblemSpec, SolverSpec, TopologySpec,
               DelaySpec, PolicyGridSpec, ExecutionSpec)
