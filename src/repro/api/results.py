"""The unified results table returned by ``repro.api.run``.

``Results`` replaces the ad-hoc ``PIAGResult`` / ``BCDResult`` /
``FedResult`` divergence at the API surface with one table of common
columns -- objective trace, step-sizes/weights (``gammas``), delays
(``taus``), horizon-clip counts (``clipped``), wall/virtual time, and cell
coordinates -- while keeping the raw solver tuple available (``raw``) so
bitwise comparisons against the underlying runners stay possible.
Solver-specific columns (``opt_residual``, ``blocks``, ``versions``) live
in ``extras``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["Results"]


@dataclasses.dataclass
class Results:
    """One row per grid cell, one column family per common output.

    Attributes:
      solver / backend: how the spec was dispatched.
      grid:       the resolved ``sweep.SweepGrid`` (cell coordinates).
      raw:        the underlying solver result tuple with a leading cell
                  axis -- EXACTLY what the dispatched runner returned
                  (``PIAGResult`` / ``BCDResult`` / ``FedResult``).
      elapsed_s:  host wall-clock of the dispatched run (compile + execute).
      tau_bar:    the measured worst-case delay bound, when the resolver
                  computed one (fixed-family tuning / horizon validation).
      spec:       the originating ``ExperimentSpec`` (None for component
                  runs that bypassed the declarative build).
      horizon:    the CONCRETE window-buffer size the run used -- the
                  resolved value when the spec said ``'auto'``.
      record_every: the trace-recording stride s: objective/gammas/taus
                  columns hold rows ``s-1, 2s-1, ...`` of the event
                  trajectory ((B, K // s) leaves).
      telemetry:  the run's ``repro.telemetry.RunRecord`` (delay histogram,
                  compile vs warm split, cache deltas) -- always built by
                  ``api.run``, written to the JSONL ledger only when a
                  ledger path is configured.
      cache_stats: this run's ``program_cache_stats()`` hit/miss/evict
                  delta (reset-scoped across ``clear_program_cache``).
    """

    solver: str
    backend: str
    grid: Any
    raw: Any
    elapsed_s: float
    tau_bar: Optional[int] = None
    spec: Any = None
    horizon: Optional[int] = None
    record_every: int = 1
    telemetry: Any = None
    cache_stats: Optional[Dict[str, Any]] = None

    # ------------------------------------------------- common columns ----

    @property
    def cells(self):
        return self.grid.cells

    @property
    def n_cells(self) -> int:
        return len(self.grid.cells)

    @property
    def n_events(self) -> int:
        return int(self.grid.n_events)

    @property
    def n_samples(self) -> int:
        """Recorded samples per cell: n_events // record_every."""
        return self.n_events // int(self.record_every)

    def sample_events(self) -> np.ndarray:
        """(n_samples,) event index of each recorded column: with stride s,
        column j holds event ``j*s + s - 1``."""
        s = int(self.record_every)
        return np.arange(self.n_samples) * s + (s - 1)

    @property
    def objective(self):
        """(B, K // record_every) objective P(x_{k+1}) at recorded events."""
        return self.raw.objective

    @property
    def gammas(self):
        """(B, K) emitted step-sizes (PIAG/BCD) or mixing weights (fed)."""
        return self.raw.weights if "weights" in self.raw._fields \
            else self.raw.gammas

    @property
    def taus(self):
        """(B, K) delay fed to the policy at each event."""
        return self.raw.taus

    @property
    def clipped(self):
        """(B,) events whose delay exceeded the policy horizon (H - 1)."""
        return self.raw.clipped

    @property
    def x(self):
        """Final iterates, leading cell axis."""
        return self.raw.x

    @property
    def extras(self) -> Dict[str, Any]:
        """Solver-specific columns not shared across the four solvers."""
        common = {"x", "objective", "gammas", "taus", "clipped", "telemetry"}
        return {f: getattr(self.raw, f) for f in self.raw._fields
                if f not in common and f != "weights"}

    def labels(self) -> List[str]:
        return self.grid.labels()

    def __len__(self) -> int:
        return self.n_cells

    # ---------------------------------------------------- derived views ----

    def final_objective(self) -> np.ndarray:
        """(B,) final objective per cell."""
        return np.asarray(self.objective)[:, -1]

    def virtual_time(self) -> np.ndarray:
        """(B, K // record_every) simulated wall-clock time of each RECORDED
        event (stride-aware: column j is event ``j*s + s - 1``).

        Recomputed from the grid's own pre-sampled randomness (the traces
        are deterministic functions of it), via the jitted trace scans --
        PIAG/BCD per bucket, federated per cell.  The stride slice happens
        INSIDE the jitted program (per device-resident array), so only the
        K // s recorded columns ever cross to the host."""
        import jax
        import jax.numpy as jnp

        s = int(self.record_every)
        if self.solver in ("piag", "bcd"):
            from repro.core.engine import trace_scan
            from repro.sweep.runners import run_bucketed

            def run_bucket(b):
                T = jnp.asarray(b.grid.service_times(b.width))
                if b.uniform:
                    vt = jax.jit(jax.vmap(
                        lambda t: trace_scan(t).t_wall[s - 1::s]))(T)
                else:
                    act = jnp.asarray(b.grid.active_masks(b.width))
                    vt = jax.jit(jax.vmap(
                        lambda t, a: trace_scan(t, active=a)
                        .t_wall[s - 1::s]))(T, act)
                return vt

            return np.asarray(run_bucketed(self.grid, run_bucket))
        from repro.federated.events import generate_federated_trace
        bs = 1
        n_steps = None
        if self.spec is not None:
            if self.solver == "fedbuff":
                bs = self.spec.solver.buffer_size
            n_steps = self.spec.solver.n_steps
        return np.stack([np.asarray(generate_federated_trace(
            c.n_workers, self.n_events, clients=list(c.workers),
            buffer_size=bs, seed=c.seed, n_steps=n_steps)
            .t_wall)[s - 1::s] for c in self.cells])

    def to_rows(self) -> List[Dict[str, Any]]:
        """Per-cell records (the JSON shape ``launch.sweep`` emits)."""
        obj = np.asarray(self.objective)
        gam = np.asarray(self.gammas)
        taus = np.asarray(self.taus)
        clipped = np.asarray(self.clipped)
        return [{
            "label": lab,
            "policy": c.policy_name,
            "seed": c.seed,
            "topology": c.topology_name,
            "n_workers": c.n_workers,
            "final_objective": float(obj[i, -1]),
            "sum_gamma": float(gam[i].sum()),
            "max_tau": int(taus[i].max()),
            "clipped": int(clipped[i]),
        } for i, (lab, c) in enumerate(zip(self.labels(), self.cells))]

    # ------------------------------------------------ analysis bridges ----

    def per_policy(self):
        """Per-policy aggregation (see ``repro.analysis``)."""
        from repro import analysis
        return analysis.per_policy_summary(self.cells, self.objective,
                                           self.gammas, self.clipped)

    def clipped_summary(self):
        from repro import analysis
        return analysis.clipped_summary(self.clipped)

    def time_to_tolerance(self, target: float, p_star: float = 0.0):
        """First EVENT index reaching the tolerance (stride-aware: recorded
        column j maps back to event ``j*s + s - 1``; -1 = never)."""
        from repro import analysis
        return analysis.time_to_tolerance(self.objective, target,
                                          p_star=p_star,
                                          record_every=self.record_every)
