"""`repro.api` -- one declarative entry point over every runner.

The paper's experiments all share one shape: (problem, solver, delay
model / topology, step-size policy grid) -> convergence traces.  This
package expresses that shape as data (the ``ExperimentSpec`` family) and
provides a single ``run(spec)`` that compiles the spec down to the
existing jitted scans -- solo per-cell runs, one-program-per-bucket
batched sweeps, or device-sharded mega-grids -- returning one unified
``Results`` table regardless of solver or backend.

Quick taste::

    from repro import api

    spec = api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=800, dim=100)),
        solver=api.SolverSpec(name="piag", horizon=4096),
        topology=api.TopologySpec(kind="standard", n_workers=(4, 8)),
        policies=api.PolicyGridSpec(names=("adaptive1", "adaptive2",
                                           "fixed"),
                                    seeds=range(4)),
        execution=api.ExecutionSpec(backend="sharded"),
        n_events=1000)
    res = api.run(spec)              # Results: (B, K) traces + coordinates
    res.per_policy()                 # repro.analysis aggregation

Swap ``backend`` between ``"solo"`` / ``"batched"`` / ``"sharded"`` and the
rows stay bitwise-identical to the runner each backend dispatches to.
"""
from .results import Results
from .run import (Resolved, component_spec, resolve, run, run_components)
from .spec import (BACKENDS, FIXED_FAMILY, SOLVERS, DelaySpec,
                   ExecutionSpec, ExperimentSpec, PolicyGridSpec,
                   ProblemSpec, SolverSpec, TopologySpec)

__all__ = [
    "ExperimentSpec", "ProblemSpec", "SolverSpec", "TopologySpec",
    "DelaySpec", "PolicyGridSpec", "ExecutionSpec", "Results", "Resolved",
    "run", "resolve", "run_components", "component_spec",
    "SOLVERS", "BACKENDS", "FIXED_FAMILY",
]
