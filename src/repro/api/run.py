"""Compile an ``ExperimentSpec`` down to the existing runners.

``resolve(spec)`` materializes the declarative axes -- problem, prox,
policies (with the paper's tau-bar tuning protocol for the fixed family),
topology factories, the ``SweepGrid`` -- and performs the build-time
horizon validation.  ``run(spec)`` then dispatches on
(solver, backend) to EXACTLY the code path that existed before the
redesign:

=========  ==========================  ===========================  =========================
solver     solo                        batched                      sharded
=========  ==========================  ===========================  =========================
piag       ``core.piag.run_piag``      ``sweep.sweep_piag``         ``shard.sharded_sweep_piag``
bcd        ``core.bcd.run_async_bcd``  ``sweep.sweep_bcd``          ``shard.sharded_sweep_bcd``
fedasync   ``federated.run_fedasync``  ``sweep.sweep_fedasync``     ``shard.sharded_sweep_fedasync``
fedbuff    ``federated.run_fedbuff``   ``sweep.sweep_fedbuff``      ``shard.sharded_sweep_fedbuff``
=========  ==========================  ===========================  =========================

The spec layer only routes -- argument-for-argument the calls match what
the legacy conveniences (``sweep_piag_logreg`` etc.) made, so spec-routed
rows are bitwise-identical to the runner they dispatch to
(``tests/test_api.py`` pins all twelve combinations).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import SweepCheckpoint
from repro.core.bcd import run_async_bcd, sample_blocks
from repro.core.engine import generate_trace, sample_service_times
from repro.core.piag import run_piag
from repro.faults.guards import summarize_faults
from repro.faults.inject import inject_service_times
from repro.core.problems import make_lasso, make_logreg
from repro.core.prox import make_prox
from repro.core.stepsize import make_policy
from repro.federated.events import (generate_federated_trace,
                                    heterogeneous_clients)
from repro.federated.server import (_problem_pieces, run_fedasync,
                                    run_fedbuff)
from repro.sweep.cache import LRU, IdKey, program_cache_stats
from repro.sweep.grid import (SweepGrid, make_grid, measure_tau_bar,
                              standard_topology_factories)
from repro.telemetry.accumulators import TelemetryConfig, summarize_telemetry
from repro.telemetry.ledger import (RunRecord, append_record, cache_delta,
                                    estimate_carry_bytes, spec_fingerprint)
from repro.telemetry.timing import COMPILE_EVENT_NAMES, drain_timings
from repro.sweep.runners import (resolve_grid_horizon, sweep_bcd,
                                 sweep_fedasync, sweep_fedbuff, sweep_piag)
from repro.mesh import (DATA_AXIS, data_axis_size, grid_mesh,
                        maybe_init_distributed, pmean_grad)
from repro.sweep.shard import (cell_mesh, sharded_sweep_bcd,
                               sharded_sweep_fedasync,
                               sharded_sweep_fedbuff, sharded_sweep_piag)

from .results import Results
from .spec import (FIXED_FAMILY, ExecutionSpec, ExperimentSpec, ProblemSpec,
                   SolverSpec, check_horizon)

__all__ = ["Resolved", "resolve", "run", "run_components", "component_spec"]

_tmap = jax.tree_util.tree_map

# resolve-time memoization: repeated api.run calls of value-equal specs
# reuse the SAME problem/prox/runner-piece objects, which is what lets the
# sweep-program cache (repro.sweep.cache) recognize the executables as
# identical instead of re-tracing per call
_PROBLEM_MEMO = LRU(16)
_PROX_MEMO = LRU(32)
_PIECES_MEMO = LRU(32)


class Resolved(NamedTuple):
    """The concrete objects a spec compiles to (pre-dispatch).

    ``horizon`` is the CONCRETE window-buffer size the dispatch uses: the
    spec's integer horizon verbatim, or -- for ``horizon='auto'`` -- the
    measured-delay sizing ``next_pow2(bound + slack)``."""

    spec: ExperimentSpec
    problem: Any
    prox: Any
    grid: SweepGrid
    tau_bar: Optional[int]
    horizon: int


# -------------------------------------------------------------- resolve ----

def _build_problem(spec: ExperimentSpec):
    ps = spec.problem
    if ps.problem is not None:
        return ps.problem
    maker = make_logreg if ps.kind == "logreg" else make_lasso
    kwargs = dict(ps.params)
    kwargs.setdefault("n_workers", spec.topology.width_max)
    try:
        key = (ps.kind, tuple(sorted(kwargs.items())))
        hash(key)
    except TypeError:  # exotic params: build fresh, skip memoization
        return maker(**kwargs)
    return _PROBLEM_MEMO.get(key, lambda: maker(**kwargs))


def _build_prox(spec: ExperimentSpec, problem):
    ps = spec.problem
    if ps.prox_op is not None:
        return ps.prox_op
    kwargs = dict(ps.prox_params)
    if ps.prox == "l1":
        kwargs.setdefault("lam", problem.lam1)
    try:
        key = (ps.prox, tuple(sorted(kwargs.items())))
        hash(key)
    except TypeError:
        return make_prox(ps.prox, **kwargs)
    return _PROX_MEMO.get(key, lambda: make_prox(ps.prox, **kwargs))


def _build_topologies(spec: ExperimentSpec) -> Dict[str, Any]:
    ts = spec.topology
    if ts.kind == "custom":
        topos = dict(ts.topologies)
    elif ts.kind == "edge":
        params = dict(ts.params)
        seed = params.pop("seed", ts.seed)  # params may pin its own seed
        topos = {"edge": lambda n, _p=params: heterogeneous_clients(
            n, seed=seed, **_p)}
    else:
        topos = standard_topology_factories(ts.seed)
    if ts.names is not None:
        unknown = set(ts.names) - set(topos)
        if unknown:
            raise ValueError(f"unknown topology names {sorted(unknown)}; "
                             f"available: {sorted(topos)}")
        topos = {n: topos[n] for n in ts.names}
    return topos


def _auto_gamma_prime(spec: ExperimentSpec, problem) -> float:
    if spec.solver.name == "piag":
        return 0.99 / problem.L
    if spec.solver.name == "bcd":
        return 0.99 / problem.block_smoothness(spec.solver.m)
    return 0.6  # federated base mixing weight alpha


def _measure_tau_bar(spec: ExperimentSpec, topos) -> int:
    """Worst-case trace delay over every (topology, width, seed) cell --
    the paper's protocol for tuning the fixed family, reused for horizon
    validation.  Worker traces only (federated staleness is not a
    service-time trace property)."""
    ts = spec.topology
    if ts.n_workers is not None:
        menu = {f"{tn}/w{int(w)}": f(int(w))
                for tn, f in topos.items() for w in ts.n_workers}
    else:
        menu = {tn: ws for tn, ws in topos.items()}
    return measure_tau_bar(menu, list(spec.policies.seeds), spec.n_events)


def _build_policies(spec: ExperimentSpec, problem, tau_bar: Optional[int]):
    pg = spec.policies
    if pg.policies is not None:
        return dict(pg.policies)
    gp = pg.gamma_prime if pg.gamma_prime is not None \
        else _auto_gamma_prime(spec, problem)
    out = {}
    for name in pg.names:
        kwargs = dict(pg.policy_kwargs.get(name, {}))
        if name in FIXED_FAMILY and "tau_bound" not in kwargs:
            bound = pg.tau_bound if pg.tau_bound is not None else tau_bar
            if bound is None:
                raise ValueError(
                    f"policy {name!r} needs a worst-case delay bound: set "
                    "PolicyGridSpec.tau_bound or enable DelaySpec.measure")
            kwargs["tau_bound"] = int(bound)
        out[name] = make_policy(name, gp, **kwargs)
    return out


def _validate_horizon(spec: ExperimentSpec, tau_bar: Optional[int]) -> None:
    exp = spec.delay.expected_max_delay
    check_horizon(spec.solver.horizon, tau_bar if exp is None else exp)


def _resolve_horizon(spec: ExperimentSpec, grid: SweepGrid,
                     tau_bar: Optional[int]) -> int:
    """The concrete window-buffer size for the dispatch.

    A thin adapter over the one shared rule
    (``sweep.runners.resolve_grid_horizon``): integer horizons pass through
    verbatim, ``'auto'`` sizes from the declared ``expected_max_delay`` or
    the already-measured worker tau-bar when available (a fresh
    measurement otherwise), with the spec's ``DelaySpec.horizon_slack``."""
    sv = spec.solver
    bound = spec.delay.expected_max_delay
    if bound is None and not sv.federated:
        bound = tau_bar  # reuse the fixed-family/validation measurement
    return resolve_grid_horizon(
        sv.horizon, grid, fed=sv.federated,
        buffer_size=sv.buffer_size if sv.name == "fedbuff" else 1,
        n_steps=sv.n_steps, slack=spec.delay.horizon_slack, bound=bound)


def resolve(spec: ExperimentSpec) -> Resolved:
    """Materialize problem, prox, policies and grid; validate the horizon.

    Fixed-family policies without an explicit ``tau_bound`` trigger a
    tau-bar measurement over the grid's own traces; so do horizon
    validation for PIAG/BCD when no ``expected_max_delay`` is declared and
    ``horizon='auto'`` sizing (one measurement serves all three).
    """
    problem = _build_problem(spec)
    prox = _build_prox(spec, problem)

    if spec.grid is not None:
        tau_bar = None
        if spec.validate_horizon:
            _validate_horizon(spec, tau_bar)
        horizon = _resolve_horizon(spec, spec.grid, tau_bar)
        return Resolved(spec, problem, prox, spec.grid, tau_bar, horizon)

    topos = _build_topologies(spec)
    pg = spec.policies
    needs_bound = (pg.policies is None and pg.tau_bound is None
                   and any(n in FIXED_FAMILY for n in pg.names))
    worker_solver = not spec.solver.federated
    auto = spec.solver.horizon == "auto"
    needs_measure = worker_solver and (
        (needs_bound and spec.delay.measure)
        or (spec.validate_horizon and spec.delay.measure
            and spec.delay.expected_max_delay is None)
        or (auto and spec.delay.expected_max_delay is None))
    tau_bar = _measure_tau_bar(spec, topos) if needs_measure else None
    if spec.solver.federated:
        tau_bar = 0  # fixed baselines are not the federated story
    elif needs_bound and tau_bar is None:
        raise ValueError(
            "fixed-family policies need tau_bound (or DelaySpec.measure)")

    policies = _build_policies(spec, problem, tau_bar)
    grid = make_grid(policies, list(pg.seeds), topos, spec.n_events,
                     n_workers=(list(spec.topology.n_workers)
                                if spec.topology.n_workers is not None
                                else None))
    if spec.validate_horizon and worker_solver:
        _validate_horizon(spec, tau_bar)
    elif spec.validate_horizon:
        _validate_horizon(spec, None)  # declared bound only
    horizon = _resolve_horizon(
        spec, grid, tau_bar if worker_solver else None)
    return Resolved(spec, problem, prox, grid, tau_bar, horizon)


# ------------------------------------------------------------- dispatch ----

def _slice_rows(tree, n: int):
    return _tmap(lambda leaf: leaf[:n], tree)


def _stack_results(rows):
    return _tmap(lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *rows)


def _mesh_for(spec: ExperimentSpec):
    ex = spec.execution
    # multi-host bootstrap must precede the first jax.devices() call so the
    # mesh spans every process; no-op unless ex.coordinator is set
    maybe_init_distributed(ex)
    if ex.mesh is not None:
        return ex.mesh
    if ex.mesh_shape is not None:
        return grid_mesh(ex.mesh_shape,
                         jax.devices()[:int(ex.devices)]
                         if ex.devices is not None else None)
    if ex.devices is not None:
        return cell_mesh(jax.devices()[:int(ex.devices)])
    return cell_mesh()


def _piag_pieces(r: Resolved):
    """(loss, x0, worker_data, objective) for PIAG, memoized per problem so
    repeated runs hand the sweep-program cache identical captured objects."""
    problem = r.problem

    def build():
        Aw, bw = problem.worker_slices()
        x0 = jnp.zeros((problem.dim,), jnp.float32)
        loss = lambda x, A, b: problem.worker_loss(x, A, b)
        return loss, x0, (Aw, bw), problem.P

    return _PIECES_MEMO.get(("piag", IdKey(problem)), build)


def _bcd_pieces(problem):
    def build():
        return (problem.grad_f, problem.P,
                jnp.zeros((problem.dim,), jnp.float32))

    return _PIECES_MEMO.get(("bcd", IdKey(problem)), build)


def _bcd_dp_grad(problem, size: int):
    """Data-parallel full gradient for sharded BCD on a (cells, data) mesh.

    BCD's ``grad_f`` is an opaque closure, so the data-parallel variant is
    rebuilt from ``problem.worker_loss`` on the problem's FULL data (for
    both built-in problem classes ``worker_loss(x, A_full, b_full) == f(x)``
    exactly) with ``pmean_grad`` psumming partial gradients over "data".
    Returns None -- replicated-compute fallback, the sharded runner warns --
    for custom problems without ``worker_loss`` + ``A``/``b``(``y``)."""
    def build():
        A = getattr(problem, "A", None)
        b = getattr(problem, "b", getattr(problem, "y", None))
        if A is None or b is None or not hasattr(problem, "worker_loss"):
            return None
        g = pmean_grad(lambda x, A_, b_: problem.worker_loss(x, A_, b_),
                       DATA_AXIS, size)
        return lambda x: g(x, A, b)

    return _PIECES_MEMO.get(("bcd/dp", IdKey(problem), size), build)


def _fed_pieces(problem, prox, local_lr, dp_size: int = 1):
    def build():
        grad_fn = None
        if dp_size > 1:
            # 2-D mesh: client gradients psum over the mesh's data axis
            grad_fn = pmean_grad(
                lambda x, A, b: problem.worker_loss(x, A, b),
                DATA_AXIS, dp_size)
        update, x0, data = _problem_pieces(problem, prox, local_lr,
                                           grad_fn=grad_fn)
        return update, x0, data, problem.P

    return _PIECES_MEMO.get(("fed", IdKey(problem), IdKey(prox), local_lr,
                             dp_size), build)


def _telemetry_cfg(spec: ExperimentSpec) -> Optional[TelemetryConfig]:
    """The scan-carry accumulator config: None (exactly the pre-telemetry
    code path) unless the spec opted in."""
    ex = spec.execution
    return TelemetryConfig(delay_bins=ex.telemetry_bins) \
        if ex.telemetry else None


# solo fault injection: the same jitted service-time transform the batched
# cells run, applied host-side before generate_trace -- threefry bits are a
# pure function of (fault seed, cell seed), so the injected matrix (and hence
# the trace and every downstream row) is bitwise the batched cell's
_INJECT_JIT = LRU(16)


def _inject_T(T, faults, cell_seed: int):
    fn = _INJECT_JIT.get(faults, lambda: jax.jit(
        lambda t, s: inject_service_times(t, faults, s)))
    return np.asarray(fn(jnp.asarray(T, jnp.float32), jnp.int32(cell_seed)))


def _solo_cells(grid, ckpt, run_cell):
    """The solo per-cell loop with optional per-cell checkpointing (cell
    files keyed on (width=n_workers, idx=cell index) through the same
    ``SweepCheckpoint`` the bucketed runners use)."""
    rows = []
    for i, c in enumerate(grid.cells):
        if ckpt is not None:
            cached = ckpt.load_bucket(c.n_workers, i)
            if cached is not None:
                rows.append(cached)
                continue
        row = run_cell(i, c)
        if ckpt is not None:
            row = jax.block_until_ready(row)
            ckpt.save_bucket(c.n_workers, i, row)
        rows.append(row)
    return _stack_results(rows)


def _run_piag(r: Resolved, ckpt=None):
    spec = r.spec
    loss, x0, wd, objective = _piag_pieces(r)
    h, utm = r.horizon, spec.delay.use_tau_max
    bw = spec.execution.bucket_widths
    s = spec.execution.record_every
    tel = _telemetry_cfg(spec)
    eng = spec.execution.engine
    fl = spec.faults
    backend = spec.execution.backend
    if backend == "batched":
        return sweep_piag(loss, x0, wd, r.grid, r.prox,
                          objective=objective, horizon=h, use_tau_max=utm,
                          bucket_widths=bw, record_every=s, telemetry=tel,
                          engine=eng, faults=fl, checkpoint=ckpt)
    if backend == "sharded":
        return sharded_sweep_piag(loss, x0, wd, r.grid, r.prox,
                                  objective=objective, horizon=h,
                                  use_tau_max=utm, mesh=_mesh_for(spec),
                                  bucket_widths=bw, record_every=s,
                                  telemetry=tel, engine=eng, faults=fl,
                                  checkpoint=ckpt)

    def run_cell(i, c):
        T = sample_service_times(c.workers, r.grid.n_events + 1, seed=c.seed)
        if fl is not None:
            T = _inject_T(T, fl, c.seed)
        tr = generate_trace(T)
        return run_piag(loss, x0, _slice_rows(wd, c.n_workers), tr,
                        c.policy, r.prox, objective=objective,
                        horizon=h, use_tau_max=utm, record_every=s,
                        telemetry=tel, engine=eng, faults=fl,
                        fault_seed=c.seed)

    return _solo_cells(r.grid, ckpt, run_cell)


def _run_bcd(r: Resolved, ckpt=None):
    spec = r.spec
    problem, m, h = r.problem, spec.solver.m, r.horizon
    grad_f, objective, x0 = _bcd_pieces(problem)
    bw = spec.execution.bucket_widths
    s = spec.execution.record_every
    tel = _telemetry_cfg(spec)
    eng = spec.execution.engine
    fl = spec.faults
    backend = spec.execution.backend
    if backend == "batched":
        return sweep_bcd(grad_f, objective, x0, m, r.grid, r.prox,
                         horizon=h, bucket_widths=bw, record_every=s,
                         telemetry=tel, engine=eng, faults=fl,
                         checkpoint=ckpt)
    if backend == "sharded":
        mesh = _mesh_for(spec)
        dp_grad_f = (_bcd_dp_grad(problem, data_axis_size(mesh))
                     if data_axis_size(mesh) > 1 else None)
        return sharded_sweep_bcd(grad_f, objective, x0, m, r.grid,
                                 r.prox, horizon=h, mesh=mesh,
                                 bucket_widths=bw, record_every=s,
                                 telemetry=tel, engine=eng, faults=fl,
                                 checkpoint=ckpt, dp_grad_f=dp_grad_f)

    def run_cell(i, c):
        T = sample_service_times(c.workers, r.grid.n_events + 1, seed=c.seed)
        if fl is not None:
            T = _inject_T(T, fl, c.seed)
        tr = generate_trace(T, kind="shared_memory")
        blocks = sample_blocks(m, r.grid.n_events, seed=c.seed)
        return run_async_bcd(grad_f, objective, x0, m, tr,
                             blocks, c.policy, r.prox, horizon=h,
                             record_every=s, telemetry=tel, engine=eng,
                             faults=fl, fault_seed=c.seed)

    return _solo_cells(r.grid, ckpt, run_cell)


def _run_fed(r: Resolved, ckpt=None):
    spec = r.spec
    sv = spec.solver
    backend = spec.execution.backend
    mesh = _mesh_for(spec) if backend == "sharded" else None
    dpn = data_axis_size(mesh) if mesh is not None else 1
    update, x0, data, objective = _fed_pieces(r.problem, r.prox, sv.local_lr,
                                              dp_size=dpn)
    h, n_steps = r.horizon, sv.n_steps
    bs = sv.buffer_size if sv.name == "fedbuff" else 1
    bw = spec.execution.bucket_widths
    s = spec.execution.record_every
    tel = _telemetry_cfg(spec)
    eng = spec.execution.engine
    fl = spec.faults
    if backend == "batched":
        if sv.name == "fedasync":
            return sweep_fedasync(update, x0, data, r.grid,
                                  objective=objective, horizon=h,
                                  reference=spec.execution.reference,
                                  n_steps=n_steps, bucket_widths=bw,
                                  record_every=s, telemetry=tel, engine=eng,
                                  faults=fl, checkpoint=ckpt)
        return sweep_fedbuff(update, x0, data, r.grid, eta=sv.eta,
                             buffer_size=bs, objective=objective,
                             horizon=h, reference=spec.execution.reference,
                             n_steps=n_steps, bucket_widths=bw,
                             record_every=s, telemetry=tel, engine=eng,
                             faults=fl, checkpoint=ckpt)
    if backend == "sharded":
        if sv.name == "fedasync":
            return sharded_sweep_fedasync(update, x0, data, r.grid,
                                          objective=objective,
                                          buffer_size=1, horizon=h,
                                          n_steps=n_steps, mesh=mesh,
                                          bucket_widths=bw, record_every=s,
                                          telemetry=tel, engine=eng,
                                          faults=fl, checkpoint=ckpt)
        return sharded_sweep_fedbuff(update, x0, data, r.grid, eta=sv.eta,
                                     buffer_size=bs, objective=objective,
                                     horizon=h, n_steps=n_steps, mesh=mesh,
                                     bucket_widths=bw, record_every=s,
                                     telemetry=tel, engine=eng, faults=fl,
                                     checkpoint=ckpt)

    def run_cell(i, c):
        tr = generate_federated_trace(c.n_workers, r.grid.n_events,
                                      clients=list(c.workers),
                                      buffer_size=bs, seed=c.seed,
                                      n_steps=n_steps, faults=fl)
        cd = _slice_rows(data, c.n_workers)
        if sv.name == "fedasync":
            return run_fedasync(update, x0, cd, tr, c.policy,
                                objective=objective, horizon=h,
                                record_every=s, telemetry=tel,
                                engine=eng, faults=fl, fault_seed=c.seed)
        return run_fedbuff(update, x0, cd, tr, c.policy, eta=sv.eta,
                           buffer_size=bs, objective=objective,
                           horizon=h, record_every=s,
                           telemetry=tel, engine=eng, faults=fl,
                           fault_seed=c.seed)

    return _solo_cells(r.grid, ckpt, run_cell)


_SOLVER_DISPATCH: Dict[str, Callable[..., Any]] = {
    "piag": _run_piag,
    "bcd": _run_bcd,
    "fedasync": _run_fed,
    "fedbuff": _run_fed,
}


def _build_record(spec: ExperimentSpec, r: Resolved, raw: Any,
                  elapsed: float, cache: Dict[str, Any],
                  timings) -> RunRecord:
    """Fold one dispatched run into the ledger's ``RunRecord`` shape.

    Host-side bookkeeping only: everything read off ``raw`` is already on
    the host after ``block_until_ready``; nothing here re-enters jit."""
    from repro import analysis

    grid, bins = r.grid, spec.execution.telemetry_bins
    tel = getattr(raw, "telemetry", None)
    if tel is not None:
        summ = summarize_telemetry(tel)
        delay_hist, hist_source = summ["hist"], "accumulator"
        tau_stats, gamma_stats = summ["tau"], summ["gamma"]
    else:
        taus = np.asarray(raw.taus).reshape(-1)
        gam = np.asarray(raw.weights if "weights" in raw._fields
                         else raw.gammas, np.float64).reshape(-1)
        delay_hist = np.bincount(np.clip(taus, 0, bins - 1),
                                 minlength=bins).astype(np.int64).tolist()
        hist_source = "recorded"
        tau_stats = {"min": int(taus.min()), "max": int(taus.max()),
                     "mean": float(taus.mean()), "std": float(taus.std())}
        gamma_stats = {"min": float(gam.min()), "max": float(gam.max()),
                       "mean": float(gam.mean()), "std": float(gam.std())}

    if spec.execution.backend == "sharded":
        mesh = _mesh_for(spec)
        devices, mesh_shape = int(mesh.devices.size), \
            [int(d) for d in mesh.devices.shape]
    else:
        devices, mesh_shape = 1, None

    compile_ms = sum(ev["ms"] for ev in timings
                     if ev["name"] in COMPILE_EVENT_NAMES)
    width = max(c.n_workers for c in grid.cells)
    return RunRecord(
        ts=time.time(),
        fingerprint=spec_fingerprint(spec, grid),
        solver=spec.solver.name,
        backend=spec.execution.backend,
        n_cells=len(grid.cells),
        n_events=int(grid.n_events),
        record_every=int(spec.execution.record_every),
        horizon=int(r.horizon),
        tau_bar=None if r.tau_bar is None else int(r.tau_bar),
        devices=devices,
        mesh_shape=mesh_shape,
        carry_bytes=estimate_carry_bytes(spec.solver.name,
                                         int(getattr(r.problem, "dim", 0)),
                                         width, r.horizon, len(grid.cells)),
        elapsed_ms=elapsed * 1e3,
        compile_ms=float(compile_ms),
        warm_ms=max(elapsed * 1e3 - compile_ms, 0.0),
        cache=cache,
        delay_hist=list(delay_hist),
        hist_source=hist_source,
        tau_stats=tau_stats,
        gamma_stats=gamma_stats,
        clipped=analysis.clipped_summary(raw.clipped),
        policies=sorted({c.policy_name for c in grid.cells}),
        timings=list(timings),
        faults=summarize_faults(getattr(raw, "faults", None)) or None,
    )


def run(spec: ExperimentSpec, resume=None) -> Results:
    """The single entry point: resolve the spec, dispatch to the runner for
    (solver, backend), return the unified ``Results`` table.

    Every run also builds a ``repro.telemetry.RunRecord`` (surfaced on
    ``Results.telemetry``; appended to the JSONL ledger when one is
    configured): the timing buffer is drained around the dispatch so
    compile-side events attribute to THIS run, and the program-cache
    counters are snapshotted for a reset-scoped hit/miss delta.

    ``resume`` names a checkpoint directory: buckets (batched/sharded) or
    cells (solo) finished by an earlier -- possibly killed -- run of the
    SAME spec are loaded from disk instead of recomputed, and fresh ones
    are persisted there as they complete.  Files are fingerprint-stamped;
    resuming a different spec into the same directory raises."""
    r = resolve(spec)
    ckpt = None
    if resume is not None:
        ckpt = SweepCheckpoint(
            resume, spec_fingerprint(spec, r.grid),
            tag=f"{spec.solver.name}_{spec.execution.backend}")
    drain_timings()  # drop events from unrelated earlier activity
    cache_before = program_cache_stats()
    t0 = time.perf_counter()
    raw = jax.block_until_ready(_SOLVER_DISPATCH[spec.solver.name](r, ckpt))
    elapsed = time.perf_counter() - t0
    record = _build_record(
        spec, r, raw, elapsed,
        cache_delta(cache_before, program_cache_stats()), drain_timings())
    append_record(record)
    return Results(solver=spec.solver.name, backend=spec.execution.backend,
                   grid=r.grid, raw=raw, elapsed_s=elapsed,
                   tau_bar=r.tau_bar, spec=spec, horizon=r.horizon,
                   record_every=spec.execution.record_every,
                   telemetry=record, cache_stats=record.cache)


# -------------------------------------------------- component escape ----

def component_spec(solver: str, backend: str, *, problem, grid, prox,
                   mesh=None, mesh_shape=None, reference: bool = False,
                   record_every: int = 1, telemetry: bool = False,
                   telemetry_bins: int = 64, engine: str = "scan",
                   faults=None, **solver_kwargs) -> ExperimentSpec:
    """A spec from prebuilt components (problem + grid + prox), bypassing
    the declarative build.  This is the form the legacy shims use; horizon
    validation and tau-bar measurement are off so shim behavior matches the
    pre-redesign runners exactly (including deliberate tiny-horizon runs).
    """
    from .spec import DelaySpec
    return ExperimentSpec(
        problem=ProblemSpec(kind="custom", problem=problem, prox_op=prox),
        solver=SolverSpec(name=solver, **solver_kwargs),
        execution=ExecutionSpec(backend=backend, mesh=mesh,
                                mesh_shape=mesh_shape,
                                reference=reference,
                                record_every=record_every,
                                telemetry=telemetry,
                                telemetry_bins=telemetry_bins,
                                engine=engine),
        delay=DelaySpec(measure=False),
        n_events=grid.n_events,
        grid=grid,
        validate_horizon=False,
        faults=faults,
    )


def run_components(solver: str, backend: str, *, problem, grid, prox,
                   mesh=None, mesh_shape=None, reference: bool = False,
                   record_every: int = 1, telemetry: bool = False,
                   telemetry_bins: int = 64, engine: str = "scan",
                   faults=None, resume=None, **solver_kwargs) -> Results:
    """``run`` over prebuilt components (see ``component_spec``)."""
    return run(component_spec(solver, backend, problem=problem, grid=grid,
                              prox=prox, mesh=mesh, mesh_shape=mesh_shape,
                              reference=reference,
                              record_every=record_every, telemetry=telemetry,
                              telemetry_bins=telemetry_bins, engine=engine,
                              faults=faults, **solver_kwargs),
               resume=resume)
