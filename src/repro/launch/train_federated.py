"""Delay-adaptive asynchronous federated training driver.

Two workload families, one server mechanism:

* the paper's convex problems (``--problem logreg`` / ``--problem lasso``)
  run fully jitted through ``repro.federated.server`` and report true
  suboptimality against the centralized optimum (``solve_centralized``);
* the small transformer presets from ``launch.train`` (``--preset 25m`` ...)
  run a host-loop federated parameter server: each client holds its own data
  stream and model snapshot, runs ``--local-steps`` SGD steps per round, and
  the server mixes client models with the delay-adaptive staleness weight
  alpha * s(tau) -- the federated analogue of the delay-adaptive gamma(tau)
  in ``launch.train``.

    PYTHONPATH=src python -m repro.launch.train_federated --problem logreg \
        --uploads 2000 --policy hinge
    PYTHONPATH=src python -m repro.launch.train_federated --preset 25m \
        --uploads 30 --clients 4 --local-steps 2
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (L1, make_lasso, make_logreg, make_policy,
                        solve_centralized)
from repro.core.stepsize import StepsizePolicy
from repro.federated import (heterogeneous_clients, run_fedasync_problem,
                             run_fedbuff_problem, simulate_federated)
from repro.models import init_params, loss_fn

__all__ = ["run_convex_federated", "run_transformer_federated", "make_weight_policy"]


def make_weight_policy(name: str, alpha: float, tau_bound: int = 0) -> StepsizePolicy:
    """Server mixing-weight policy.  ``fixed_taubound`` is the worst-case
    -tuned constant alpha/(tau_bound+1); the adaptive policies only need the
    measured staleness."""
    if name == "fixed_taubound":
        return make_policy("constant", alpha / (tau_bound + 1))
    if name == "fixed_taubound_sqrt":
        return make_policy("constant", alpha / float(np.sqrt(tau_bound + 1)))
    if name == "hinge":
        return make_policy("hinge", alpha, a=0.5, b=16.0)
    if name == "poly":
        return make_policy("poly", alpha, a=0.3)
    if name == "constant":
        return make_policy("constant", alpha)
    raise ValueError(f"unknown weight policy {name!r}")


def run_convex_federated(problem_name: str = "logreg", *, uploads: int = 2000,
                         n_clients: int = 8, policy_name: str = "hinge",
                         alpha: float = 0.4, buffer_size: int = 1,
                         eta: float = 0.4, seed: int = 0,
                         out_dir: Optional[str] = None):
    """FedAsync/FedBuff on logreg or lasso; returns the metrics log."""
    if problem_name == "logreg":
        prob = make_logreg(n_samples=500, dim=50, n_workers=n_clients, seed=seed)
    elif problem_name == "lasso":
        prob = make_lasso(n_samples=500, dim=100, n_workers=n_clients, seed=seed)
    else:
        raise ValueError(f"unknown problem {problem_name!r}")
    prox = L1(lam=prob.lam1)
    _, objs = solve_centralized(prob, prox, iters=3000)
    p_star = float(objs[-1])

    clients = heterogeneous_clients(n_clients, spread=4.0, seed=seed + 1,
                                    p_straggle=0.05, p_dropout=0.02)
    trace = simulate_federated(n_clients, uploads, clients,
                               buffer_size=buffer_size, seed=seed + 1)
    # FedAsync mixes with alpha*s(tau) directly; FedBuff's per-delta weight is
    # the bare s(tau) (gamma'=1) with alpha applied once as the server lr eta.
    base_weight = alpha if buffer_size == 1 else 1.0
    policy = make_weight_policy(policy_name, base_weight, trace.max_delay())
    print(f"problem={problem_name} clients={n_clients} uploads={uploads} "
          f"buffer={buffer_size} policy={policy_name} alpha={alpha} "
          f"max_tau={trace.max_delay()} P*={p_star:.5f}")

    t0 = time.perf_counter()
    if buffer_size == 1:
        res = run_fedasync_problem(prob, trace, policy, prox,
                                   local_lr=0.5 / prob.L)
    else:
        res = run_fedbuff_problem(prob, trace, policy, prox, eta=eta,
                                  buffer_size=buffer_size,
                                  local_lr=0.5 / prob.L)
    wall = time.perf_counter() - t0
    sub = np.asarray(res.objective) - p_star
    log = {"problem": problem_name, "policy": policy_name,
           "uploads": uploads, "buffer": buffer_size,
           "max_tau": int(trace.max_delay()), "p_star": p_star,
           "final_subopt": float(sub[-1]), "best_subopt": float(sub.min()),
           "wall_s": wall}
    print(f"final P-P* = {sub[-1]:.6f}  best = {sub.min():.6f}  "
          f"({wall:.1f}s, {uploads / wall:.0f} uploads/s)")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "federated_log.json"), "w") as f:
            json.dump(log, f, indent=1)
    return log


def run_transformer_federated(cfg, *, uploads: int = 30, n_clients: int = 4,
                              local_steps: int = 2, policy_name: str = "hinge",
                              alpha: float = 0.4, local_lr: float = 3e-3,
                              batch: int = 4, seq: int = 128, seed: int = 0,
                              log_every: int = 5):
    """Host-loop FedAsync on a small transformer preset.

    Memory = (n_clients + 1) x params (server model + per-client snapshots),
    so this runs the 25m preset comfortably on CPU.  The event structure
    comes from the same ``FederatedTrace`` the convex path uses; only the
    client update (local SGD on the client's token stream) differs.
    """
    from repro.launch.train import make_stream

    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    n_params = sum(int(np.prod(l.shape)) for l in
                   jax.tree_util.tree_leaves(params))

    clients = heterogeneous_clients(n_clients, spread=3.0, seed=seed,
                                    p_straggle=0.05, p_dropout=0.01,
                                    local_epochs=local_steps)
    trace = simulate_federated(n_clients, uploads, clients, seed=seed)
    policy = make_weight_policy(policy_name, alpha, trace.max_delay())
    print(f"model={cfg.name} params={n_params / 1e6:.1f}M clients={n_clients} "
          f"uploads={uploads} local_steps={local_steps} policy={policy_name} "
          f"max_tau={trace.max_delay()}")

    # per-client disjoint data streams (different seeds = different shards)
    streams = [make_stream(cfg, batch, seq, seed=seed + 100 + c)
               for c in range(n_clients)]
    eval_stream = make_stream(cfg, batch, seq, seed=seed + 999)

    grad_fn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))
    loss_jit = jax.jit(lambda p, b: loss_fn(p, cfg, b)[0])
    sgd = jax.jit(lambda p, g, lr: jax.tree_util.tree_map(
        lambda a, b: (a - lr * b).astype(a.dtype), p, g))
    mix = jax.jit(lambda x, xc, gamma: jax.tree_util.tree_map(
        lambda a, c: (a + gamma * (c - a)).astype(a.dtype), x, xc))
    ss_step = jax.jit(policy.step)

    snapshots = [params for _ in range(n_clients)]  # model each client reads
    ss = policy.init()
    log = []
    t0 = time.perf_counter()
    for k in range(uploads):
        c = int(trace.client[k])
        tau = jnp.int32(int(trace.tau[k]))
        # client c: local_steps SGD steps from its snapshot on its own stream
        xc = snapshots[c]
        for s in range(int(trace.local_steps[k])):
            xc = sgd(xc, grad_fn(xc, streams[c].batch_at(k * local_steps + s)),
                     local_lr)
        gamma, ss = ss_step(ss, tau)
        params = mix(params, xc, gamma)
        snapshots[c] = params           # client picks up the new server model
        if k % log_every == 0 or k == uploads - 1:
            lv = float(loss_jit(params, eval_stream.batch_at(10_000)))
            rec = {"upload": k, "loss": lv, "gamma": float(gamma),
                   "tau": int(tau), "wall_s": time.perf_counter() - t0}
            log.append(rec)
            print(f"upload {k:4d} loss {lv:.4f} gamma {float(gamma):.3f} "
                  f"tau {int(tau)} ({rec['wall_s']:.1f}s)")
    return log


def main() -> None:
    from repro.launch.train import PRESETS

    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--problem", choices=["logreg", "lasso"])
    g.add_argument("--preset", choices=list(PRESETS))
    ap.add_argument("--uploads", type=int, default=2000)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--policy", default="hinge",
                    choices=["hinge", "poly", "constant", "fixed_taubound",
                             "fixed_taubound_sqrt"])
    ap.add_argument("--alpha", type=float, default=0.4)
    ap.add_argument("--buffer", type=int, default=1,
                    help="FedBuff buffer |R|; 1 = FedAsync")
    ap.add_argument("--eta", type=float, default=0.4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-lr", type=float, default=3e-3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.problem:
        run_convex_federated(args.problem, uploads=args.uploads,
                             n_clients=args.clients, policy_name=args.policy,
                             alpha=args.alpha, buffer_size=args.buffer,
                             eta=args.eta, seed=args.seed, out_dir=args.out)
    else:
        run_transformer_federated(PRESETS[args.preset], uploads=args.uploads,
                                  n_clients=args.clients,
                                  local_steps=args.local_steps,
                                  policy_name=args.policy, alpha=args.alpha,
                                  local_lr=args.local_lr, batch=args.batch,
                                  seq=args.seq, seed=args.seed,
                                  log_every=args.log_every)


if __name__ == "__main__":
    main()
