"""Sharding planner: maps every parameter / optimizer / activation / cache
leaf to a PartitionSpec on the production mesh.

Rules (divisibility-checked -- any dim not divisible by its axis size is
left replicated rather than unevenly sharded):

* parameters: the largest divisible feature dim goes to "model" (ties break
  toward the *later* dim, i.e. column-parallel for up-projections and
  row-parallel for down-projections); a second divisible dim goes to the
  data axes (FSDP/ZeRO-3) so the 236B config fits 16 GB/chip.  The leading
  stacked-layers axis is never sharded (it is scanned over).
* MoE expert tensors: the expert dim goes to "model" when divisible
  (expert parallelism, e.g. deepseek's 160 experts on 16-way model axis);
  otherwise falls back to the feature rule (qwen2-moe's 60 experts).
* batches: the global-batch dim is sharded over ("pod","data"); everything
  else replicated.  long_500k (batch=1) shards the cache sequence dim over
  the data axes instead (context parallelism).
* optimizer state: same rule as its parameter (identical shapes).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes, dp_size, model_size


def _key_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(f"#{k.idx}")
    return tuple(names)


def _param_spec(names: Tuple[str, ...], shape: Tuple[int, ...], mesh,
                fsdp: bool = True, small_out_threshold: int = 0) -> P:
    md = model_size(mesh)
    dps = dp_axes(mesh)
    dsz = dp_size(mesh)
    ndim = len(shape)
    spec: list = [None] * ndim

    # leading stacked-layers axis (params under "layers"/"shared" groups are
    # stacked (L, ...) or (G, ...)): never sharded
    start = 1 if ("layers" in names and ndim >= 2) else 0
    cand = list(range(start, ndim))

    # expert parallelism: 4-D (L, E, D, F) expert tensors
    model_dim: Optional[int] = None
    if any("w" in n for n in names) and "moe" in names and ndim >= 4:
        e_dim = start
        if shape[e_dim] % md == 0:
            model_dim = e_dim
    if model_dim is None:
        best = -1
        for i in cand:
            if md > 1 and shape[i] % md == 0 and shape[i] >= md:
                if shape[i] >= best:
                    best = shape[i]
                    model_dim = i
    # §Perf H2: row-parallel sharding of a projection with a SMALL output
    # (e.g. MLA's w_dkv: 5120 -> 576) forces a per-token all-reduce of the
    # partial sums that dwarfs the weight itself -- replicate over "model"
    # (FSDP still shards it over data) instead.
    if (small_out_threshold and model_dim is not None and ndim >= 2 and
            model_dim == ndim - 2 and shape[-1] <= small_out_threshold):
        model_dim = None
    if model_dim is not None and md > 1:
        spec[model_dim] = "model"

    if fsdp and dps:
        best = -1
        fsdp_dim = None
        for i in cand:
            if i == model_dim:
                continue
            if shape[i] % dsz == 0 and shape[i] >= dsz:
                if shape[i] > best:
                    best = shape[i]
                    fsdp_dim = i
        if fsdp_dim is not None:
            spec[fsdp_dim] = dps if len(dps) > 1 else dps[0]
    return P(*spec)


def param_shardings(tree: Any, mesh, fsdp: bool = True,
                    small_out_threshold: int = 0):
    """NamedShardings for a parameter-shaped pytree (params or opt state)."""
    def one(path, leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _param_spec(
            _key_names(path), shape, mesh, fsdp=fsdp,
            small_out_threshold=small_out_threshold))
    return jax.tree_util.tree_map_with_path(one, tree)


def batch_shardings(tree: Any, mesh, global_batch: int):
    """Shard the global-batch dim over ("pod","data")."""
    dps = dp_axes(mesh)
    dsz = dp_size(mesh)
    dp = dps if len(dps) > 1 else (dps[0] if dps else None)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        if global_batch % max(dsz, 1) == 0 and dsz > 1:
            for i, s in enumerate(shape):
                if s == global_batch:
                    spec[i] = dp
                    break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def cache_shardings(tree: Any, mesh, global_batch: int, seq_len: int,
                    context_parallel: bool = False):
    """Decode-cache sharding.

    Baseline: batch dim -> data axes; a KV/feature dim -> "model" when
    divisible; batch=1 -> cache sequence dim -> data axes.

    ``context_parallel=True`` (§Perf H3): the cache *sequence* dim is sharded
    over "model" instead of the feature dim, so the per-token attention
    gathers only O(B*H*S) f32 score statistics instead of the whole
    O(B*S*r) latent / O(B*S*KV*hd) KV cache every step."""
    dps = dp_axes(mesh)
    dsz = dp_size(mesh)
    md = model_size(mesh)
    dp = dps if len(dps) > 1 else (dps[0] if dps else None)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        spec: list = [None] * ndim
        if ndim <= 1:
            return NamedSharding(mesh, P(*spec))
        dp_dim = None
        if dsz > 1 and global_batch % dsz == 0 and global_batch > 1:
            for i in range(1, ndim):
                if shape[i] == global_batch:
                    dp_dim = i
                    spec[i] = dp
                    break
        elif dsz > 1:
            # batch too small: context-parallel the sequence dim over data
            for i in range(1, ndim):
                if shape[i] == seq_len and seq_len % dsz == 0:
                    dp_dim = i
                    spec[i] = dp
                    break
        if md > 1:
            mdim = None
            if context_parallel:
                for i in range(1, ndim):
                    if i != dp_dim and shape[i] == seq_len and \
                            seq_len % md == 0:
                        mdim = i
                        break
            if mdim is None and not context_parallel:
                best = -1
                for i in range(1, ndim):
                    if i == dp_dim or shape[i] == seq_len:
                        continue
                    if shape[i] % md == 0 and shape[i] >= md and shape[i] > best:
                        best = shape[i]
                        mdim = i
            if mdim is not None:
                spec[mdim] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def replicated(tree: Any, mesh):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def describe_shardings(tree, shardings, max_rows: int = 0):
    """Human-readable (path, shape, spec) table for DESIGN/EXPERIMENTS."""
    rows = []
    flat_t = jax.tree_util.tree_flatten_with_path(tree)[0]
    flat_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    for (path, leaf), sh in zip(flat_t, flat_s):
        rows.append(("/".join(_key_names(path)), tuple(leaf.shape),
                     str(sh.spec)))
    if max_rows:
        rows = rows[:max_rows]
    return rows
