"""Re-export shim: the sharding planner moved to ``repro.mesh``.

The 2-D sweep-mesh work consolidated every mesh concern (sweep cell/grid
meshes, topology cache keys, jax.distributed bootstrap, and this
parameter/batch/cache planner) into the single :mod:`repro.mesh` module.
This shim keeps the historical ``repro.launch.sharding`` import path
working.
"""
from __future__ import annotations

from repro.mesh import (  # noqa: F401
    _key_names,
    _param_spec,
    batch_shardings,
    cache_shardings,
    describe_shardings,
    param_shardings,
    replicated,
)

__all__ = ["param_shardings", "batch_shardings", "cache_shardings",
           "replicated", "describe_shardings"]
