"""Measured per-(family, shape) optimization recipes (EXPERIMENTS.md §Perf).

The §Perf hillclimbs showed the knob bundle is NOT a safe global default:
``shard_acts`` regresses embedding-input models (VLM/audio) whose batch
sharding XLA already propagates well, and ``small_out`` slightly regresses
decode.  This table encodes the measured guidance; ``recommended_knobs``
returns kwargs for ``launch.dryrun.build_lowered`` / the trainer.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import SHAPES, get_config

# keyed by (token_inputs, shape.kind)
_RECIPES: Dict[tuple, dict] = {
    # token-input models (dense / moe / ssm / hybrid)
    (True, "train"): dict(remat_chunk=True, shard_acts=True, seq_shard=True,
                          ce_chunk=512),
    (True, "prefill"): dict(shard_acts=True),
    (True, "decode"): dict(cp_cache=True),
    (True, "decode_long"): dict(cp_cache=True),
    # embedding-input models (audio / vlm): activation constraints fight
    # XLA's layout -- remat only (H5)
    (False, "train"): dict(remat_chunk=True),
    (False, "prefill"): dict(),
    (False, "decode"): dict(cp_cache=True),
    (False, "decode_long"): dict(cp_cache=True),
}


def recommended_knobs(arch: str, shape_name: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    knobs = dict(_RECIPES[(not cfg.embed_inputs, shape.kind)])
    # chunked CE only pays off for big vocabularies
    if knobs.get("ce_chunk") and cfg.vocab < 100_000:
        knobs.pop("ce_chunk")
    return knobs
