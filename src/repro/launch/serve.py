"""Batched serving driver: prefill a prompt batch, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.train import PRESETS
from repro.models import (decode_step, init_params, make_cache, prefill)
from repro.models.config import ModelConfig


def generate(cfg: ModelConfig, params, prompts: jnp.ndarray, gen: int,
             temperature: float = 0.0, seed: int = 0):
    """prompts (B, S) int32 -> (B, S+gen) greedy/temperature sampling."""
    B, S = prompts.shape
    max_len = S + gen
    logits, pf_cache = jax.jit(
        lambda p, b: prefill(p, cfg, b))(params, {"tokens": prompts})
    # copy prefill cache into a max_len cache
    cache = make_cache(cfg, B, max_len)
    def graft(buf, c):
        if buf.ndim == c.ndim and buf.shape != c.shape:
            return jax.lax.dynamic_update_slice_in_dim(
                buf, c.astype(buf.dtype), 0,
                axis=next(i for i in range(buf.ndim)
                          if buf.shape[i] != c.shape[i]))
        return c.astype(buf.dtype) if buf.shape == c.shape else buf
    cache = jax.tree_util.tree_map(graft, cache, pf_cache)

    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    key = jax.random.PRNGKey(seed)
    toks = [prompts]
    last = logits
    out = prompts
    t0 = time.perf_counter()
    for i in range(gen):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(last[:, -1], axis=-1)[:, None]
        nxt = nxt.astype(jnp.int32)
        out = jnp.concatenate([out, nxt], axis=1)
        last, cache = step(params, cache, nxt, jnp.int32(S + i))
    dt = time.perf_counter() - t0
    return out, {"decode_s": dt, "tok_per_s": B * gen / dt}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--arch", choices=ARCH_IDS)
    g.add_argument("--preset", choices=list(PRESETS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = PRESETS[args.preset] if args.preset else get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")
    if cfg.embed_inputs:
        raise SystemExit("serve driver is text-only; VLM prefill needs the "
                         "frontend stub (see examples/serve_decode.py)")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, dtype=jnp.int32)
    out, stats = generate(cfg, params, prompts, args.gen,
                          temperature=args.temperature)
    print(f"generated {out.shape} in {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    print(np.asarray(out[:, args.prompt_len:][:2]))


if __name__ == "__main__":
    main()
