import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh(es) and record memory / cost / collective analyses.

MUST be run as a script / module (the XLA_FLAGS line above executes before
any jax import -- jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-780m \
        --shape decode_32k --mesh 2x4        # reduced mesh (tests)

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, input_specs  # noqa: E402
from repro.configs.shapes import cache_len, decode_window, uses_ring  # noqa: E402
from repro.mesh import dp_size, make_mesh, make_production_mesh  # noqa: E402
from repro.launch.roofline import (model_flops, parse_collective_bytes)  # noqa: E402
from repro.mesh import (batch_shardings, cache_shardings,  # noqa: E402
                                   param_shardings)
from repro.launch.steps import make_prefill_step, make_serve_step, make_trainer  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

DEFAULT_OUT = "experiments/dryrun"


def mesh_tag(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def build_lowered(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
                  remat_chunk: bool = False, shard_acts: bool = False,
                  seq_shard: bool = False, cp_cache: bool = False,
                  small_out: int = 0, ce_chunk: int = 0):
    """Construct and lower the step for one (arch, shape, mesh) combo.

    The keyword knobs are the §Perf beyond-paper optimizations; all default
    OFF so the recorded baseline stays the paper-faithful configuration."""
    from repro.mesh import dp_axes
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return None, why
    if remat_chunk or shard_acts or seq_shard or ce_chunk:
        cfg = cfg.replace(remat_chunk=remat_chunk,
                          shard_activations=shard_acts,
                          seq_shard=seq_shard,
                          ce_chunk=ce_chunk,
                          act_dp_axes=tuple(dp_axes(mesh)))
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        trainer = make_trainer(cfg, n_workers=dp_size(mesh))
        state_specs = trainer.state_specs()
        p_sh = param_shardings(state_specs.params, mesh, fsdp=fsdp,
                               small_out_threshold=small_out)
        o_sh = param_shardings(state_specs.opt.inner, mesh, fsdp=fsdp,
                               small_out_threshold=small_out)
        from repro.launch.steps import TrainState
        from repro.optim.optimizers import DelayAdaptiveState
        opt_sh = DelayAdaptiveState(
            step=NamedSharding(mesh, P()),
            ss=jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()),
                                      state_specs.opt.ss),
            inner=o_sh,
            worker_stamp=NamedSharding(mesh, P()),
        )
        state_sh = TrainState(params=p_sh, opt=opt_sh)
        b_sh = batch_shardings(specs["batch"], mesh, shape.global_batch)
        w_sh = NamedSharding(mesh, P())
        step = trainer.train_step
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, b_sh, w_sh),
            out_shardings=(state_sh, None),
        ).lower(state_specs, specs["batch"], jax.ShapeDtypeStruct((), jnp.int32))
        return lowered, ""

    from repro.models import param_specs as _pspecs
    pspecs = _pspecs(cfg)
    p_sh = param_shardings(pspecs, mesh, fsdp=fsdp,
                           small_out_threshold=small_out)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        b_sh = batch_shardings(specs["batch"], mesh, shape.global_batch)
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
            pspecs, specs["batch"])
        return lowered, ""

    # decode / decode_long
    window = decode_window(cfg, shape)
    ring = uses_ring(cfg, shape)
    step = make_serve_step(cfg, window=window, ring=ring)
    c_sh = cache_shardings(specs["cache"], mesh, shape.global_batch,
                           cache_len(cfg, shape), context_parallel=cp_cache)
    t_sh = batch_shardings(specs["token"], mesh, shape.global_batch)
    lowered = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, t_sh, NamedSharding(mesh, P())),
        out_shardings=(None, c_sh),
    ).lower(pspecs, specs["cache"], specs["token"],
            jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, ""


def analyze(lowered, compiled, cfg_arch: str, shape_name: str, mesh) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(cfg_arch)
    chips = mesh.size
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # raw XLA numbers under-count while-loop bodies (counted once); the
    # while-aware HLO cost model recovers exact per-step totals.
    flops_raw = float(cost.get("flops", 0.0))
    nbytes_raw = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    from repro.launch.hlo_cost import analyze_hlo
    adj = analyze_hlo(hlo)
    flops = float(adj.flops)
    nbytes = float(adj.bytes)
    coll = {k: float(v) for k, v in adj.coll_breakdown.items()}
    coll_counts = parse_collective_bytes(hlo).pop("_counts")
    coll_total = float(adj.coll_bytes)
    mem = compiled.memory_analysis()
    memd = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        memd[attr] = int(getattr(mem, attr, 0) or 0)
    mf = model_flops(cfg, shape)
    return {
        "arch": cfg_arch,
        "shape": shape_name,
        "mesh": mesh_tag(mesh),
        "chips": chips,
        "flops_per_device_xla_raw": flops_raw,
        "hbm_bytes_per_device_xla_raw": nbytes_raw,
        "flops_per_device": flops,
        "hbm_bytes_per_device": nbytes,
        "collective_bytes_per_device": coll_total,
        "collective_breakdown": coll,
        "collective_counts": coll_counts,
        "memory": memd,
        "model_flops_total": mf,
    }


def run_one(arch: str, shape_name: str, mesh, out_dir: str, *,
            fsdp: bool = True, tag: str = "", verbose: bool = True,
            **knobs) -> dict:
    t0 = time.time()
    lowered, why = build_lowered(arch, shape_name, mesh, fsdp=fsdp, **knobs)
    if lowered is None:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag(mesh),
               "skipped": why}
        _write(out_dir, rec, tag)
        if verbose:
            print(f"SKIP  {arch} x {shape_name} x {mesh_tag(mesh)}: {why}")
        return rec
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = analyze(lowered, compiled, arch, shape_name, mesh)
    rec["t_lower_s"] = t_lower
    rec["t_compile_s"] = t_compile
    _write(out_dir, rec, tag)
    if verbose:
        mb = rec["memory"]
        per_dev_gb = (mb["argument_size_in_bytes"] + mb["temp_size_in_bytes"] +
                      mb["output_size_in_bytes"]) / 2**30
        print(f"OK    {arch} x {shape_name} x {mesh_tag(mesh)}  "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"coll/dev={rec['collective_bytes_per_device']:.3e}B "
              f"mem(arg+tmp+out)={per_dev_gb:.2f}GiB  "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
    return rec


def _write(out_dir: str, rec: dict, tag: str = "") -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if tag:
        name += f"__{tag}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true", help="all arch x shape")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16")
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. 2x4 (data x model) or 2x2x2")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="")
    # §Perf beyond-paper knobs (baseline = all off)
    ap.add_argument("--remat-chunk", action="store_true")
    ap.add_argument("--shard-acts", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--cp-cache", action="store_true")
    ap.add_argument("--small-out", type=int, default=0)
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--opt", action="store_true",
                    help="enable the full optimized bundle")
    args = ap.parse_args()
    if args.opt:
        args.remat_chunk = args.shard_acts = args.cp_cache = True
        args.small_out = args.small_out or 1024
        if not args.tag:
            args.tag = "opt"
    knobs = dict(remat_chunk=args.remat_chunk, shard_acts=args.shard_acts,
                 seq_shard=args.seq_shard, cp_cache=args.cp_cache,
                 small_out=args.small_out, ce_chunk=args.ce_chunk)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(dims)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    failures = []
    with mesh:
        for a, s in combos:
            try:
                run_one(a, s, mesh, args.out, fsdp=not args.no_fsdp,
                        tag=args.tag, **knobs)
            except Exception as e:  # pragma: no cover
                failures.append((a, s, repr(e)))
                print(f"FAIL  {a} x {s}: {e}")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run combos failed: {failures}")


if __name__ == "__main__":
    main()
