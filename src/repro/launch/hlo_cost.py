"""While-loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers program under-reports FLOPs / bytes / collective traffic by
~n_layers x (verified in tests/test_hlo_cost.py).  The optimized HLO however
annotates ``backend_config={"known_trip_count":{"n":...}}``, which lets us
recover exact per-step totals:

    cost(program) = sum_instr cost(instr) * prod(trip counts of enclosing whiles)

* FLOPs: 2 * prod(result dims) * prod(contracting dims) for every ``dot``
  (including dots inside fusions); other ops contribute ~0 FLOPs at matmul
  scale.
* HBM bytes: result + operand bytes per *top-level* op (fusion internals do
  not round-trip HBM -- the post-fusion graph is the HBM-traffic proxy).
  Pure data-movement ops (tuple plumbing, parameters, constants, bitcasts)
  are skipped.
* Collective bytes: result bytes (operand bytes for reduce-scatter) of every
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
  times enclosing trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_SINGLE_RE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)")
_CALL_MULTI_RE = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str  # args + attributes


def parse_hlo(text: str):
    """-> (computations: name -> [Instr], entry_name, shapes: name -> shape)."""
    comps: Dict[str, List[Instr]] = {}
    shapes: Dict[str, str] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, op, rest = m.groups()
            comps[cur].append(Instr(name, shape, op, rest))
            shapes[name] = shape
    return comps, entry, shapes


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    # result dims x contracting dims (from lhs)
    rdims = _shape_dims(instr.shape)
    if not rdims:
        return 0.0
    rprod = 1
    for d in rdims[0][1]:
        rprod *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) else []
    # first operand name
    ops_m = re.findall(r"%([\w.\-]+)", instr.rest)
    cprod = 1
    if ops_m and cdims:
        lhs_shape = shapes.get(ops_m[0], "")
        ldims = _shape_dims(lhs_shape)
        if ldims:
            for c in cdims:
                if c < len(ldims[0][1]):
                    cprod *= ldims[0][1][c]
    return 2.0 * rprod * cprod


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k in COLLECTIVES:
            self.coll_breakdown[k] += o.coll_breakdown[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_breakdown.items()})


def _fusion_flops(comp_name, comps, shapes, memo) -> float:
    """FLOPs of dots inside a fusion/called computation (counted once)."""
    if comp_name in memo:
        return memo[comp_name]
    total = 0.0
    for ins in comps.get(comp_name, []):
        if ins.op == "dot":
            total += _dot_flops(ins, shapes)
        elif ins.op in ("fusion", "call", "map"):
            for ref in _called(ins):
                total += _fusion_flops(ref, comps, shapes, memo)
    memo[comp_name] = total
    return total


def _called(ins: Instr) -> List[str]:
    out = [m.group(1) for m in _CALL_SINGLE_RE.finditer(ins.rest)]
    for m in _CALL_MULTI_RE.finditer(ins.rest):
        out.extend(nm.strip().lstrip("%") for nm in m.group(1).split(","))
    return out


def _operand_bytes(ins: Instr, shapes: Dict[str, str]) -> int:
    total = 0
    for nm in re.findall(r"%([\w.\-]+)", ins.rest.split(")", 1)[0] + ")"):
        if nm in shapes:
            total += _shape_bytes(shapes[nm])
    return total


def _max_operand_bytes(ins: Instr, shapes: Dict[str, str]) -> int:
    best = 0
    for nm in re.findall(r"%([\w.\-]+)", ins.rest.split(")", 1)[0] + ")"):
        if nm in shapes:
            best = max(best, _shape_bytes(shapes[nm]))
    return best


def _instr_bytes(ins: Instr, shapes: Dict[str, str]) -> float:
    """HBM traffic estimate for one top-level op (or fusion).

    dynamic-update-slice executes in place (XLA aliases the accumulator):
    traffic = slice write + small reads, NOT the full buffer round-trip.
    dynamic-slice reads only the slice: traffic = 2 x result.
    """
    name = ins.name
    rb = _shape_bytes(ins.shape)
    ob = _operand_bytes(ins, shapes)
    if ins.op == "dynamic-update-slice" or "dynamic-update-slice" in name:
        mx = _max_operand_bytes(ins, shapes)
        return float(max(rb + ob - 2 * mx, rb - mx, 0))
    if ins.op == "dynamic-slice" or (
            "dynamic-slice" in name and "update" not in name):
        return float(2 * rb)
    return float(rb + ob)


def _comp_cost(comp_name: str, comps, shapes, memo, fus_memo) -> Cost:
    if comp_name in memo:
        return memo[comp_name]
    cost = Cost()
    for ins in comps.get(comp_name, []):
        if ins.op == "while":
            m = _TRIP_RE.search(ins.rest)
            trip = int(m.group(1)) if m else 1
            inner = Cost()
            for ref in _called(ins):  # body + condition
                inner += _comp_cost(ref, comps, shapes, memo, fus_memo)
            cost += inner.scaled(trip)
        elif ins.op == "conditional":
            branches = [_comp_cost(r, comps, shapes, memo, fus_memo)
                        for r in _called(ins)]
            if branches:  # conservative: the max-cost branch
                big = max(branches, key=lambda c: c.flops + c.bytes)
                cost += big
        elif ins.op in ("call", "async-start", "custom-call"):
            for ref in _called(ins):
                cost += _comp_cost(ref, comps, shapes, memo, fus_memo)
            if ins.op not in SKIP_BYTES_OPS:
                cost.bytes += _shape_bytes(ins.shape)
        elif ins.op == "fusion":
            cost.flops += _fusion_flops(_called(ins)[0], comps, shapes,
                                        fus_memo) if _called(ins) else 0.0
            cost.bytes += _instr_bytes(ins, shapes)
        elif ins.op == "dot":
            cost.flops += _dot_flops(ins, shapes)
            cost.bytes += _instr_bytes(ins, shapes)
        elif any(ins.op == c or ins.op == c + "-start" or
                 ins.op.startswith(c + ".") for c in COLLECTIVES):
            base = next(c for c in COLLECTIVES
                        if ins.op == c or ins.op == c + "-start" or
                        ins.op.startswith(c + "."))
            if base == "reduce-scatter":
                nb = max(_operand_bytes(ins, shapes), _shape_bytes(ins.shape))
            else:
                nb = _shape_bytes(ins.shape)
            cost.coll_bytes += nb
            cost.coll_breakdown[base] += nb
            cost.bytes += _shape_bytes(ins.shape)
        elif ins.op in SKIP_BYTES_OPS or ins.op.endswith("-done"):
            pass
        else:
            cost.bytes += _instr_bytes(ins, shapes)
    memo[comp_name] = cost
    return cost


def analyze_hlo(text: str) -> Cost:
    comps, entry, shapes = parse_hlo(text)
    if entry is None:
        return Cost()
    return _comp_cost(entry, comps, shapes, {}, {})


def top_contributors(text: str, metric: str = "bytes", k: int = 25):
    """Attribute cost to individual instructions (x enclosing trip counts).

    metric: "bytes" | "flops" | "coll".  Returns [(cost, comp, instr line)].
    Used by the §Perf hillclimbs to find what actually dominates a term.
    """
    comps, entry, shapes = parse_hlo(text)
    if entry is None:
        return []
    out = []
    fus_memo: Dict[str, float] = {}

    def visit(comp_name: str, mult: float, seen):
        if comp_name in seen:
            return
        for ins in comps.get(comp_name, []):
            if ins.op == "while":
                m = _TRIP_RE.search(ins.rest)
                trip = int(m.group(1)) if m else 1
                for ref in _called(ins):
                    visit(ref, mult * trip, seen)
            elif ins.op == "conditional":
                for ref in _called(ins):
                    visit(ref, mult, seen)
            elif ins.op in ("call", "async-start", "custom-call"):
                for ref in _called(ins):
                    visit(ref, mult, seen)
            else:
                if metric == "flops":
                    v = _dot_flops(ins, shapes) if ins.op == "dot" else (
                        _fusion_flops(_called(ins)[0], comps, shapes, fus_memo)
                        if ins.op == "fusion" and _called(ins) else 0.0)
                elif metric == "coll":
                    v = 0.0
                    for c in COLLECTIVES:
                        if ins.op == c or ins.op == c + "-start" or \
                                ins.op.startswith(c + "."):
                            v = float(_shape_bytes(ins.shape))
                            break
                else:
                    if ins.op in SKIP_BYTES_OPS or ins.op.endswith("-done"):
                        v = 0.0
                    else:
                        v = _instr_bytes(ins, shapes)
                if v > 0:
                    out.append((v * mult, comp_name, ins.op, ins.name,
                                ins.shape[:80]))

    visit(entry, 1.0, set())
    out.sort(reverse=True)
    return out[:k]
