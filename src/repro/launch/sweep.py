"""CLI driver for vectorized policy x seed x topology sweeps.

    PYTHONPATH=src python -m repro.launch.sweep \
        --solver piag --policies adaptive1,adaptive2,fixed \
        --seeds 4 --events 1000 --workers 8 [--json sweep.json]

Builds a ``repro.sweep.SweepGrid`` over the requested policies, seeds and
the standard worker topologies, runs the whole grid as one batched program,
and prints a per-policy summary (mean/min final objective, step-size
integral).  The paper's figures fall out of grids like these; see
``benchmarks/sweep_grid.py`` for the timed batched-vs-looped comparison.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import jax

from repro.core import L1, make_logreg, make_policy
from repro.sweep import (make_grid, measure_tau_bar, standard_topologies,
                         sweep_bcd_logreg, sweep_piag_logreg)

FIXED_FAMILY = ("fixed", "sun_deng", "davis")


def build_policies(names, gp: float, tau_bar: int):
    out = {}
    for name in names:
        kwargs = {"tau_bound": tau_bar} if name in FIXED_FAMILY else {}
        out[name] = make_policy(name, gp, **kwargs)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solver", choices=["piag", "bcd"], default="piag")
    ap.add_argument("--policies", default="adaptive1,adaptive2,fixed",
                    help="comma-separated names from core.stepsize.POLICIES")
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--events", type=int, default=1000)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--samples", type=int, default=800)
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--blocks", type=int, default=20, help="bcd only")
    ap.add_argument("--json", default=None, help="write per-cell results here")
    a = ap.parse_args()

    prob = make_logreg(a.samples, a.dim, n_workers=a.workers, seed=0)
    gp = 0.99 / (prob.L if a.solver == "piag" else prob.block_smoothness(a.blocks))
    prox = L1(lam=prob.lam1)
    seeds = list(range(a.seeds))
    topos = standard_topologies(a.workers)

    # worst-case bound tau-bar for the fixed baselines, measured over the grid
    tau_bar = measure_tau_bar(topos, seeds, a.events)

    grid = make_grid(build_policies(a.policies.split(","), gp, tau_bar),
                     seeds, topos, a.events)
    print(f"sweep: {len(grid)} cells ({a.policies} x {a.seeds} seeds x "
          f"{len(topos)} topologies), {a.events} events, tau_bar={tau_bar}")

    t0 = time.perf_counter()
    if a.solver == "piag":
        res = jax.block_until_ready(sweep_piag_logreg(prob, grid, prox))
    else:
        res = jax.block_until_ready(sweep_bcd_logreg(prob, grid, prox,
                                                     m=a.blocks))
    dt = time.perf_counter() - t0
    obj = np.asarray(res.objective)
    gam = np.asarray(res.gammas)
    print(f"one batched program: {dt:.2f}s "
          f"({dt / len(grid) * 1e3:.1f} ms/cell incl. compile)")

    print(f"{'policy':<16} {'mean P_final':>12} {'min P_final':>12} "
          f"{'mean sum(gamma)':>16}")
    for pn in dict.fromkeys(c.policy_name for c in grid.cells):
        rows = [i for i, c in enumerate(grid.cells) if c.policy_name == pn]
        print(f"{pn:<16} {obj[rows, -1].mean():>12.5f} "
              f"{obj[rows, -1].min():>12.5f} {gam[rows].sum(1).mean():>16.3f}")

    if a.json:
        cells = [{"label": lab, "final_objective": float(obj[i, -1]),
                  "sum_gamma": float(gam[i].sum()),
                  "max_tau": int(np.asarray(res.taus)[i].max())}
                 for i, lab in enumerate(grid.labels())]
        Path(a.json).write_text(json.dumps(
            {"solver": a.solver, "events": a.events, "tau_bar": tau_bar,
             "seconds": dt, "cells": cells}, indent=2) + "\n")
        print(f"wrote {a.json}")


if __name__ == "__main__":
    main()
