"""Spec-driven CLI for policy x seed x topology (x worker-count) sweeps.

Flags build a ``repro.api.ExperimentSpec`` (or ``--spec`` loads one from a
Python file), ``repro.api.run`` executes it on the requested backend, and
the per-policy summary comes from ``repro.analysis`` -- no solver- or
backend-specific code lives here anymore.

    PYTHONPATH=src python -m repro.launch.sweep \
        --solver piag --policies adaptive1,adaptive2,fixed \
        --seeds 4 --events 1000 --workers 8 [--json sweep.json]

    # ragged worker-count axis + device sharding (forced host devices need
    # XLA_FLAGS=--xla_force_host_platform_device_count=N in the environment)
    PYTHONPATH=src python -m repro.launch.sweep \
        --solver piag --n-workers 4,8,16 --backend sharded

    # per-cell solo runs (the pre-sweep reference path)
    PYTHONPATH=src python -m repro.launch.sweep --solver bcd --backend solo

    # federated sweeps (fused jitted trace generation + server scan)
    PYTHONPATH=src python -m repro.launch.sweep \
        --solver fedbuff --policies hinge,poly,constant --buffer-size 4

    # a spec file: any Python file defining SPEC (an ExperimentSpec) or
    # make_spec() -> ExperimentSpec; flags are ignored except --json
    PYTHONPATH=src python -m repro.launch.sweep --spec examples/spec_sweep.py
"""
from __future__ import annotations

import argparse
import json
import runpy
from pathlib import Path

import jax

from repro import analysis, api, telemetry
from repro.faults import parse_faults


def load_spec(path: str) -> api.ExperimentSpec:
    """Load an ``ExperimentSpec`` from a Python file: either a module-level
    ``SPEC`` or a ``make_spec()`` factory."""
    ns = runpy.run_path(path)
    spec = ns.get("SPEC")
    if spec is None and callable(ns.get("make_spec")):
        spec = ns["make_spec"]()
    if not isinstance(spec, api.ExperimentSpec):
        raise SystemExit(
            f"{path} must define SPEC (an api.ExperimentSpec) or "
            "make_spec() returning one")
    return spec


def parse_horizon(value: str):
    """``'auto'`` (measured-delay sizing) or a concrete integer H."""
    return "auto" if value == "auto" else int(value)


def spec_from_flags(a: argparse.Namespace) -> api.ExperimentSpec:
    federated = a.solver in ("fedasync", "fedbuff")
    policy_names = tuple((a.policies or
                          ("hinge,poly,constant" if federated
                           else "adaptive1,adaptive2,fixed")).split(","))
    widths = tuple(int(w) for w in a.n_workers.split(",")) \
        if a.n_workers else (a.workers,)
    return api.ExperimentSpec(
        problem=api.ProblemSpec(
            kind="logreg",
            params=dict(n_samples=a.samples, dim=a.dim, seed=0)),
        solver=api.SolverSpec(name=a.solver,
                              horizon=parse_horizon(a.horizon), m=a.blocks,
                              eta=a.eta, buffer_size=a.buffer_size),
        topology=api.TopologySpec(kind="edge" if federated else "standard",
                                  n_workers=widths),
        # the federated base mixing weight (0.6) and the worker gamma' =
        # 0.99/L defaults are the resolver's auto rule; fixed-family
        # baselines are tuned from the measured tau-bar (worker solvers) or
        # pinned at 0 (federated -- not the federated story)
        policies=api.PolicyGridSpec(names=policy_names,
                                    seeds=tuple(range(a.seeds))),
        execution=api.ExecutionSpec(backend=a.backend,
                                    record_every=a.record_every,
                                    telemetry=a.telemetry,
                                    telemetry_bins=a.telemetry_bins),
        n_events=a.events,
        faults=parse_faults(a.faults))


def print_summary(res: api.Results) -> None:
    summaries = analysis.summarize(res)
    clip = analysis.clipped_summary(res.clipped)
    # THE clip-pressure path: a real RuntimeWarning (visible to -W filters
    # and log collectors, not just the console) whose message also lands in
    # the printed output and -- via clipped_summary -- in --json
    msg = telemetry.warn_clip_pressure(clip, horizon=res.horizon)
    if msg:
        print(f"WARNING: {msg}")
    print(f"{'policy':<16} {'mean P_final':>12} {'min P_final':>12} "
          f"{'mean sum(gamma)':>16} {'clipped':>8}")
    for pn, s in summaries.items():
        print(f"{pn:<16} {s.mean_final:>12.5f} {s.min_final:>12.5f} "
              f"{s.mean_sum_gamma:>16.3f} {s.clipped_events:>8}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", default=None,
                    help="Python file defining SPEC or make_spec(); "
                    "overrides every flag except --json")
    ap.add_argument("--solver", choices=list(api.SOLVERS), default="piag")
    ap.add_argument("--backend", choices=list(api.BACKENDS),
                    default="batched")
    ap.add_argument("--shard", action="store_true",
                    help="alias for --backend sharded (back-compat)")
    ap.add_argument("--policies", default=None,
                    help="comma-separated names from core.stepsize.POLICIES "
                    "(default: adaptive1,adaptive2,fixed; federated: "
                    "hinge,poly,constant)")
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--events", type=int, default=1000)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--n-workers", default=None,
                    help="comma-separated worker counts: grows the ragged "
                    "n_workers grid axis (overrides --workers)")
    ap.add_argument("--samples", type=int, default=800)
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--blocks", type=int, default=20, help="bcd only")
    ap.add_argument("--eta", type=float, default=0.5,
                    help="fedbuff server rate")
    ap.add_argument("--buffer-size", type=int, default=1,
                    help="fedbuff |R| (fedasync forces 1)")
    ap.add_argument("--horizon", default="4096",
                    help="step-size window-sum horizon H (largest "
                    "representable delay is H - 1; specs whose measured "
                    "delay bound exceeds it fail fast), or 'auto': size H "
                    "to next_pow2(measured tau-bar + 1) -- bitwise-equal "
                    "results, a fraction of the scan carry")
    ap.add_argument("--record-every", type=int, default=1,
                    help="decimated trace recording stride s: materialize "
                    "(and evaluate the objective at) only every s-th event "
                    "row; must divide --events (stride 1 = record all)")
    ap.add_argument("--telemetry", action="store_true",
                    help="ride the in-scan delay/step-size accumulators in "
                    "the solver carry (bitwise-neutral; exact histogram "
                    "even under --record-every decimation)")
    ap.add_argument("--telemetry-bins", type=int, default=64,
                    help="delay-histogram buckets (last bin = overflow)")
    ap.add_argument("--ledger", default=None,
                    help="append this run's RunRecord to a JSONL ledger "
                    "file (also honored with --spec; see launch/report.py)")
    ap.add_argument("--faults", default=None,
                    help="fault-injection spec: a preset name "
                    "(crash/straggler/corrupt/chaos) optionally followed by "
                    "comma-separated key=value overrides, e.g. "
                    "'chaos,p_crash=0.1,staleness_cutoff=64', or bare "
                    "key=value pairs (see repro.faults.FaultSpec)")
    ap.add_argument("--resume", default=None,
                    help="checkpoint directory: finished sweep buckets are "
                    "saved here and loaded (bitwise) on re-run, so a killed "
                    "sweep resumes instead of recomputing")
    ap.add_argument("--json", default=None, help="write per-cell results here")
    a = ap.parse_args()
    if a.shard:
        a.backend = "sharded"
    if a.ledger:
        telemetry.set_ledger_path(a.ledger)

    spec = load_spec(a.spec) if a.spec else spec_from_flags(a)

    res = api.run(spec, resume=a.resume)
    grid, n_dev = res.grid, len(jax.devices())
    policy_names = list(dict.fromkeys(c.policy_name for c in grid.cells))
    widths = sorted({c.n_workers for c in grid.cells})
    auto = spec.solver.horizon == "auto"
    print(f"sweep[{res.solver}/{res.backend}]: {len(grid)} cells "
          f"({','.join(policy_names)} x "
          f"{len({c.seed for c in grid.cells})} seeds x widths {widths}), "
          f"{grid.n_events} events, tau_bar={res.tau_bar}, "
          f"horizon={res.horizon}{' (auto)' if auto else ''}, "
          f"record_every={res.record_every}, devices={n_dev}")
    rec = res.telemetry
    print(f"{res.backend} backend: {res.elapsed_s:.2f}s "
          f"({res.elapsed_s / len(grid) * 1e3:.1f} ms/cell incl. compile; "
          f"compile {rec.compile_ms:.0f}ms / warm {rec.warm_ms:.0f}ms, "
          f"cache {rec.cache['hits']}h/{rec.cache['misses']}m)")
    print_summary(res)
    if spec.execution.telemetry:
        dp = analysis.delay_profile(res)
        print(f"delay profile ({dp['source']}): {dp['count']} events, "
              f"tau in [{dp['tau']['min']}, {dp['tau']['max']}], "
              f"mean {dp['tau']['mean']:.2f} +/- {dp['tau']['std']:.2f}")
    if rec.faults:
        print("faults: " + ", ".join(f"{k}={v}"
                                     for k, v in sorted(rec.faults.items())))
    if a.ledger:
        print(f"appended RunRecord to {a.ledger}")

    if a.json:
        Path(a.json).write_text(json.dumps(
            {"solver": res.solver, "backend": res.backend,
             "events": grid.n_events, "tau_bar": res.tau_bar,
             "horizon": res.horizon, "horizon_auto": auto,
             "record_every": res.record_every,
             "devices": n_dev, "seconds": res.elapsed_s,
             "clipped": analysis.clipped_summary(res.clipped),
             "clipped_summary": analysis.clip_pressure(res),
             "telemetry": {"compile_ms": rec.compile_ms,
                           "warm_ms": rec.warm_ms, "cache": rec.cache,
                           "delay_hist": rec.delay_hist,
                           "hist_source": rec.hist_source},
             "faults": rec.faults,
             "cells": res.to_rows()}, indent=2) + "\n")
        print(f"wrote {a.json}")


if __name__ == "__main__":
    main()
