"""CLI driver for vectorized policy x seed x topology (x worker-count)
sweeps, optionally sharded across devices.

    PYTHONPATH=src python -m repro.launch.sweep \
        --solver piag --policies adaptive1,adaptive2,fixed \
        --seeds 4 --events 1000 --workers 8 [--json sweep.json]

    # ragged worker-count axis + device sharding (forced host devices need
    # XLA_FLAGS=--xla_force_host_platform_device_count=N in the environment)
    PYTHONPATH=src python -m repro.launch.sweep \
        --solver piag --n-workers 4,8,16 --shard

    # federated sweeps (fused jitted trace generation + server scan)
    PYTHONPATH=src python -m repro.launch.sweep \
        --solver fedbuff --policies hinge,poly,constant --buffer-size 4

Builds a ``repro.sweep.SweepGrid`` over the requested policies, seeds and
the standard worker/client topologies, runs the whole grid as one batched
program per bucket (sharded over all devices with ``--shard``), and prints a
per-policy summary (mean/min final objective, step-size integral, horizon-
clip counts).  The paper's figures fall out of grids like these; see
``benchmarks/sweep_grid.py`` and ``benchmarks/mega_grid.py`` for the timed
comparisons.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import jax

from repro.core import L1, make_logreg, make_policy
from repro.federated.events import heterogeneous_clients
from repro.sweep import (make_grid, measure_tau_bar,
                         sharded_sweep_piag_logreg,
                         standard_topology_factories, sweep_bcd_logreg,
                         sweep_fedasync_problem, sweep_fedbuff_problem,
                         sweep_piag_logreg)

FIXED_FAMILY = ("fixed", "sun_deng", "davis")


def build_policies(names, gp: float, tau_bar: int):
    out = {}
    for name in names:
        kwargs = {"tau_bound": tau_bar} if name in FIXED_FAMILY else {}
        out[name] = make_policy(name, gp, **kwargs)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solver", choices=["piag", "bcd", "fedasync", "fedbuff"],
                    default="piag")
    ap.add_argument("--policies", default=None,
                    help="comma-separated names from core.stepsize.POLICIES "
                    "(default: adaptive1,adaptive2,fixed; federated: "
                    "hinge,poly,constant)")
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--events", type=int, default=1000)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--n-workers", default=None,
                    help="comma-separated worker counts: grows the ragged "
                    "n_workers grid axis (overrides --workers)")
    ap.add_argument("--shard", action="store_true",
                    help="shard the cell axis across all devices "
                    "(piag only for now)")
    ap.add_argument("--samples", type=int, default=800)
    ap.add_argument("--dim", type=int, default=100)
    ap.add_argument("--blocks", type=int, default=20, help="bcd only")
    ap.add_argument("--buffer-size", type=int, default=1,
                    help="fedbuff |R| (fedasync forces 1)")
    ap.add_argument("--horizon", type=int, default=4096,
                    help="step-size window-sum horizon H (largest "
                    "representable delay is H - 1; raise when cells clip)")
    ap.add_argument("--json", default=None, help="write per-cell results here")
    a = ap.parse_args()

    federated = a.solver in ("fedasync", "fedbuff")
    policy_names = (a.policies or
                    ("hinge,poly,constant" if federated
                     else "adaptive1,adaptive2,fixed")).split(",")
    widths = ([int(w) for w in a.n_workers.split(",")]
              if a.n_workers else [a.workers])
    w_max = max(widths)

    prob = make_logreg(a.samples, a.dim, n_workers=w_max, seed=0)
    prox = L1(lam=prob.lam1)

    if federated:
        gp = 0.6
        factories = {"edge": lambda n: heterogeneous_clients(n, seed=0)}
        tau_bar = 0  # fixed-family baselines are not the federated story
        grid = make_grid(build_policies(policy_names, gp, tau_bar),
                         list(range(a.seeds)), factories, a.events,
                         n_workers=widths)
    else:
        gp = 0.99 / (prob.L if a.solver == "piag"
                     else prob.block_smoothness(a.blocks))
        factories = standard_topology_factories()
        tau_bar = measure_tau_bar(
            {f"{tn}/w{w}": f(w) for tn, f in factories.items()
             for w in widths},
            list(range(a.seeds)), a.events)
        grid = make_grid(build_policies(policy_names, gp, tau_bar),
                         list(range(a.seeds)), factories, a.events,
                         n_workers=widths)

    n_dev = len(jax.devices())
    print(f"sweep: {len(grid)} cells ({','.join(policy_names)} x {a.seeds} "
          f"seeds x {len(factories)} topologies x widths {widths}), "
          f"{a.events} events, tau_bar={tau_bar}, devices={n_dev}"
          f"{' [sharded]' if a.shard else ''}")

    t0 = time.perf_counter()
    if a.solver == "piag":
        run = sharded_sweep_piag_logreg if a.shard else sweep_piag_logreg
        res = jax.block_until_ready(run(prob, grid, prox, horizon=a.horizon))
    elif a.solver == "bcd":
        res = jax.block_until_ready(sweep_bcd_logreg(prob, grid, prox,
                                                     m=a.blocks,
                                                     horizon=a.horizon))
    elif a.solver == "fedasync":
        res = jax.block_until_ready(sweep_fedasync_problem(
            prob, grid, prox, horizon=a.horizon))
    else:
        res = jax.block_until_ready(sweep_fedbuff_problem(
            prob, grid, prox, eta=0.5, buffer_size=a.buffer_size,
            horizon=a.horizon))
    dt = time.perf_counter() - t0
    obj = np.asarray(res.objective)
    gam = np.asarray(res.weights if federated else res.gammas)
    clipped = np.asarray(res.clipped)
    print(f"one batched program per bucket: {dt:.2f}s "
          f"({dt / len(grid) * 1e3:.1f} ms/cell incl. compile)")
    if np.any(clipped > 0):
        print(f"WARNING: {int(np.sum(clipped > 0))} cells clipped delays at "
              "the policy horizon (H - 1); raise --horizon")

    print(f"{'policy':<16} {'mean P_final':>12} {'min P_final':>12} "
          f"{'mean sum(gamma)':>16} {'clipped':>8}")
    for pn in dict.fromkeys(c.policy_name for c in grid.cells):
        rows = [i for i, c in enumerate(grid.cells) if c.policy_name == pn]
        print(f"{pn:<16} {obj[rows, -1].mean():>12.5f} "
              f"{obj[rows, -1].min():>12.5f} {gam[rows].sum(1).mean():>16.3f} "
              f"{int(clipped[rows].sum()):>8}")

    if a.json:
        cells = [{"label": lab, "final_objective": float(obj[i, -1]),
                  "sum_gamma": float(gam[i].sum()),
                  "max_tau": int(np.asarray(res.taus)[i].max()),
                  "clipped": int(clipped[i]),
                  "n_workers": grid.cells[i].n_workers}
                 for i, lab in enumerate(grid.labels())]
        Path(a.json).write_text(json.dumps(
            {"solver": a.solver, "events": a.events, "tau_bar": tau_bar,
             "devices": n_dev, "sharded": bool(a.shard), "seconds": dt,
             "cells": cells}, indent=2) + "\n")
        print(f"wrote {a.json}")


if __name__ == "__main__":
    main()
