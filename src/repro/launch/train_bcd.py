"""Async-BCD trainer for neural networks (the paper's Algorithm 2 at NN
scale, feature-space distribution).

The parameter pytree is partitioned into ``m`` blocks (contiguous layer
groups + embeddings); simulated workers repeatedly read a (stale) full
snapshot, compute the gradient restricted to one randomly chosen block, and
write that block back with the delay-adaptive step-size chosen inside the
write event -- exactly Eq. (5) with R = 0 (or weight-decay prox).

This complements the data-parallel PIAG/ASGD trainer: here staleness lives
in the *iterate snapshot* (model parallelism across feature blocks), not in
the gradient message.  Used by tests and the fig4-style NN comparison.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import simulate_shared_memory
from repro.core.prox import ProxOp, Zero
from repro.core.stepsize import StepsizePolicy
from repro.data import TokenStream
from repro.models import loss_fn
from repro.models.config import ModelConfig

__all__ = ["block_partition", "run_bcd_training"]


def block_partition(params, m: int) -> List[List[int]]:
    """Partition leaf indices into m roughly-equal blocks by element count."""
    leaves = jax.tree_util.tree_leaves(params)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    order = np.argsort(sizes)[::-1]  # biggest first, greedy bin packing
    blocks: List[List[int]] = [[] for _ in range(m)]
    loads = np.zeros(m)
    for i in order:
        b = int(np.argmin(loads))
        blocks[b].append(int(i))
        loads[b] += sizes[i]
    return [sorted(b) for b in blocks if b]


def run_bcd_training(cfg: ModelConfig, policy: StepsizePolicy, *,
                     steps: int = 100, batch: int = 4, seq: int = 64,
                     m_blocks: int = 4, n_workers: int = 3, seed: int = 0,
                     prox: ProxOp = Zero(), log_every: int = 10,
                     lr_scale: float = 1.0) -> List[Dict]:
    """Async-BCD over parameter blocks with real stale snapshots."""
    from repro.models import init_params

    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    blocks = block_partition(params, m_blocks)
    m = len(blocks)
    stream = TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)

    grad_fn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))
    loss_jit = jax.jit(lambda p, b: loss_fn(p, cfg, b)[0])
    ss_step = jax.jit(policy.step)

    trace = simulate_shared_memory(n_workers, steps, m, seed=seed)
    rng = np.random.default_rng(seed)
    block_choice = rng.integers(0, m, size=steps)

    # worker snapshots: each holds the leaves it read (stale)
    snapshots = [list(leaves) for _ in range(n_workers)]
    ss = policy.init()
    log: List[Dict] = []
    t0 = time.perf_counter()
    for k in range(steps):
        w = int(trace.worker[k])
        j = int(block_choice[k])
        tau = int(trace.tau[k])
        # worker w computed grads on ITS stale snapshot (Algorithm 2 line 4)
        snap = jax.tree_util.tree_unflatten(treedef, snapshots[w])
        g = grad_fn(snap, stream.batch_at(k))
        g_leaves = jax.tree_util.tree_leaves(g)
        gamma, ss = ss_step(ss, jnp.int32(tau))
        lr = float(gamma) * lr_scale
        # write block j (Eq. 5) -- only block-j leaves move
        for i in blocks[j]:
            leaves[i] = prox.prox(leaves[i] - lr * g_leaves[i], lr)
        # worker w re-reads the shared iterate (line 10)
        snapshots[w] = list(leaves)
        if k % log_every == 0 or k == steps - 1:
            cur = jax.tree_util.tree_unflatten(treedef, leaves)
            lv = float(loss_jit(cur, stream.batch_at(10_000)))
            log.append({"step": k, "loss": lv, "gamma": float(gamma),
                        "tau": tau, "block": j,
                        "wall_s": time.perf_counter() - t0})
            print(f"bcd step {k:4d} block {j} loss {lv:.4f} "
                  f"gamma {float(gamma):.2e} tau {tau}")
    return log
