"""Step functions lowered by the dry-run and executed by the trainer.

``train_step`` embeds the paper's mechanism end-to-end in one compiled
program: gradient computation for the arriving worker's shard, write-event
delay bookkeeping (Algorithm 1's ``tau = k - s[worker]``), the delay-adaptive
step-size (principle (8) via core.stepsize) and the (optionally proximal)
parameter update.  On the production mesh the "workers" are the data-parallel
groups; the scalar delay program costs nothing but appears in the lowered HLO
(the dry-run therefore certifies the full mechanism, not just the model)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.prox import ProxOp, Zero
from repro.core.stepsize import Adaptive1, StepsizePolicy
from repro.models import decode_step, forward, loss_fn, prefill
from repro.models.config import ModelConfig
from repro.optim.optimizers import (AdamW, DelayAdaptiveOptimizer,
                                    DelayAdaptiveState, Momentum, Sgd)

__all__ = ["TrainState", "Trainer", "make_trainer", "make_prefill_step",
           "make_serve_step"]


class TrainState(NamedTuple):
    params: Any
    opt: DelayAdaptiveState


@dataclasses.dataclass(frozen=True)
class Trainer:
    cfg: ModelConfig
    optimizer: DelayAdaptiveOptimizer

    def init(self, key) -> TrainState:
        from repro.models import init_params
        params = init_params(self.cfg, key)
        return TrainState(params=params, opt=self.optimizer.init(params))

    def state_specs(self) -> TrainState:
        from repro.models import param_specs
        p = param_specs(self.cfg)
        opt = jax.eval_shape(self.optimizer.init, p)
        return TrainState(params=p, opt=opt)

    def train_step(self, state: TrainState, batch: Dict[str, jnp.ndarray],
                   worker: jnp.ndarray) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, self.cfg, batch), has_aux=True)(state.params)
        params, opt, gamma, tau = self.optimizer.update(
            state.params, grads, state.opt, worker)
        metrics = dict(metrics)
        metrics.update(loss=loss, gamma=gamma, tau=tau)
        return TrainState(params=params, opt=opt), metrics


def make_trainer(cfg: ModelConfig, policy: Optional[StepsizePolicy] = None,
                 base: str = "adamw", prox: ProxOp = Zero(),
                 n_workers: int = 1, lr: float = 1e-3,
                 grad_clip: Optional[float] = 1.0,
                 weight_decay: float = 0.0) -> Trainer:
    """Default production trainer: delay-adaptive AdamW.

    gamma' (the step-size budget of principle (8)) plays the base-LR role;
    the emitted gamma_k scales the AdamW update by the observed staleness."""
    policy = policy or Adaptive1(gamma_prime=lr, alpha=0.9)
    bases = {"adamw": AdamW(weight_decay=weight_decay),
             "momentum": Momentum(), "sgd": Sgd()}
    opt = DelayAdaptiveOptimizer(
        policy=policy, base=bases[base],
        prox=prox, grad_clip=grad_clip, n_workers=n_workers, horizon=1024)
    return Trainer(cfg=cfg, optimizer=opt)


def make_prefill_step(cfg: ModelConfig, window: Optional[int] = None,
                      ring: bool = False) -> Callable:
    if cfg.has_decode:
        def prefill_step(params, batch):
            return prefill(params, cfg, batch, window=window, ring=ring)
        return prefill_step

    def encode_step(params, batch):  # encoder-only: logits, no cache
        logits, _ = forward(params, cfg, batch)
        return logits
    return encode_step


def make_serve_step(cfg: ModelConfig, window: Optional[int] = None,
                    ring: bool = False) -> Callable:
    def serve_step(params, cache, token, pos):
        return decode_step(params, cfg, cache, token, pos, window=window,
                           ring=ring)
    return serve_step
