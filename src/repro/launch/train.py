"""Asynchronous delay-adaptive training driver (runs on this container).

Implements the paper's parameter-server semantics with REAL stale gradients
on one host: each simulated worker holds the gradient it computed on the
iterate version it last read; at each write event the arriving worker's
(stale) gradient is applied with the delay-adaptive step-size, and the worker
picks up the new iterate.  Memory = n_workers x grad size, so this runs a
~100M-parameter model with genuine gradient staleness.

    PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --reduced \
        --steps 50 --policy adaptive2
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.engine import heterogeneous_workers, simulate_parameter_server
from repro.core.stepsize import make_policy
from repro.data import EmbedStream, TokenStream
from repro.launch.steps import make_trainer
from repro.models import init_params, loss_fn
from repro.models.config import ModelConfig
from repro.checkpoint import save_checkpoint

PRESETS = {
    # ~103M params: the end-to-end driver scale
    "100m": ModelConfig(name="lm-100m", n_layers=12, d_model=768, n_heads=12,
                        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192,
                        q_chunk=256),
    "25m": ModelConfig(name="lm-25m", n_layers=8, d_model=384, n_heads=8,
                       n_kv_heads=4, head_dim=48, d_ff=1024, vocab=4096,
                       q_chunk=256),
    "moe-tiny": ModelConfig(name="moe-tiny", family="moe", n_layers=6,
                            d_model=384, n_heads=8, n_kv_heads=8, head_dim=48,
                            d_ff=512, n_experts=8, top_k=2, moe_ff=512,
                            shared_ff=512, vocab=4096, q_chunk=256),
}


def make_stream(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    if cfg.embed_inputs:
        return EmbedStream(d_model=cfg.d_model, vocab=cfg.vocab, batch=batch,
                           seq=seq, seed=seed, mrope=cfg.rope == "mrope")
    return TokenStream(vocab=cfg.vocab, batch=batch, seq=seq, seed=seed)


def run_training(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
                 policy_name: str = "adaptive1", lr: float = 3e-3,
                 n_workers: int = 4, seed: int = 0, log_every: int = 10,
                 straggler: float = 0.05, out_dir: Optional[str] = None,
                 tau_bound_for_fixed: int = 8,
                 resume_from: Optional[str] = None,
                 save_every: int = 0):
    """Returns the metrics log (list of dicts)."""
    from repro.checkpoint import load_checkpoint
    key = jax.random.PRNGKey(seed)
    kwargs = {}
    if policy_name in ("fixed", "sun_deng"):
        kwargs["tau_bound"] = tau_bound_for_fixed
    policy = make_policy(policy_name, lr, **kwargs)
    trainer = make_trainer(cfg, policy=policy, n_workers=n_workers)
    state = trainer.init(key)
    start_step = 0
    if resume_from:
        (state,), meta = load_checkpoint(resume_from, (state,))
        start_step = int(meta.get("steps", 0))
        print(f"resumed from {resume_from} at step {start_step}")
    n_params = sum(int(np.prod(l.shape)) for l in
                   jax.tree_util.tree_leaves(state.params))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M "
          f"policy={policy_name} gamma'={lr} workers={n_workers}")

    workers = heterogeneous_workers(n_workers, spread=2.0, seed=seed,
                                    p_straggle=straggler, straggle_x=8.0)
    trace = simulate_parameter_server(n_workers, steps, workers, seed=seed)
    stream = make_stream(cfg, batch, seq, seed)

    grad_fn = jax.jit(jax.grad(
        lambda p, b: loss_fn(p, cfg, b)[0]))
    loss_jit = jax.jit(lambda p, b: loss_fn(p, cfg, b)[0])
    apply_jit = jax.jit(trainer.optimizer.step_fn)

    # Algorithm-1 init: every worker computes a gradient at x_0
    pending = {}
    for w in range(n_workers):
        pending[w] = (grad_fn(state.params, stream.batch_at(w)), 0)

    params, opt = state.params, state.opt
    log = []
    t0 = time.perf_counter()
    for k in range(steps):
        w = int(trace.worker[k])
        g, s_read = pending[w]
        tau = jnp.int32(k - s_read)
        params, opt, gamma = apply_jit(params, g, opt, tau)
        # worker w picks up x_{k+1} and computes its next gradient
        pending[w] = (grad_fn(params, stream.batch_at(n_workers + k)), k + 1)
        if k % log_every == 0 or k == steps - 1:
            lv = float(loss_jit(params, stream.batch_at(10_000)))
            rec = {"step": start_step + k, "loss": lv, "gamma": float(gamma),
                   "tau": int(tau), "wall_s": time.perf_counter() - t0}
            log.append(rec)
            print(f"step {start_step + k:5d} loss {lv:.4f} "
                  f"gamma {float(gamma):.2e} tau {int(tau)} "
                  f"({rec['wall_s']:.1f}s)")
        if out_dir and save_every and (k + 1) % save_every == 0:
            os.makedirs(out_dir, exist_ok=True)
            from repro.launch.steps import TrainState
            save_checkpoint(os.path.join(out_dir, f"step_{start_step + k + 1}.npz"),
                            (TrainState(params=params, opt=opt),),
                            {"steps": start_step + k + 1,
                             "policy": policy_name})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        from repro.launch.steps import TrainState
        save_checkpoint(os.path.join(out_dir, "final.npz"),
                        (TrainState(params=params, opt=opt),),
                        {"steps": start_step + steps, "policy": policy_name,
                         "final_loss": log[-1]["loss"]})
        with open(os.path.join(out_dir, "log.json"), "w") as f:
            json.dump(log, f, indent=1)
    return log


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--preset", choices=list(PRESETS))
    g.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke variant of --arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--policy", default="adaptive1",
                    choices=["adaptive1", "adaptive2", "fixed", "sun_deng",
                             "naive"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--resume-from", default=None,
                    help="checkpoint .npz to resume params+optimizer from")
    ap.add_argument("--save-every", type=int, default=0)
    args = ap.parse_args()

    if args.preset:
        cfg = PRESETS[args.preset]
    else:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        else:
            print("WARNING: full config on CPU; use --reduced for smoke runs")
    run_training(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                 policy_name=args.policy, lr=args.lr, n_workers=args.workers,
                 seed=args.seed, out_dir=args.out,
                 resume_from=args.resume_from, save_every=args.save_every)


if __name__ == "__main__":
    main()
