"""Roofline analysis from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip):
    peak bf16 compute   197e12 FLOP/s
    HBM bandwidth       819e9  B/s
    ICI link bandwidth  ~50e9  B/s per link

Terms (seconds, per chip, one step):
    compute    = HLO_FLOPs    / peak
    memory     = HLO_bytes    / hbm_bw
    collective = coll_bytes   / link_bw
where HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` of the
*per-device* SPMD program and coll_bytes sums the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the post-partitioning optimized HLO (``compiled.as_text()``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# result shapes like: bf16[2048,5120]{1,0} or (f32[8,128], s32[4])
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Result size equals the full operand footprint for all-gather (output is
    the gathered tensor) and all-reduce/all-to-all; for reduce-scatter the
    *operand* is the large side -- we use max(result, operand) per line to be
    conservative."""
    out: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "name = <result-shape> op-name(args...)"; skip -done halves of async
        # pairs (the -start carries the shape) and fusion-internal mentions.
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([\w\-]+)", s)
        if not m:
            continue
        result_shape, opname = m.group(1), m.group(2)
        for op in COLLECTIVE_OPS:
            if opname == op or opname == op + "-start" or opname.startswith(op + "."):
                if op == "reduce-scatter":
                    # operand is the large side
                    args = s[s.find("("):]
                    nbytes = max(_shape_bytes(args), _shape_bytes(result_shape))
                else:
                    nbytes = _shape_bytes(result_shape)
                out[op] += nbytes
                counts[op] += 1
                break
    out["_counts"] = counts  # type: ignore
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    collective_bytes: float      # per-device collective bytes
    model_flops_total: float     # analytic 6ND / 2ND (whole step, all chips)
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (per-device HLO FLOPs x chips)."""
        total_hlo = self.flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def step_time(self) -> float:
        """Simple max-of-terms bound (no overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops_total": self.model_flops_total,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flop_ratio": self.useful_ratio,
            "step_time_bound_s": self.step_time,
        }


def count_params(cfg) -> Tuple[float, float]:
    """(total, active) parameter counts from the config (analytic)."""
    import jax
    import numpy as np
    from repro.models import param_specs

    specs = param_specs(cfg)
    total = 0.0
    active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        n = float(np.prod(leaf.shape))
        total += n
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if cfg.n_experts and "moe" in names and any(
                str(nm).startswith("w") and "shared" not in str(nm) for nm in names):
            active += n * (cfg.top_k / cfg.n_experts)
        else:
            active += n
    return total, active


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for
    inference, D = tokens processed this step."""
    _, n_active = count_params(cfg)
    tokens = shape.global_batch * (shape.seq if shape.kind in ("train", "prefill") else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens
