import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "8")).strip()

"""ACTUALLY EXECUTE the sharded delay-adaptive train/serve step on a small
host-device mesh (default 8 CPU devices) -- the dry-run proves lowering; this
proves the distributed program runs: real sharded params, real collectives
(emulated on host), real delay-adaptive updates.

    PYTHONPATH=src python -m repro.launch.run_distributed --arch qwen2-moe-a2.7b \
        --reduced --steps 3 --mesh 2x4
"""
import argparse          # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.data import EmbedStream, TokenStream  # noqa: E402
from repro.mesh import dp_size, make_mesh  # noqa: E402
from repro.mesh import batch_shardings, param_shardings  # noqa: E402
from repro.launch.steps import make_trainer  # noqa: E402
from repro.launch.train import PRESETS  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--arch", choices=ARCH_IDS)
    g.add_argument("--preset", choices=list(PRESETS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="2x4")
    args = ap.parse_args()

    cfg = (PRESETS[args.preset] if args.preset else get_config(args.arch))
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")))
    n_workers = dp_size(mesh)
    trainer = make_trainer(cfg, n_workers=n_workers, lr=1e-3)

    with mesh:
        state = trainer.init(jax.random.PRNGKey(0))
        p_sh = param_shardings(state.params, mesh)
        state = state._replace(
            params=jax.device_put(state.params, p_sh),
            opt=state.opt._replace(
                inner=jax.device_put(
                    state.opt.inner,
                    param_shardings(state.opt.inner, mesh))))
        if cfg.embed_inputs:
            stream = EmbedStream(d_model=cfg.d_model, vocab=cfg.vocab,
                                 batch=args.batch, seq=args.seq,
                                 mrope=cfg.rope == "mrope")
        else:
            stream = TokenStream(vocab=cfg.vocab, batch=args.batch,
                                 seq=args.seq)
        step = jax.jit(trainer.train_step)
        for k in range(args.steps):
            batch = stream.batch_at(k)
            batch = jax.device_put(batch,
                                   batch_shardings(batch, mesh, args.batch))
            t0 = time.perf_counter()
            state, metrics = step(state, batch, jnp.int32(k % n_workers))
            loss = float(metrics["loss"])
            assert loss == loss, "NaN loss in distributed execution"
            print(f"step {k} loss {loss:.4f} gamma "
                  f"{float(metrics['gamma']):.2e} tau {int(metrics['tau'])} "
                  f"({time.perf_counter() - t0:.2f}s) "
                  f"devices={len(jax.devices())}")
    # param shards really live on distinct devices
    leaf = jax.tree_util.tree_leaves(state.params)[0]
    print(f"sharding of first param: {leaf.sharding}")
    print("DISTRIBUTED_RUN_OK")


if __name__ == "__main__":
    main()
