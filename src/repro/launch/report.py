"""Render a telemetry run ledger into a terminal report.

The ledger is the JSONL file ``repro.api.run`` appends to when
``REPRO_TELEMETRY_LEDGER`` (or ``telemetry.set_ledger_path`` /
``launch.sweep --ledger``) names one -- one ``RunRecord`` per run.  This
CLI is the human-facing side of that file:

    PYTHONPATH=src python -m repro.launch.report ledger.jsonl
    PYTHONPATH=src python -m repro.launch.report ledger.jsonl --last 10
    PYTHONPATH=src python -m repro.launch.report ledger.jsonl --json out.json

Per run: a delay-histogram sparkline (last bucket = overflow), the
compile-ms vs warm-ms split and the program-cache delta.  Across runs: a
solver x backend timing table and the aggregate cache efficiency -- a
healthy repeated-spec workflow shows compile-ms collapsing to ~0 as the
program cache warms.
"""
from __future__ import annotations

import argparse
import datetime
import json
from pathlib import Path
from typing import Any, Dict, List

from repro.telemetry.ledger import read_ledger

SPARKS = "▁▂▃▄▅▆▇█"


def sparkline(hist: List[int], width: int = 32) -> str:
    """Fixed-width sparkline of a histogram: bins are folded down to at
    most ``width`` columns (summing adjacent buckets) and scaled to the
    tallest column; empty columns render as the lowest tick."""
    if not hist:
        return ""
    n = len(hist)
    cols = min(width, n)
    folded = [sum(hist[i * n // cols:(i + 1) * n // cols])
              for i in range(cols)]
    peak = max(folded)
    if peak <= 0:
        return SPARKS[0] * cols
    return "".join(SPARKS[min((v * len(SPARKS)) // (peak + 1),
                              len(SPARKS) - 1)] for v in folded)


def _fmt_ms(ms: float) -> str:
    return f"{ms / 1e3:.2f}s" if ms >= 1e3 else f"{ms:.0f}ms"


def render_runs(records: List[Dict[str, Any]]) -> List[str]:
    lines = [f"{'when':<9}{'solver':<10}{'backend':<9}{'cells':>6}"
             f"{'events':>8}{'elapsed':>9}{'compile':>9}{'warm':>9}"
             f"{'cache':>8}  delay histogram (tau 0..overflow)"]
    for r in records:
        when = datetime.datetime.fromtimestamp(r["ts"]).strftime("%H:%M:%S")
        cache = r.get("cache", {})
        tau = r.get("tau_stats", {})
        clip = r.get("clipped", {})
        spark = sparkline(r.get("delay_hist", []))
        mark = "*" if r.get("hist_source") == "recorded" else ""
        warn = (f"  CLIPPED x{clip['events_clipped']}"
                if clip.get("events_clipped") else "")
        lines.append(
            f"{when:<9}{r['solver']:<10}{r['backend']:<9}"
            f"{r['n_cells']:>6}{r['n_events']:>8}"
            f"{_fmt_ms(r['elapsed_ms']):>9}{_fmt_ms(r['compile_ms']):>9}"
            f"{_fmt_ms(r['warm_ms']):>9}"
            f"{cache.get('hits', 0):>4}h{cache.get('misses', 0):>2}m"
            f"  {spark}{mark} tau<={tau.get('max', '?')}{warn}")
    if any(r.get("hist_source") == "recorded" for r in records):
        lines.append("  (* histogram binned from recorded rows only -- a "
                     "1/record_every sample; run with telemetry for exact)")
    return lines


def render_timing_table(records: List[Dict[str, Any]]) -> List[str]:
    """solver x backend aggregate: run count, mean elapsed/compile/warm."""
    groups: Dict[tuple, List[Dict[str, Any]]] = {}
    for r in records:
        groups.setdefault((r["solver"], r["backend"]), []).append(r)
    lines = [f"{'solver':<10}{'backend':<9}{'runs':>5}{'policies':>20}"
             f"{'mean elapsed':>13}{'mean compile':>13}{'mean warm':>11}"]
    for (solver, backend), rs in sorted(groups.items()):
        pols: List[str] = []
        for r in rs:
            for p in r.get("policies", []):
                if p not in pols:
                    pols.append(p)
        mean = lambda k: sum(r[k] for r in rs) / len(rs)
        ptxt = ",".join(pols)
        if len(ptxt) > 19:
            ptxt = ptxt[:16] + "..."
        lines.append(f"{solver:<10}{backend:<9}{len(rs):>5}{ptxt:>20}"
                     f"{_fmt_ms(mean('elapsed_ms')):>13}"
                     f"{_fmt_ms(mean('compile_ms')):>13}"
                     f"{_fmt_ms(mean('warm_ms')):>11}")
    return lines


def render_cache(records: List[Dict[str, Any]]) -> str:
    hits = sum(r.get("cache", {}).get("hits", 0) for r in records)
    misses = sum(r.get("cache", {}).get("misses", 0) for r in records)
    evict = sum(r.get("cache", {}).get("evictions", 0) for r in records)
    total = hits + misses
    rate = f"{100.0 * hits / total:.0f}%" if total else "n/a"
    compile_ms = sum(r["compile_ms"] for r in records)
    elapsed_ms = sum(r["elapsed_ms"] for r in records)
    frac = f"{100.0 * compile_ms / elapsed_ms:.0f}%" if elapsed_ms else "n/a"
    return (f"program cache: {hits} hits / {misses} misses ({rate} hit "
            f"rate), {evict} evictions; compile time {_fmt_ms(compile_ms)} "
            f"= {frac} of {_fmt_ms(elapsed_ms)} total")


def report(records: List[Dict[str, Any]]) -> str:
    records = sorted(records, key=lambda r: r.get("ts", 0.0))
    out = [f"== runs ({len(records)}) =="]
    out += render_runs(records)
    out += ["", "== solver x backend timing =="]
    out += render_timing_table(records)
    out += ["", render_cache(records)]
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("ledger", help="JSONL run ledger (one RunRecord/line)")
    ap.add_argument("--last", type=int, default=None,
                    help="only the most recent N records")
    ap.add_argument("--json", default=None,
                    help="also write the analysis.run_timeline rows here")
    a = ap.parse_args()
    records = list(read_ledger(a.ledger))
    if not records:
        raise SystemExit(f"{a.ledger}: no records")
    records.sort(key=lambda r: r.get("ts", 0.0))
    if a.last is not None:
        records = records[-a.last:]
    print(report(records))
    if a.json:
        from repro import analysis
        Path(a.json).write_text(
            json.dumps(analysis.run_timeline(records), indent=2) + "\n")
        print(f"wrote {a.json}")


if __name__ == "__main__":
    main()
