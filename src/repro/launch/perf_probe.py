import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Per-op cost attribution for one (arch, shape) combo -- the §Perf profile.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch yi-34b \
        --shape train_4k --metric bytes --top 20
"""
import argparse  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES  # noqa: E402
from repro.launch.dryrun import build_lowered  # noqa: E402
from repro.launch.hlo_cost import analyze_hlo, top_contributors  # noqa: E402
from repro.mesh import make_mesh, make_production_mesh  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--metric", default="bytes",
                    choices=["bytes", "flops", "coll"])
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat-chunk", action="store_true")
    ap.add_argument("--shard-acts", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--cp-cache", action="store_true")
    ap.add_argument("--small-out", type=int, default=0)
    ap.add_argument("--describe", action="store_true",
                    help="print the sharding plan (param -> PartitionSpec)")
    args = ap.parse_args()

    if args.mesh:
        mesh = make_mesh(tuple(int(x) for x in args.mesh.split("x")))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    if args.describe:
        from repro.configs import get_config
        from repro.mesh import describe_shardings, param_shardings
        from repro.models import param_specs
        cfg = get_config(args.arch)
        specs = param_specs(cfg)
        sh = param_shardings(specs, mesh, small_out_threshold=args.small_out)
        for name, shape, spec in describe_shardings(specs, sh):
            print(f"{name:48s} {str(shape):28s} {spec}")
        return

    with mesh:
        lowered, why = build_lowered(
            args.arch, args.shape, mesh, remat_chunk=args.remat_chunk,
            shard_acts=args.shard_acts, seq_shard=args.seq_shard,
            cp_cache=args.cp_cache, small_out=args.small_out)
        if lowered is None:
            raise SystemExit(f"skipped: {why}")
        compiled = lowered.compile()
    txt = compiled.as_text()
    cost = analyze_hlo(txt)
    print(f"total flops/dev {cost.flops:.3e}  bytes/dev {cost.bytes:.3e}  "
          f"coll/dev {cost.coll_bytes:.3e}")
    print(f"collective breakdown: "
          f"{ {k: f'{v:.2e}' for k, v in cost.coll_breakdown.items()} }")
    print(f"\ntop {args.top} by {args.metric}:")
    for v, comp, op, name, shape in top_contributors(txt, args.metric,
                                                     args.top):
        print(f"{v:.3e}  {op:22s} {shape:60s} in {comp[:40]} ({name[:40]})")


if __name__ == "__main__":
    main()
