"""Production mesh builders.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model"); the
"pod" axis extends data parallelism across the ICI/DCN boundary.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run sets
XLA_FLAGS before importing anything else).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (used by reduced-size tests, e.g. (2, 4))."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
