"""Re-export shim: the production mesh builders moved to ``repro.mesh``.

The 2-D sweep-mesh work consolidated every mesh concern (sweep cell/grid
meshes, topology cache keys, jax.distributed bootstrap, and these
production builders) into the single :mod:`repro.mesh` module.  This shim
keeps the historical ``repro.launch.mesh`` import path working.
"""
from __future__ import annotations

from repro.mesh import (  # noqa: F401
    dp_axes,
    dp_size,
    make_mesh,
    make_production_mesh,
    model_size,
)

__all__ = ["make_production_mesh", "make_mesh", "dp_axes", "dp_size",
           "model_size"]
