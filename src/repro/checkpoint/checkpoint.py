"""Minimal npz pytree checkpointing (no orbax in this environment).

Saves a flattened pytree (params + optimizer + step-size state) with its
treedef recorded as a JSON keypath list, plus arbitrary JSON metadata.
Atomic via write-to-temp + rename.  Works for any pytree of arrays/scalars.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_key_str(k) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiubc":  # ml_dtypes (bf16, f8, ...) -> f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save_checkpoint(path: str, tree: Any, metadata: Optional[dict] = None) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __metadata__=json.dumps(metadata or {}), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__metadata__"]))
        flat = {k: z[k] for k in z.files if k != "__metadata__"}
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [SEP.join(_key_str(k) for k in p)
             for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    leaves = []
    for key, ref in zip(paths, leaves_like):
        arr = flat[key]
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
