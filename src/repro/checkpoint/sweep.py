"""Sweep checkpointing: resume a killed mega-grid run.

``api.run(spec, resume=path)`` threads a :class:`SweepCheckpoint` through
the sweep runners into ``sweep.runners.run_bucketed``: every completed
bucket's stacked result is written to ``{dir}/{tag}_w{width}_b{idx}.npz``
(atomic temp+rename, the ``repro.checkpoint`` idiom), and a re-run with the
same spec loads finished buckets instead of recomputing them -- bucket
granularity, so a killed 8-bucket sweep resumes at the first unfinished
bucket.  The solo backend checkpoints per cell through the same object.

Unlike ``repro.checkpoint.load_checkpoint`` (which needs a ``like``
skeleton), bucket files are SELF-DESCRIBING: a JSON structure descriptor
records the pytree shape (NamedTuple classes by name, nested tuples, None
leaves) alongside the arrays, and decoding rebuilds the exact result tuple
-- so stitching resumed and fresh buckets in ``run_bucketed`` sees one
uniform treedef.  Every file carries the originating spec fingerprint;
resuming into a directory written by a DIFFERENT spec raises instead of
silently mixing results.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Union

import numpy as np

__all__ = ["SweepCheckpoint", "encode_tree", "decode_tree"]


def _registry() -> Dict[str, type]:
    """NamedTuple classes a sweep result can contain, by class name.
    Imported lazily (the checkpoint module must not drag solver imports in
    at package-import time)."""
    from repro.core.piag import PIAGResult
    from repro.core.bcd import BCDResult
    from repro.federated.server import FedResult
    from repro.core.stepsize import StepsizeState, LipschitzState
    from repro.faults.guards import FaultState
    import repro.telemetry.accumulators as acc
    reg: Dict[str, type] = {}
    for cls in (PIAGResult, BCDResult, FedResult, StepsizeState,
                LipschitzState, FaultState):
        reg[cls.__name__] = cls
    for name in dir(acc):  # TelemetryState + any finalized telemetry tuple
        obj = getattr(acc, name)
        if isinstance(obj, type) and issubclass(obj, tuple) \
                and hasattr(obj, "_fields"):
            reg[obj.__name__] = obj
    return reg


def encode_tree(tree: Any):
    """Flatten a result pytree into (arrays dict, JSON-able descriptor)."""
    arrays: Dict[str, np.ndarray] = {}

    def rec(obj):
        if obj is None:
            return {"t": "none"}
        if isinstance(obj, tuple) and hasattr(obj, "_fields"):
            return {"t": "nt", "cls": type(obj).__name__,
                    "items": [rec(getattr(obj, f)) for f in obj._fields],
                    "fields": list(obj._fields)}
        if isinstance(obj, (tuple, list)):
            return {"t": "tuple" if isinstance(obj, tuple) else "list",
                    "items": [rec(v) for v in obj]}
        if isinstance(obj, dict):
            keys = sorted(obj)
            return {"t": "dict", "keys": keys,
                    "items": [rec(obj[k]) for k in keys]}
        key = f"a{len(arrays)}"
        arrays[key] = np.asarray(obj)
        return {"t": "arr", "key": key}

    return arrays, rec(tree)


def decode_tree(arrays, desc: Dict[str, Any]) -> Any:
    """Inverse of :func:`encode_tree` (NamedTuples resolved by name)."""
    reg = _registry()

    def rec(d):
        t = d["t"]
        if t == "none":
            return None
        if t == "arr":
            return arrays[d["key"]]
        if t == "nt":
            cls = reg.get(d["cls"])
            if cls is None:
                raise ValueError(
                    f"checkpoint references unknown result type {d['cls']!r} "
                    "(written by an incompatible version?)")
            if list(cls._fields) != d["fields"]:
                raise ValueError(
                    f"checkpointed {d['cls']} fields {d['fields']} do not "
                    f"match the current definition {list(cls._fields)}")
            return cls(*[rec(i) for i in d["items"]])
        if t == "tuple":
            return tuple(rec(i) for i in d["items"])
        if t == "list":
            return [rec(i) for i in d["items"]]
        if t == "dict":
            return {k: rec(i) for k, i in zip(d["keys"], d["items"])}
        raise ValueError(f"unknown checkpoint node type {t!r}")


class SweepCheckpoint:
    """Bucket-granular sweep persistence rooted at ``directory``.

    ``tag`` namespaces files within the directory (``api.run`` sets it to
    ``{solver}_{backend}``); ``fingerprint`` (``telemetry.spec_fingerprint``)
    is stamped into every file and verified on load.
    """

    def __init__(self, directory: Union[str, Path], fingerprint: str = "",
                 tag: str = ""):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fingerprint = fingerprint
        self.tag = tag
        self.loaded = 0   # buckets resumed from disk (observability)
        self.saved = 0

    def with_tag(self, tag: str) -> "SweepCheckpoint":
        other = SweepCheckpoint(self.dir, self.fingerprint, tag)
        return other

    def _path(self, width: int, idx: int) -> Path:
        tag = self.tag or "sweep"
        return self.dir / f"{tag}_w{int(width)}_b{int(idx)}.npz"

    def load_bucket(self, width: int, idx: int) -> Optional[Any]:
        """The bucket's decoded result, or None when not yet checkpointed.
        Raises when the file belongs to a different spec fingerprint."""
        path = self._path(width, idx)
        if not path.exists():
            return None
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            if self.fingerprint and meta.get("fingerprint") \
                    and meta["fingerprint"] != self.fingerprint:
                raise ValueError(
                    f"resume checkpoint {path} was written by a different "
                    f"spec (fingerprint {meta['fingerprint']} != "
                    f"{self.fingerprint}); use a fresh --resume directory")
            arrays = {k: z[k] for k in z.files if k != "__meta__"}
        self.loaded += 1
        return decode_tree(arrays, meta["tree"])

    def save_bucket(self, width: int, idx: int, tree: Any) -> Path:
        path = self._path(width, idx)
        arrays, desc = encode_tree(tree)
        meta = json.dumps({"fingerprint": self.fingerprint, "tree": desc,
                           "tag": self.tag})
        fd, tmp = tempfile.mkstemp(dir=str(self.dir),
                                   prefix=path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as fh:
                np.savez(fh, __meta__=np.asarray(meta), **arrays)
            os.replace(tmp, path)  # atomic: a killed run never half-writes
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.saved += 1
        return path

    def stats(self) -> Dict[str, int]:
        return {"loaded": self.loaded, "saved": self.saved}
