from .checkpoint import load_checkpoint, save_checkpoint
from .sweep import SweepCheckpoint, decode_tree, encode_tree

__all__ = ["load_checkpoint", "save_checkpoint", "SweepCheckpoint",
           "encode_tree", "decode_tree"]
