from .synthetic import EmbedStream, TokenStream

__all__ = ["EmbedStream", "TokenStream"]
