"""Synthetic data pipelines (offline container -> deterministic generators).

* ``TokenStream``    -- language-model token batches with a learnable
                        structure (Markov-ish bigram process) so a ~100M model
                        trained for a few hundred steps shows a real loss
                        drop, not noise-floor hovering.
* ``EmbedStream``    -- frame/patch embedding batches for the audio/VLM stub
                        frontends (the assignment's carve-out): produces
                        (B, S, D) embeddings + targets, plus M-RoPE position
                        grids for the VLM case.
* logistic-regression generators live in ``repro.core.problems``.
All generators are seeded, stateless per batch index (sample k is a pure
function of (seed, k)), so every data-parallel worker can source its own
shard without coordination -- which is exactly what an asynchronous
parameter-server needs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    n_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, K = self.vocab, min(self.n_states, self.vocab)
        # sparse bigram transition table: each state strongly prefers ~4 next
        self._next = rng.integers(0, V, size=(K, 4))
        self._state_of = rng.integers(0, K, size=(V,))

    def batch_at(self, index: int, batch: Optional[int] = None,
                 seq: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        B = batch or self.batch
        S = seq or self.seq
        rng = np.random.default_rng((self.seed, index))
        toks = np.zeros((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=(B,))
        noise = rng.random((B, S))
        pick = rng.integers(0, 4, size=(B, S))
        rand_tok = rng.integers(0, self.vocab, size=(B, S))
        for t in range(S):
            st = self._state_of[toks[:, t]]
            nxt = self._next[st, pick[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.85, nxt, rand_tok[:, t])
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:])}


@dataclasses.dataclass
class EmbedStream:
    """Precomputed modality embeddings (audio frames / vision patches)."""

    d_model: int
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    mrope: bool = False
    image_grid: tuple = (8, 8)   # (h, w) patch grid at the sequence start

    def batch_at(self, index: int, batch: Optional[int] = None,
                 seq: Optional[int] = None) -> Dict[str, jnp.ndarray]:
        B = batch or self.batch
        S = seq or self.seq
        rng = np.random.default_rng((self.seed, index, 7))
        emb = rng.normal(size=(B, S, self.d_model)).astype(np.float32) * 0.1
        tgt = rng.integers(0, self.vocab, size=(B, S)).astype(np.int32)
        out = {"embeds": jnp.asarray(emb), "targets": jnp.asarray(tgt)}
        if self.mrope:
            out["positions"] = jnp.asarray(self.mrope_positions(B, S))
        return out

    def mrope_positions(self, B: int, S: int) -> np.ndarray:
        """(3, B, S) (t, h, w) grids: image patches first, then text."""
        h, w = self.image_grid
        n_img = min(h * w, S)
        t = np.zeros((S,), np.int32)
        hh = np.zeros((S,), np.int32)
        ww = np.zeros((S,), np.int32)
        idx = np.arange(n_img)
        hh[:n_img] = idx // w
        ww[:n_img] = idx % w
        # text continues after the image's temporal footprint
        text_pos = np.arange(S - n_img) + max(h, w)
        t[n_img:] = text_pos
        hh[n_img:] = text_pos
        ww[n_img:] = text_pos
        pos = np.stack([t, hh, ww])          # (3, S)
        return np.broadcast_to(pos[:, None, :], (3, B, S)).copy()
