"""Learning-rate schedules.  These modulate gamma' (the step-size budget),
NOT the delay adaptation -- the paper's gamma_k <= gamma' - window_sum
principle composes with any schedule on gamma' as long as the window sums use
the *emitted* gammas (which core.stepsize guarantees by construction)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.full((), value, jnp.float32)


def linear_warmup(base: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return base * frac
    return fn


def cosine_decay(base: float, total_steps: int, warmup_steps: int = 0,
                 final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0) if warmup_steps else 1.0
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base * warm * cos
    return fn


SCHEDULES = {"constant": constant, "linear_warmup": linear_warmup,
             "cosine": cosine_decay}
