from .optimizers import (AdamW, DelayAdaptiveOptimizer, DelayAdaptiveState,
                         Momentum, Sgd, apply_updates, clip_by_global_norm,
                         global_norm, make_optimizer)
from .schedules import SCHEDULES, constant, cosine_decay, linear_warmup

__all__ = [k for k in dir() if not k.startswith("_")]
