"""Optimizers (from scratch -- no optax in this environment).

``Sgd`` / ``Momentum`` / ``AdamW`` share a tiny (init, update) interface:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates, lr)

The delay-adaptive learning rate from the paper is deliberately kept
*outside* these rules: ``DelayAdaptiveOptimizer`` composes any base rule with
a ``core.stepsize`` policy -- gamma_k multiplies the update and is chosen
from the observed write-event staleness.  This is the "delay-adaptive
step-sizes plug into any asynchronous learner" framing of the paper's §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.prox import ProxOp, Zero
from repro.core.stepsize import StepsizePolicy, StepsizeState

Pytree = Any


def tree_map(fn, *ts):
    return jax.tree_util.tree_map(fn, *ts)


def apply_updates(params: Pytree, updates: Pytree, lr) -> Pytree:
    return tree_map(lambda p, u: (p - lr * u).astype(p.dtype), params, updates)


def global_norm(tree: Pytree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return tree_map(lambda g: g * scale, grads)


@dataclasses.dataclass(frozen=True)
class Sgd:
    def init(self, params):
        return ()

    def update(self, grads, state, params=None):
        return grads, state


class MomentumState(NamedTuple):
    mu: Pytree


@dataclasses.dataclass(frozen=True)
class Momentum:
    beta: float = 0.9
    nesterov: bool = False

    def init(self, params):
        return MomentumState(mu=tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(self, grads, state, params=None):
        mu = tree_map(lambda m, g: self.beta * m + g.astype(jnp.float32),
                      state.mu, grads)
        if self.nesterov:
            upd = tree_map(lambda m, g: self.beta * m + g.astype(jnp.float32), mu, grads)
        else:
            upd = mu
        return upd, MomentumState(mu=mu)


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Pytree
    nu: Pytree


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(count=jnp.zeros((), jnp.int32),
                         mu=tree_map(z, params), nu=tree_map(z, params))

    def update(self, grads, state, params=None):
        c = state.count + 1
        mu = tree_map(lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
                      state.mu, grads)
        nu = tree_map(lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
        bc1 = 1 - self.b1 ** c.astype(jnp.float32)
        bc2 = 1 - self.b2 ** c.astype(jnp.float32)
        upd = tree_map(lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + self.eps), mu, nu)
        if self.weight_decay and params is not None:
            upd = tree_map(lambda u, p: u + self.weight_decay * p.astype(jnp.float32),
                           upd, params)
        return upd, AdamState(count=c, mu=mu, nu=nu)


OPTIMIZERS = {"sgd": Sgd, "momentum": Momentum, "adamw": AdamW}


def make_optimizer(name: str, **kw):
    return OPTIMIZERS[name](**kw)


# ---------------------------------------------------------------------------
#  Delay-adaptive composition (the paper's contribution, optimizer-agnostic)
# ---------------------------------------------------------------------------


class DelayAdaptiveState(NamedTuple):
    step: jnp.ndarray          # master write-event counter
    ss: StepsizeState          # step-size window state (principle (8))
    inner: Any                 # base optimizer state
    worker_stamp: jnp.ndarray  # (n_workers,) iterate version each worker read


@dataclasses.dataclass(frozen=True)
class DelayAdaptiveOptimizer:
    """Compose a base optimizer with a delay-adaptive step-size policy.

    The policy's gamma' plays the role of the base learning rate; the emitted
    gamma_k (a function of the true write-event delay tau_k) scales the
    update, and an optional prox handles the composite term R.
    """

    policy: StepsizePolicy
    base: Any = Sgd()
    prox: ProxOp = Zero()
    lr_scale: float = 1.0
    grad_clip: Optional[float] = None
    n_workers: int = 1
    horizon: int = 4096

    def init(self, params: Pytree) -> DelayAdaptiveState:
        return DelayAdaptiveState(
            step=jnp.zeros((), jnp.int32),
            ss=self.policy.init(self.horizon),
            inner=self.base.init(params),
            worker_stamp=jnp.zeros((self.n_workers,), jnp.int32),
        )

    def observe(self, state: DelayAdaptiveState, worker) -> Tuple[jnp.ndarray, DelayAdaptiveState]:
        """Write-event delay bookkeeping (Algorithm 1 lines 12/15)."""
        tau = state.step - state.worker_stamp[worker]
        stamps = state.worker_stamp.at[worker].set(state.step + 1)
        return tau, state._replace(worker_stamp=stamps)

    def step_fn(self, params: Pytree, grads: Pytree, state: DelayAdaptiveState,
                tau) -> Tuple[Pytree, DelayAdaptiveState, jnp.ndarray]:
        if self.grad_clip:
            grads = clip_by_global_norm(grads, self.grad_clip)
        upd, inner = self.base.update(grads, state.inner, params)
        gamma, ss = self.policy.step(state.ss, tau)
        lr = self.lr_scale * gamma
        params = apply_updates(params, upd, lr)
        params = self.prox.prox(params, lr)
        return params, DelayAdaptiveState(step=state.step + 1, ss=ss,
                                          inner=inner,
                                          worker_stamp=state.worker_stamp), gamma

    def update(self, params, grads, state, worker):
        tau, state = self.observe(state, worker)
        params, state, gamma = self.step_fn(params, grads, state, tau)
        return params, state, gamma, tau
