"""Jaxpr contract verifier: the engine's prose invariants as machine checks.

The repo's correctness story rests on a handful of structural contracts
that were, until now, enforced only by numeric pin tests:

* **off-is-absent** -- ``faults=None`` / a disabled ``FaultSpec`` and
  ``telemetry=None`` produce EXACTLY the pre-feature jaxpr (the solver
  scans branch host-side on ``x is None``, never on a traced predicate),
  and passing the kwargs explicitly as ``None`` is identical to omitting
  them (default-drift guard);
* **on-is-live** -- enabling faults / telemetry actually changes the
  traced program (a dead knob would silently pin nothing);
* **engine parity** -- ``engine='fused'`` (Pallas) and ``engine='scan'``
  (pure XLA) agree on input AND output avals: same interface, different
  body.

Verified at two levels:

* **scan level** (the solo backend's substrate): ``jax.make_jaxpr`` of
  ``piag_scan`` / ``bcd_scan`` / ``fedasync_scan`` / ``fedbuff_scan``
  called directly -- this exercises the in-scan ``normalize_faults`` and
  keyword defaults;
* **program level** (batched / sharded backends): the exact executables
  ``api.run`` would cache, intercepted via
  :func:`repro.staticcheck.cachekey.capture` (traced, never compiled),
  compared by canonical fingerprint -- and their cache keys must agree or
  differ in lockstep with the jaxprs.

CLI: ``python -m repro.staticcheck.contracts`` (CI: static-analysis lane).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import ExecutionSpec
from repro.core.bcd import bcd_scan
from repro.core.piag import piag_scan
from repro.core.problems import make_logreg
from repro.core.prox import make_prox
from repro.core.stepsize import make_policy
from repro.faults.inject import update_fault_codes
from repro.faults.spec import FaultSpec
from repro.federated.server import _problem_pieces, fedasync_scan, fedbuff_scan
from repro.telemetry.accumulators import TelemetryConfig

from . import cachekey as _ck
from . import jaxpr as _jaxpr

__all__ = ["Check", "verify_scan_level", "verify_program_level", "verify",
           "SOLVERS", "main"]

SOLVERS = ("piag", "bcd", "fedasync", "fedbuff")

_K = 12  # events in the scan-level traces
_FAULTED = FaultSpec(p_crash=0.05, p_spike=0.1, p_drop=0.1, p_corrupt=0.05,
                     seed=0)
_DISABLED = FaultSpec(p_drop=0.9, p_corrupt=0.9, staleness_cutoff=2,
                      enabled=False)  # loud knobs that must all be inert


@dataclasses.dataclass(frozen=True)
class Check:
    name: str
    ok: bool
    detail: str = ""


# ----------------------------------------------------------- scan level ----

def _pieces():
    problem = make_logreg(48, 6, n_workers=3, seed=0)
    prox = make_prox("l1", lam=0.01)
    policy = make_policy("adaptive1", 0.1)
    return problem, prox, policy


def _scan_caller(solver: str) -> Callable[..., Any]:
    """A closure ``call(**extra) -> ClosedJaxpr`` tracing the solver's core
    scan with tiny fixed pieces; ``extra`` kwargs are forwarded verbatim so
    callers can compare explicit-``None`` against kwarg-omitted traces."""
    problem, prox, policy = _pieces()
    H = dict(horizon=32)
    if solver == "piag":
        Aw, bw = problem.worker_slices()
        x0 = jnp.zeros((problem.dim,), jnp.float32)
        loss = lambda x, A, b: problem.worker_loss(x, A, b)

        def call(**extra):
            def fn(w, tau):
                return piag_scan(loss, x0, (Aw, bw), (w, tau), policy, prox,
                                 objective=problem.P, **H, **extra)
            return jax.make_jaxpr(fn)(jnp.zeros(_K, jnp.int32),
                                      jnp.zeros(_K, jnp.int32))
        return call
    if solver == "bcd":
        x0 = jnp.zeros((problem.dim,), jnp.float32)

        def call(**extra):
            def fn(w, tau, blk):
                return bcd_scan(problem.grad_f, problem.P, x0, 3, 3,
                                (w, tau, blk), policy, prox, **H, **extra)
            z = jnp.zeros(_K, jnp.int32)
            return jax.make_jaxpr(fn)(z, z, z)
        return call
    # federated
    update, x0, data = _problem_pieces(problem, prox, None)
    scan = fedasync_scan if solver == "fedasync" else fedbuff_scan
    fed_kw = {} if solver == "fedasync" else dict(eta=1.0, buffer_size=1)

    def call(**extra):
        def fn(client, tau, steps, agg, version):
            events = (client, tau, steps, agg, version)
            return scan(update, x0, data, events, policy,
                        objective=problem.P, **fed_kw, **H, **extra)
        z = jnp.zeros(_K, jnp.int32)
        return jax.make_jaxpr(fn)(z, z, jnp.ones(_K, jnp.int32),
                                  jnp.ones(_K, jnp.float32), z)
    return call


def verify_scan_level(solvers=SOLVERS) -> List[Check]:
    checks: List[Check] = []
    for s in solvers:
        call = _scan_caller(s)
        base = call()

        def add(name: str, ok: bool, detail: str = ""):
            checks.append(Check(f"scan/{s}/{name}", ok, detail))

        explicit = call(faults=None, telemetry=None)
        add("explicit-none-is-omitted",
            _jaxpr.fingerprint(explicit) == _jaxpr.fingerprint(base),
            _jaxpr.diff(base, explicit, "omitted", "explicit None"))

        disabled = call(faults=_DISABLED)
        add("disabled-faults-are-none",
            _jaxpr.fingerprint(disabled) == _jaxpr.fingerprint(base),
            _jaxpr.diff(base, disabled, "faults=None", "disabled FaultSpec"))

        codes = update_fault_codes(_FAULTED, _K, 0)
        faulted = call(faults=_FAULTED, fault_codes=codes)
        add("faults-live",
            _jaxpr.fingerprint(faulted) != _jaxpr.fingerprint(base),
            "enabling faults did not change the traced program (dead knob)")

        telem = call(telemetry=TelemetryConfig())
        add("telemetry-live",
            _jaxpr.fingerprint(telem) != _jaxpr.fingerprint(base),
            "enabling telemetry did not change the traced program")

        fused = call(engine="fused")
        add("fused-scan-io-parity",
            _jaxpr.io_avals(fused) == _jaxpr.io_avals(base),
            f"fused {_jaxpr.io_avals(fused)} != scan {_jaxpr.io_avals(base)}")
        add("fused-is-a-different-body",
            _jaxpr.fingerprint(fused) != _jaxpr.fingerprint(base),
            "engine='fused' traced identically to 'scan'")
    return checks


# -------------------------------------------------------- program level ----

def _spec(solver: str, backend: str, **over):
    return _ck.base_spec(
        solver,
        execution=ExecutionSpec(backend=backend,
                                **over.pop("execution_kw", {})),
        **over)


def verify_program_level(solvers=SOLVERS,
                         backends=("batched", "sharded")) -> List[Check]:
    checks: List[Check] = []
    for s in solvers:
        for b in backends:
            base = _ck.capture(_spec(s, b))

            def add(name: str, ok: bool, detail: str = ""):
                checks.append(Check(f"{b}/{s}/{name}", ok, detail))

            if base is None:
                add("captured", False,
                    f"backend {b} unexpectedly bypassed cached_program")
                continue

            disabled = _ck.capture(_spec(s, b, faults=_DISABLED))
            add("disabled-faults-are-none",
                disabled is not None
                and disabled.fingerprint == base.fingerprint
                and disabled.key == base.key,
                "disabled FaultSpec must reuse the faults=None program AND "
                "its cache key (normalize_faults chain)")

            faulted = _ck.capture(_spec(s, b, faults=_FAULTED))
            add("faults-live",
                faulted is not None
                and faulted.fingerprint != base.fingerprint
                and faulted.key != base.key,
                "enabling faults must change program and key")

            telem = _ck.capture(
                _spec(s, b, execution_kw=dict(telemetry=True)))
            add("telemetry-live",
                telem is not None and telem.fingerprint != base.fingerprint
                and telem.key != base.key,
                "enabling telemetry must change program and key")

            if b == "batched":
                fused = _ck.capture(
                    _spec(s, b, execution_kw=dict(engine="fused")))
                add("fused-scan-io-parity",
                    fused is not None
                    and fused.in_avals == base.in_avals
                    and fused.out_avals == base.out_avals
                    and fused.fingerprint != base.fingerprint,
                    "fused and scan programs must agree on input/output "
                    "avals while differing in body")
    return checks


def verify(quick: bool = False) -> List[Check]:
    """The full contract matrix; ``quick=True`` restricts to PIAG +
    FedBuff and the batched backend (the test-suite subset)."""
    solvers = ("piag", "fedbuff") if quick else SOLVERS
    backends = ("batched",) if quick else ("batched", "sharded")
    return verify_scan_level(solvers) + verify_program_level(solvers,
                                                             backends)


# ----------------------------------------------------------------- CLI ----

def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.staticcheck.contracts",
        description="jaxpr contract verifier (solvers x backends)")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--verbose", action="store_true")
    args = p.parse_args(argv)

    checks = verify(quick=args.quick)
    failed = [c for c in checks if not c.ok]
    for c in checks:
        if args.verbose or not c.ok:
            status = "ok" if c.ok else "FAIL"
            print(f"[{status}] {c.name}")
            if not c.ok and c.detail:
                head = "\n".join(c.detail.splitlines()[:40])
                print(f"       {head}")
    print(f"contracts: {len(checks) - len(failed)}/{len(checks)} ok")
    return 0 if not failed else 1


if __name__ == "__main__":
    raise SystemExit(main())
