"""The trace-safety lint rules (`repro.staticcheck.rules`).

Each rule is distilled from a bug this repo actually shipped (or nearly
shipped) and carries a known-bad fixture under ``staticcheck/fixtures/``:

* ``PAL001`` -- ``lax.switch`` inside a Pallas kernel body.  ``switch``
  has no lowering inside compiled Pallas kernels (PR 2's wrap bug hid
  behind exactly this; ``fused_step.select_gamma`` exists because the
  switch had to become a nested ``where`` chain).
* ``PAL002`` -- 0-d ``ShapeDtypeStruct(())`` in Pallas scope.  Pallas
  refs must carry scalars as shape ``(1,)``; a 0-d ref traces in
  interpret mode and dies in the Mosaic/Triton lowering (the fused-step
  carry layout note in ROADMAP).
* ``PAL003`` -- a ``pl.pallas_call`` not routed through
  ``kernels.dispatch``: missing ``interpret=`` kwarg, a hard-coded
  literal, or a module that never touches ``default_interpret`` /
  ``resolve_interpret``.  PR 7 fixed a wrong backend default precisely
  because call sites resolved interpret ad hoc.
* ``JIT001`` -- Python ``random`` / ``time`` / ``datetime`` (or
  ``numpy.random``) called inside jit-decorated functions or
  scan/while/cond bodies: traced once, frozen forever -- the value the
  program bakes in is whatever the clock/RNG said at TRACE time.
* ``JIT002`` -- host-side ``if``/``while`` on a traced value inside a
  scan/while/cond body (a ``TracerBoolConversionError`` at best, silent
  python-level specialization at worst).  ``x is None`` / ``isinstance``
  tests are exempt: those branch on trace-time structure, the engine's
  sanctioned pattern (``faults is None`` IS the faults-off contract).
* ``CACHE001`` -- in-place mutation of an array after it was captured by
  ``IdKey`` / ``tree_key`` for a ``cached_program`` key: identity keying
  treats captures as frozen; mutating one serves stale executables
  (see ``sweep.cache`` docs and ``REPRO_CACHE_CHECK``).

Rules are pure AST analysis -- no imports of the linted code, so the lint
runs without jax and in a fraction of a second.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["Finding", "ModuleInfo", "Rule", "ALL_RULES", "RULE_DOCS"]


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


# ------------------------------------------------------------- helpers ----

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _call_name(call: ast.Call) -> str:
    return _dotted(call.func) or ""


def _last(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _walk_calls(node: ast.AST) -> Iterable[ast.Call]:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            yield n


def _own_body(func: ast.AST) -> Iterable[ast.AST]:
    """Walk a function's (or module's) body EXCLUDING nested function defs
    -- their statements belong to the nested scope.  Pre-order, source
    order: taint propagation in JIT002 depends on seeing assignments
    before the branches that use them."""
    def rec(nodes):
        for n in nodes:
            if isinstance(n, _FUNC_NODES):
                continue  # nested scope: analyzed separately
            yield n
            yield from rec(ast.iter_child_nodes(n))
    yield from rec(getattr(func, "body", []))


def _first_pos_func_name(call: ast.Call, index: int = 0) -> Optional[str]:
    """Function name passed at positional ``index``: a bare Name, or the
    first argument of a ``functools.partial(...)`` wrapper."""
    if len(call.args) <= index:
        return None
    arg = call.args[index]
    if isinstance(arg, ast.Name):
        return arg.id
    if isinstance(arg, ast.Call) and _last(_call_name(arg)) == "partial" \
            and arg.args and isinstance(arg.args[0], ast.Name):
        return arg.args[0].id
    return None


# traced-body positions of the jax control-flow primitives
_BODY_POSITIONS = {
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2),
}


def _is_lax_flow(name: str, seg: str) -> bool:
    """True for ``lax.scan`` / ``jax.lax.scan`` style spellings (and the
    bare name when imported from lax -- accepted; the repo idiom is the
    qualified form)."""
    return _last(name) == seg and (name == seg or ".lax." in f".{name}"
                                   or name.startswith("lax."))


class ModuleInfo:
    """Shared per-module analysis consumed by every rule."""

    def __init__(self, tree: ast.Module, path: str):
        self.tree = tree
        self.path = path
        # every named function in the module (any nesting), by bare name
        self.funcs: Dict[str, List[ast.AST]] = {}
        for n in ast.walk(tree):
            if isinstance(n, _FUNC_NODES):
                self.funcs.setdefault(n.name, []).append(n)
        self.pallas_calls: List[ast.Call] = [
            c for c in _walk_calls(tree)
            if _last(_call_name(c)) == "pallas_call"]
        self.references_dispatch = any(
            _last(_dotted(n) or "") in ("default_interpret",
                                        "resolve_interpret")
            for n in ast.walk(tree)
            if isinstance(n, (ast.Name, ast.Attribute)))
        self._kernel_funcs: Optional[Set[ast.AST]] = None
        self._pallas_scope: Optional[Set[ast.AST]] = None
        self._traced_scopes: Optional[List[Tuple[ast.AST, str]]] = None

    # -- call-graph closures (same-module, by bare name) ----------------
    def _closure(self, roots: Set[ast.AST]) -> Set[ast.AST]:
        seen = set(roots)
        frontier = list(roots)
        while frontier:
            f = frontier.pop()
            for call in _walk_calls(f):
                callee = _last(_call_name(call))
                for g in self.funcs.get(callee, []):
                    if g not in seen:
                        seen.add(g)
                        frontier.append(g)
        return seen

    @property
    def kernel_funcs(self) -> Set[ast.AST]:
        """Functions that run INSIDE a Pallas kernel: the first positional
        argument of each ``pallas_call`` plus same-module transitive
        callees."""
        if self._kernel_funcs is None:
            roots: Set[ast.AST] = set()
            for call in self.pallas_calls:
                name = _first_pos_func_name(call)
                if name:
                    roots.update(self.funcs.get(name, []))
            self._kernel_funcs = self._closure(roots)
        return self._kernel_funcs

    @property
    def pallas_scope(self) -> Set[ast.AST]:
        """Functions involved in LAUNCHING Pallas kernels: any function
        containing a ``pallas_call`` plus same-module transitive callees
        (out-shape builders and the like) plus the kernel bodies."""
        if self._pallas_scope is None:
            launchers = {
                f for fs in self.funcs.values() for f in fs
                if any(_last(_call_name(c)) == "pallas_call"
                       for c in _walk_calls(f))}
            self._pallas_scope = self._closure(launchers) | self.kernel_funcs
        return self._pallas_scope

    @property
    def traced_scopes(self) -> List[Tuple[ast.AST, str]]:
        """(function, origin) pairs whose bodies execute under a trace:
        jit-decorated functions (origin ``'jit'``) and functions passed as
        scan/while/fori/cond bodies (origin = the primitive name), plus
        functions nested inside either (origin ``'<outer origin>+nested'``)."""
        if self._traced_scopes is None:
            scopes: Dict[ast.AST, str] = {}
            for fs in self.funcs.values():
                for f in fs:
                    for dec in getattr(f, "decorator_list", []):
                        target = dec.func if isinstance(dec, ast.Call) else dec
                        name = _dotted(target) or ""
                        if _last(name) == "jit":
                            scopes[f] = "jit"
                        elif isinstance(dec, ast.Call) \
                                and _last(name) == "partial" \
                                and dec.args \
                                and _last(_dotted(dec.args[0]) or "") == "jit":
                            scopes[f] = "jit"
            for call in _walk_calls(self.tree):
                name = _call_name(call)
                for seg, positions in _BODY_POSITIONS.items():
                    if not _is_lax_flow(name, seg):
                        continue
                    for pos in positions:
                        fname = _first_pos_func_name(call, pos)
                        for f in self.funcs.get(fname or "", []):
                            scopes.setdefault(f, seg)
                # switch: every element of the branch list is a body
                if _is_lax_flow(name, "switch") and len(call.args) > 1 \
                        and isinstance(call.args[1], (ast.List, ast.Tuple)):
                    for el in call.args[1].elts:
                        if isinstance(el, ast.Name):
                            for f in self.funcs.get(el.id, []):
                                scopes.setdefault(f, "switch")
            for f, origin in list(scopes.items()):
                for n in ast.walk(f):
                    if isinstance(n, _FUNC_NODES) and n is not f \
                            and n not in scopes:
                        scopes[n] = f"{origin}+nested"
            self._traced_scopes = list(scopes.items())
        return self._traced_scopes


class Rule:
    name = ""
    doc = ""

    def check(self, info: ModuleInfo) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, info: ModuleInfo, node: ast.AST, msg: str) -> Finding:
        return Finding(info.path, getattr(node, "lineno", 0),
                       getattr(node, "col_offset", 0), self.name, msg)


# --------------------------------------------------------------- rules ----

class SwitchInKernel(Rule):
    name = "PAL001"
    doc = ("lax.switch inside a Pallas kernel body (no lowering in "
           "compiled kernels; use a nested `where` chain like "
           "fused_step.select_gamma)")

    def check(self, info: ModuleInfo) -> List[Finding]:
        out = []
        for f in info.kernel_funcs:
            for call in _walk_calls(f):
                if _is_lax_flow(_call_name(call), "switch"):
                    out.append(self.finding(
                        info, call,
                        f"lax.switch inside Pallas kernel body "
                        f"{getattr(f, 'name', '?')!r}: switch does not "
                        "lower in compiled kernels (interpret mode hides "
                        "it); use a nested jnp.where chain"))
        return out


class ScalarRefShape(Rule):
    name = "PAL002"
    doc = ("0-d ShapeDtypeStruct(()) in Pallas scope; kernel refs must "
           "carry scalars as shape (1,)")

    def check(self, info: ModuleInfo) -> List[Finding]:
        out = []
        for f in info.pallas_scope:
            for call in _walk_calls(f):
                if _last(_call_name(call)) != "ShapeDtypeStruct":
                    continue
                if call.args and isinstance(call.args[0], ast.Tuple) \
                        and not call.args[0].elts:
                    out.append(self.finding(
                        info, call,
                        "0-d ShapeDtypeStruct(()) in Pallas scope: kernel "
                        "refs must carry scalars as shape (1,) (0-d refs "
                        "trace in interpret mode but fail to lower)"))
        return out


class UnroutedPallasCall(Rule):
    name = "PAL003"
    doc = ("pallas_call not routed through kernels.dispatch: interpret= "
           "must be present, non-literal, and resolved via "
           "default_interpret/resolve_interpret")

    def check(self, info: ModuleInfo) -> List[Finding]:
        out = []
        for call in info.pallas_calls:
            kw = next((k for k in call.keywords if k.arg == "interpret"),
                      None)
            if kw is None:
                out.append(self.finding(
                    info, call,
                    "pallas_call without interpret=...: the backend "
                    "default must come from kernels.dispatch "
                    "(default_interpret/resolve_interpret), not jax's"))
                continue
            if isinstance(kw.value, ast.Constant):
                out.append(self.finding(
                    info, kw.value,
                    f"pallas_call with hard-coded interpret="
                    f"{kw.value.value!r}: pass the caller's interpret "
                    "through kernels.dispatch.resolve_interpret instead"))
            elif not info.references_dispatch:
                out.append(self.finding(
                    info, call,
                    "pallas_call in a module that never references "
                    "kernels.dispatch (default_interpret/"
                    "resolve_interpret): new Pallas entry points must "
                    "route their interpret default through dispatch"))
        return out


_ENTROPY_PREFIXES = ("random.", "time.", "datetime.", "np.random.",
                     "numpy.random.")


class HostEntropyInTrace(Rule):
    name = "JIT001"
    doc = ("python random/time/datetime inside jitted or scanned code "
           "(traced once, frozen into the executable)")

    def check(self, info: ModuleInfo) -> List[Finding]:
        out = []
        for f, origin in info.traced_scopes:
            for call in _own_body_calls(f):
                name = _call_name(call)
                if any(name == p[:-1] or name.startswith(p)
                       for p in _ENTROPY_PREFIXES):
                    out.append(self.finding(
                        info, call,
                        f"{name}() inside traced code ({origin} scope "
                        f"{getattr(f, 'name', '?')!r}): evaluated once at "
                        "trace time and baked into every later execution; "
                        "thread PRNG keys / host timestamps in as "
                        "arguments instead"))
        return out


def _own_body_calls(func: ast.AST) -> Iterable[ast.Call]:
    for n in _own_body(func):
        if isinstance(n, ast.Call):
            yield n


class TracedBranch(Rule):
    name = "JIT002"
    doc = ("host-side if/while on a traced value inside a scan/while/cond "
           "body (`is None` / isinstance structure tests are exempt)")

    def check(self, info: ModuleInfo) -> List[Finding]:
        out = []
        for f, origin in info.traced_scopes:
            if origin == "jit":
                continue  # jit statics are legitimate host branches
            tainted = {a.arg for a in _all_args(f)} - {"self"}
            for stmt in _stmts_in_order(f):
                if isinstance(stmt, ast.Assign):
                    if any(isinstance(n, ast.Name) and n.id in tainted
                           for n in ast.walk(stmt.value)):
                        for t in stmt.targets:
                            for n in ast.walk(t):
                                if isinstance(n, ast.Name):
                                    tainted.add(n.id)
                if isinstance(stmt, (ast.If, ast.While)) \
                        and not _branch_exempt(stmt.test, tainted):
                    names = sorted({n.id for n in ast.walk(stmt.test)
                                    if isinstance(n, ast.Name)
                                    and n.id in tainted})
                    out.append(self.finding(
                        info, stmt,
                        f"host `{type(stmt).__name__.lower()}` on traced "
                        f"value(s) {names} inside {origin} body "
                        f"{getattr(f, 'name', '?')!r}: python control flow "
                        "cannot branch on tracers; use jnp.where / "
                        "lax.cond (or restructure so the branch is on a "
                        "host static)"))
        return out


def _all_args(func: ast.AST) -> List[ast.arg]:
    a = getattr(func, "args", None)
    if a is None:
        return []
    return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs) + \
        ([a.vararg] if a.vararg else []) + ([a.kwarg] if a.kwarg else [])


def _stmts_in_order(func: ast.AST) -> Iterable[ast.stmt]:
    for n in _own_body(func):
        if isinstance(n, ast.stmt):
            yield n


def _branch_exempt(test: ast.expr, tainted: Set[str]) -> bool:
    """True when the test cannot be a tracer-boolean: no tainted names, or
    every tainted reference sits under an `is [not] None` / isinstance
    structure check (trace-time constants)."""
    if not any(isinstance(n, ast.Name) and n.id in tainted
               for n in ast.walk(test)):
        return True
    if isinstance(test, ast.Compare) \
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.Call) \
            and _last(_call_name(test)) in ("isinstance", "callable",
                                            "hasattr", "len"):
        return _last(_call_name(test)) != "len"
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _branch_exempt(test.operand, tainted)
    if isinstance(test, ast.BoolOp):
        return all(_branch_exempt(v, tainted) for v in test.values)
    return False


_MUTATING_METHODS = ("fill", "sort", "put", "resize", "itemset", "setfield",
                     "partition", "setflags")


class MutateCaptured(Rule):
    name = "CACHE001"
    doc = ("in-place mutation of an array after capture by IdKey/tree_key "
           "(cached_program treats captures as frozen)")

    def check(self, info: ModuleInfo) -> List[Finding]:
        out = []
        scopes: List[ast.AST] = [info.tree]
        scopes += [f for fs in info.funcs.values() for f in fs]
        for scope in scopes:
            nodes = list(_own_body(scope))
            captured = {
                c.args[0].id
                for c in nodes if isinstance(c, ast.Call)
                and _last(_call_name(c)) in ("IdKey", "tree_key")
                and c.args and isinstance(c.args[0], ast.Name)}
            if not captured:
                continue
            for n in nodes:
                target = None
                if isinstance(n, ast.Assign):
                    for t in n.targets:
                        if isinstance(t, ast.Subscript) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id in captured:
                            target = t.value.id
                elif isinstance(n, ast.AugAssign) \
                        and isinstance(n.target, ast.Subscript) \
                        and isinstance(n.target.value, ast.Name) \
                        and n.target.value.id in captured:
                    target = n.target.value.id
                elif isinstance(n, ast.Call) \
                        and isinstance(n.func, ast.Attribute) \
                        and n.func.attr in _MUTATING_METHODS \
                        and isinstance(n.func.value, ast.Name) \
                        and n.func.value.id in captured:
                    target = n.func.value.id
                if target is not None:
                    out.append(self.finding(
                        info, n,
                        f"in-place mutation of {target!r} after it was "
                        "captured by IdKey/tree_key for a cached_program "
                        "key: identity-keyed captures are frozen -- the "
                        "cache would keep serving the executable compiled "
                        "against the old contents (REPRO_CACHE_CHECK=1 "
                        "catches this at runtime; build a new array "
                        "instead)"))
        return out


ALL_RULES: Sequence[Rule] = (SwitchInKernel(), ScalarRefShape(),
                             UnroutedPallasCall(), HostEntropyInTrace(),
                             TracedBranch(), MutateCaptured())

RULE_DOCS = {r.name: r.doc for r in ALL_RULES}
