"""Canonical text form of jaxprs, for structural comparison.

``jax.make_jaxpr`` output is almost-but-not-quite comparable: variable
names depend on trace order and counter state, equation ``source_info``
carries file/line noise, param dicts print in insertion order, and object
reprs leak memory addresses.  This module renders a ``ClosedJaxpr`` to a
deterministic list of lines such that two traces of semantically identical
programs produce identical text:

* variables are alpha-renamed ``v0, v1, ...`` in first-appearance order
  (constvars, then invars, then eqn outputs in program order);
* equations keep program order, with params sorted by name;
* ``source_info`` is simply never rendered;
* nested jaxprs (``pjit``/``scan``/``while``/``cond`` branches,
  ``pallas_call`` kernels, ``shard_map`` bodies) recurse with a fresh
  naming scope;
* array-valued consts and params are summarized as
  ``dtype[shape]#<sha1 prefix>`` so captured data participates in
  identity without dumping buffers;
* any residual repr is scrubbed of ``0x...`` addresses.

The canonical lines feed :func:`fingerprint` (sha1) for cheap equality and
:func:`diff` (unified diff) for readable contract-violation reports.
"""
from __future__ import annotations

import hashlib
import re
from difflib import unified_diff
from typing import Any, Dict, List, Tuple

import jax
import numpy as np
from jax.core import ClosedJaxpr, Jaxpr, Literal, Var

__all__ = ["canonical_lines", "canonical_text", "fingerprint", "diff",
           "assert_identical", "io_avals"]

_ADDR = re.compile(r"0x[0-9a-fA-F]+")


def _hash_bytes(data: bytes) -> str:
    return hashlib.sha1(data).hexdigest()[:12]


def _array_token(arr: Any) -> str:
    a = np.asarray(arr)
    flat = np.ascontiguousarray(a).reshape(-1)
    if flat.size > 65536:
        flat = np.ascontiguousarray(flat[:: flat.size // 65536 + 1])
    return f"{a.dtype}[{','.join(map(str, a.shape))}]#{_hash_bytes(flat.tobytes())}"


def _render_value(val: Any, depth: int) -> str:
    """Deterministic rendering of an eqn param / const value."""
    if isinstance(val, (ClosedJaxpr, Jaxpr)):
        inner = canonical_lines(val)
        pad = "  " * (depth + 1)
        return "{\n" + "\n".join(pad + ln for ln in inner) + "\n" + "  " * depth + "}"
    if isinstance(val, (tuple, list)):
        body = ", ".join(_render_value(v, depth) for v in val)
        return ("(" + body + ")") if isinstance(val, tuple) else ("[" + body + "]")
    if isinstance(val, dict):
        body = ", ".join(f"{k}={_render_value(v, depth)}"
                         for k, v in sorted(val.items(), key=lambda kv: str(kv[0])))
        return "{" + body + "}"
    if isinstance(val, (np.ndarray, jax.Array)):
        return _array_token(val)
    if isinstance(val, (bool, int, float, complex, str, bytes)) or val is None:
        return repr(val)
    if callable(val):
        name = getattr(val, "__name__", type(val).__name__)
        return f"<fn {name}>"
    return _ADDR.sub("0x~", repr(val))


class _Namer:
    """Alpha-renaming scope: Var -> ``v<n>`` in first-appearance order."""

    def __init__(self):
        self.names: Dict[Var, str] = {}

    def __call__(self, v: Any) -> str:
        if isinstance(v, Literal):
            val = v.val
            if isinstance(val, (np.ndarray, jax.Array)) and np.ndim(val) > 0:
                return _array_token(val)
            return f"lit:{_render_value(np.asarray(val).item() if isinstance(val, (np.ndarray, jax.Array)) else val, 0)}"
        name = self.names.get(v)
        if name is None:
            name = f"v{len(self.names)}"
            self.names[v] = name
        return f"{name}:{v.aval.str_short()}"


def canonical_lines(closed: Any) -> List[str]:
    """Render a ``ClosedJaxpr`` (or bare ``Jaxpr``) to canonical lines."""
    if isinstance(closed, ClosedJaxpr):
        jaxpr, consts = closed.jaxpr, closed.consts
    else:
        jaxpr, consts = closed, ()
    name = _Namer()
    lines: List[str] = []
    for i, cv in enumerate(jaxpr.constvars):
        const = consts[i] if i < len(consts) else "<abstract>"
        tok = (_array_token(const)
               if isinstance(const, (np.ndarray, jax.Array))
               else _render_value(const, 0))
        lines.append(f"const {name(cv)} = {tok}")
    lines.append("in  (" + ", ".join(name(v) for v in jaxpr.invars) + ")")
    for eqn in jaxpr.eqns:
        ins = ", ".join(name(v) for v in eqn.invars)
        outs = ", ".join(name(v) for v in eqn.outvars)
        params = " ".join(
            f"{k}={_render_value(v, 1)}"
            for k, v in sorted(eqn.params.items(), key=lambda kv: kv[0]))
        line = f"{outs} = {eqn.primitive.name}[{params}]({ins})"
        lines.append(_ADDR.sub("0x~", line))
    lines.append("out (" + ", ".join(name(v) for v in jaxpr.outvars) + ")")
    return lines


def canonical_text(closed: Any) -> str:
    return "\n".join(canonical_lines(closed))


def fingerprint(closed: Any) -> str:
    """sha1 of the canonical text -- equal iff structurally identical."""
    return hashlib.sha1(canonical_text(closed).encode()).hexdigest()


def diff(a: Any, b: Any, label_a: str = "a", label_b: str = "b") -> str:
    """Unified diff of two canonical jaxprs ('' when identical)."""
    la, lb = canonical_lines(a), canonical_lines(b)
    return "\n".join(unified_diff(la, lb, fromfile=label_a, tofile=label_b,
                                  lineterm=""))


def assert_identical(a: Any, b: Any, label: str = "jaxpr contract") -> None:
    d = diff(a, b)
    if d:
        head = "\n".join(d.splitlines()[:60])
        raise AssertionError(f"{label}: canonical jaxprs differ\n{head}")


def io_avals(closed: ClosedJaxpr) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """(input avals, output avals) as short strings -- the interface
    signature two engines must agree on even when their bodies differ."""
    return (tuple(a.str_short() for a in closed.in_avals),
            tuple(a.str_short() for a in closed.out_avals))
