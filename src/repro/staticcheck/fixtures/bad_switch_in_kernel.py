"""Known-bad fixture for PAL001: ``lax.switch`` inside a Pallas kernel.

This file is NEVER imported or executed -- it exists so the lint's test
suite can prove the rule fires.  The pallas_call itself is routed
correctly (non-literal interpret via dispatch) so that ONLY PAL001
triggers here.
"""
import jax
import jax.experimental.pallas as pl
from jax import lax

from repro.kernels.dispatch import resolve_interpret


def _branch_a(x):
    return x + 1.0


def _branch_b(x):
    return x - 1.0


def _kernel(idx_ref, x_ref, o_ref):
    x = x_ref[...]
    # BAD: switch has no lowering inside compiled Pallas kernels; it only
    # appears to work because interpret mode traces it.
    o_ref[...] = lax.switch(idx_ref[0], [_branch_a, _branch_b], x)


def run(idx, x, interpret=None):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=resolve_interpret(interpret),
    )(idx, x)
