"""Known-bad fixture for CACHE001: in-place mutation of an array after it
was captured by ``IdKey`` / ``tree_key`` for a ``cached_program`` key.

Never imported or executed.  Both function-local and module-level capture
scopes are exercised.
"""
import numpy as np

from repro.sweep.cache import IdKey, cached_program, tree_key

_DATA = np.ones(4)
_KEY = ("fixture", IdKey(_DATA))
_DATA[:] = 0.0  # BAD: the key above now points at different contents


def build_and_mutate(data, x0):
    key = ("fixture", IdKey(data), tree_key(x0))
    prog = cached_program(key, lambda: None)
    data[0] = 0.0  # BAD: mutates a captured array after keying
    data.fill(1.0)  # BAD: ditto, via a mutating ndarray method
    return prog
