"""Known-bad fixture for PAL003: ``pallas_call`` not routed through
``kernels.dispatch``.

Never imported or executed.  Three distinct failure shapes, all PAL003:
no ``interpret=`` at all, a hard-coded literal, and a pass-through
variable in a module that never touches dispatch.
"""
import jax
import jax.experimental.pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run_missing(x):
    # BAD: no interpret kwarg -- jax's default, not the backend-aware one.
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def run_literal(x):
    # BAD: hard-coded interpret flag.
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def run_unrouted(x, interpret):
    # BAD: non-literal, but this module never references
    # default_interpret/resolve_interpret, so the default can't be the
    # dispatch one.
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
