"""Known-bad fixture for PAL002: 0-d ``ShapeDtypeStruct(())`` in Pallas
scope.

Never imported or executed.  The call site is otherwise routed correctly
so that ONLY PAL002 triggers here.
"""
import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret


def _sum_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].sum()


def run(x, interpret=None):
    # BAD: a 0-d out-shape traces in interpret mode but the ref fails to
    # lower; scalars must ride shape (1,) refs.
    return pl.pallas_call(
        _sum_kernel,
        out_shape=jax.ShapeDtypeStruct((), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(x)
