"""Known-bad fixture for JIT001: host entropy inside traced code.

Never imported or executed.  Covers both traced-scope origins: a
jit-decorated function and a ``lax.scan`` body.
"""
import random
import time

import jax
from jax import lax


@jax.jit
def noisy_step(x):
    jitter = random.random()  # BAD: frozen at trace time
    time.sleep(0.001)  # BAD: runs once, at trace time only
    return x * (1.0 + jitter)


def _body(carry, x):
    now = time.time()  # BAD: the scan bakes in one timestamp forever
    return carry + x * now, x


def run(xs):
    return lax.scan(_body, 0.0, xs)
