"""Known-bad fixture for JIT002: host ``if``/``while`` on a traced value
inside a scan/while body.

Never imported or executed.  The ``faults is None`` idiom the engine
actually uses is exempt (structure test) -- included below to prove the
exemption holds.
"""
import jax.numpy as jnp
from jax import lax


def _body(carry, x):
    if x > 0:  # BAD: python branch on a tracer
        carry = carry + x
    return carry, carry


def run(xs):
    return lax.scan(_body, jnp.float32(0.0), xs)


def _cond(val):
    return val < 10.0


def _loop_body(val):
    total = val
    while total < 10.0:  # BAD: python loop on a tracer (via assignment)
        total = total + 1.0
    return total


def run_while(x0):
    return lax.while_loop(_cond, _loop_body, x0)


def _ok_body(carry, x):
    if x is not None:  # OK: `is` tests are trace-time structure checks
        return carry + x, x
    return carry, x


def run_ok(xs):
    return lax.scan(_ok_body, jnp.float32(0.0), xs)
