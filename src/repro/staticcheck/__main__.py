"""``python -m repro.staticcheck``: the full static-analysis gate.

Runs, in order: the trace-safety lint over ``src/``, the jaxpr contract
verifier, and the cache-key completeness + retrace-budget checks -- the
same three lanes CI's ``static-analysis`` job runs individually.  Exits
non-zero if ANY layer fails.
"""
from __future__ import annotations

import os

from . import cachekey, contracts, lint


def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="lint + jaxpr contracts + cache-key completeness")
    p.add_argument("--quick", action="store_true",
                   help="contract subset (piag+fedbuff, batched only)")
    p.add_argument("--verbose", action="store_true")
    p.add_argument("--src", default=None,
                   help="tree to lint (default: the repro package itself)")
    args = p.parse_args(argv)

    src = args.src or os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))

    print(f"== lint {src} ==")
    rc_lint = lint.main([src])
    print("== jaxpr contracts ==")
    rc_contracts = contracts.main(
        (["--quick"] if args.quick else [])
        + (["--verbose"] if args.verbose else []))
    print("== cache-key completeness ==")
    rc_cachekey = cachekey.main(["--verbose"] if args.verbose else [])
    return 1 if (rc_lint or rc_contracts or rc_cachekey) else 0


if __name__ == "__main__":
    raise SystemExit(main())
