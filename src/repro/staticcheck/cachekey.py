"""Cache-key completeness checking for the sweep-program cache.

The stale-executable-reuse bug class (patched by hand in PRs 5-8): a spec
knob that changes the traced program but does NOT ride the
``sweep.cache`` key makes ``cached_program`` serve an executable compiled
for a different configuration.  This module closes the class mechanically:

* :func:`capture` runs ``api.run(spec)`` with a ``sweep.cache`` capture
  hook installed -- the hook intercepts the ``cached_program`` dispatch,
  traces the program with ``jax.make_jaxpr`` (no compile, no execution),
  and aborts with the ``(cache key, canonical jaxpr, input avals)``
  triple.
* :func:`check_completeness` perturbs every registered spec knob one at a
  time against a tiny base spec and classifies the effect.  The violation
  predicate is exact: a perturbation is a stale-reuse hazard iff it leaves
  the cache key AND the input avals unchanged while changing the
  canonical jaxpr (equal avals matter: jit's own shape-keyed trace cache
  re-traces on aval changes, so e.g. ``n_events`` is safe without a key
  entry).
* the registry is a FORCING FUNCTION: every field of every class in
  ``api.spec.SPEC_FAMILY`` (plus ``FaultSpec`` and ``TelemetryConfig``)
  must carry either a perturbation or an explicit skip-with-reason;
  an unregistered field fails the check, so a knob added by a later PR
  cannot silently dodge coverage.
* :func:`check_retrace_budget` captures a representative spec matrix and
  gates the number of distinct ``cached_program`` builds (and asserts
  value-equal specs reuse one key -- the resolve-memoization contract).

CLI: ``python -m repro.staticcheck.cachekey`` (CI: static-analysis lane).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.api.run import run
from repro.api.spec import (SPEC_FAMILY, DelaySpec, ExecutionSpec,
                            ExperimentSpec, PolicyGridSpec, ProblemSpec,
                            SolverSpec, TopologySpec)
from repro.faults.spec import FaultSpec
from repro.sweep import cache as _cache
from repro.telemetry.accumulators import TelemetryConfig

from . import jaxpr as _jaxpr

__all__ = ["ProgramCapture", "Captured", "capture", "BASES", "REGISTRY",
           "Perturb", "Skip", "Outcome", "check_completeness",
           "check_retrace_budget", "strip_faults_from_key",
           "RETRACE_BUDGET", "REPRESENTATIVE", "main"]


# ------------------------------------------------------------- capture ----

class ProgramCapture(Exception):
    """Abort signal carrying one intercepted ``cached_program`` dispatch."""

    def __init__(self, key, closed):
        self.key = key
        self.closed = closed
        super().__init__("cached_program dispatch captured")


@dataclasses.dataclass(frozen=True)
class Captured:
    """What one dispatch looked like: the cache key, the program's input
    avals, and the canonical-jaxpr fingerprint (lines kept for diffs)."""

    key: Any
    in_avals: Tuple[str, ...]
    out_avals: Tuple[str, ...]
    fingerprint: str
    lines: Tuple[str, ...]

    def jaxpr_equal(self, other: "Captured") -> bool:
        return self.fingerprint == other.fingerprint


def capture(spec: ExperimentSpec,
            key_filter: Optional[Callable[[tuple], tuple]] = None
            ) -> Optional[Captured]:
    """Trace the first sweep program ``api.run(spec)`` would dispatch.

    Returns ``None`` when the run never consults ``cached_program``
    (solo backend, federated ``reference=True``) -- those paths build
    fresh per call, so they cannot serve a stale executable; the run then
    executes for real (keep such specs tiny).

    ``key_filter`` post-processes the observed cache key before it is
    recorded -- the seam the seeded-mutation self-test uses to simulate
    "someone removed knob X from the key".
    """

    def hook(key, build):
        fn = build()

        def tracer(*args, **kwargs):
            closed = jax.make_jaxpr(fn)(*args, **kwargs)
            raise ProgramCapture(
                key if key_filter is None else key_filter(key), closed)

        return tracer

    prev = _cache.set_capture_hook(hook)
    try:
        try:
            run(spec)
        except ProgramCapture as pc:
            return Captured(
                key=pc.key,
                in_avals=tuple(a.str_short() for a in pc.closed.in_avals),
                out_avals=tuple(a.str_short() for a in pc.closed.out_avals),
                fingerprint=_jaxpr.fingerprint(pc.closed),
                lines=tuple(_jaxpr.canonical_lines(pc.closed)))
        return None
    finally:
        _cache.set_capture_hook(prev)


def strip_faults_from_key(key: tuple) -> tuple:
    """The seeded mutation: drop the ``FaultSpec`` element from a cache
    key, simulating a runner that forgot to thread ``faults`` through --
    under this filter :func:`check_completeness` MUST report violations."""
    return tuple(el for el in key if not isinstance(el, FaultSpec))


# ---------------------------------------------------------- base specs ----

_TINY_PROBLEM = dict(n_samples=48, dim=6, seed=0)


def base_spec(solver: str = "piag", **over) -> ExperimentSpec:
    """A deliberately tiny spec: 3 workers, 6 dims, 12 events, horizon 32
    -- cheap to trace, yet it exercises the same cache-key construction
    as a production sweep."""
    fed = solver in ("fedasync", "fedbuff")
    fields: Dict[str, Any] = dict(
        problem=ProblemSpec(kind="logreg", params=dict(_TINY_PROBLEM)),
        solver=SolverSpec(name=solver, horizon=32, m=3),
        topology=TopologySpec(kind="edge" if fed else "standard",
                              names=None if fed else ("uniform",),
                              n_workers=(3,)),
        policies=PolicyGridSpec(names=("adaptive1",), seeds=(0,)),
        delay=DelaySpec(measure=False),
        execution=ExecutionSpec(backend="batched"),
        n_events=12,
        validate_horizon=False,
    )
    fields.update(over)
    return ExperimentSpec(**fields)


_FAULTED = FaultSpec(p_crash=0.05, p_spike=0.1, p_drop=0.1, p_dup=0.05,
                     p_corrupt=0.05, seed=0)

# named bases so registry entries (and reports) reference them by string
BASES: Dict[str, Callable[[], ExperimentSpec]] = {
    "piag": lambda: base_spec("piag"),
    "bcd": lambda: base_spec("bcd"),
    "fedasync": lambda: base_spec("fedasync"),
    "fedbuff": lambda: base_spec("fedbuff"),
    "piag/faulted": lambda: base_spec("piag", faults=_FAULTED),
    "piag/telemetry": lambda: base_spec(
        "piag", execution=ExecutionSpec(backend="batched", telemetry=True)),
    "piag/sharded": lambda: base_spec(
        "piag", execution=ExecutionSpec(backend="sharded")),
}


# ------------------------------------------------------------ registry ----

@dataclasses.dataclass(frozen=True)
class Perturb:
    """One knob perturbation: run ``apply(BASES[base]())`` and compare the
    captured (key, avals, jaxpr) against the base capture."""

    base: str
    apply: Callable[[ExperimentSpec], ExperimentSpec]


@dataclasses.dataclass(frozen=True)
class Skip:
    """Explicit opt-out; the reason is part of the report (and the point:
    a skip must argue why the knob cannot cause stale reuse)."""

    reason: str


def _re(spec: ExperimentSpec, **kw) -> ExperimentSpec:
    return spec.replace(**kw)


def _sub(attr: str):
    def inner(spec: ExperimentSpec, **kw) -> ExperimentSpec:
        return spec.replace(
            **{attr: dataclasses.replace(getattr(spec, attr), **kw)})
    return inner


_ex, _sv, _tp, _dl, _pg, _pb = (_sub("execution"), _sub("solver"),
                                _sub("topology"), _sub("delay"),
                                _sub("policies"), _sub("problem"))


def _fl(spec: ExperimentSpec, **kw) -> ExperimentSpec:
    return spec.replace(faults=dataclasses.replace(spec.faults, **kw))


_COMPOUND = Skip("compound spec object; its fields are enumerated "
                 "individually below")

REGISTRY: Dict[Tuple[str, str], Any] = {
    # ExperimentSpec ----------------------------------------------------
    ("ExperimentSpec", "problem"): _COMPOUND,
    ("ExperimentSpec", "solver"): _COMPOUND,
    ("ExperimentSpec", "topology"): _COMPOUND,
    ("ExperimentSpec", "policies"): _COMPOUND,
    ("ExperimentSpec", "delay"): _COMPOUND,
    ("ExperimentSpec", "execution"): _COMPOUND,
    ("ExperimentSpec", "faults"): Skip(
        "compound FaultSpec; fields enumerated individually (it rides "
        "every key by value -- frozen hashable dataclass)"),
    ("ExperimentSpec", "n_events"): Perturb(
        "piag", lambda s: _re(s, n_events=24)),
    ("ExperimentSpec", "grid"): Skip(
        "prebuilt-SweepGrid escape hatch: its service times / policy "
        "params are traced program INPUTS, and captured worker data is "
        "identity-keyed (IdKey) -- a different grid object never aliases "
        "a cached program's captures"),
    ("ExperimentSpec", "validate_horizon"): Skip(
        "resolve-time validation toggle; raises or passes before any "
        "program is built, never reaches the traced program"),
    # ProblemSpec -------------------------------------------------------
    ("ProblemSpec", "kind"): Perturb(
        "piag", lambda s: _pb(s, kind="lasso")),
    ("ProblemSpec", "params"): Perturb(
        "piag", lambda s: _pb(s, params=dict(_TINY_PROBLEM, seed=1))),
    ("ProblemSpec", "prox"): Perturb(
        "piag", lambda s: _pb(s, prox="l2", prox_params=dict(lam=0.01))),
    ("ProblemSpec", "prox_params"): Perturb(
        "piag", lambda s: _pb(s, prox_params=dict(lam=0.05))),
    ("ProblemSpec", "problem"): Skip(
        "prebuilt-object escape hatch; the object itself is captured and "
        "identity-keyed (IdKey) through the runner pieces"),
    ("ProblemSpec", "prox_op"): Skip(
        "prebuilt-object escape hatch; identity-keyed like `problem`"),
    # SolverSpec --------------------------------------------------------
    ("SolverSpec", "name"): Perturb(
        "piag", lambda s: _sv(s, name="bcd")),
    ("SolverSpec", "horizon"): Perturb(
        "piag", lambda s: _sv(s, horizon=64)),
    ("SolverSpec", "m"): Perturb(
        "bcd", lambda s: _sv(s, m=2)),
    ("SolverSpec", "eta"): Perturb(
        "fedbuff", lambda s: _sv(s, eta=0.5)),
    ("SolverSpec", "buffer_size"): Perturb(
        "fedbuff", lambda s: _sv(s, buffer_size=2)),
    ("SolverSpec", "local_lr"): Perturb(
        "fedbuff", lambda s: _sv(s, local_lr=0.05)),
    ("SolverSpec", "n_steps"): Perturb(
        "fedasync", lambda s: _sv(s, n_steps=40)),
    # TopologySpec ------------------------------------------------------
    ("TopologySpec", "kind"): Skip(
        "selects the worker/client factory family; reaches the program "
        "only through sampled service-time VALUES (traced inputs) and the "
        "width axis, both covered by `names` / `n_workers`"),
    ("TopologySpec", "names"): Perturb(
        "piag", lambda s: _tp(s, names=("hetero2",))),
    ("TopologySpec", "n_workers"): Perturb(
        "piag", lambda s: _tp(s, n_workers=(4,))),
    ("TopologySpec", "seed"): Perturb(
        "piag", lambda s: _tp(s, seed=1)),
    ("TopologySpec", "params"): Skip(
        "forwarded to the topology factory; like `seed`, it only changes "
        "sampled service-time values (traced inputs), never the program"),
    ("TopologySpec", "topologies"): Skip(
        "custom escape hatch (concrete worker lists / factories); "
        "service-time values only, as above"),
    # DelaySpec ---------------------------------------------------------
    ("DelaySpec", "use_tau_max"): Perturb(
        "piag", lambda s: _dl(s, use_tau_max=False)),
    ("DelaySpec", "expected_max_delay"): Perturb(
        "piag", lambda s: _dl(s, expected_max_delay=20)),
    ("DelaySpec", "measure"): Perturb(
        "piag", lambda s: _dl(s, measure=True)),
    ("DelaySpec", "horizon_slack"): Perturb(
        "piag", lambda s: _dl(s, horizon_slack=2)),
    # PolicyGridSpec ----------------------------------------------------
    ("PolicyGridSpec", "names"): Perturb(
        "piag", lambda s: _pg(s, names=("adaptive2",))),
    ("PolicyGridSpec", "seeds"): Perturb(
        "piag", lambda s: _pg(s, seeds=(0, 1))),
    ("PolicyGridSpec", "gamma_prime"): Perturb(
        "piag", lambda s: _pg(s, gamma_prime=0.5)),
    ("PolicyGridSpec", "tau_bound"): Perturb(
        "piag", lambda s: _pg(s, tau_bound=8)),
    ("PolicyGridSpec", "policy_kwargs"): Skip(
        "forwarded to policy constructors; lands in PolicyParams, which "
        "are traced program inputs (the fused select chain dispatches on "
        "a traced policy id, not on the program structure)"),
    ("PolicyGridSpec", "policies"): Skip(
        "prebuilt-StepsizePolicy escape hatch; params are traced inputs "
        "as above"),
    # ExecutionSpec -----------------------------------------------------
    ("ExecutionSpec", "backend"): Perturb(
        "piag", lambda s: _ex(s, backend="solo")),
    ("ExecutionSpec", "devices"): Perturb(
        "piag/sharded", lambda s: _ex(s, backend="sharded", devices=1)),
    ("ExecutionSpec", "mesh"): Skip(
        "prebuilt-Mesh escape hatch; meshes ride the sharded cache keys by "
        "TOPOLOGY (repro.mesh.mesh_topology: axis names + shape + device "
        "kind + process count), so any mesh with a different topology keys "
        "fresh while same-topology meshes deliberately share the executable "
        "(cells are placement-agnostic)"),
    # a (1, 1) grid mesh works on the single-device static-analysis lane:
    # the psum over a size-1 "data" axis is still a distinct jaxpr AND a
    # distinct mesh_topology (axes/shape change), so the key must move
    ("ExecutionSpec", "mesh_shape"): Perturb(
        "piag/sharded", lambda s: _ex(s, backend="sharded",
                                      mesh_shape=(1, 1))),
    ("ExecutionSpec", "coordinator"): Skip(
        "multi-host bootstrap address, consumed ONCE by "
        "jax.distributed.initialize before the mesh is built; it never "
        "reaches a traced program, and the resulting process count rides "
        "every sharded cache key via mesh_topology"),
    ("ExecutionSpec", "num_processes"): Skip(
        "multi-host process-grid size, consumed by "
        "jax.distributed.initialize only; the live process count is keyed "
        "via mesh_topology, so a different world size keys fresh"),
    ("ExecutionSpec", "process_id"): Skip(
        "selects THIS host's slot in the process grid at initialize time; "
        "never reaches a traced program and must NOT key programs (every "
        "process must build the same executable for the same spec)"),
    # padding a 3-worker grid to width-4 buckets needs 4 rows of worker
    # data, so the problem is widened alongside (both changes ride the key)
    ("ExecutionSpec", "bucket_widths"): Perturb(
        "piag", lambda s: _ex(
            _pb(s, params=dict(_TINY_PROBLEM, n_workers=4)),
            bucket_widths=(4,))),
    ("ExecutionSpec", "reference"): Perturb(
        "fedasync", lambda s: _ex(s, reference=True)),
    ("ExecutionSpec", "record_every"): Perturb(
        "piag", lambda s: _ex(s, record_every=2)),
    ("ExecutionSpec", "telemetry"): Perturb(
        "piag", lambda s: _ex(s, telemetry=True)),
    ("ExecutionSpec", "telemetry_bins"): Perturb(
        "piag/telemetry", lambda s: _ex(s, telemetry=True,
                                        telemetry_bins=8)),
    ("ExecutionSpec", "engine"): Perturb(
        "piag", lambda s: _ex(s, engine="fused")),
    # FaultSpec ---------------------------------------------------------
    ("FaultSpec", "p_crash"): Perturb(
        "piag/faulted", lambda s: _fl(s, p_crash=0.2)),
    ("FaultSpec", "p_rejoin"): Perturb(
        "piag/faulted", lambda s: _fl(s, p_rejoin=0.5)),
    ("FaultSpec", "crash_scale"): Perturb(
        "piag/faulted", lambda s: _fl(s, crash_scale=10.0)),
    ("FaultSpec", "p_spike"): Perturb(
        "piag/faulted", lambda s: _fl(s, p_spike=0.3)),
    ("FaultSpec", "spike_scale"): Perturb(
        "piag/faulted", lambda s: _fl(s, spike_scale=4.0)),
    ("FaultSpec", "spike_tail"): Perturb(
        "piag/faulted", lambda s: _fl(s, spike_tail=2.0)),
    ("FaultSpec", "p_drop"): Perturb(
        "piag/faulted", lambda s: _fl(s, p_drop=0.3)),
    ("FaultSpec", "p_dup"): Perturb(
        "piag/faulted", lambda s: _fl(s, p_dup=0.2)),
    ("FaultSpec", "p_corrupt"): Perturb(
        "piag/faulted", lambda s: _fl(s, p_corrupt=0.2)),
    ("FaultSpec", "corrupt_mode"): Perturb(
        "piag/faulted", lambda s: _fl(s, corrupt_mode="inf")),
    ("FaultSpec", "guard_nonfinite"): Perturb(
        "piag/faulted", lambda s: _fl(s, guard_nonfinite=False)),
    ("FaultSpec", "staleness_cutoff"): Perturb(
        "piag/faulted", lambda s: _fl(s, staleness_cutoff=8)),
    ("FaultSpec", "degrade_on_clip"): Perturb(
        "piag/faulted", lambda s: _fl(s, degrade_on_clip=False)),
    ("FaultSpec", "seed"): Perturb(
        "piag/faulted", lambda s: _fl(s, seed=1)),
    ("FaultSpec", "enabled"): Perturb(
        "piag/faulted", lambda s: _fl(s, enabled=False)),
    # TelemetryConfig ---------------------------------------------------
    ("TelemetryConfig", "delay_bins"): Perturb(
        "piag/telemetry", lambda s: _ex(s, telemetry=True,
                                        telemetry_bins=16)),
}

# the classes whose fields the forcing function enumerates
_ENUMERATED = tuple(SPEC_FAMILY) + (FaultSpec, TelemetryConfig)


def unregistered_fields() -> List[Tuple[str, str]]:
    """Spec-family fields with neither a perturbation nor a skip -- the
    forcing function's output; non-empty fails the check."""
    missing = []
    for cls in _ENUMERATED:
        for f in dataclasses.fields(cls):
            if (cls.__name__, f.name) not in REGISTRY:
                missing.append((cls.__name__, f.name))
    return missing


# ------------------------------------------------------- completeness ----

@dataclasses.dataclass(frozen=True)
class Outcome:
    """The classified effect of one knob perturbation."""

    cls: str
    field: str
    base: str
    status: str  # key-changed | value-only | shape-retrace | uncached |
    #              skip | VIOLATION
    detail: str = ""

    @property
    def violation(self) -> bool:
        return self.status == "VIOLATION"


def _classify(name: Tuple[str, str], base_name: str, a: Optional[Captured],
              b: Optional[Captured]) -> Outcome:
    cls, field = name
    if a is None or b is None:
        which = [w for w, c in (("base", a), ("perturbed", b)) if c is None]
        return Outcome(cls, field, base_name, "uncached",
                       f"{'/'.join(which)} run never consulted "
                       "cached_program (solo / heapq reference path: "
                       "built fresh per call, no stale-reuse surface)")
    key_same = a.key == b.key
    avals_same = a.in_avals == b.in_avals
    jaxpr_same = a.jaxpr_equal(b)
    if jaxpr_same:
        return Outcome(cls, field, base_name, "value-only",
                       "program unchanged (knob reaches it as a traced "
                       "value, or not at all)"
                       + ("" if key_same else "; key changed anyway"))
    if not key_same:
        return Outcome(cls, field, base_name, "key-changed",
                       "program changed and so did the cache key")
    if not avals_same:
        return Outcome(cls, field, base_name, "shape-retrace",
                       "program changed under the SAME key, but input "
                       "avals changed too -- jit's shape-keyed trace "
                       "cache re-traces, no stale reuse")
    return Outcome(cls, field, base_name, "VIOLATION",
                   "canonical jaxpr changed while cache key AND input "
                   "avals stayed equal -- cached_program would serve the "
                   "stale executable")


def check_completeness(
        key_filter: Optional[Callable[[tuple], tuple]] = None,
        only: Optional[List[Tuple[str, str]]] = None) -> List[Outcome]:
    """Run every registered perturbation and classify it; see module
    docstring for the violation predicate.  ``only`` restricts to a subset
    of ``(class, field)`` names (tests); ``key_filter`` simulates a key
    mutation (the self-test seam)."""
    missing = unregistered_fields()
    if missing and only is None:
        raise AssertionError(
            "spec knobs with no cache-key coverage registered in "
            f"repro.staticcheck.cachekey.REGISTRY: {missing}.  Register a "
            "Perturb (or an explicit Skip with a reason) for each.")
    base_caps: Dict[str, Optional[Captured]] = {}
    outcomes: List[Outcome] = []
    for name, entry in REGISTRY.items():
        if only is not None and name not in only:
            continue
        if isinstance(entry, Skip):
            outcomes.append(Outcome(name[0], name[1], "-", "skip",
                                    entry.reason))
            continue
        if entry.base not in base_caps:
            base_caps[entry.base] = capture(BASES[entry.base](),
                                            key_filter=key_filter)
        a = base_caps[entry.base]
        b = capture(entry.apply(BASES[entry.base]()), key_filter=key_filter)
        outcomes.append(_classify(name, entry.base, a, b))
    return outcomes


# ----------------------------------------------------- retrace budget ----

# the representative matrix CI counts distinct cached_program builds over;
# entries are (label, spec builder) -- note the deliberate duplicate of the
# plain piag spec, asserting value-equal specs land on ONE key
REPRESENTATIVE: List[Tuple[str, Callable[[], ExperimentSpec]]] = [
    ("piag", BASES["piag"]),
    ("piag (repeat)", BASES["piag"]),
    ("piag telemetry", BASES["piag/telemetry"]),
    ("piag record_every=2",
     lambda: base_spec("piag",
                       execution=ExecutionSpec(backend="batched",
                                               record_every=2))),
    ("bcd", BASES["bcd"]),
    ("fedasync", BASES["fedasync"]),
    ("fedbuff", BASES["fedbuff"]),
    # 2-D mesh representative: (1, 1) builds on one device; the psum'd
    # gradient and the reshaped mesh_topology make this a distinct program
    # from the plain sharded base by design
    ("piag sharded 2-D mesh",
     lambda: base_spec("piag",
                       execution=ExecutionSpec(backend="sharded",
                                               mesh_shape=(1, 1)))),
]

# exact number of distinct (key, in_avals) programs the matrix may build;
# raising it needs a deliberate edit here (a retrace regression otherwise)
# 6 -> 7: the 2-D (cells, data) mesh representative compiles its own
# program (pmean_grad psum + distinct mesh_topology key) -- intentional
RETRACE_BUDGET = 7


def check_retrace_budget() -> Tuple[int, List[str]]:
    """Capture the representative matrix; return (distinct program count,
    failure messages).  Failures: budget exceeded, or a repeated
    value-equal spec failing to reuse its key (a resolve-memoization
    regression -- api.run's memos must hand the cache identical captured
    objects)."""
    captures = [(label, capture(build())) for label, build in REPRESENTATIVE]
    errors: List[str] = []
    seen: Dict[Any, str] = {}
    for label, cap in captures:
        if cap is None:
            errors.append(f"{label}: unexpectedly uncached")
            continue
        seen.setdefault((cap.key, cap.in_avals), label)
    distinct = len(seen)
    by_label = dict(captures)
    a, b = by_label.get("piag"), by_label.get("piag (repeat)")
    if a is None or b is None or a.key != b.key:
        errors.append(
            "value-equal piag specs produced DIFFERENT cache keys -- the "
            "resolve memoization (api.run _PROBLEM_MEMO/_PIECES_MEMO) is "
            "no longer handing cached_program identical captured objects")
    if distinct > RETRACE_BUDGET:
        errors.append(
            f"representative matrix built {distinct} distinct programs > "
            f"budget {RETRACE_BUDGET}; if the growth is intentional, raise "
            "RETRACE_BUDGET in repro/staticcheck/cachekey.py")
    return distinct, errors


# ----------------------------------------------------------------- CLI ----

def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.staticcheck.cachekey",
        description="cache-key completeness + retrace-budget checks")
    p.add_argument("--verbose", action="store_true",
                   help="print every outcome, not just failures")
    args = p.parse_args(argv)

    outcomes = check_completeness()
    violations = [o for o in outcomes if o.violation]
    counts: Dict[str, int] = {}
    for o in outcomes:
        counts[o.status] = counts.get(o.status, 0) + 1
    print("cache-key completeness:",
          ", ".join(f"{v} {k}" for k, v in sorted(counts.items())))
    for o in outcomes:
        if args.verbose or o.violation:
            print(f"  [{o.status}] {o.cls}.{o.field} (base {o.base}): "
                  f"{o.detail}")

    distinct, errors = check_retrace_budget()
    print(f"retrace budget: {distinct} distinct programs "
          f"(budget {RETRACE_BUDGET})")
    for e in errors:
        print(f"  [FAIL] {e}")

    ok = not violations and not errors
    print("cachekey:", "OK" if ok else "FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
