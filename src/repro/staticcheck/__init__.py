"""Static analysis for the async-sweep engine (`repro.staticcheck`).

Three layers, each turning a prose contract from ROADMAP's durable notes
into a machine check:

* :mod:`repro.staticcheck.jaxpr` -- canonicalize ``ClosedJaxpr``s
  (alpha-rename, source-info-free, param-sorted) so two traces can be
  structurally diffed; the substrate for the other layers.
* :mod:`repro.staticcheck.contracts` -- jaxpr contract verifier: disabled
  faults are bitwise the ``faults=None`` program, feature knobs actually
  change the trace when enabled, ``engine='fused'`` and ``'scan'`` agree on
  input/output avals; across solvers and backends.
* :mod:`repro.staticcheck.cachekey` -- cache-key completeness: perturb
  every spec knob one at a time and assert that any perturbation changing
  the canonical jaxpr also changes the ``sweep.cache`` key (the
  stale-executable-reuse bug class), plus a retrace-budget gate.
* :mod:`repro.staticcheck.lint` / ``rules`` -- trace-safety AST lint
  (``python -m repro.staticcheck.lint src/``) with repo-specific rules
  distilled from historical bugs, each backed by a known-bad fixture under
  ``staticcheck/fixtures/``.

``python -m repro.staticcheck`` runs the dynamic layers (contracts +
completeness + retrace budget); the lint CLI is its own module so it stays
importable without jax.
"""
from __future__ import annotations

__all__ = ["jaxpr", "contracts", "cachekey", "lint", "rules"]
