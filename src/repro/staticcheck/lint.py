"""Trace-safety lint CLI: ``python -m repro.staticcheck.lint src/``.

Runs every rule in :mod:`repro.staticcheck.rules` over the given files or
directories (``.py`` files, recursively).  The known-bad fixture corpus
under ``staticcheck/fixtures/`` is excluded by default -- those files
exist to PROVE each rule fires (see ``tests/test_staticcheck_lint.py``)
and must not fail the tree's own lint; pass ``--include-fixtures`` to
lint them anyway.

Exit status: 0 when clean, 1 when any finding (or a file fails to parse).
Pure AST analysis: no jax import, no execution of the linted code.
"""
from __future__ import annotations

import ast
import os
import sys
from typing import Iterable, List, Optional, Sequence

from .rules import ALL_RULES, Finding, ModuleInfo, Rule

__all__ = ["lint_file", "lint_paths", "iter_py", "main"]


def _rules_for(select: Optional[Sequence[str]]) -> Sequence[Rule]:
    if not select:
        return ALL_RULES
    wanted = {s.upper() for s in select}
    unknown = wanted - {r.name for r in ALL_RULES}
    if unknown:
        raise SystemExit(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                         f"known: {', '.join(r.name for r in ALL_RULES)}")
    return [r for r in ALL_RULES if r.name in wanted]


def lint_file(path: str,
              select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one python file; a syntax error is itself reported as a
    finding (rule ``PARSE``) rather than crashing the run."""
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, e.offset or 0, "PARSE",
                        f"syntax error: {e.msg}")]
    info = ModuleInfo(tree, path)
    findings: List[Finding] = []
    for rule in _rules_for(select):
        findings.extend(rule.check(info))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def _is_fixture_dir(dirpath: str) -> bool:
    parts = os.path.normpath(dirpath).split(os.sep)
    return "fixtures" in parts and "staticcheck" in parts


def iter_py(paths: Iterable[str],
            include_fixtures: bool = False) -> Iterable[str]:
    """Yield ``.py`` files under ``paths`` (files pass through verbatim);
    ``staticcheck/fixtures/`` trees are skipped unless requested."""
    for path in paths:
        if os.path.isfile(path):
            yield path
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__",))
            if not include_fixtures and _is_fixture_dir(dirpath):
                continue
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None,
               include_fixtures: bool = False) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py(paths, include_fixtures=include_fixtures):
        findings.extend(lint_file(f, select=select))
    return findings


def main(argv=None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.staticcheck.lint",
        description="trace-safety lint for the async-sweep engine")
    p.add_argument("paths", nargs="+",
                   help=".py files or directories to lint")
    p.add_argument("--select", action="append", default=None,
                   metavar="RULE",
                   help="run only these rule IDs (repeatable)")
    p.add_argument("--include-fixtures", action="store_true",
                   help="also lint staticcheck/fixtures/ (known-bad corpus)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    args = p.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.name}: {r.doc}")
        return 0

    findings = lint_paths(args.paths, select=args.select,
                          include_fixtures=args.include_fixtures)
    for f in findings:
        print(f)
    n_files = sum(1 for _ in iter_py(args.paths,
                                     include_fixtures=args.include_fixtures))
    status = "clean" if not findings else f"{len(findings)} finding(s)"
    print(f"lint: {n_files} file(s), {status}", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
