"""Sweep-level analysis (`repro.analysis`).

Per-policy aggregation, time-to-tolerance, best-fixed-vs-adaptive gaps and
clipped-horizon summaries used to be computed inline (and divergently) in
``benchmarks/sweep_grid.py``, ``benchmarks/fig5_federated.py`` and
``launch/sweep.py``.  This module is the single home for those reductions;
the benchmarks, the CLI and ``api.Results`` all route through it
(``tests/test_analysis.py`` pins the numbers against the formerly-inline
formulas on the 64-cell fast grid).

Everything operates on plain arrays + the grid's ``SweepCell`` coordinate
list, so the functions work on ``api.Results`` columns and on raw
``PIAGResult`` / ``BCDResult`` / ``FedResult`` leaves alike.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, NamedTuple, Optional

import numpy as np

__all__ = ["PolicySummary", "policy_rows", "per_policy_summary",
           "mean_final_objective", "time_to_tolerance",
           "best_fixed_vs_adaptive", "clipped_summary", "summarize",
           "delay_profile", "clip_pressure", "run_timeline"]


class PolicySummary(NamedTuple):
    """Aggregates over all cells (seeds x topologies x widths) of a policy."""

    policy: str
    n_cells: int
    mean_final: float        # mean final objective
    min_final: float         # best final objective
    mean_sum_gamma: float    # mean total step-size / mixing-weight budget
    clipped_cells: int       # cells with any horizon-clipped delay
    clipped_events: int      # total horizon-clipped events


def policy_rows(cells) -> Dict[str, List[int]]:
    """Cell indices grouped by policy name, in first-seen (grid) order."""
    rows: Dict[str, List[int]] = {}
    for i, c in enumerate(cells):
        rows.setdefault(c.policy_name, []).append(i)
    return rows


def per_policy_summary(cells, objective, gammas=None,
                       clipped=None) -> Dict[str, PolicySummary]:
    """The per-policy table ``launch.sweep`` prints: mean/min final
    objective, mean summed step-size, clip counts, keyed by policy name in
    grid order.

    Stride-aware by construction: final objective and clip counts are exact
    under decimated recording (the last event is always recorded and
    ``clipped`` comes from the scan carry); ``mean_sum_gamma`` sums the
    RECORDED gamma samples, i.e. ~1/s of the full-budget value at stride s
    -- comparable within a sweep, not across strides."""
    obj = np.asarray(objective)
    gam = None if gammas is None else np.asarray(gammas)
    clp = None if clipped is None else np.asarray(clipped)
    out = {}
    for pn, rows in policy_rows(cells).items():
        rows = np.asarray(rows)
        out[pn] = PolicySummary(
            policy=pn,
            n_cells=int(rows.size),
            mean_final=float(obj[rows, -1].mean()),
            min_final=float(obj[rows, -1].min()),
            mean_sum_gamma=(float(gam[rows].sum(1).mean())
                            if gam is not None else float("nan")),
            clipped_cells=(int(np.sum(clp[rows] > 0))
                           if clp is not None else 0),
            clipped_events=(int(clp[rows].sum()) if clp is not None else 0),
        )
    return out


def mean_final_objective(cells, objective) -> Dict[str, float]:
    """Mean final objective per policy (the ``benchmarks/sweep_grid.py``
    ``mean_final_objective`` payload), keyed in grid order."""
    obj = np.asarray(objective)
    return {pn: float(np.mean(obj[rows, -1]))
            for pn, rows in policy_rows(cells).items()}


def time_to_tolerance(objective, target: float, p_star: float = 0.0,
                      record_every: int = 1):
    """First event index where ``objective - p_star <= target``; -1 when
    the tolerance is never reached.

    1-D input -> int (the ``benchmarks/fig5_federated.py`` events-to-target
    metric); 2-D (B, K) input -> (B,) int array, one per cell.

    ``record_every=s`` declares the input as a DECIMATED trajectory
    (columns are events ``s-1, 2s-1, ...``, see ``ExecutionSpec``): the
    returned index is mapped back to event units, ``j*s + s - 1`` for the
    first hit column j, so thresholds stay comparable across strides (a
    decimated run can only report a hit at or after the stride-1 event).
    """
    s = int(record_every)
    if s < 1:
        raise ValueError(f"record_every must be >= 1, got {record_every}")
    sub = np.asarray(objective) - p_star
    hit = sub <= target
    if sub.ndim == 1:
        return (int(np.argmax(hit)) * s + (s - 1)) if hit.any() else -1
    first = np.argmax(hit, axis=-1) * s + (s - 1)
    return np.where(hit.any(axis=-1), first, -1).astype(np.int64)


def best_fixed_vs_adaptive(events_to_target: Mapping[str, Optional[int]],
                           fixed: Optional[Iterable[str]] = None,
                           adaptive: Optional[Iterable[str]] = None) -> dict:
    """The paper's headline derived metric: best (fewest events to the
    tolerance) fixed-family policy vs best adaptive policy.

    ``events_to_target`` maps policy name -> event count (-1 or None =
    never reached).  ``fixed`` defaults to names starting with ``"fixed"``
    plus the other worst-case-bound baselines (``sun_deng`` / ``davis`` /
    ``constant``, the non-adaptive families of ``core.stepsize``);
    ``adaptive`` defaults to every other name.  Returns ``best_fixed``,
    ``best_adaptive`` (-1 = never) and ``speedup`` (fixed / adaptive; None
    unless both reached the tolerance).
    """
    names = list(events_to_target)
    fixed = set(fixed) if fixed is not None \
        else {n for n in names
              if n.startswith("fixed") or n in ("sun_deng", "davis",
                                                "constant")}
    adaptive = set(adaptive) if adaptive is not None \
        else set(names) - fixed

    def best(group):
        vals = [int(events_to_target[n]) for n in names
                if n in group and events_to_target[n] is not None
                and int(events_to_target[n]) >= 0]
        return min(vals, default=-1)

    bf, ba = best(fixed), best(adaptive)
    speedup = (bf / ba) if bf > 0 and ba > 0 else None
    return {"best_fixed": bf, "best_adaptive": ba, "speedup": speedup}


def clipped_summary(clipped) -> dict:
    """Horizon-clipping across a sweep: how many cells silently truncated
    window sums (delay > H - 1) and how badly.  ``cells_clipped > 0`` means
    the horizon was undersized for some cells -- raise it."""
    clp = np.asarray(clipped)
    return {
        "cells": int(clp.size),
        "cells_clipped": int(np.sum(clp > 0)),
        "events_clipped": int(clp.sum()),
        "max_events_clipped": int(clp.max()) if clp.size else 0,
    }


def summarize(results) -> Dict[str, PolicySummary]:
    """Per-policy aggregation straight off an ``api.Results`` table."""
    return per_policy_summary(results.cells, results.objective,
                              results.gammas, results.clipped)


# ------------------------------------------------ telemetry bridges ----

def delay_profile(results) -> dict:
    """The run's delay distribution off an ``api.Results`` table (or its
    ``RunRecord``): histogram (last bin = overflow bucket when the source
    is the in-scan accumulator), tau min/max/mean/std, and the source tag
    (``"accumulator"`` = exact over every event; ``"recorded"`` = binned
    from the recorded 1/s sample)."""
    rec = getattr(results, "telemetry", results)
    hist = [int(h) for h in _rec_get(rec, "delay_hist")]
    return {
        "hist": hist,
        "count": int(sum(hist)),
        "tau": dict(_rec_get(rec, "tau_stats")),
        "gamma": dict(_rec_get(rec, "gamma_stats")),
        "source": _rec_get(rec, "hist_source"),
    }


def clip_pressure(results) -> dict:
    """Horizon-clip pressure with the run's horizon attached: the
    ``clipped_summary`` block plus ``horizon`` and the fraction of events
    clipped, off an ``api.Results`` table or a ledger record."""
    rec = getattr(results, "telemetry", results)
    clip = dict(_rec_get(rec, "clipped"))
    total = int(_rec_get(rec, "n_cells")) * int(_rec_get(rec, "n_events"))
    clip["horizon"] = _rec_get(rec, "horizon")
    clip["clip_fraction"] = (clip.get("events_clipped", 0) / total
                             if total else 0.0)
    return clip


def run_timeline(records) -> List[dict]:
    """Chronological per-run timing rows from a ledger: pass an iterable of
    record dicts / ``RunRecord`` objects, or a ledger file path.  Each row
    carries the compile/warm split and the cache delta, so a sequence of
    runs shows cache warm-up as compile-ms collapsing to ~0."""
    if isinstance(records, (str, bytes)) or hasattr(records, "__fspath__"):
        from repro.telemetry.ledger import read_ledger
        records = read_ledger(records)
    rows = [{
        "ts": _rec_get(r, "ts"),
        "fingerprint": _rec_get(r, "fingerprint"),
        "solver": _rec_get(r, "solver"),
        "backend": _rec_get(r, "backend"),
        "n_cells": _rec_get(r, "n_cells"),
        "elapsed_ms": _rec_get(r, "elapsed_ms"),
        "compile_ms": _rec_get(r, "compile_ms"),
        "warm_ms": _rec_get(r, "warm_ms"),
        "cache": _rec_get(r, "cache"),
    } for r in records]
    rows.sort(key=lambda row: row["ts"])
    return rows


def _rec_get(rec, field):
    """Field access across the three record shapes analysis accepts:
    ``RunRecord`` dataclasses, raw ledger dicts, and ``Results`` proxies."""
    if isinstance(rec, dict):
        return rec[field]
    return getattr(rec, field)
