"""Batched sweep engine benchmark: the Fig. 2/3 policy-comparison grid at
~100x the per-figure cell count, batched vs the status-quo Python loop.

The looped baseline is exactly what ``fig2_piag.py`` does per cell today --
a Python ``heapq`` trace simulation plus a ``run_piag_logreg`` call that
re-traces and re-compiles -- repeated for every (policy, seed, topology)
cell.  The batched path runs the SAME cells (same service-time matrices,
same policies) as one ``vmap``'d XLA program: jitted trace generation
composed with the PIAG scan, one compile for the whole grid.

Emits ``BENCH_sweep_grid.json`` with wall-clock for both paths, the
speedup, and an equivalence spot-check of sampled rows against solo runs.

    PYTHONPATH=src python -m benchmarks.sweep_grid [--events N] [--seeds N]
        [--workers N] [--loop-cells N] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import mean_final_objective
from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1,
                        SunDengFixed, make_logreg, run_piag_logreg,
                        simulate_parameter_server)
from repro.sweep import (make_grid, make_sweep_piag, measure_tau_bar,
                         standard_topologies)

from .common import emit


def build_grid(n_workers: int, n_seeds: int, n_events: int, gp: float):
    """policies x seeds x topologies; fixed baselines tuned from the
    worst-case bound tau-bar measured over the whole grid's traces (the
    paper's protocol for the fixed step-size)."""
    seeds = list(range(n_seeds))
    topos = standard_topologies(n_workers)
    tau_bar = measure_tau_bar(topos, seeds, n_events)
    policies = {
        "adaptive1": Adaptive1(gamma_prime=gp, alpha=0.9),
        "adaptive2": Adaptive2(gamma_prime=gp),
        "fixed": FixedStepSize(gamma_prime=gp, tau_bound=tau_bar),
        "fixed_sun_deng": SunDengFixed(gamma_prime=gp, tau_bound=tau_bar),
    }
    return make_grid(policies, seeds, topos, n_events), tau_bar


def run(n_events: int = 800, n_seeds: int = 4, n_workers: int = 8,
        loop_cells: int | None = None, out: str = "BENCH_sweep_grid.json") -> dict:
    prob = make_logreg(800, 100, n_workers=n_workers, seed=0)
    gp = 0.99 / prob.L
    prox = L1(lam=prob.lam1)
    grid, tau_bar = build_grid(n_workers, n_seeds, n_events, gp)
    B = len(grid)
    emit("sweep_grid/config", 0.0,
         f"cells={B};events={n_events};workers={n_workers};tau_bar={tau_bar}")

    # ---- batched path: one program for the whole grid --------------------
    # the stacked service-time tensor is DONATED (its buffer reused in
    # place) on accelerator backends, so each timed call re-uploads from
    # the host copy -- the pattern the sweep runners use, keeping peak
    # memory flat at dispatch (donation is a warning-only no-op on CPU)
    Aw, bw = prob.worker_slices()
    x0 = jnp.zeros((prob.dim,), jnp.float32)
    fn = make_sweep_piag(lambda x, A, b: prob.worker_loss(x, A, b), x0,
                         (Aw, bw), prox, objective=prob.P,
                         donate=jax.default_backend() != "cpu")
    T_np = grid.service_times()
    params = grid.policy_params()

    t0 = time.perf_counter()
    res = jax.block_until_ready(fn(jnp.asarray(T_np), params))
    batched_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = jax.block_until_ready(fn(jnp.asarray(T_np), params))
    batched_warm = time.perf_counter() - t0
    emit("sweep_grid/batched", batched_cold * 1e6,
         f"warm_us={batched_warm * 1e6:.1f};cells={B}")

    # ---- looped status quo: heapq trace + fresh jit per cell -------------
    # subsampled cells are spread across the whole grid (linspace over cell
    # indices) so every policy family is both timed and equivalence-checked
    n_loop = B if loop_cells is None else min(loop_cells, B)
    loop_idx = np.unique(np.linspace(0, B - 1, n_loop).round().astype(int))
    t0 = time.perf_counter()
    loop_obj = {}
    for i in loop_idx:
        c = grid.cells[i]
        tr = simulate_parameter_server(n_workers, n_events, list(c.workers),
                                       seed=c.seed, service_times=T_np[i])
        solo = run_piag_logreg(prob, tr, c.policy, prox)
        loop_obj[int(i)] = np.asarray(solo.objective)
    loop_s = (time.perf_counter() - t0) * (B / len(loop_idx))
    emit("sweep_grid/looped", loop_s * 1e6,
         f"cells_run={len(loop_idx)};scaled_to={B}")

    speedup_cold = loop_s / batched_cold
    speedup_warm = loop_s / batched_warm
    emit("sweep_grid/speedup", 0.0,
         f"cold={speedup_cold:.1f}x;warm={speedup_warm:.1f}x")

    # ---- equivalence spot-check on the rows the loop already ran ---------
    atol = 16 * float(np.spacing(np.float32(gp)))
    max_obj = 0.0
    for i, obj_i in loop_obj.items():
        max_obj = max(max_obj, float(np.max(np.abs(
            obj_i - np.asarray(res.objective[i])))))
    rows_ok = bool(max_obj <= 1e-4)
    emit("sweep_grid/equivalence", 0.0,
         f"rows={len(loop_obj)};max_obj_diff={max_obj:.2e};ok={rows_ok}")

    # per-policy summary: mean final objective across seeds x topologies
    # (aggregated by repro.analysis, the sweeps' shared reduction layer)
    finals = mean_final_objective(grid.cells, res.objective)
    for pn, v in finals.items():
        emit(f"sweep_grid/final_P/{pn}", 0.0, f"mean_P_final={v:.5f}")

    payload = {
        "bench": "sweep_grid",
        "cells": B,
        "n_events": n_events,
        "n_workers": n_workers,
        "tau_bar": tau_bar,
        "grid": {"policies": sorted({c.policy_name for c in grid.cells}),
                 "seeds": n_seeds,
                 "topologies": sorted({c.topology_name for c in grid.cells})},
        "loop_seconds": loop_s,
        "loop_cells_run": int(len(loop_idx)),
        "batched_seconds_cold": batched_cold,
        "batched_seconds_warm": batched_warm,
        "speedup_cold": speedup_cold,
        "speedup_warm": speedup_warm,
        "equivalence": {"rows_checked": int(len(loop_obj)),
                        "max_objective_diff": max_obj,
                        "gamma_atol_envelope": atol,
                        "ok": rows_ok},
        "mean_final_objective": finals,
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}: {B} cells, speedup cold {speedup_cold:.1f}x / "
          f"warm {speedup_warm:.1f}x, equivalence ok={rows_ok}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=800)
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--loop-cells", type=int, default=None,
                    help="run only this many looped cells and scale the "
                    "loop time linearly (CI shortcut; default: all)")
    ap.add_argument("--out", default="BENCH_sweep_grid.json")
    a = ap.parse_args()
    payload = run(n_events=a.events, n_seeds=a.seeds, n_workers=a.workers,
                  loop_cells=a.loop_cells, out=a.out)
    if not payload["equivalence"]["ok"]:
        raise SystemExit("equivalence spot-check failed")


if __name__ == "__main__":
    main()
