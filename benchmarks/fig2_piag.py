"""Paper Figure 2: PIAG convergence, delay-adaptive vs best fixed step-size
(Sun/Deng h/(L(tau+1/2))), on rcv1-like and MNIST-like synthetic data.

Derived metric: events to reach the fixed policy's final objective
(the paper reports ~2-3x fewer iterations for the adaptive policies)."""
from __future__ import annotations

import numpy as np

from repro.configs.paper_logreg import MNIST_LIKE, RCV1_LIKE
from repro.core import (Adaptive1, Adaptive2, L1, SunDengFixed,
                        run_piag_logreg, simulate_parameter_server)

from .common import emit, timeit

EVENTS = 4000


def run() -> dict:
    out = {}
    for wl in [RCV1_LIKE, MNIST_LIKE]:
        prob = wl.build(seed=0)
        trace = simulate_parameter_server(wl.n_workers, EVENTS, seed=2)
        tau_max = trace.max_delay()
        gp = 0.99 / prob.L
        prox = L1(lam=prob.lam1)
        runs = {}
        for name, pol in {
            "adaptive1": Adaptive1(gamma_prime=gp, alpha=0.9),
            "adaptive2": Adaptive2(gamma_prime=gp),
            "fixed_sun_deng": SunDengFixed(gamma_prime=gp, tau_bound=tau_max),
        }.items():
            us, res = timeit(
                lambda p=pol: run_piag_logreg(prob, trace, p, prox), repeats=1)
            obj = np.asarray(res.objective)
            runs[name] = obj
            emit(f"fig2/{wl.name}/{name}", us,
                 f"P_final={obj[-1]:.4f};max_tau={tau_max}")
        target = float(runs["fixed_sun_deng"][-1])
        for name in ["adaptive1", "adaptive2"]:
            hit = np.argmax(runs[name] <= target)
            frac = (hit / EVENTS) if runs[name][-1] <= target else 1.0
            emit(f"fig2/{wl.name}/{name}_events_to_fixed_final", 0.0,
                 f"events={int(hit)};fraction={frac:.2f}")
        out[wl.name] = runs
    return out
