"""Figure 5 (new workload): delay-adaptive vs fixed-tau-bound federated mixing.

FedAsync on the paper's logreg workload under a heterogeneous straggler
client population (4x speed spread, 5% straggler rounds, 2% dropouts).
Every policy sees the SAME event trace; the derived metric is the number of
server write events needed to reach the target suboptimality
P - P* <= 0.2 (P(x_0) - P*), with P* from the centralized FISTA reference.

The fixed family is tuned from the worst-case staleness bound tau_max the
way fixed step-sizes are tuned in the paper (alpha/(tau_max+1), plus sqrt
and 4x variants); the adaptive policies (hinge/poly) only use the measured
per-upload staleness.  A FedBuff (|R|=4) row shows the buffered semi-async
server with the same adaptive weight.

Writes the full JSON trace to BENCH_fig5_federated.json (repo root).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.analysis import best_fixed_vs_adaptive, time_to_tolerance
from repro.core import L1, make_logreg, make_policy, solve_centralized
from repro.core.stepsize import auto_horizon
from repro.federated import (heterogeneous_clients, run_fedasync_problem,
                             run_fedbuff_problem, simulate_federated)

from .common import emit, timeit

UPLOADS = 3000
N_CLIENTS = 8
ALPHA = 0.4
OUT_JSON = os.environ.get("FIG5_JSON", "BENCH_fig5_federated.json")


def run() -> dict:
    prob = make_logreg(n_samples=500, dim=50, n_workers=N_CLIENTS, seed=0)
    prox = L1(lam=prob.lam1)
    _, objs = solve_centralized(prob, prox, iters=3000)
    p_star = float(objs[-1])
    gap0 = float(prob.P(np.zeros(prob.dim, np.float32))) - p_star
    target = 0.2 * gap0

    clients = heterogeneous_clients(N_CLIENTS, spread=4.0, seed=1,
                                    p_straggle=0.05, p_dropout=0.02)
    trace = simulate_federated(N_CLIENTS, UPLOADS, clients, seed=1)
    trace_b4 = simulate_federated(N_CLIENTS, UPLOADS, clients, buffer_size=4,
                                  seed=1)
    tau_max = trace.max_delay()

    fixed = {
        "fixed_taubound": make_policy("constant", ALPHA / (tau_max + 1)),
        "fixed_taubound_sqrt": make_policy(
            "constant", ALPHA / float(np.sqrt(tau_max + 1))),
        "fixed_taubound_x4": make_policy("constant", 4 * ALPHA / (tau_max + 1)),
    }
    adaptive = {
        "hinge": make_policy("hinge", ALPHA, a=0.5, b=16.0),
        "poly": make_policy("poly", ALPHA, a=0.3),
    }

    results = {}

    def record(name, res, n_writes_per_event=1):
        sub = np.asarray(res.objective) - p_star
        hit = time_to_tolerance(res.objective, target, p_star=p_star)
        writes = hit * n_writes_per_event if hit >= 0 else -1
        results[name] = {
            "final_subopt": float(sub[-1]),
            "best_subopt": float(sub.min()),
            "events_to_target": int(hit),
            "writes_to_target": int(writes) if hit >= 0 else None,
        }
        emit(f"fig5/logreg/{name}", 0.0,
             f"final_subopt={sub[-1]:.5f};events_to_target={hit}")

    # horizon='auto': the weight-policy buffer is sized from each trace's
    # own measured staleness (bitwise-identical rows -- the tau_max above is
    # ~2 orders of magnitude below the 4096 worst-case carry these runs
    # used to pay; pinned in tests/test_engine_opt.py)
    for name, pol in {**adaptive, **fixed}.items():
        us, res = timeit(lambda p=pol: run_fedasync_problem(
            prob, trace, p, prox, local_lr=0.5 / prob.L, horizon="auto"),
            repeats=1)
        record(name, res)
        results[name]["us_per_run"] = us

    # FedBuff |R|=4 with the adaptive weight (writes = uploads / 4)
    us, res = timeit(lambda: run_fedbuff_problem(
        prob, trace_b4, make_policy("poly", 1.0, a=0.3), prox, eta=ALPHA,
        buffer_size=4, local_lr=0.5 / prob.L, horizon="auto"), repeats=1)
    sub = np.asarray(res.objective) - p_star
    hit = time_to_tolerance(res.objective, target, p_star=p_star)
    results["fedbuff4_poly"] = {
        "final_subopt": float(sub[-1]), "best_subopt": float(sub.min()),
        "events_to_target": int(hit),
        "writes_to_target": int(hit // 4) if hit >= 0 else None,
        "us_per_run": us,
    }
    emit("fig5/logreg/fedbuff4_poly", us,
         f"final_subopt={sub[-1]:.5f};events_to_target={hit}")

    gap = best_fixed_vs_adaptive(
        {n: r["events_to_target"] for n, r in results.items()},
        fixed={n for n in results if n.startswith("fixed_")},
        adaptive=set(adaptive))
    best_fixed, best_adaptive = gap["best_fixed"], gap["best_adaptive"]
    if gap["speedup"] is not None:
        derived = (f"adaptive={best_adaptive};fixed={best_fixed};"
                   f"speedup={gap['speedup']:.1f}x")
    else:
        derived = (f"adaptive={'never' if best_adaptive < 0 else best_adaptive};"
                   f"fixed={'never' if best_fixed < 0 else best_fixed}")
    emit("fig5/logreg/adaptive_vs_best_fixed", 0.0, derived)

    payload = {
        "workload": "logreg_federated_stragglers",
        "uploads": UPLOADS, "n_clients": N_CLIENTS, "alpha": ALPHA,
        "tau_max": int(tau_max),
        # the horizon each horizon='auto' run actually used, per trace (the
        # fedbuff trace's staleness distribution differs from fedasync's)
        "horizon_auto": int(auto_horizon(int(np.max(np.asarray(trace.tau))))),
        "horizon_auto_fedbuff": int(auto_horizon(
            int(np.max(np.asarray(trace_b4.tau))))),
        "tau_p50": float(np.percentile(trace.tau, 50)),
        "tau_p90": float(np.percentile(trace.tau, 90)),
        "p_star": p_star, "initial_gap": gap0, "target_subopt": target,
        "policies": results,
        "best_fixed_events": best_fixed,
        "best_adaptive_events": best_adaptive,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {OUT_JSON}")
    return payload
