"""Benchmark harness -- one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [fig1 fig2 fig3 fig4 fig5 sweep engine_opt pallas mega roofline kernels faults]

Prints ``name,us_per_call,derived`` CSV lines.  Benchmark runs that go
through ``repro.api.run`` also append their telemetry ``RunRecord`` to a
``BENCH_ledger.jsonl`` next to the ``BENCH_*.json`` artifacts (override or
disable via the ``REPRO_TELEMETRY_LEDGER`` environment variable -- set it
empty to silence); render it with ``python -m repro.launch.report``.

``mega`` (the device-sharded mega-grid) forces multiple host devices at jax
init -- a process-wide, irreversible setting that would split host threads
across fake devices and understate every OTHER benchmark's numbers.  It
therefore only runs when EXPLICITLY selected (never as part of the
no-selector full suite), and when selected it runs first so the flag lands
before any other module imports jax; combine it with other selections at
your own risk.
"""
from __future__ import annotations

import os
import sys

# route api.run telemetry to a ledger artifact beside the BENCH_*.json
# outputs; setdefault so an explicit env var (including "") wins
os.environ.setdefault("REPRO_TELEMETRY_LEDGER", "BENCH_ledger.jsonl")


def main() -> None:
    sel = set(sys.argv[1:])

    def want(name: str) -> bool:
        return not sel or name in sel

    print("name,us_per_call,derived")
    if "mega" in sel:  # explicit-only (see module docstring), and first:
        # must set XLA_FLAGS before any other module imports jax
        from . import mega_grid
        mega_grid.run()
    if want("fig1"):
        from . import fig1_stepsizes
        fig1_stepsizes.run()
    if want("fig2"):
        from . import fig2_piag
        fig2_piag.run()
    if want("fig3"):
        from . import fig3_delays
        fig3_delays.run()
    if want("fig4"):
        from . import fig4_bcd
        fig4_bcd.run()
    if want("fig5"):
        from . import fig5_federated
        fig5_federated.run()
    if want("kernels"):
        from . import kernel_bench
        kernel_bench.run()
    if want("sweep"):
        from . import sweep_grid
        sweep_grid.run()
    if want("engine_opt"):
        from . import engine_opt
        engine_opt.run()
    if want("pallas"):
        from . import pallas_engine
        pallas_engine.run()
    if want("ext"):
        from . import ext_lipschitz
        ext_lipschitz.run()
    if want("wallclock"):
        from . import ext_wallclock
        ext_wallclock.run()
    if want("roofline"):
        from . import roofline_report
        roofline_report.run()
    if want("faults"):
        from . import fig_faults
        fig_faults.run()


if __name__ == "__main__":
    main()
