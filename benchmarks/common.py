"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Tuple


def timeit(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    """Return (microseconds per call, last result)."""
    out = fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out


def emit(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line
