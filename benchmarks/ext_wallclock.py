"""Systems-level motivation check (paper §1): synchronous vs asynchronous
wall-clock under heterogeneous workers, SWEEPING straggler severity.

Synchronous prox-gradient descent pays max_i(service time) every round
(fast workers idle); asynchronous PIAG (delay-adaptive, no delay bound)
processes one write event per completion and never idles.  Same worker
timing model, same total gradient work per unit wall-clock modeled; we
report simulated wall-clock to a common objective target.  The HONEST
result: with mild heterogeneity sync's exact full gradients win; as
stragglers worsen, the idle-time tax flips the outcome -- exactly the
regime the paper's asynchronous setting targets (and where its adaptive
step-sizes are what keep async tunable, since tau_max explodes)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (Adaptive1, L1, heterogeneous_workers, make_logreg,
                        run_piag_logreg, simulate_parameter_server)

from .common import emit

EVENTS = 4000
N = 10

SEVERITIES = {
    "mild": dict(p_straggle=0.05, straggle_x=8.0, spread=2.0),
    "heavy": dict(p_straggle=0.25, straggle_x=25.0, spread=3.0),
    "extreme": dict(p_straggle=0.4, straggle_x=80.0, spread=4.0),
    # one PERMANENTLY 30x-slow machine: sync pays 30x every round; PIAG's
    # tau_max grows with that worker's staleness and throttles gamma for
    # everyone -- the documented limitation of max-delay-coupled step-sizes
    "persistent": None,
}


def run() -> dict:
    prob = make_logreg(1500, 200, n_workers=N, seed=0)
    prox = L1(lam=prob.lam1)
    gp = 0.99 / prob.L
    grad = jax.jit(prob.grad_f)
    P = jax.jit(prob.P)
    out = {}

    for sev, kw in SEVERITIES.items():
        if sev == "persistent":
            from repro.core import WorkerModel
            workers = [WorkerModel(mean=30.0 if i == 0 else 1.0)
                       for i in range(N)]
        else:
            workers = heterogeneous_workers(N, seed=0, **kw)
        # async PIAG: the event trace carries simulated wall-clock
        trace = simulate_parameter_server(N, EVENTS, workers, seed=1)
        res = run_piag_logreg(prob, trace, Adaptive1(gamma_prime=gp), prox)
        obj_a, t_a = np.asarray(res.objective), trace.t_wall

        # synchronous prox-GD: each round costs max_i(service), n grads
        rng = np.random.default_rng(1)
        rounds = EVENTS // N
        t_s = np.cumsum([max(w.sample(rng) for w in workers)
                         for _ in range(rounds)])
        x = jnp.zeros((prob.dim,), jnp.float32)
        obj_s = []
        for _ in range(rounds):
            x = prox.prox(x - gp * grad(x), gp)
            obj_s.append(float(P(x)))
        obj_s = np.array(obj_s)

        target = max(obj_s[-1], obj_a[-1]) + 1e-4
        i_a = int(np.argmax(obj_a <= target))
        i_s = int(np.argmax(obj_s <= target))
        ta = t_a[i_a] if obj_a[i_a] <= target else float("inf")
        ts = t_s[i_s] if obj_s[i_s] <= target else float("inf")
        emit(f"ext/wallclock/{sev}", 0.0,
             f"async_t={ta:.0f}su;sync_t={ts:.0f}su;"
             f"speedup={ts / ta:.2f}x;max_tau={trace.max_delay()}")
        out[sev] = (ts, ta)
    return out
