"""Chaos figure: delay-adaptive vs fixed worst-case-bound step-sizes under
crash/rejoin fault injection.

The robustness claim this gates: crash/rejoin outages spike the measured
staleness far past its stationary level (>= 4x tau_bar here), and a fixed
step tuned to that worst-case bound gamma'/(tau_max+1) pays for the spike
on EVERY event, while the delay-adaptive policies only slow down when a
stale update actually arrives.  Concretely, at least one adaptive policy
must reach the 20%-gap target objective while the best fixed
worst-case-bound step either diverges or needs >= 2x the server writes to
get there.

Emits ``BENCH_faults.json`` and exits non-zero when the gate fails.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro import api
from repro.core import Adaptive1, Adaptive2, FixedStepSize, L1, make_logreg
from repro.core.engine import heterogeneous_workers
from repro.faults import FaultSpec
from repro.sweep import make_grid

from .common import emit

# rare long outages: a crashed worker's next completion lands with a large
# measured staleness (the rejoin spike), exactly the regime where the
# worst-case bound is loosest
CHAOS = FaultSpec(p_crash=0.04, p_rejoin=0.15, crash_scale=60.0, seed=0)

SPIKE_FACTOR = 4.0     # faulted tau_max must exceed this x stationary tau_bar
GAP_FRACTION = 0.2     # target: close 80% of the gap to the best final
WRITE_RATIO = 2.0      # fixed must need >= this x the adaptive's writes


def _grid(problem, policies, n_events):
    return make_grid(policies=policies, seeds=[0],
                     topologies={"hetero": heterogeneous_workers(
                         problem.n_workers, seed=1)},
                     n_events=n_events)


def _objective_rows(res):
    """policy name -> (n_rows,) objective trace (single seed/topology)."""
    obj = np.asarray(res.raw.objective)
    return {c.policy_name: obj[i] for i, c in enumerate(res.grid.cells)}


def _events_to(obj, target):
    """First recorded event index reaching the target, or None."""
    finite = np.isfinite(obj)
    hit = finite & (obj <= target)
    return int(np.argmax(hit)) if hit.any() else None


def run(n_events: int = 3000, out: str = "BENCH_faults.json") -> dict:
    problem = make_logreg(800, 100, n_workers=8, seed=0)
    prox = L1(lam=problem.lam1)
    gp = 0.99 / problem.L

    # phase 1: measure the delay regime -- stationary (faults off) vs
    # faulted -- with a throwaway adaptive run each
    probe = {"probe": Adaptive1(gamma_prime=gp)}
    stat = api.run_components("piag", "batched", problem=problem,
                              grid=_grid(problem, probe, n_events),
                              prox=prox, horizon=4096)
    chaos_probe = api.run_components("piag", "batched", problem=problem,
                                     grid=_grid(problem, probe, n_events),
                                     prox=prox, horizon=4096, faults=CHAOS)
    taus_stat = np.asarray(stat.raw.taus)
    taus_chaos = np.asarray(chaos_probe.raw.taus)
    tau_bar = float(taus_stat.mean())
    tau_max_faulted = int(taus_chaos.max())
    spike = tau_max_faulted / max(tau_bar, 1.0)
    emit("fig_faults/delay_regime", 0.0,
         f"tau_bar={tau_bar:.1f};tau_max_faulted={tau_max_faulted};"
         f"spike={spike:.1f}x")

    # phase 2: the race.  The fixed baseline is tuned to the measured
    # worst-case bound -- the best a fixed policy can certify under this
    # fault process
    policies = {
        "adaptive1": Adaptive1(gamma_prime=gp),
        "adaptive2": Adaptive2(gamma_prime=gp),
        "fixed_wc": FixedStepSize(gamma_prime=gp, tau_bound=tau_max_faulted),
    }
    race = api.run_components("piag", "batched", problem=problem,
                              grid=_grid(problem, policies, n_events),
                              prox=prox, horizon=4096, faults=CHAOS)
    traces = _objective_rows(race)

    finals = {n: float(t[-1]) if np.isfinite(t[-1]) else float("inf")
              for n, t in traces.items()}
    p0 = float(next(iter(traces.values()))[0])
    p_star = min(finals.values())
    target = p_star + GAP_FRACTION * (p0 - p_star)

    hits = {n: _events_to(t, target) for n, t in traces.items()}
    diverged = {n: not np.all(np.isfinite(t)) or finals[n] > p0
                for n, t in traces.items()}
    for n, t in traces.items():
        emit(f"fig_faults/{n}", 0.0,
             f"P_final={finals[n]:.4f};events_to_target="
             f"{hits[n] if hits[n] is not None else 'never'};"
             f"diverged={diverged[n]}")

    adaptive_hits = [hits[n] for n in ("adaptive1", "adaptive2")
                     if hits[n] is not None and not diverged[n]]
    best_adaptive = min(adaptive_hits) if adaptive_hits else None
    fixed_hit = hits["fixed_wc"]
    fixed_ratio = (fixed_hit / best_adaptive
                   if fixed_hit is not None and best_adaptive else None)

    gate_spike = spike >= SPIKE_FACTOR
    gate_adaptive = best_adaptive is not None
    gate_fixed = diverged["fixed_wc"] or fixed_hit is None \
        or (best_adaptive is not None
            and fixed_hit >= WRITE_RATIO * best_adaptive)
    gate = gate_spike and gate_adaptive and gate_fixed

    result = {
        "n_events": n_events,
        "faults": {"p_crash": CHAOS.p_crash, "p_rejoin": CHAOS.p_rejoin,
                   "crash_scale": CHAOS.crash_scale, "seed": CHAOS.seed},
        "tau_bar_stationary": tau_bar,
        "tau_max_faulted": tau_max_faulted,
        "spike_factor": spike,
        "target_objective": target,
        "finals": finals,
        "events_to_target": hits,
        "diverged": diverged,
        "fixed_over_adaptive_writes": fixed_ratio,
        "fault_counters": race.telemetry.faults,
        "gates": {"spike_ge_4x": gate_spike,
                  "adaptive_reaches_target": gate_adaptive,
                  "fixed_diverges_or_2x_writes": gate_fixed,
                  "pass": gate},
    }
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    emit("fig_faults/gate", 0.0,
         f"pass={gate};spike={gate_spike};adaptive={gate_adaptive};"
         f"fixed={gate_fixed};wrote={out}")
    if not gate:
        raise SystemExit(
            f"fig_faults gate FAILED: spike_ge_4x={gate_spike} "
            f"adaptive_reaches_target={gate_adaptive} "
            f"fixed_diverges_or_2x_writes={gate_fixed} (see {out})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=3000)
    ap.add_argument("--out", default="BENCH_faults.json")
    a = ap.parse_args()
    run(n_events=a.events, out=a.out)
