"""Paper Figure 4: Async-BCD convergence, delay-adaptive vs the fixed
step-sizes of [Sun'17] h/(L(tau+1/2)) and [Davis'16] h/(Lhat+2L tau/sqrt(m)).

Derived: final objective on the same shared-memory event trace."""
from __future__ import annotations

import numpy as np

from repro.configs.paper_logreg import MNIST_LIKE, RCV1_LIKE
from repro.core import (Adaptive1, Adaptive2, DavisFixed, L1, SunDengFixed,
                        run_bcd_logreg, simulate_shared_memory)

from .common import emit, timeit

EVENTS = 4000
M_BLOCKS = 20
N_WORKERS = 8


def run() -> dict:
    out = {}
    for wl in [RCV1_LIKE, MNIST_LIKE]:
        prob = wl.build(seed=0)
        trace = simulate_shared_memory(N_WORKERS, EVENTS, M_BLOCKS, seed=4)
        tau_max = trace.max_delay()
        Lhat = prob.block_smoothness(M_BLOCKS)   # Assumption 1 (block-wise)
        gp = 0.99 / Lhat
        prox = L1(lam=prob.lam1)
        # Davis'16 ratio: 2 L / (Lhat sqrt(m)) with L <= m Lhat bound -> use
        # the measured global L
        ratio = 2.0 * prob.L / (Lhat * np.sqrt(M_BLOCKS))
        pols = {
            "adaptive1": Adaptive1(gamma_prime=gp, alpha=0.9),
            "adaptive2": Adaptive2(gamma_prime=gp),
            "fixed_sun": SunDengFixed(gamma_prime=gp, tau_bound=tau_max),
            "fixed_davis": DavisFixed(gamma_prime=gp, tau_bound=tau_max,
                                      ratio=float(ratio)),
        }
        runs = {}
        for name, pol in pols.items():
            us, res = timeit(lambda p=pol: run_bcd_logreg(
                prob, trace, p, prox, m=M_BLOCKS), repeats=1)
            obj = np.asarray(res.objective)
            runs[name] = obj
            emit(f"fig4/{wl.name}/{name}", us,
                 f"P_final={obj[-1]:.4f};max_tau={tau_max}")
        out[wl.name] = runs
    return out
