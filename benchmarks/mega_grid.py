"""Device-sharded mega-grid benchmark: a >= 512-cell policy x seed x
topology x n_workers PIAG grid, single-device batched vs sharded across
forced host devices.

Three timed paths over the SAME cells (same service-time matrices, same
policies, bucketed by padded worker count exactly as ``repro.sweep`` does):

* ``single``   -- the PR 2 path: one ``jit(vmap(cell))`` program per bucket
                  on one device.
* ``sharded1`` -- the shard_map path over a 1-device mesh (measures the
                  shard_map overhead in isolation).
* ``shardedN`` -- the shard_map path over every device: the cell axis
                  round-robin-padded to a device multiple and partitioned,
                  stacked service-time tensors donated.

Also re-runs the PR 2 64-cell ``benchmarks/sweep_grid.py`` in a clean
single-device subprocess (refreshing ``BENCH_sweep_grid.json``) so the
sweep-engine baseline stays comparable release to release.  Gate: the
refreshed warm time must stay within ``GRID64_REGRESSION_TOLERANCE`` of the
prior artifact's (shared/throttled CI runners jitter real timings by tens
of percent, so the tolerance is deliberately loose -- it catches
algorithmic regressions, not noise).

Emits ``BENCH_mega_grid.json``.  Run with forced host devices (done
automatically when this module is imported before jax, e.g. ``python -m
benchmarks.mega_grid``):

    PYTHONPATH=src python -m benchmarks.mega_grid \
        [--events N] [--seeds N] [--widths 4,8] [--out PATH]
"""
from __future__ import annotations

import os
import sys

# must precede ANY jax import in the process: forced host devices are fixed
# at backend init (no-op if the operator already set a device count)
_FLAG = "xla_force_host_platform_device_count"
if "jax" not in sys.modules and _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --{_FLAG}={os.environ.get('MEGA_GRID_DEVICES', '8')}").strip()

import argparse
import json
import subprocess
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import clipped_summary
from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1,
                        SunDengFixed, make_logreg)
from repro.core.engine import WorkerModel, trace_scan, sample_service_times
from repro.core.piag import piag_scan
from repro.core.stepsize import auto_horizon
from repro.mesh import cell_axis_size, grid_mesh
from repro.sweep import (cell_mesh, make_grid, make_sharded_sweep_piag,
                         make_sweep_piag, measure_tau_bar, round_robin_pad,
                         run_bucketed, sharded_sweep_piag,
                         standard_topology_factories)
from repro.sweep.runners import _slice_workers
from repro.sweep.shard import _settle_replicas

from .common import emit

# 64-cell warm-time regression gate: refreshed / prior must stay below this
# (loose on purpose: shared CI runners jitter wall-clock by tens of percent)
GRID64_REGRESSION_TOLERANCE = 1.5


def _host_cores() -> int:
    """Physical parallelism actually granted to this process.  Forced host
    DEVICES are XLA-level threads: on a 1-core container they multiplex a
    single core and no sharded layout can beat a narrower one, so the
    speedup gates (never the bitwise-equivalence gates) key on this."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def build_mega_grid(widths, n_seeds, n_events, gp):
    seeds = list(range(n_seeds))
    topos = standard_topology_factories()
    tau_bar = measure_tau_bar(
        {f"{tn}/w{w}": f(w) for tn, f in topos.items() for w in widths},
        seeds, n_events)
    policies = {
        "adaptive1": Adaptive1(gamma_prime=gp, alpha=0.9),
        "adaptive2": Adaptive2(gamma_prime=gp),
        "fixed": FixedStepSize(gamma_prime=gp, tau_bound=tau_bar),
        "fixed_sun_deng": SunDengFixed(gamma_prime=gp, tau_bound=tau_bar),
    }
    return make_grid(policies, seeds, topos, n_events,
                     n_workers=list(widths)), tau_bar


class BucketedRunner:
    """Pre-built per-bucket programs + pre-stacked inputs, so repeated calls
    time execution (warm) instead of rebuild+retrace.  ``mesh=None`` is the
    plain single-device path; otherwise shard_map over the mesh (inputs are
    re-uploaded per call because the sharded program donates them).

    ``horizon`` is the step-size window-buffer size: the mega-grid now runs
    on the measured-delay sizing (``auto_horizon(tau_bar)``) -- rows stay
    bitwise-equal to the old 4096 default (no delay exceeds the measured
    bound by construction), with a 4096/H x leaner per-cell scan carry."""

    def __init__(self, problem, grid, prox, mesh=None, horizon=4096):
        Aw, bw = problem.worker_slices()
        x0 = jnp.zeros((problem.dim,), jnp.float32)
        loss = lambda x, A, b: problem.worker_loss(x, A, b)
        self.grid, self.mesh = grid, mesh
        self.plan = {}
        for b in grid.buckets():
            wd = _slice_workers((Aw, bw), b.width)
            masked = not b.uniform
            if mesh is None:
                fn = make_sweep_piag(loss, x0, wd, prox, objective=problem.P,
                                     masked=masked, horizon=horizon)
                idx = None
            else:
                fn = make_sharded_sweep_piag(loss, x0, wd, prox,
                                             objective=problem.P,
                                             masked=masked, mesh=mesh,
                                             horizon=horizon)
                # pad to the CELLS-axis size, not the device count: on a
                # 2-D (cells, data) mesh the data axis replicates the batch
                idx = round_robin_pad(len(b.grid), cell_axis_size(mesh))
            T = b.grid.service_times(b.width)
            act = b.grid.active_masks(b.width)
            pp = b.grid.policy_params()
            self.plan[b.width] = (fn, masked, idx, T, act, pp)

    def __call__(self):
        def run_bucket_cached(b):
            fn, masked, idx, T, act, pp = self.plan[b.width]
            args = (jnp.asarray(T),) + (
                (jnp.asarray(act),) if masked else ()) + (pp,)
            if idx is not None:
                args = tuple(
                    jax.tree_util.tree_map(lambda x: jnp.asarray(x)[idx], a)
                    for a in args)
            out = fn(*args)
            if idx is not None:
                out = jax.tree_util.tree_map(
                    lambda x: x[:len(b.grid)], _settle_replicas(out, self.mesh))
            return out

        return jax.block_until_ready(
            run_bucketed(self.grid, run_bucket_cached))


def _time(runner):
    t0 = time.perf_counter()
    res = runner()
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = runner()
    warm = time.perf_counter() - t0
    return cold, warm, res


def run_2d(n_events: int = 120, n_cells: int = 4, samples_per_worker: int = 2048,
           dim: int = 384, data_shards: int = 2) -> dict:
    """1-D vs 2-D mesh on a transformer-preset per-cell workload.

    Few big cells (``dim`` matches the 25m launch preset's d_model=384;
    thousands of samples per worker) -- the regime where the per-event
    worker gradient dominates and extra devices on a second ``data`` axis
    pay for themselves.  Both paths use the SAME ``n_cells``-wide cells
    axis; the 2-D mesh adds ``data_shards`` devices per cell shard for
    data-parallel gradients (``pmean_grad``).  Rows must stay bitwise on
    taus/gammas; the gate (>= 8 devices) requires the 2-D warm time to
    beat 1-D."""
    n_dev = len(jax.devices())
    if n_dev < n_cells * data_shards:
        return {"skipped": f"needs {n_cells * data_shards} devices, "
                           f"have {n_dev}"}
    n_workers = 8
    prob = make_logreg(n_workers * samples_per_worker, dim,
                       n_workers=n_workers, seed=0)
    gp = 0.99 / prob.L
    prox = L1(lam=prob.lam1)
    grid = make_grid(
        policies={"adaptive1": Adaptive1(gamma_prime=gp, alpha=0.9)},
        seeds=list(range(n_cells)),
        topologies={"uniform": [WorkerModel() for _ in range(n_workers)]},
        n_events=n_events)
    loss = lambda x, A, b: prob.worker_loss(x, A, b)
    obj = prob.P
    x0 = jnp.zeros((prob.dim,), jnp.float32)
    wd = prob.worker_slices()
    mesh_1d = grid_mesh((n_cells,))
    mesh_2d = grid_mesh((n_cells, data_shards))
    emit("mega_grid/2d_config", 0.0,
         f"cells={len(grid)};events={n_events};dim={dim};"
         f"samples_per_worker={samples_per_worker};"
         f"mesh_1d=({n_cells},);mesh_2d=({n_cells},{data_shards})")

    def runner(mesh):
        return lambda: jax.block_until_ready(sharded_sweep_piag(
            loss, x0, wd, grid, prox, objective=obj, mesh=mesh))

    cold_1d, warm_1d, res_1d = _time(runner(mesh_1d))
    emit("mega_grid/2d_mesh1d", cold_1d * 1e6, f"warm_us={warm_1d * 1e6:.1f}")
    cold_2d, warm_2d, res_2d = _time(runner(mesh_2d))
    emit("mega_grid/2d_mesh2d", cold_2d * 1e6, f"warm_us={warm_2d * 1e6:.1f}")
    speedup_warm = warm_1d / warm_2d
    emit("mega_grid/2d_speedup", 0.0,
         f"warm={speedup_warm:.2f}x;cold={cold_1d / cold_2d:.2f}x")

    taus_equal = bool(np.array_equal(np.asarray(res_1d.taus),
                                     np.asarray(res_2d.taus)))
    gammas_equal = bool(np.array_equal(np.asarray(res_1d.gammas),
                                       np.asarray(res_2d.gammas)))
    obj_diff = float(np.max(np.abs(np.asarray(res_1d.objective)
                                   - np.asarray(res_2d.objective))))
    ok = taus_equal and gammas_equal and obj_diff <= 1e-4
    emit("mega_grid/2d_equivalence", 0.0,
         f"taus_bitwise={taus_equal};gammas_bitwise={gammas_equal};"
         f"max_objective_diff={obj_diff:.2e};ok={ok}")
    return {
        "cells": len(grid), "n_events": n_events, "dim": dim,
        "samples_per_worker": samples_per_worker,
        "host_cores": _host_cores(),
        "mesh_1d": [n_cells], "mesh_2d": [n_cells, data_shards],
        "seconds_cold_1d": cold_1d, "seconds_warm_1d": warm_1d,
        "seconds_cold_2d": cold_2d, "seconds_warm_2d": warm_2d,
        "speedup_2d_vs_1d_warm": speedup_warm,
        "equivalence": {"taus_bitwise_equal": taus_equal,
                        "gammas_bitwise_equal": gammas_equal,
                        "max_objective_diff": obj_diff, "ok": ok},
    }


def run(n_events: int = 300, n_seeds: int = 16, widths=(4, 8),
        loop_cells: int = 6, out: str = "BENCH_mega_grid.json") -> dict:
    n_dev = len(jax.devices())
    prob = make_logreg(480, 60, n_workers=max(widths), seed=0)
    gp = 0.99 / prob.L
    prox = L1(lam=prob.lam1)
    grid, tau_bar = build_mega_grid(widths, n_seeds, n_events, gp)
    B = len(grid)
    horizon = auto_horizon(tau_bar)  # measured-delay sizing, bitwise rows
    emit("mega_grid/config", 0.0,
         f"cells={B};events={n_events};widths={list(widths)};"
         f"devices={n_dev};tau_bar={tau_bar};horizon={horizon}")

    single = BucketedRunner(prob, grid, prox, mesh=None, horizon=horizon)
    cold_1, warm_1, res_single = _time(single)
    emit("mega_grid/single_device", cold_1 * 1e6, f"warm_us={warm_1 * 1e6:.1f}")

    sharded1 = BucketedRunner(prob, grid, prox,
                              mesh=cell_mesh(jax.devices()[:1]),
                              horizon=horizon)
    cold_s1, warm_s1, _ = _time(sharded1)
    emit("mega_grid/sharded_1dev", cold_s1 * 1e6,
         f"warm_us={warm_s1 * 1e6:.1f}")

    shardedN = BucketedRunner(prob, grid, prox, mesh=cell_mesh(),
                              horizon=horizon)
    cold_sN, warm_sN, res_shard = _time(shardedN)
    speedup_cold = cold_1 / cold_sN
    speedup_warm = warm_1 / warm_sN
    emit("mega_grid/sharded_all", cold_sN * 1e6,
         f"warm_us={warm_sN * 1e6:.1f};devices={n_dev}")
    emit("mega_grid/speedup_vs_single", 0.0,
         f"cold={speedup_cold:.2f}x;warm={speedup_warm:.2f}x")
    emit("mega_grid/device_scaling", 0.0,
         f"warm_1dev_mesh={warm_s1:.3f}s;warm_{n_dev}dev={warm_sN:.3f}s;"
         f"scaling={warm_s1 / warm_sN:.2f}x")

    # ---- row equivalence: sharded == single-device, spot-check vs solo ----
    max_diff = float(np.max(np.abs(np.asarray(res_single.objective)
                                   - np.asarray(res_shard.objective))))
    taus_equal = bool(np.array_equal(np.asarray(res_single.taus),
                                     np.asarray(res_shard.taus)))
    Aw, bw = prob.worker_slices()
    x0 = jnp.zeros((prob.dim,), jnp.float32)
    solo_diff = 0.0
    for i in np.unique(np.linspace(0, B - 1, loop_cells).round().astype(int)):
        c = grid.cells[i]
        T = sample_service_times(c.workers, n_events + 1, seed=c.seed)
        tr = trace_scan(jnp.asarray(T))
        w = c.n_workers
        solo = jax.jit(lambda ev, _w=w, _p=c.policy: piag_scan(
            lambda x, A, b: prob.worker_loss(x, A, b), x0,
            (Aw[:_w], bw[:_w]), ev, _p, prox,
            objective=prob.P))((tr.worker, tr.tau_max))
        solo_diff = max(solo_diff, float(np.max(np.abs(
            np.asarray(solo.objective)
            - np.asarray(res_shard.objective[i])))))
    rows_ok = taus_equal and max_diff <= 1e-5 and solo_diff <= 1e-4
    emit("mega_grid/equivalence", 0.0,
         f"sharded_vs_single_max_diff={max_diff:.2e};"
         f"solo_rows_max_diff={solo_diff:.2e};ok={rows_ok}")

    # ---- clipped-horizon diagnostic now visible per cell ------------------
    n_clipped = clipped_summary(res_shard.clipped)["cells_clipped"]
    emit("mega_grid/clipped_cells", 0.0, f"cells_with_clipping={n_clipped}")

    # ---- 2-D (cells, data) mesh on a transformer-sized workload ----------
    two_d = run_2d()

    # ---- PR 2 compat: the 64-cell grid must not have regressed -----------
    # re-run benchmarks/sweep_grid.py (the SAME bench that produced the
    # prior BENCH_sweep_grid.json) in a clean single-device subprocess --
    # measuring it inside this multi-forced-device process would understate
    # it (host threads are split across the forced devices) -- refreshing
    # the artifact with current-code numbers and comparing against the
    # prior ones
    prior = None
    prior_path = Path("BENCH_sweep_grid.json")
    events64 = 800
    if prior_path.exists():
        pj = json.loads(prior_path.read_text())
        events64 = int(pj.get("n_events", events64))
        prior = {"cold": pj["batched_seconds_cold"],
                 "warm": pj["batched_seconds_warm"]}
    compat = {"n_events": events64, "prior_bench_sweep_grid": prior}
    env = dict(os.environ)
    env["XLA_FLAGS"] = " ".join(
        f for f in env.get("XLA_FLAGS", "").split() if _FLAG not in f)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep_grid",
         "--events", str(events64), "--loop-cells", "4"],
        env=env, capture_output=True, text=True)
    if proc.returncode == 0 and prior_path.exists():
        pj = json.loads(prior_path.read_text())
        compat.update(cells=pj["cells"],
                      batched_seconds_cold=pj["batched_seconds_cold"],
                      batched_seconds_warm=pj["batched_seconds_warm"])
        emit("mega_grid/compat64", pj["batched_seconds_cold"] * 1e6,
             f"warm_us={pj['batched_seconds_warm'] * 1e6:.1f};"
             f"events={events64};prior={prior}")
    else:
        compat["error"] = (proc.stderr or "")[-500:]
        emit("mega_grid/compat64", 0.0, "FAILED;see json")

    payload = {
        "bench": "mega_grid",
        "devices": n_dev,
        "host_cores": _host_cores(),
        "cells": B,
        "n_events": n_events,
        "widths": list(widths),
        "buckets": [{"width": b.width, "cells": len(b.grid)}
                    for b in grid.buckets()],
        "tau_bar": tau_bar,
        "horizon": horizon,
        "single_device_seconds_cold": cold_1,
        "single_device_seconds_warm": warm_1,
        "sharded_1dev_seconds_cold": cold_s1,
        "sharded_1dev_seconds_warm": warm_s1,
        "sharded_seconds_cold": cold_sN,
        "sharded_seconds_warm": warm_sN,
        "speedup_sharded_vs_single_cold": speedup_cold,
        "speedup_sharded_vs_single_warm": speedup_warm,
        "device_scaling_warm_1_to_N": warm_s1 / warm_sN,
        "cells_with_horizon_clipping": n_clipped,
        "equivalence": {"taus_bitwise_equal": taus_equal,
                        "sharded_vs_single_max_objective_diff": max_diff,
                        "solo_rows_checked": int(loop_cells),
                        "solo_rows_max_objective_diff": solo_diff,
                        "ok": rows_ok},
        "two_d": two_d,
        "grid64_compat": compat,
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}: {B} cells on {n_dev} devices, sharded speedup "
          f"cold {speedup_cold:.2f}x / warm {speedup_warm:.2f}x, "
          f"equivalence ok={rows_ok}")
    return payload


def _gate_2d(two_d: dict, n_dev: int) -> None:
    """CI gate for the 2-D section: bitwise rows always; measured warm
    speedup over the 1-D mesh when the full 8-device mesh ran AND the host
    has cores beyond the cells axis for the data axis to use."""
    if "skipped" in two_d:
        print(f"2-D mesh section skipped: {two_d['skipped']}")
        return
    if not two_d["equivalence"]["ok"]:
        raise SystemExit("2-D mesh equivalence failed: "
                         f"{two_d['equivalence']}")
    cores = _host_cores()
    if (n_dev >= 8 and cores > two_d["mesh_1d"][0]
            and two_d["speedup_2d_vs_1d_warm"] <= 1.0):
        raise SystemExit(
            f"2-D (cells, data) mesh failed to beat the 1-D mesh on a "
            f"{cores}-core host: warm {two_d['seconds_warm_2d']:.2f}s vs "
            f"{two_d['seconds_warm_1d']:.2f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=300)
    ap.add_argument("--seeds", type=int, default=16)
    ap.add_argument("--widths", default="4,8",
                    help="comma-separated worker counts (the ragged axis)")
    ap.add_argument("--loop-cells", type=int, default=6,
                    help="solo spot-check rows")
    ap.add_argument("--only-2d", action="store_true",
                    help="run just the 2-D (cells, data) mesh comparison "
                         "and its gate (CI multi-device lane); writes no "
                         "artifact")
    ap.add_argument("--out", default="BENCH_mega_grid.json")
    a = ap.parse_args()
    if a.only_2d:
        two_d = run_2d()
        _gate_2d(two_d, len(jax.devices()))
        if "skipped" not in two_d:
            print(f"2-D mesh: warm {two_d['seconds_warm_2d']:.2f}s vs 1-D "
                  f"{two_d['seconds_warm_1d']:.2f}s "
                  f"({two_d['speedup_2d_vs_1d_warm']:.2f}x), "
                  f"equivalence ok={two_d['equivalence']['ok']}")
        return
    widths = tuple(int(w) for w in a.widths.split(","))
    payload = run(n_events=a.events, n_seeds=a.seeds, widths=widths,
                  loop_cells=a.loop_cells, out=a.out)
    if not payload["equivalence"]["ok"]:
        raise SystemExit("equivalence spot-check failed")
    if (payload["devices"] > 1 and _host_cores() > 1
            and payload["speedup_sharded_vs_single_warm"] <= 1.0):
        raise SystemExit("sharded path failed to beat single-device")
    _gate_2d(payload["two_d"], payload["devices"])
    compat = payload["grid64_compat"]
    if "error" in compat:
        raise SystemExit(f"64-cell compat re-run failed: {compat['error']}")
    prior = compat.get("prior_bench_sweep_grid")
    if prior and compat["batched_seconds_warm"] > (
            GRID64_REGRESSION_TOLERANCE * prior["warm"]):
        raise SystemExit(
            f"64-cell batched warm time regressed: "
            f"{compat['batched_seconds_warm']:.2f}s vs prior "
            f"{prior['warm']:.2f}s (tolerance {GRID64_REGRESSION_TOLERANCE}x)")


if __name__ == "__main__":
    main()
