"""Paper Figure 1: step-size sequences and integrals under the three delay
models (constant / random / burst), adaptive vs fixed.

Derived metric: sum_{t<=k} gamma_t at k=2000 relative to the fixed policy
(the paper's speed proxy -- Theorems 2-3 tie convergence to this integral)."""
from __future__ import annotations

import numpy as np

from repro.core import (Adaptive1, Adaptive2, FixedStepSize, make_delays)

from .common import emit, timeit

TAU = 5
K = 2000
GAMMA_PRIME = 1.0
ALPHA = 0.9


def run() -> dict:
    results = {}
    for model in ["constant", "random", "burst"]:
        taus = make_delays(model, K, TAU, seed=0)
        pols = {
            "adaptive1": Adaptive1(gamma_prime=GAMMA_PRIME, alpha=ALPHA),
            "adaptive2": Adaptive2(gamma_prime=GAMMA_PRIME),
            "fixed": FixedStepSize(gamma_prime=GAMMA_PRIME, tau_bound=TAU),
        }
        sums = {}
        for name, pol in pols.items():
            us, g = timeit(lambda p=pol: np.asarray(p.run(taus)))
            sums[name] = float(g.sum())
            emit(f"fig1/{model}/{name}", us,
                 f"sum_gamma={g.sum():.1f}")
        r1 = sums["adaptive1"] / sums["fixed"]
        r2 = sums["adaptive2"] / sums["fixed"]
        emit(f"fig1/{model}/ratio", 0.0,
             f"adaptive1/fixed={r1:.2f};adaptive2/fixed={r2:.2f}")
        results[model] = sums
    # paper claim: burst ratio approaches alpha*(tau+1) for adaptive1
    burst_target = ALPHA * (TAU + 1)
    got = results["burst"]["adaptive1"] / results["burst"]["fixed"]
    emit("fig1/burst/claim", 0.0,
         f"adaptive1_ratio={got:.2f};paper_asymptote={burst_target:.2f}")
    return results
