"""Paper Figure 3: delay distributions measured on-line by the trackers,
from (a) the threaded parameter-server runtime and (b) the threaded
shared-memory Async-BCD runtime on this container's cores.

Derived: max delay and the fraction of delays <= 25 (the paper reports >92%
for PIAG and >97% <= 20 for Async-BCD on their 10/8-worker machine)."""
from __future__ import annotations

import numpy as np

from repro.core import (Adaptive1, L1, PIAGServer, SharedMemoryBCD,
                        make_logreg)

from .common import emit, timeit

EVENTS = 600


def run() -> dict:
    prob = make_logreg(1500, 200, n_workers=8, seed=0)
    out = {}

    srv = PIAGServer(prob, Adaptive1(gamma_prime=0.99 / prob.L),
                     L1(lam=prob.lam1), n_workers=8, record_every=1)
    us, log = timeit(lambda: srv.run(EVENTS), repeats=1)
    taus = np.array(log.taus)
    out["piag"] = taus
    emit("fig3/piag_threads", us,
         f"max_tau={taus.max()};frac_le_25={np.mean(taus <= 25):.3f};"
         f"median={np.median(taus):.0f}")

    bcd = SharedMemoryBCD(prob, Adaptive1(gamma_prime=0.99 / prob.Lhat),
                          L1(lam=prob.lam1), n_workers=8, m_blocks=20,
                          record_every=1)
    us, log2 = timeit(lambda: bcd.run(EVENTS), repeats=1)
    taus2 = np.array(log2.taus)
    out["bcd"] = taus2
    emit("fig3/bcd_threads", us,
         f"max_tau={taus2.max()};frac_le_20={np.mean(taus2 <= 20):.3f};"
         f"median={np.median(taus2):.0f}")
    return out
