"""Kernel micro-benchmarks: Pallas (interpret on CPU; compiled on TPU) vs the
pure-jnp reference, plus the unfused-XLA prox baseline.  On CPU the interpret
numbers measure Python-level emulation, NOT TPU performance -- the derived
column reports the analytic VMEM working set and arithmetic intensity that
size the TPU schedule."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, timeit


def run() -> None:
    key = jax.random.PRNGKey(0)

    # prox_step: memory-bound -> report bytes moved per element
    n = 1 << 20
    x = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))

    ref_fn = jax.jit(lambda: ref.prox_step_ref(x, g, jnp.float32(0.1),
                                               "l1", 1e-3))
    us, _ = timeit(lambda: jax.block_until_ready(ref_fn()))
    emit("kernels/prox_step/xla_ref", us,
         f"n={n};bytes_per_elem=12(read x,g; write y)")
    us, _ = timeit(lambda: jax.block_until_ready(
        ops.prox_step(x, g, 0.1, kind="l1", lam=1e-3)))
    emit("kernels/prox_step/pallas_interpret", us,
         "fused 1-pass; VMEM tile 256x1024xf32=1MiB/operand")

    # flash attention: report score-matrix HBM traffic eliminated
    B, S, H, KV, d = 1, 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, d), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    from repro.models.attention import attend
    naive = jax.jit(lambda: attend(q, k, v, pos, pos, causal=True,
                                   window=None, scale=d ** -0.5, q_chunk=256,
                                   impl="naive"))
    us, _ = timeit(lambda: jax.block_until_ready(naive()))
    score_bytes = B * H * S * S * 4
    emit("kernels/flash_attention/xla_naive", us,
         f"S={S};score_matrix_bytes={score_bytes}")
    us, _ = timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, pos, pos, causal=True, scale=d ** -0.5)))
    emit("kernels/flash_attention/pallas_interpret", us,
         f"blocks=(128,512);vmem_acc={128*d*4}B/row-block;score HBM traffic=0")

    # ssd intra-chunk
    Bt, S2, Hh, P, G, N = 2, 512, 8, 64, 1, 64
    ks = jax.random.split(key, 5)
    xs = jax.random.normal(ks[0], (Bt, S2, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S2, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)))
    Bv = jax.random.normal(ks[3], (Bt, S2, G, N))
    Cv = jax.random.normal(ks[4], (Bt, S2, G, N))
    from repro.models.ssm import ssd_chunked
    jnp_fn = jax.jit(lambda: ssd_chunked(xs, dt, A, Bv, Cv, chunk=128))
    us, _ = timeit(lambda: jax.block_until_ready(jnp_fn()[0]))
    emit("kernels/ssd_scan/xla_ref", us, f"S={S2};chunk=128")
    us, _ = timeit(lambda: jax.block_until_ready(
        ops.ssd_scan_pallas(xs, dt, A, Bv, Cv, chunk=128)[0]))
    q_ = 128
    vmem = (q_ * P + 2 * q_ * N + q_ * q_) * 4
    emit("kernels/ssd_scan/pallas_interpret", us,
         f"chunk={q_};vmem_work_set={vmem}B;mxu_dims=({q_},{N})x({N},{q_})")

    # fused rmsnorm
    xr = jax.random.normal(key, (4096, 2048))
    sc = jnp.ones((2048,))
    xla_fn = jax.jit(lambda: ref.rmsnorm_ref(xr, sc))
    us, _ = timeit(lambda: jax.block_until_ready(xla_fn()))
    emit("kernels/rmsnorm/xla_ref", us, "rows=4096;D=2048;3 HBM passes unfused")
    us, _ = timeit(lambda: jax.block_until_ready(ops.rmsnorm_fused(xr, sc)))
    emit("kernels/rmsnorm/pallas_interpret", us,
         "1-pass; block=(256,D); stats in VMEM")
