"""Beyond-paper extension benchmark: PIAG with on-line Lipschitz estimation
(the paper's §5 future work) vs the oracle-L adaptive and fixed policies.

Derived: final objective + final L estimate vs the true constant, starting
from a deliberately absurd initial budget (gamma0 = 1000/L-ish)."""
from __future__ import annotations

import numpy as np

from repro.core import (Adaptive1, L1, SunDengFixed, make_logreg,
                        run_piag_lipschitz, run_piag_logreg,
                        simulate_parameter_server)

from .common import emit, timeit

EVENTS = 3000


def run() -> dict:
    prob = make_logreg(1500, 200, n_workers=8, seed=0)
    trace = simulate_parameter_server(8, EVENTS, seed=2)
    prox = L1(lam=prob.lam1)
    gp = 0.99 / prob.L

    us, res_lip = timeit(lambda: run_piag_lipschitz(
        prob, trace, prox, gamma0=1000.0), repeats=1)
    emit("ext/lipschitz_piag", us,
         f"P_final={float(res_lip.objective[-1]):.4f};"
         f"L_true={prob.L:.3e};L_est={float(res_lip.opt_residual[-1]):.3e};"
         f"gamma0_error=1000x")

    us, res_orc = timeit(lambda: run_piag_logreg(
        prob, trace, Adaptive1(gamma_prime=gp), prox), repeats=1)
    emit("ext/oracle_adaptive1", us,
         f"P_final={float(res_orc.objective[-1]):.4f}")

    us, res_fix = timeit(lambda: run_piag_logreg(
        prob, trace, SunDengFixed(gamma_prime=gp,
                                  tau_bound=trace.max_delay()), prox),
        repeats=1)
    emit("ext/fixed_sun_deng", us,
         f"P_final={float(res_fix.objective[-1]):.4f}")
    return {"lip": res_lip, "orc": res_orc, "fix": res_fix}
