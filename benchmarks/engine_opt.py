"""Lean-carry engine benchmark: measured-delay horizons + decimated
recording + executable reuse vs. the seed configuration, on the PR 2
64-cell policy x seed x topology PIAG grid.

Two spec-driven configurations over the SAME cells (same traces, same
policies, same tau-bar tuning protocol):

* ``seed`` -- the status quo: ``horizon=4096`` (the worst-case default
  every run used to carry) and ``record_every=1`` (every event's objective
  materialized).
* ``opt``  -- ``horizon='auto'`` (the buffer sized to
  ``next_pow2(measured tau-bar + 1)`` -- 4096/H x smaller scan carry,
  bitwise-identical rows) and ``record_every=s`` (only every s-th
  objective/gamma/tau sample computed + materialized; recorded rows
  bitwise-equal to the stride-1 slices).

Each configuration runs ``api.run`` twice: cold (compile + execute) and
warm -- and because value-equal specs now resolve to memoized components
and cached executables (``repro.sweep.cache``), the warm pass measures
EXECUTION, not rebuild+retrace, for both configurations alike.

Equivalence gates (hard failures):
* auto-horizon rows at stride 1 are BITWISE-equal to the seed rows
  (objective, gammas, and -- explicitly -- taus);
* decimated rows are bitwise the stride-s slices of the seed rows.

Perf gate: >= 1.5x warm speedup, or >= 4x scan-carry reduction at parity
(<= 1.1x warm time).  Emits ``BENCH_engine_opt.json``.

    PYTHONPATH=src python -m benchmarks.engine_opt [--events N]
        [--seeds N] [--workers N] [--record-every S] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import jax

from repro import api
from repro.core.stepsize import DEFAULT_HORIZON
from repro.sweep import clear_program_cache, program_cache_stats

from .common import emit

POLICY_NAMES = ("adaptive1", "adaptive2", "fixed", "sun_deng")


def build_spec(n_events: int, n_seeds: int, n_workers: int,
               horizon, record_every: int) -> api.ExperimentSpec:
    """The PR 2 64-cell grid as a declarative spec: 4 policies x n_seeds x
    the 4 standard topology regimes, fixed family tuned from the measured
    tau-bar (the resolver's protocol, same as the old inline build)."""
    return api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=800, dim=100, seed=0)),
        solver=api.SolverSpec(name="piag", horizon=horizon),
        topology=api.TopologySpec(kind="standard", n_workers=(n_workers,)),
        policies=api.PolicyGridSpec(names=POLICY_NAMES,
                                    seeds=tuple(range(n_seeds))),
        execution=api.ExecutionSpec(backend="batched",
                                    record_every=record_every),
        n_events=n_events)


def timed_runs(spec: api.ExperimentSpec):
    t0 = time.perf_counter()
    res = api.run(spec)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = api.run(spec)
    warm = time.perf_counter() - t0
    return cold, warm, res


def run(n_events: int = 800, n_seeds: int = 4, n_workers: int = 8,
        record_every: int = 8, out: str = "BENCH_engine_opt.json") -> dict:
    clear_program_cache()
    seed_spec = build_spec(n_events, n_seeds, n_workers, 4096, 1)
    opt_spec = build_spec(n_events, n_seeds, n_workers, "auto", record_every)

    cold_seed, warm_seed, res_seed = timed_runs(seed_spec)
    B = res_seed.n_cells
    emit("engine_opt/seed", cold_seed * 1e6,
         f"warm_us={warm_seed * 1e6:.1f};cells={B};horizon=4096;stride=1")

    cold_opt, warm_opt, res_opt = timed_runs(opt_spec)
    H = res_opt.horizon
    carry_reduction = DEFAULT_HORIZON / H
    emit("engine_opt/opt", cold_opt * 1e6,
         f"warm_us={warm_opt * 1e6:.1f};horizon={H};stride={record_every};"
         f"carry_reduction={carry_reduction:.1f}x")
    speedup_cold = cold_seed / cold_opt
    speedup_warm = warm_seed / warm_opt
    emit("engine_opt/speedup", 0.0,
         f"cold={speedup_cold:.2f}x;warm={speedup_warm:.2f}x")
    emit("engine_opt/cache", 0.0,
         ";".join(f"{k}={v}" for k, v in program_cache_stats().items()))

    # ---- equivalence: auto-horizon bitwise at stride 1 -------------------
    auto1_spec = build_spec(n_events, n_seeds, n_workers, "auto", 1)
    res_auto1 = api.run(auto1_spec)
    obj_s = np.asarray(res_seed.objective)
    auto_bitwise = {
        "objective": bool(np.array_equal(obj_s,
                                         np.asarray(res_auto1.objective))),
        "gammas": bool(np.array_equal(np.asarray(res_seed.gammas),
                                      np.asarray(res_auto1.gammas))),
        "taus": bool(np.array_equal(np.asarray(res_seed.taus),
                                    np.asarray(res_auto1.taus))),
    }
    # ---- equivalence: decimated rows are the bitwise stride-s slices -----
    s = record_every
    dec_bitwise = {
        "objective": bool(np.array_equal(obj_s[:, s - 1::s],
                                         np.asarray(res_opt.objective))),
        "gammas": bool(np.array_equal(np.asarray(res_seed.gammas)[:, s - 1::s],
                                      np.asarray(res_opt.gammas))),
        "taus": bool(np.array_equal(np.asarray(res_seed.taus)[:, s - 1::s],
                                    np.asarray(res_opt.taus))),
        "x": bool(np.array_equal(np.asarray(res_seed.x),
                                 np.asarray(res_opt.x))),
        "clipped": bool(np.array_equal(np.asarray(res_seed.clipped),
                                       np.asarray(res_opt.clipped))),
    }
    rows_ok = all(auto_bitwise.values()) and all(dec_bitwise.values())
    emit("engine_opt/equivalence", 0.0,
         f"auto_bitwise={all(auto_bitwise.values())};"
         f"decimated_bitwise={all(dec_bitwise.values())};ok={rows_ok}")

    parity = warm_opt <= 1.1 * warm_seed
    perf_ok = bool(speedup_warm >= 1.5
                   or (carry_reduction >= 4.0 and parity))

    payload = {
        "bench": "engine_opt",
        "cells": B,
        "n_events": n_events,
        "n_workers": n_workers,
        "tau_bar": res_opt.tau_bar,
        "devices": len(jax.devices()),
        "seed_config": {"horizon": 4096, "record_every": 1,
                        "seconds_cold": cold_seed, "seconds_warm": warm_seed},
        "opt_config": {"horizon": H, "horizon_mode": "auto",
                       "record_every": record_every,
                       "seconds_cold": cold_opt, "seconds_warm": warm_opt},
        "speedup_cold": speedup_cold,
        "speedup_warm": speedup_warm,
        "carry_reduction": carry_reduction,
        "recorded_samples": res_opt.n_samples,
        "program_cache": program_cache_stats(),
        "equivalence": {"auto_horizon_bitwise": auto_bitwise,
                        "decimated_bitwise": dec_bitwise,
                        "ok": rows_ok},
        "perf_gate": {"warm_speedup_target": 1.5,
                      "carry_reduction_target": 4.0,
                      "parity": parity, "ok": perf_ok},
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}: {B} cells, auto horizon {H} "
          f"({carry_reduction:.0f}x leaner carry), stride {record_every}, "
          f"warm speedup {speedup_warm:.2f}x, equivalence ok={rows_ok}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=800)
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--record-every", type=int, default=8)
    ap.add_argument("--out", default="BENCH_engine_opt.json")
    a = ap.parse_args()
    payload = run(n_events=a.events, n_seeds=a.seeds, n_workers=a.workers,
                  record_every=a.record_every, out=a.out)
    if not payload["equivalence"]["ok"]:
        raise SystemExit("bitwise equivalence failed")
    if not payload["perf_gate"]["ok"]:
        raise SystemExit(
            f"perf gate failed: warm speedup "
            f"{payload['speedup_warm']:.2f}x < 1.5x and carry reduction "
            f"{payload['carry_reduction']:.1f}x not at parity")


if __name__ == "__main__":
    main()
