"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and prints, per (arch x shape x mesh):
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs,
and a one-line improvement note.  Also writes the markdown table used in
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Optional

from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, RooflineTerms

from .common import emit

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")

NOTES = {
    ("train", "memory"): "shard activations over 'model' (sequence parallel) "
                         "+ tighter remat policy",
    ("train", "compute"): "near roofline for compute; raise per-chip batch or "
                          "overlap collectives",
    ("train", "collective"): "reduce-scatter grads instead of all-reduce; "
                             "overlap FSDP all-gathers with compute",
    ("prefill", "memory"): "flash-attention kernel (fused QK^T+softmax+PV) "
                           "removes score-matrix HBM traffic",
    ("prefill", "compute"): "compute-bound as expected for prefill",
    ("prefill", "collective"): "sequence-parallel attention (ring) to cut "
                               "activation all-gathers",
    ("decode", "memory"): "decode is weight/KV-bandwidth-bound by nature; "
                          "quantize KV cache or batch wider",
    ("decode", "collective"): "keep KV cache fully resident per shard; avoid "
                              "cache resharding between steps",
    ("decode", "compute"): "unusual: check for redundant cache reshuffles",
}


def load_records(mesh: Optional[str] = None) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "skipped" in r:
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if "__" in os.path.basename(path) and r.get("tag"):
            continue
        recs.append(r)
    return recs


def to_terms(r: dict) -> RooflineTerms:
    return RooflineTerms(
        flops=r["flops_per_device"],
        hbm_bytes=r["hbm_bytes_per_device"],
        collective_bytes=r["collective_bytes_per_device"],
        model_flops_total=r["model_flops_total"],
        chips=r["chips"],
    )


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def markdown_table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
        "dominant | model/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = to_terms(r)
        note = NOTES.get((kind_of(r["shape"]), t.dominant), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{t.t_compute*1e3:.2f} | {t.t_memory*1e3:.2f} | "
            f"{t.t_collective*1e3:.2f} | **{t.dominant}** | "
            f"{t.useful_ratio:.2f} | {note} |")
    return "\n".join(lines)


def run(write_md: bool = True) -> List[dict]:
    recs = load_records(mesh="16x16")
    if not recs:
        emit("roofline/none", 0.0, "no dry-run artifacts found")
        return []
    worst = None
    for r in recs:
        t = to_terms(r)
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"dom={t.dominant};t_comp_ms={t.t_compute*1e3:.2f};"
             f"t_mem_ms={t.t_memory*1e3:.2f};t_coll_ms={t.t_collective*1e3:.2f};"
             f"useful={t.useful_ratio:.2f}")
        score = t.step_time / max(t.t_compute, 1e-12)
        if worst is None or score > worst[0]:
            worst = (score, r["arch"], r["shape"])
    emit("roofline/worst_fraction", 0.0,
         f"{worst[1]}x{worst[2]};imbalance={worst[0]:.1f}")
    if write_md:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline_16x16.md", "w") as f:
            f.write(markdown_table(recs) + "\n")
    return recs
