"""Fused Pallas engine benchmark: ``engine='fused'`` vs ``engine='scan'``
on the 64-cell policy x seed x topology PIAG grid.

Two spec-driven configurations over the SAME cells (same traces, same
policies, same tau-bar tuning protocol), differing only in
``ExecutionSpec.engine``:

* ``scan``  -- the pure-XLA per-event inner loop (status quo);
* ``fused`` -- the policy update (window-sum / select / circular push) and
  the prox step launched as ONE Pallas kernel per event
  (``repro.kernels.fused_step``), compiled on TPU/GPU and interpreted on
  CPU (``repro.kernels.dispatch``).

Hard gates (``main`` exits nonzero):

* every result leaf of the fused run is BITWISE-equal to the scan run;
* the fused kernel's per-event boundary traffic
  (``fused_step.boundary_bytes`` -- the compiled-backend HBM contract:
  operands + results, refs stream through on-chip memory) is smaller than
  the scan engine's measured per-event HLO bytes
  (``launch.hlo_cost.analyze_hlo`` on the jitted single-step program);
* the telemetry ledger records a clean compile-ms/warm-ms split for the
  fused runs: the cold record carries compile time, the warm record
  (cached executable) carries none.

Reported but NOT gated: warm wall-clock scan vs fused, and the
whole-sweep HLO byte counts of both engines.  On CPU the kernel runs in
interpret mode, where ref reads materialize whole arrays as ordinary XLA
ops -- the fused whole-program bytes are INFLATED there and the kernel
brings no wall-clock win; the boundary contract above is what a compiled
backend moves.  Emits ``BENCH_pallas_engine.json``.

    PYTHONPATH=src python -m benchmarks.pallas_engine [--events N]
        [--seeds N] [--workers N] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.core.prox import make_prox
from repro.core.stepsize import make_policy
from repro.kernels.fused_step import boundary_bytes
from repro.launch.hlo_cost import analyze_hlo
from repro.sweep import clear_program_cache, program_cache_stats

from .common import emit

POLICY_NAMES = ("adaptive1", "adaptive2", "fixed", "sun_deng")
LEAVES = ("objective", "gammas", "taus", "x", "clipped")


def build_spec(n_events: int, n_seeds: int, n_workers: int,
               engine: str) -> api.ExperimentSpec:
    """The engine_opt 64-cell PIAG grid, parameterized on the engine."""
    return api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=800, dim=100, seed=0)),
        solver=api.SolverSpec(name="piag", horizon="auto"),
        topology=api.TopologySpec(kind="standard", n_workers=(n_workers,)),
        policies=api.PolicyGridSpec(names=POLICY_NAMES,
                                    seeds=tuple(range(n_seeds))),
        execution=api.ExecutionSpec(backend="batched", engine=engine),
        n_events=n_events)


def timed_runs(spec: api.ExperimentSpec):
    """Cold (compile + execute) then warm (cached executable) ``api.run``;
    returns both Results so the ledger records of each are inspectable."""
    t0 = time.perf_counter()
    cold_res = api.run(spec)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm_res = api.run(spec)
    warm = time.perf_counter() - t0
    return cold, warm, cold_res, warm_res


def step_bytes(horizon: int, dim: int) -> dict:
    """Per-event memory traffic, both engines.

    scan: measured HLO bytes of the jitted single-step program (policy
    window-sum/select/push + prox) -- every intermediate the unfused op
    sequence materializes.  fused: the kernel-boundary contract."""
    policy = make_policy("adaptive1", 0.3)
    prox = make_prox("l1", lam=0.05)
    ss = policy.init(horizon)
    x = jnp.zeros((dim,), jnp.float32)
    g = jnp.ones((dim,), jnp.float32)
    tau = jnp.asarray(3, jnp.int32)

    @jax.jit
    def scan_step(ss, tau, x, g):
        gamma, ss = policy.step(ss, tau)
        return gamma, ss, prox.prox(x - gamma * g, gamma)

    cost = analyze_hlo(scan_step.lower(ss, tau, x, g).compile().as_text())
    return {"scan_hlo_bytes": float(cost.bytes),
            "fused_boundary_bytes": float(boundary_bytes(horizon, dim)),
            "horizon": horizon, "dim": dim}


def sweep_costs(res_scan: api.Results, res_fused: api.Results,
                n_events: int) -> dict:
    """Whole-sweep HLO flops/bytes of both engines' batched programs
    (bytes/FLOP published for the roofline report; interpret mode inflates
    the fused count on CPU -- see module docstring)."""
    out = {}
    from repro.api.run import _piag_pieces, resolve
    from repro.sweep.runners import make_sweep_piag
    for name, res in (("scan", res_scan), ("fused", res_fused)):
        spec = res.spec
        # rebuild the cached batched program and lower it for analysis
        r = resolve(spec)
        loss, x0, wd, objective = _piag_pieces(r)
        fn = make_sweep_piag(loss, x0, wd, r.prox, objective=objective,
                             horizon=r.horizon,
                             engine=spec.execution.engine)
        b = r.grid.buckets()[0]
        T = jnp.asarray(b.grid.service_times(b.width))
        pp = b.grid.policy_params()
        cost = analyze_hlo(fn.lower(T, pp).compile().as_text())
        out[name] = {"flops": float(cost.flops), "bytes": float(cost.bytes),
                     "bytes_per_flop": float(cost.bytes / max(cost.flops, 1)),
                     "bytes_per_step": float(cost.bytes / n_events)}
    return out


def _ledger_split(res: api.Results) -> dict:
    rec = res.telemetry
    return {"compile_ms": float(rec.compile_ms),
            "warm_ms": float(rec.warm_ms),
            "elapsed_ms": float(rec.elapsed_ms)}


def run(n_events: int = 400, n_seeds: int = 4, n_workers: int = 8,
        out: str = "BENCH_pallas_engine.json") -> dict:
    clear_program_cache()
    scan_spec = build_spec(n_events, n_seeds, n_workers, "scan")
    fused_spec = build_spec(n_events, n_seeds, n_workers, "fused")

    cold_s, warm_s, cold_res_s, res_s = timed_runs(scan_spec)
    B = res_s.n_cells
    emit("pallas_engine/scan", cold_s * 1e6,
         f"warm_us={warm_s * 1e6:.1f};cells={B};horizon={res_s.horizon}")

    cold_f, warm_f, cold_res_f, res_f = timed_runs(fused_spec)
    emit("pallas_engine/fused", cold_f * 1e6,
         f"warm_us={warm_f * 1e6:.1f};interpret_cpu="
         f"{jax.default_backend() not in ('tpu', 'gpu')}")
    warm_speedup = warm_s / warm_f
    emit("pallas_engine/speedup", 0.0, f"warm={warm_speedup:.2f}x")

    # ---- hard gate 1: bitwise equivalence on every leaf ------------------
    bitwise = {
        f: bool(np.array_equal(np.asarray(getattr(res_s.raw, f)),
                               np.asarray(getattr(res_f.raw, f))))
        for f in LEAVES}
    bitwise_ok = all(bitwise.values())
    emit("pallas_engine/equivalence", 0.0, f"bitwise_ok={bitwise_ok}")

    # ---- hard gate 2: kernel-boundary bytes/event < scan step bytes ------
    per_event = step_bytes(res_f.horizon, 100)
    bytes_ok = (per_event["fused_boundary_bytes"]
                < per_event["scan_hlo_bytes"])
    reduction = per_event["scan_hlo_bytes"] / per_event["fused_boundary_bytes"]
    emit("pallas_engine/bytes_per_event", per_event["fused_boundary_bytes"],
         f"scan={per_event['scan_hlo_bytes']:.0f};"
         f"reduction={reduction:.2f}x;ok={bytes_ok}")

    # ---- hard gate 3: ledger compile/warm split for the fused runs -------
    split_cold = _ledger_split(cold_res_f)
    split_warm = _ledger_split(res_f)
    ledger_ok = (split_cold["compile_ms"] > 0.0
                 and split_warm["compile_ms"] < 0.1 * split_cold["compile_ms"]
                 and split_warm["warm_ms"] > 0.0)
    emit("pallas_engine/ledger", split_cold["compile_ms"] * 1e3,
         f"warm_compile_ms={split_warm['compile_ms']:.1f};ok={ledger_ok}")

    sweeps = sweep_costs(res_s, res_f, n_events)

    payload = {
        "bench": "pallas_engine",
        "cells": B,
        "n_events": n_events,
        "n_workers": n_workers,
        "horizon": res_f.horizon,
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() not in ("tpu", "gpu"),
        "scan": {"seconds_cold": cold_s, "seconds_warm": warm_s,
                 "ledger": _ledger_split(res_s)},
        "fused": {"seconds_cold": cold_f, "seconds_warm": warm_f,
                  "ledger_cold": split_cold, "ledger_warm": split_warm},
        "warm_speedup": warm_speedup,
        "bytes_per_event": {**per_event, "reduction": reduction},
        "sweep_hlo": sweeps,
        "program_cache": program_cache_stats(),
        "equivalence": {"bitwise": bitwise, "ok": bitwise_ok},
        "gates": {"bitwise": bitwise_ok, "bytes_per_event": bytes_ok,
                  "ledger_split": ledger_ok,
                  "ok": bitwise_ok and bytes_ok and ledger_ok},
    }
    Path(out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}: {B} cells, bitwise ok={bitwise_ok}, "
          f"bytes/event {per_event['fused_boundary_bytes']:.0f} vs scan "
          f"{per_event['scan_hlo_bytes']:.0f} ({reduction:.2f}x less), "
          f"ledger split ok={ledger_ok}, warm speedup {warm_speedup:.2f}x")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--events", type=int, default=400)
    ap.add_argument("--seeds", type=int, default=4)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--out", default="BENCH_pallas_engine.json")
    a = ap.parse_args()
    payload = run(n_events=a.events, n_seeds=a.seeds, n_workers=a.workers,
                  out=a.out)
    if not payload["gates"]["ok"]:
        raise SystemExit(f"pallas_engine gates failed: {payload['gates']}")


if __name__ == "__main__":
    main()
