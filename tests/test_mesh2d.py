"""2-D ``(cells, data)`` sweep meshes (PR 10).

The sharded backend's second mesh axis computes each cell's per-worker
gradients data-parallel (``repro.mesh.pmean_grad``: slice the sample axis
per data shard, psum the partial gradients).  The pins here are the
tentpole acceptance criteria:

* solo vs 1-D sharded vs 2-D sharded rows are bitwise-equal on every
  integer leaf (taus, clipped, blocks, versions, fault counters) for all
  four solvers, objectives equal under jit -- including ragged bucket
  widths and faults-on chaos runs;
* ``round_robin_pad`` keys on the CELLS axis only (a (2, 4) mesh pads like
  a (2,) mesh);
* meshes key the program cache by TOPOLOGY (axis names + shape + device
  kind + process count), so a 1-D and a reshaped 2-D mesh over the same
  devices never share an executable, while same-topology rebuilds do;
* the multi-host knobs bootstrap ``jax.distributed`` exactly once and
  never reach a traced program.

Multi-device assertions activate under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (CI multi-device
lane); on fewer devices they skip.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.mesh as rmesh
from repro.api import run_components
from repro.core import (Adaptive1, FixedStepSize, L1, make_logreg,
                        sample_service_times, trace_scan)
from repro.core.engine import WorkerModel, heterogeneous_workers
from repro.core.piag import piag_scan
from repro.core.stepsize import HingeWeight
from repro.faults import FaultSpec
from repro.federated.events import heterogeneous_clients
from repro.federated.server import _problem_pieces, local_prox_sgd
from repro.mesh import (DATA_AXIS, cell_axis_size, cell_mesh, data_axis_size,
                        grid_mesh, mesh_topology, pmean_grad)
from repro.sweep import (clear_program_cache, make_grid, program_cache_stats,
                         round_robin_pad, sharded_sweep_bcd,
                         sharded_sweep_fedasync, sharded_sweep_fedbuff,
                         sharded_sweep_piag, standard_topology_factories,
                         sweep_bcd_logreg, sweep_piag_logreg)
from repro.sweep.cache import IdKey, _key_fingerprints

N_DEV = len(jax.devices())
needs2 = pytest.mark.skipif(
    N_DEV < 2, reason="needs XLA_FLAGS=--xla_force_host_platform_"
                      "device_count=N (CI multi-device lane)")
needs4 = pytest.mark.skipif(N_DEV < 4, reason="needs >= 4 forced devices")
needs8 = pytest.mark.skipif(N_DEV < 8, reason="needs >= 8 forced devices")


@pytest.fixture(scope="module")
def problem():
    # 32 samples per worker: divisible by every data-axis size used here
    return make_logreg(256, 40, n_workers=8, seed=0)


def _mesh_2d(data: int = 2):
    """(cells, data) mesh over all forced devices."""
    return grid_mesh((N_DEV // data, data))


def _grid(gp, n_events=120, widths=None):
    # ragged grids take width -> workers factories instead of worker lists
    topos = (standard_topology_factories() if widths is not None else
             {"uniform": [WorkerModel() for _ in range(8)],
              "hetero": heterogeneous_workers(8, seed=1)})
    kw = {} if widths is None else {"n_workers": list(widths)}
    return make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=12)},
        seeds=[0, 1], topologies=topos, n_events=n_events, **kw)


def _assert_int_leaves_equal(a, b, fields):
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


# ----------------------------------------------------- mesh construction ----

def test_grid_mesh_shapes_and_validation():
    m1 = grid_mesh((1,))
    assert tuple(m1.axis_names) == ("cells",)
    m2 = grid_mesh((1, 1))
    assert tuple(m2.axis_names) == ("cells", "data")
    assert cell_axis_size(m2) == 1 and data_axis_size(m2) == 1
    assert data_axis_size(m1) == 1
    with pytest.raises(ValueError, match="positive"):
        grid_mesh((0, 2))
    with pytest.raises(ValueError, match="mesh_shape"):
        grid_mesh((1, 2, 3))
    with pytest.raises(ValueError, match="devices"):
        grid_mesh((N_DEV + 1, 2))
    # a sweep mesh without a "cells" axis is rejected loudly
    from jax.sharding import Mesh
    with pytest.raises(ValueError, match="cells"):
        cell_axis_size(Mesh(np.array(jax.devices()[:1]), ("data",)))


def test_execution_spec_mesh_shape_validation():
    from repro.api import ExecutionSpec
    ex = ExecutionSpec(backend="sharded", mesh_shape=(1, 1))
    assert ex.mesh_shape == (1, 1)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ExecutionSpec(backend="sharded", mesh=cell_mesh(), mesh_shape=(1, 1))
    with pytest.raises(ValueError, match="sharded"):
        ExecutionSpec(backend="batched", mesh_shape=(1, 1))
    with pytest.raises(ValueError, match="positive"):
        ExecutionSpec(backend="sharded", mesh_shape=(1, 0))
    with pytest.raises(ValueError, match="process_id"):
        ExecutionSpec(backend="sharded", num_processes=2, process_id=2)
    with pytest.raises(ValueError, match="sharded"):
        ExecutionSpec(backend="batched", coordinator="localhost:1234")


# ------------------------------------------------- topology cache keying ----

def test_mesh_topology_distinct_1d_vs_2d():
    t1 = mesh_topology(cell_mesh(jax.devices()[:1]))
    t2 = mesh_topology(grid_mesh((1, 1)))
    assert t1 != t2  # same device, reshaped: must key fresh
    # same topology, distinct Mesh objects: must key equal
    assert mesh_topology(grid_mesh((1, 1))) == t2
    if N_DEV >= 8:
        tops = {mesh_topology(cell_mesh()),
                mesh_topology(grid_mesh((4, 2))),
                mesh_topology(grid_mesh((2, 4)))}
        assert len(tops) == 3


def test_key_fingerprints_meshes_by_topology():
    """Satellite: meshes inside cache keys fingerprint by (axis names,
    shape, device kind, process count) -- not value identity -- raw or
    IdKey-wrapped."""
    m_a = cell_mesh(jax.devices()[:1])
    m_b = cell_mesh(jax.devices()[:1])   # distinct object, same topology
    m_2d = grid_mesh((1, 1))
    fp = _key_fingerprints(("tag", m_a, IdKey(m_2d)))
    assert len(fp) == 2
    assert all("cells" in print_ for _, print_ in fp)
    assert _key_fingerprints(("tag", m_a)) == _key_fingerprints(("tag", m_b))
    assert _key_fingerprints(("tag", m_a)) != _key_fingerprints(("tag", m_2d))


def test_program_cache_keys_distinct_1d_vs_2d(problem):
    """A 1-D and a (reshaped) 2-D mesh over the same devices build distinct
    executables; a same-topology mesh rebuild reuses the cached one."""
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = _grid(gp, n_events=30)
    # identity-keyed captures must be the SAME objects across calls (note
    # `problem.P` binds a fresh method object per access -- hoist it)
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    obj = problem.P
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    wd = problem.worker_slices()
    clear_program_cache()
    m1 = cell_mesh(jax.devices()[:1])
    sharded_sweep_piag(loss, x0, wd, grid, prox, objective=obj, mesh=m1)
    misses_1d = program_cache_stats()["misses"]
    sharded_sweep_piag(loss, x0, wd, grid, prox, objective=obj,
                       mesh=grid_mesh((1, 1)))
    stats = program_cache_stats()
    assert stats["misses"] == misses_1d + 1  # 2-D keys fresh
    sharded_sweep_piag(loss, x0, wd, grid, prox, objective=obj,
                       mesh=cell_mesh(jax.devices()[:1]))  # fresh Mesh object
    stats2 = program_cache_stats()
    assert stats2["misses"] == stats["misses"]  # same topology: cache hit
    assert stats2["hits"] > stats["hits"]


# ------------------------------------------------------ round-robin rule ----

def test_round_robin_pad_keys_on_cells_axis():
    """The >= 2-cells-per-shard rule applies to the cells axis ONLY: 3
    cells on a (2, 4) mesh pad to 4 rows (2 shards x 2), not to 8 x 2."""
    np.testing.assert_array_equal(round_robin_pad(3, 2), [0, 1, 2, 0])
    assert round_robin_pad(3, 2).shape == (4,)
    # single cell-shard keeps the no-minimum rule regardless of data axis
    np.testing.assert_array_equal(round_robin_pad(3, 1), [0, 1, 2])


@needs8
def test_round_robin_pad_2x4_mesh_regression(problem):
    """Regression (satellite): a 3-cell grid on a (2, 4) mesh on 8 forced
    host devices pads on the 2-wide cells axis and reproduces batched rows
    exactly."""
    mesh = grid_mesh((2, 4))
    assert cell_axis_size(mesh) == 2 and data_axis_size(mesh) == 4
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp)},
        seeds=[0, 1, 2],
        topologies={"uniform": [WorkerModel() for _ in range(8)]},
        n_events=60)
    assert len(grid) == 3
    batched = sweep_piag_logreg(problem, grid, prox)
    sharded = sharded_sweep_piag(
        lambda x, A, b: problem.worker_loss(x, A, b),
        jnp.zeros((problem.dim,), jnp.float32), problem.worker_slices(),
        grid, prox, objective=problem.P, mesh=mesh)
    _assert_int_leaves_equal(batched, sharded, ("taus", "clipped"))
    np.testing.assert_array_equal(np.asarray(batched.gammas),
                                  np.asarray(sharded.gammas))
    np.testing.assert_allclose(np.asarray(batched.objective),
                               np.asarray(sharded.objective),
                               rtol=1e-6, atol=1e-7)


# --------------------------------------- solo vs 1-D vs 2-D: all solvers ----

@needs2
def test_piag_2d_rows_equal_1d_and_solo(problem):
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = _grid(gp)
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    wd = problem.worker_slices()
    one_d = sharded_sweep_piag(loss, x0, wd, grid, prox,
                               objective=problem.P, mesh=cell_mesh())
    two_d = sharded_sweep_piag(loss, x0, wd, grid, prox,
                               objective=problem.P, mesh=_mesh_2d(2))
    # 1-D vs 2-D: identical ParamPolicy arithmetic -> gammas bitwise too
    _assert_int_leaves_equal(one_d, two_d, ("taus", "clipped"))
    np.testing.assert_array_equal(np.asarray(one_d.gammas),
                                  np.asarray(two_d.gammas))
    np.testing.assert_allclose(np.asarray(one_d.objective),
                               np.asarray(two_d.objective),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(one_d.x), np.asarray(two_d.x),
                               rtol=1e-6, atol=1e-7)
    # 2-D vs solo (dataclass policy): taus exact, floats to the usual
    # cross-path envelope
    Aw, bw = wd
    for i in (0, len(grid) // 2, len(grid) - 1):
        cell = grid.cells[i]
        T = sample_service_times(cell.workers, grid.n_events + 1,
                                 seed=cell.seed)
        tr = trace_scan(jnp.asarray(T))
        solo = jax.jit(lambda ev: piag_scan(
            loss, x0, (Aw, bw), ev, cell.policy, prox,
            objective=problem.P))((tr.worker, tr.tau_max))
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(two_d.taus[i]))
        np.testing.assert_array_equal(np.asarray(solo.clipped),
                                      np.asarray(two_d.clipped[i]))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(two_d.objective[i]),
                                   rtol=1e-5, atol=1e-6)


@needs2
def test_piag_2d_ragged_and_chaos_rows_equal(problem):
    """Ragged bucket widths AND faults-on chaos: every integer output --
    taus, clipped, the FaultState counter tuple -- bitwise across 1-D vs
    2-D meshes."""
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    chaos = FaultSpec(p_crash=0.05, p_rejoin=0.3, crash_scale=20.0,
                      p_spike=0.1, spike_scale=10.0, p_drop=0.1,
                      p_dup=0.05, p_corrupt=0.05, seed=7)
    grid = _grid(gp, n_events=100, widths=(4, 8))
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    wd = problem.worker_slices()
    one_d = sharded_sweep_piag(loss, x0, wd, grid, prox,
                               objective=problem.P, mesh=cell_mesh(),
                               faults=chaos)
    two_d = sharded_sweep_piag(loss, x0, wd, grid, prox,
                               objective=problem.P, mesh=_mesh_2d(2),
                               faults=chaos)
    _assert_int_leaves_equal(one_d, two_d, ("taus", "clipped"))
    np.testing.assert_array_equal(np.asarray(one_d.gammas),
                                  np.asarray(two_d.gammas))
    for la, lb in zip(jax.tree_util.tree_leaves(one_d.faults),
                      jax.tree_util.tree_leaves(two_d.faults)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_allclose(np.asarray(one_d.objective),
                               np.asarray(two_d.objective),
                               rtol=1e-6, atol=1e-7)


@needs2
def test_bcd_2d_rows_equal(problem):
    m = 8
    gp = 0.99 / problem.block_smoothness(m)
    prox = L1(lam=problem.lam1)
    grid = _grid(gp, n_events=80)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    mesh2 = _mesh_2d(2)
    dp = pmean_grad(lambda x, A, b: problem.worker_loss(x, A, b),
                    DATA_AXIS, data_axis_size(mesh2))
    dp_grad_f = lambda x: dp(x, problem.A, problem.b)
    batched = sweep_bcd_logreg(problem, grid, prox, m=m)
    two_d = sharded_sweep_bcd(problem.grad_f, problem.P, x0, m, grid, prox,
                              mesh=mesh2, dp_grad_f=dp_grad_f)
    _assert_int_leaves_equal(batched, two_d, ("taus", "blocks", "clipped"))
    np.testing.assert_array_equal(np.asarray(batched.gammas),
                                  np.asarray(two_d.gammas))
    # dp grad is grad(worker_loss) vs the analytic grad_f: same math,
    # different float path -> objectives to the cross-path envelope
    np.testing.assert_allclose(np.asarray(batched.objective),
                               np.asarray(two_d.objective),
                               rtol=1e-4, atol=1e-5)


@needs2
def test_bcd_2d_without_dp_grad_warns_but_matches(problem):
    """A 2-D mesh with an opaque grad_f degrades to replicated compute:
    a RuntimeWarning fires and the rows are bitwise the 1-D mesh rows."""
    m = 8
    gp = 0.99 / problem.block_smoothness(m)
    prox = L1(lam=problem.lam1)
    grid = _grid(gp, n_events=60)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    one_d = sharded_sweep_bcd(problem.grad_f, problem.P, x0, m, grid, prox,
                              mesh=cell_mesh())
    with pytest.warns(RuntimeWarning, match="replicated"):
        two_d = sharded_sweep_bcd(problem.grad_f, problem.P, x0, m, grid,
                                  prox, mesh=_mesh_2d(2))
    _assert_int_leaves_equal(one_d, two_d, ("taus", "blocks", "clipped"))
    np.testing.assert_allclose(np.asarray(one_d.objective),
                               np.asarray(two_d.objective),
                               rtol=1e-6, atol=1e-7)


@needs2
def test_fed_2d_rows_equal(problem):
    prox = L1(lam=problem.lam1)
    lr = 0.5 / problem.L
    grid = make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6)},
        seeds=[0, 1, 2],
        topologies={"edge": heterogeneous_clients(8, seed=5)},
        n_events=80)
    update, x0, data = _problem_pieces(problem, prox, lr)
    mesh2 = _mesh_2d(2)
    dp_update = local_prox_sgd(
        lambda x, A, b: problem.worker_loss(x, A, b), prox, lr,
        grad_fn=pmean_grad(lambda x, A, b: problem.worker_loss(x, A, b),
                           DATA_AXIS, data_axis_size(mesh2)))
    for solver, kw in (("fedasync", {}), ("fedbuff",
                                          dict(eta=0.4, buffer_size=2))):
        runner = (sharded_sweep_fedasync if solver == "fedasync"
                  else sharded_sweep_fedbuff)
        one_d = runner(update, x0, data, grid, objective=problem.P,
                       mesh=cell_mesh(), **kw)
        two_d = runner(dp_update, x0, data, grid, objective=problem.P,
                       mesh=mesh2, **kw)
        _assert_int_leaves_equal(one_d, two_d,
                                 ("taus", "versions", "clipped"))
        np.testing.assert_array_equal(np.asarray(one_d.weights),
                                      np.asarray(two_d.weights))
        np.testing.assert_allclose(np.asarray(one_d.objective),
                                   np.asarray(two_d.objective),
                                   rtol=1e-6, atol=1e-7, err_msg=solver)


@needs2
@pytest.mark.parametrize("solver", ["piag", "bcd", "fedasync", "fedbuff"])
def test_api_mesh_shape_routes_2d_for_all_solvers(problem, solver):
    """ExecutionSpec.mesh_shape end-to-end: the spec path builds the 2-D
    mesh, injects the data-parallel gradient (pmean_grad for PIAG, the
    worker_loss-derived dp grad for BCD, the dp client update for the
    federated servers), and reproduces the 1-D rows with bitwise integer
    leaves."""
    prox = L1(lam=problem.lam1)
    if solver == "bcd":
        gp = 0.99 / problem.block_smoothness(8)
    elif solver == "piag":
        gp = 0.99 / problem.L
    else:
        gp = 0.6
    if solver in ("fedasync", "fedbuff"):
        grid = make_grid(
            policies={"hinge": HingeWeight(gamma_prime=gp)},
            seeds=[0, 1],
            topologies={"edge": heterogeneous_clients(8, seed=5)},
            n_events=60)
    else:
        grid = _grid(gp, n_events=60)
    kw = {"m": 8} if solver == "bcd" else {}
    if solver == "fedbuff":
        kw = dict(eta=0.4, buffer_size=2)
    one_d = run_components(solver, "sharded", problem=problem, grid=grid,
                           prox=prox, mesh_shape=(N_DEV,), **kw)
    two_d = run_components(solver, "sharded", problem=problem, grid=grid,
                           prox=prox, mesh_shape=(N_DEV // 2, 2), **kw)
    ints = {"piag": ("taus", "clipped"), "bcd": ("taus", "blocks", "clipped"),
            "fedasync": ("taus", "versions", "clipped"),
            "fedbuff": ("taus", "versions", "clipped")}[solver]
    _assert_int_leaves_equal(one_d.raw, two_d.raw, ints)
    rtol, atol = (1e-4, 1e-5) if solver == "bcd" else (1e-6, 1e-7)
    np.testing.assert_allclose(np.asarray(one_d.raw.objective),
                               np.asarray(two_d.raw.objective),
                               rtol=rtol, atol=atol)


# ------------------------------------------------------------ guard rails ----

@needs4
def test_pmean_grad_rejects_indivisible_sample_axis():
    """30 samples per worker on a 4-wide data axis: loud trace-time error,
    never a silent sample drop."""
    prob = make_logreg(240, 20, n_workers=8, seed=0)  # 30 per worker
    prox = L1(lam=prob.lam1)
    grid = _grid(0.99 / prob.L, n_events=20)
    with pytest.raises(ValueError, match="divide"):
        sharded_sweep_piag(lambda x, A, b: prob.worker_loss(x, A, b),
                           jnp.zeros((prob.dim,), jnp.float32),
                           prob.worker_slices(), grid, prox,
                           objective=prob.P, mesh=grid_mesh((1, 4)))


def test_maybe_init_distributed_consumes_knobs_once(monkeypatch):
    """The multi-host knobs call jax.distributed.initialize exactly once
    per process and are otherwise inert (no coordinator -> no-op)."""
    calls = []
    monkeypatch.setattr(rmesh, "_DISTRIBUTED_INITIALIZED", False)
    monkeypatch.setattr(
        jax.distributed, "initialize",
        lambda coordinator_address, num_processes, process_id:
            calls.append((coordinator_address, num_processes, process_id)))
    from repro.api import ExecutionSpec
    ex = ExecutionSpec(backend="sharded", coordinator="localhost:9876",
                       num_processes=1, process_id=0)
    assert rmesh.maybe_init_distributed(ex) is True
    assert calls == [("localhost:9876", 1, 0)]
    assert rmesh.maybe_init_distributed(ex) is True  # idempotent
    assert len(calls) == 1
    assert rmesh.maybe_init_distributed(
        ExecutionSpec(backend="sharded")) is False
    assert len(calls) == 1
