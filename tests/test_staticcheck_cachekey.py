"""Tests for repro.staticcheck.cachekey: capture, the completeness
predicate, the seeded-mutation self-test, and the retrace budget.

Full-registry sweeps run in CI via ``python -m repro.staticcheck.cachekey``;
these tests keep to small ``only=`` subsets so tier-1 stays fast.
"""
import pytest

from repro.api.spec import ExecutionSpec
from repro.staticcheck import cachekey as ck


def test_capture_returns_key_and_jaxpr():
    cap = ck.capture(ck.BASES["piag"]())
    assert cap is not None
    assert cap.key[0] == "piag"
    assert cap.fingerprint and cap.in_avals and cap.lines


def test_value_equal_specs_reuse_one_key():
    a = ck.capture(ck.BASES["piag"]())
    b = ck.capture(ck.BASES["piag"]())
    assert a is not None and b is not None
    assert a.key == b.key
    assert a.jaxpr_equal(b)


def test_solo_backend_is_uncached():
    spec = ck.base_spec("piag", execution=ExecutionSpec(backend="solo"))
    assert ck.capture(spec) is None  # builds fresh per call, no cache surface


def test_completeness_subset_classifications():
    subset = [("ExecutionSpec", "record_every"),
              ("SolverSpec", "horizon"),
              ("ExperimentSpec", "n_events")]
    outcomes = {(o.cls, o.field): o
                for o in ck.check_completeness(only=subset)}
    assert not any(o.violation for o in outcomes.values())
    assert outcomes[("ExecutionSpec", "record_every")].status == "key-changed"
    assert outcomes[("SolverSpec", "horizon")].status == "key-changed"
    # n_events changes event-array shapes: jit's shape-keyed trace cache
    # re-traces, so it is safe without a key entry
    assert outcomes[("ExperimentSpec", "n_events")].status == "shape-retrace"


def test_seeded_key_mutation_is_caught():
    """The self-test the checker's value rests on: simulate 'someone
    dropped faults from the key' and the completeness check MUST flag it."""
    subset = [("FaultSpec", "p_drop")]
    clean = ck.check_completeness(only=subset)
    assert all(not o.violation for o in clean)
    mutated = ck.check_completeness(key_filter=ck.strip_faults_from_key,
                                    only=subset)
    assert any(o.violation for o in mutated), \
        "stripping FaultSpec from the cache key must surface a VIOLATION"


def test_forcing_function_covers_every_field():
    assert ck.unregistered_fields() == []


def test_forcing_function_flags_missing_entry(monkeypatch):
    pruned = {k: v for k, v in ck.REGISTRY.items()
              if k != ("FaultSpec", "p_drop")}
    monkeypatch.setattr(ck, "REGISTRY", pruned)
    assert ("FaultSpec", "p_drop") in ck.unregistered_fields()
    with pytest.raises(AssertionError, match="no cache-key coverage"):
        ck.check_completeness()


def test_retrace_budget_subset():
    # two budget properties cheap enough for tier-1: value-equal reuse and
    # a knob keying fresh; the full REPRESENTATIVE matrix gate runs in CI
    a = ck.capture(ck.BASES["piag"]())
    b = ck.capture(ck.BASES["piag"]())
    c = ck.capture(ck.BASES["piag/telemetry"]())
    assert a.key == b.key
    assert c.key != a.key
