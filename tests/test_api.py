"""The unified spec API's redesign contract: bitwise fidelity.

For every solver in {piag, bcd, fedasync, fedbuff} and every backend in
{solo, batched, sharded}, ``repro.api.run(spec)`` rows must be
BITWISE-identical to the pre-redesign runner the spec dispatches to --
the spec layer routes, it never re-implements numerics.  The expected
values here are computed by calling those runners directly with exactly
the argument patterns the legacy conveniences used.

Also pinned: the declarative build path (spec -> problem/policies/grid)
matches the manual construction it automates, spec-build-time horizon
validation (satellite: fail early instead of the post-hoc ``clipped``
counter), the legacy shims (DeprecationWarning + bitwise-equal rows), the
``Results`` common columns, and the small-grid round-robin padding fix.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1,
                        make_logreg)
from repro.core.bcd import run_async_bcd, sample_blocks
from repro.core.engine import (WorkerModel, generate_trace,
                               heterogeneous_workers, sample_service_times)
from repro.core.piag import run_piag
from repro.core.stepsize import HingeWeight, PolyWeight
from repro.federated.events import (generate_federated_trace,
                                    heterogeneous_clients)
from repro.federated.server import (_problem_pieces, run_fedasync,
                                    run_fedbuff)
from repro.sweep import make_grid, round_robin_pad
from repro.sweep.runners import (sweep_bcd, sweep_fedasync, sweep_fedbuff,
                                 sweep_piag)
from repro.sweep.shard import (sharded_sweep_bcd, sharded_sweep_fedasync,
                               sharded_sweep_fedbuff, sharded_sweep_piag)

N_EVENTS = 100
N_EVENTS_FED = 80
M_BLOCKS = 8


@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


@pytest.fixture(scope="module")
def prox(problem):
    return L1(lam=problem.lam1)


@pytest.fixture(scope="module")
def worker_grid(problem):
    gp = 0.99 / problem.L
    return make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=40)},
        seeds=[0, 1],
        topologies={"uniform": [WorkerModel() for _ in range(4)],
                    "hetero": heterogeneous_workers(4, seed=1)},
        n_events=N_EVENTS)


@pytest.fixture(scope="module")
def fed_grid():
    return make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6),
                  "poly": PolyWeight(gamma_prime=0.6, a=0.5)},
        seeds=[0, 1],
        topologies={"edge": heterogeneous_clients(4, seed=2)},
        n_events=N_EVENTS_FED)


def assert_raw_bitwise(actual, expected):
    """Every leaf of the solver result tuple, bit for bit."""
    assert type(actual).__name__ == type(expected).__name__
    for f in expected._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(expected, f)), np.asarray(getattr(actual, f)),
            err_msg=f)


def _stack(rows):
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *rows)


# ------------------------------------------- solver x backend parity ----

def _piag_expected(problem, grid, prox, backend):
    Aw, bw = problem.worker_slices()
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    if backend == "batched":
        return sweep_piag(loss, x0, (Aw, bw), grid, prox,
                          objective=problem.P, horizon=4096)
    if backend == "sharded":
        return sharded_sweep_piag(loss, x0, (Aw, bw), grid, prox,
                                  objective=problem.P, horizon=4096)
    rows = []
    for c in grid.cells:
        T = sample_service_times(c.workers, grid.n_events + 1, seed=c.seed)
        tr = generate_trace(T)
        w = c.n_workers
        rows.append(run_piag(loss, x0, (Aw[:w], bw[:w]), tr, c.policy, prox,
                             objective=problem.P, horizon=4096))
    return _stack(rows)


def _bcd_expected(problem, grid, prox, backend):
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    if backend == "batched":
        return sweep_bcd(problem.grad_f, problem.P, x0, M_BLOCKS, grid, prox,
                         horizon=4096)
    if backend == "sharded":
        return sharded_sweep_bcd(problem.grad_f, problem.P, x0, M_BLOCKS,
                                 grid, prox, horizon=4096)
    rows = []
    for c in grid.cells:
        T = sample_service_times(c.workers, grid.n_events + 1, seed=c.seed)
        tr = generate_trace(T, kind="shared_memory")
        blocks = sample_blocks(M_BLOCKS, grid.n_events, seed=c.seed)
        rows.append(run_async_bcd(problem.grad_f, problem.P, x0, M_BLOCKS,
                                  tr, blocks, c.policy, prox, horizon=4096))
    return _stack(rows)


def _fed_expected(problem, grid, prox, backend, solver):
    update, x0, data = _problem_pieces(problem, prox, None)
    eta, bs = 0.5, (2 if solver == "fedbuff" else 1)
    if backend == "batched":
        if solver == "fedasync":
            return sweep_fedasync(update, x0, data, grid,
                                  objective=problem.P, horizon=4096)
        return sweep_fedbuff(update, x0, data, grid, eta=eta, buffer_size=bs,
                             objective=problem.P, horizon=4096)
    if backend == "sharded":
        if solver == "fedasync":
            return sharded_sweep_fedasync(update, x0, data, grid,
                                          objective=problem.P, horizon=4096)
        return sharded_sweep_fedbuff(update, x0, data, grid, eta=eta,
                                     buffer_size=bs, objective=problem.P,
                                     horizon=4096)
    rows = []
    for c in grid.cells:
        tr = generate_federated_trace(c.n_workers, grid.n_events,
                                      clients=list(c.workers),
                                      buffer_size=bs, seed=c.seed)
        cd = jax.tree_util.tree_map(lambda l: l[:c.n_workers], data)
        if solver == "fedasync":
            rows.append(run_fedasync(update, x0, cd, tr, c.policy,
                                     objective=problem.P, horizon=4096))
        else:
            rows.append(run_fedbuff(update, x0, cd, tr, c.policy, eta=eta,
                                    buffer_size=bs, objective=problem.P,
                                    horizon=4096))
    return _stack(rows)


@pytest.mark.parametrize("backend", api.BACKENDS)
def test_api_piag_rows_bitwise_equal_runner(problem, worker_grid, prox,
                                            backend):
    res = api.run_components("piag", backend, problem=problem,
                             grid=worker_grid, prox=prox, horizon=4096)
    assert res.solver == "piag" and res.backend == backend
    assert_raw_bitwise(res.raw,
                       _piag_expected(problem, worker_grid, prox, backend))


@pytest.mark.parametrize("backend", api.BACKENDS)
def test_api_bcd_rows_bitwise_equal_runner(problem, worker_grid, prox,
                                           backend):
    res = api.run_components("bcd", backend, problem=problem,
                             grid=worker_grid, prox=prox, m=M_BLOCKS,
                             horizon=4096)
    assert_raw_bitwise(res.raw,
                       _bcd_expected(problem, worker_grid, prox, backend))


@pytest.mark.parametrize("backend", api.BACKENDS)
def test_api_fedasync_rows_bitwise_equal_runner(problem, fed_grid, prox,
                                                backend):
    res = api.run_components("fedasync", backend, problem=problem,
                             grid=fed_grid, prox=prox, horizon=4096)
    assert_raw_bitwise(res.raw, _fed_expected(problem, fed_grid, prox,
                                              backend, "fedasync"))


@pytest.mark.parametrize("backend", api.BACKENDS)
def test_api_fedbuff_rows_bitwise_equal_runner(problem, fed_grid, prox,
                                               backend):
    res = api.run_components("fedbuff", backend, problem=problem,
                             grid=fed_grid, prox=prox, eta=0.5,
                             buffer_size=2, horizon=4096)
    assert_raw_bitwise(res.raw, _fed_expected(problem, fed_grid, prox,
                                              backend, "fedbuff"))


# ----------------------------------------------- declarative build ----

def test_declarative_spec_matches_manual_build():
    """A fully-declarative spec (problem + topology + policies built by the
    resolver) reproduces the manually-constructed grid run bitwise: the
    resolver uses the same make_* factories and the same tau-bar protocol
    the callers used inline."""
    spec = api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=240, dim=40, seed=0)),
        solver=api.SolverSpec(name="piag", horizon=4096),
        topology=api.TopologySpec(kind="standard",
                                  names=("uniform", "hetero2"),
                                  n_workers=(4,)),
        policies=api.PolicyGridSpec(names=("adaptive1", "adaptive2"),
                                    seeds=(0, 1)),
        n_events=N_EVENTS)
    res = api.run(spec)

    # the manual equivalent of what the resolver builds
    from repro.sweep import standard_topology_factories
    problem = make_logreg(n_samples=240, dim=40, seed=0, n_workers=4)
    prox = L1(lam=problem.lam1)
    gp = 0.99 / problem.L
    facs = standard_topology_factories(0)
    grid = make_grid({"adaptive1": Adaptive1(gamma_prime=gp),
                      "adaptive2": Adaptive2(gamma_prime=gp)},
                     [0, 1],
                     {k: facs[k] for k in ("uniform", "hetero2")},
                     N_EVENTS, n_workers=[4])
    assert [c.policy_name for c in res.grid.cells] == \
        [c.policy_name for c in grid.cells]
    expected = _piag_expected(problem, grid, prox, "batched")
    assert_raw_bitwise(res.raw, expected)


# ---------------------------------------------- horizon validation ----

def test_spec_construction_rejects_unrepresentable_declared_delay():
    """Satellite: a spec whose horizon cannot represent the DECLARED
    expected max delay fails at construction (window_sum caps at H - 1),
    not via the post-hoc clipped counter."""
    with pytest.raises(ValueError, match="H - 1"):
        api.ExperimentSpec(
            solver=api.SolverSpec(name="piag", horizon=16),
            delay=api.DelaySpec(expected_max_delay=16))
    # H - 1 == expected delay is representable: constructs fine
    api.ExperimentSpec(solver=api.SolverSpec(name="piag", horizon=17),
                       delay=api.DelaySpec(expected_max_delay=16))


def test_resolve_rejects_horizon_below_measured_tau_bar():
    """With no declared bound, the resolver measures tau-bar from the
    grid's own traces and validates the horizon against it BEFORE running
    anything."""
    spec = api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=120, dim=20, seed=0)),
        solver=api.SolverSpec(name="piag", horizon=4),
        topology=api.TopologySpec(kind="standard", names=("straggler",),
                                  n_workers=(4,)),
        policies=api.PolicyGridSpec(names=("adaptive1",), seeds=(0,)),
        n_events=60)
    with pytest.raises(ValueError, match="expected max delay"):
        spec.validate()
    # a roomy horizon passes the same validation
    spec.replace(solver=api.SolverSpec(name="piag", horizon=4096)).validate()


def test_component_spec_skips_validation_for_deliberate_tiny_horizons(
        problem, worker_grid, prox):
    """The shims must keep serving deliberate undersized-horizon runs (the
    clipped-counter diagnostics), so component specs validate nothing."""
    res = api.run_components("piag", "batched", problem=problem,
                             grid=worker_grid, prox=prox, horizon=2)
    assert np.asarray(res.clipped).sum() > 0  # post-hoc counter still works


# ------------------------------------------------------ legacy shims ----

def test_legacy_shims_warn_and_match_spec_rows(problem, worker_grid, prox):
    from repro.sweep import sweep_piag_logreg
    with pytest.warns(DeprecationWarning, match="repro.api"):
        legacy = sweep_piag_logreg(problem, worker_grid, prox)
    res = api.run_components("piag", "batched", problem=problem,
                             grid=worker_grid, prox=prox, horizon=4096)
    assert_raw_bitwise(legacy, res.raw)


def test_legacy_fed_shim_warns_and_matches(problem, fed_grid, prox):
    from repro.sweep import sweep_fedasync_problem
    with pytest.warns(DeprecationWarning, match="repro.api"):
        legacy = sweep_fedasync_problem(problem, fed_grid, prox)
    res = api.run_components("fedasync", "batched", problem=problem,
                             grid=fed_grid, prox=prox, horizon=4096)
    assert_raw_bitwise(legacy, res.raw)


# ------------------------------------------------- Results surface ----

def test_results_common_columns(problem, fed_grid, prox):
    res = api.run_components("fedbuff", "batched", problem=problem,
                             grid=fed_grid, prox=prox, eta=0.5,
                             buffer_size=2)
    # fed weights surface under the unified `gammas` column
    np.testing.assert_array_equal(np.asarray(res.gammas),
                                  np.asarray(res.raw.weights))
    assert "versions" in res.extras
    rows = res.to_rows()
    assert rows[0].keys() >= {"label", "policy", "seed", "topology",
                              "n_workers", "final_objective", "sum_gamma",
                              "max_tau", "clipped"}
    summary = res.per_policy()
    assert set(summary) == {"hinge", "poly"}
    assert res.clipped_summary()["cells"] == len(fed_grid)


def test_results_virtual_time_matches_traces(problem, worker_grid, prox):
    """The wall/virtual-time column reproduces each cell's trace clock."""
    res = api.run_components("piag", "batched", problem=problem,
                             grid=worker_grid, prox=prox)
    vt = res.virtual_time()
    assert vt.shape == (len(worker_grid), worker_grid.n_events)
    c = worker_grid.cells[0]
    T = sample_service_times(c.workers, worker_grid.n_events + 1, seed=c.seed)
    tr = generate_trace(T)
    np.testing.assert_array_equal(vt[0], tr.t_wall.astype(vt.dtype))


def test_execution_spec_bucket_widths_routes_to_runners(problem, prox):
    """ExecutionSpec.bucket_widths overrides the ragged grid's padded-width
    menu: forcing every cell into one width-8 masked bucket must reproduce
    the default (pow-2 buckets) rows -- the bucketed == exact-width
    guarantee -- through the spec API."""
    gp = 0.99 / problem.L
    from repro.sweep import standard_topology_factories
    facs = standard_topology_factories()
    grid = make_grid({"a1": Adaptive1(gamma_prime=gp)}, [0, 1],
                     {"uniform": facs["uniform"]}, 80, n_workers=[3, 4])
    default = api.run_components("piag", "batched", problem=problem,
                                 grid=grid, prox=prox)
    forced = api.run(api.component_spec(
        "piag", "batched", problem=problem, grid=grid, prox=prox).replace(
            execution=api.ExecutionSpec(backend="batched",
                                        bucket_widths=(4,))))
    np.testing.assert_array_equal(np.asarray(default.taus),
                                  np.asarray(forced.taus))
    np.testing.assert_allclose(np.asarray(default.objective),
                               np.asarray(forced.objective),
                               rtol=1e-6, atol=1e-7)


# ------------------------------------------------ shard pad fix ----

def test_round_robin_pad_keeps_two_cells_per_device():
    """Regression: one cell per device made XLA's sharding propagation
    reject the while-loop trace scan; small grids now replay a second
    round-robin round instead."""
    idx = round_robin_pad(8, 8)
    assert idx.size == 16 and set(idx) == set(range(8))
    # single device: no extra padding
    assert round_robin_pad(8, 1).size == 8
    # big grids unchanged: ceil(12 / 8) is already >= 2 per device
    assert round_robin_pad(12, 8).size == 16
    assert round_robin_pad(512, 8).size == 512
