"""Chaos tests for the threaded runtimes (`repro.core.runtime`).

The acceptance pin: a worker killed mid-``PIAGServer.run`` must surface
as an exception on the master within the heartbeat (5s), never a hang --
the old master blocked forever on ``out_q.get()``.  Plus: crash/respawn
with DelayTracker re-stamping, join-leak accounting, and
``SharedMemoryBCD`` worker-exception propagation (the old master spun
forever on the write counter).
"""
import time

import numpy as np
import pytest

from repro.core import Adaptive1, L1, PIAGServer, SharedMemoryBCD, make_logreg
from repro.core.runtime import RunLog, WorkerCrash


@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


@pytest.fixture(scope="module")
def policy(problem):
    return Adaptive1(gamma_prime=0.99 / problem.L)


@pytest.fixture(scope="module")
def prox(problem):
    return L1(lam=problem.lam1)


def test_healthy_run_reports_zero_incidents(problem, policy, prox):
    srv = PIAGServer(problem, policy, prox, n_workers=4, record_every=10)
    log = srv.run(100)
    assert len(log.objective) == 10
    assert log.crashes == 0 and log.respawns == 0 and log.join_failures == 0
    assert np.all(np.isfinite(np.asarray(log.objective)))


@pytest.mark.timeout(30)
def test_killed_worker_raises_within_heartbeat(problem, policy, prox):
    """THE hang fix: worker dies mid-run -> WorkerCrash on the master,
    chained to the worker's own exception, well inside 5s."""
    calls = {"n": 0}

    def killer(i):
        calls["n"] += 1
        if i == 1 and calls["n"] > 6:
            raise RuntimeError("injected kill")
        return 0.0

    srv = PIAGServer(problem, policy, prox, n_workers=4,
                     worker_sleep=killer, heartbeat=5.0)
    t0 = time.perf_counter()
    with pytest.raises(WorkerCrash) as ei:
        srv.run(2000)
    elapsed = time.perf_counter() - t0
    assert elapsed < 5.0, f"crash took {elapsed:.1f}s to surface"
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert "injected kill" in str(ei.value.__cause__)


@pytest.mark.timeout(60)
def test_all_workers_dead_raises_not_hangs(problem, policy, prox):
    def kill_all(i):
        raise RuntimeError("everyone dies")

    srv = PIAGServer(problem, policy, prox, n_workers=4,
                     worker_sleep=kill_all, heartbeat=5.0)
    t0 = time.perf_counter()
    with pytest.raises((WorkerCrash, TimeoutError)):
        srv.run(100)
    assert time.perf_counter() - t0 < 10.0


@pytest.mark.timeout(60)
def test_respawn_revives_crashed_worker(problem, policy, prox):
    """respawn=True: the crashed worker is revived, its DelayTracker entry
    re-stamped at the current write count, and the run completes with the
    incident counted."""
    state = {"killed": False}

    def kill_once(i):
        if i == 2 and not state["killed"]:
            state["killed"] = True
            raise RuntimeError("transient death")
        return 0.0

    srv = PIAGServer(problem, policy, prox, n_workers=4,
                     worker_sleep=kill_once, respawn=True)
    log = srv.run(200, x0=None)
    assert log.crashes == 1 and log.respawns == 1
    assert len(log.objective) == 200
    assert np.all(np.isfinite(np.asarray(log.objective)))
    # a rejoined worker was re-stamped: delays stay bounded by the run
    assert max(log.taus) < 200


@pytest.mark.timeout(60)
def test_respawn_budget_exhausts_to_crash(problem, policy, prox):
    def always_kill(i):
        if i == 0:
            raise RuntimeError("persistent death")
        return 0.0

    srv = PIAGServer(problem, policy, prox, n_workers=4,
                     worker_sleep=always_kill, respawn=True, max_respawns=2)
    with pytest.raises(WorkerCrash):
        srv.run(2000)


@pytest.mark.timeout(60)
def test_bcd_worker_exception_propagates(problem, policy):
    """The BCD master used to spin forever on the write counter when a
    worker died; now the boxed exception re-raises chained."""
    base = L1(lam=problem.lam1)

    class BadProx:
        calls = 0

        def prox(self, v, gamma):
            BadProx.calls += 1
            if BadProx.calls > 10:
                raise RuntimeError("bcd injected kill")
            return base.prox(v, gamma)

    bcd = SharedMemoryBCD(problem, policy, BadProx(), n_workers=4, m_blocks=5)
    t0 = time.perf_counter()
    with pytest.raises(WorkerCrash) as ei:
        bcd.run(100000)
    assert time.perf_counter() - t0 < 10.0
    assert "bcd injected kill" in str(ei.value.__cause__)


def test_bcd_healthy_run_unaffected(problem, policy, prox):
    bcd = SharedMemoryBCD(problem, policy, prox, n_workers=4, m_blocks=5,
                          record_every=10)
    log = bcd.run(100)
    assert len(log.objective) == 10
    assert log.crashes == 0 and log.join_failures == 0


def test_runlog_incident_fields_default_zero():
    log = RunLog()
    assert (log.crashes, log.respawns, log.join_failures) == (0, 0, 0)
    # as_arrays is unchanged: four columns, incident counters stay scalar
    assert len(log.as_arrays()) == 4
