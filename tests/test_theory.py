"""Theorem 1, Example 1, and convergence-rate order checks."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Adaptive1, Adaptive2, NaiveAdaptive, example1,
                        example1_divergence_threshold, verify_theorem1)


def test_example1_divergence_naive():
    """Paper Example 1: gamma_k = c/(tau_k+b) diverges when T > b(e^{2/c}-1)."""
    c, b = 0.5, 1.0
    T = example1_divergence_threshold(c, b)
    xs, gammas, taus = example1(NaiveAdaptive(gamma_prime=c, b=b), T, 40)
    assert xs[-1] > 1e3 * xs[0]
    # per-period contraction factor |1 - sum gamma| > 1
    s = gammas[:T].sum()
    assert s > 2.0


def test_example1_adaptive_converges():
    c, b = 0.5, 1.0
    T = example1_divergence_threshold(c, b)
    for pol in [Adaptive1(gamma_prime=0.9, alpha=0.9),
                Adaptive2(gamma_prime=0.9)]:
        xs, _, _ = example1(pol, T, 40)
        assert xs[-1] < 1e-6


def _mk_theorem1_instance(rng, K, linear=False):
    """Random non-negative sequences engineered to satisfy (9)-(10)."""
    taus = np.minimum(rng.integers(0, 6, size=K), np.arange(K))
    q = np.full(K, 0.95 if linear else 1.0)
    W = rng.random(K) * 2.0
    r = np.full(K, 2.0)
    p = np.full(K, 0.05)   # small p => (10) easy to satisfy; checked anyway
    V = np.zeros(K + 1)
    X = np.zeros(K + 1)
    V[0] = 10.0
    for k in range(K):
        tau = int(taus[k])
        budget = q[k] * V[k] + p[k] * W[k - tau:k].sum() - r[k] * W[k]
        if budget < 0:
            W[k] = max(0.0, W[k] + budget / r[k])  # shrink W_k to keep RHS >= 0
            budget = q[k] * V[k] + p[k] * W[k - tau:k].sum() - r[k] * W[k]
        split = rng.random()
        X[k + 1] = max(budget, 0.0) * split * rng.random()
        V[k + 1] = max(budget, 0.0) - X[k + 1]
    return V, X, W, p, r, q, taus


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_theorem1_numeric(seed, linear):
    rng = np.random.default_rng(seed)
    V, X, W, p, r, q, taus = _mk_theorem1_instance(rng, 60, linear)
    rep = verify_theorem1(V, X, W, p, r, q, taus)
    if rep.premises_hold:
        assert rep.conclusion_V, "Eq. (11) failed though premises hold"
        assert rep.conclusion_X, "Eq. (12) failed though premises hold"


def test_rate_order_sublinear():
    """Corollary 1: with bounded delays, sum of step-sizes grows linearly ->
    O(1/k) objective rate for convex PIAG (checked on the integral)."""
    rng = np.random.default_rng(3)
    n = 800
    taus = np.minimum(rng.integers(0, 9, size=n), np.arange(n))
    g = np.asarray(Adaptive1(gamma_prime=1.0).run(taus.astype(np.int32)))
    csum = np.cumsum(g)
    # integral lower bound ~ alpha*gamma'/(tau+1) * k  (Prop. 1)
    k = np.arange(1, n + 1)
    assert np.all(csum >= 0.9 * 1.0 / 9.0 * k * 0.5 - 1e-6)
