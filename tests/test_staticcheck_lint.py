"""Tests for the trace-safety lint: every rule catches its known-bad
fixture, and the current tree lints clean."""
import os

import pytest

import repro.staticcheck as sc_pkg
from repro.staticcheck.lint import iter_py, lint_file, lint_paths
from repro.staticcheck.rules import ALL_RULES, RULE_DOCS

_PKG_DIR = os.path.dirname(os.path.abspath(sc_pkg.__file__))
_FIXTURES = os.path.join(_PKG_DIR, "fixtures")
_SRC_REPRO = os.path.dirname(_PKG_DIR)  # .../src/repro

EXPECTED = {
    "bad_switch_in_kernel.py": "PAL001",
    "bad_scalar_ref.py": "PAL002",
    "bad_unrouted_pallas.py": "PAL003",
    "bad_host_entropy.py": "JIT001",
    "bad_traced_branch.py": "JIT002",
    "bad_mutate_captured.py": "CACHE001",
}


@pytest.mark.parametrize("fixture,rule", sorted(EXPECTED.items()))
def test_each_rule_catches_its_fixture(fixture, rule):
    findings = lint_file(os.path.join(_FIXTURES, fixture))
    assert findings, f"{fixture} produced no findings"
    assert {f.rule for f in findings} == {rule}, \
        f"{fixture} must trigger ONLY {rule}: {[str(f) for f in findings]}"


def test_rule_catalogue_matches_fixture_corpus():
    assert {r.name for r in ALL_RULES} == set(EXPECTED.values())
    assert set(RULE_DOCS) == set(EXPECTED.values())
    present = {f for f in os.listdir(_FIXTURES) if f.endswith(".py")}
    assert present == set(EXPECTED), \
        "fixture corpus and EXPECTED map drifted apart"


def test_current_tree_lints_clean():
    findings = lint_paths([_SRC_REPRO])
    assert not findings, "\n".join(str(f) for f in findings)


def test_fixtures_excluded_by_default():
    default = set(iter_py([_SRC_REPRO]))
    included = set(iter_py([_SRC_REPRO], include_fixtures=True))
    assert not any("fixtures" in p for p in default)
    assert included - default == {
        os.path.join(_FIXTURES, f) for f in EXPECTED}


def test_select_restricts_rules():
    path = os.path.join(_FIXTURES, "bad_unrouted_pallas.py")
    assert lint_file(path, select=["PAL003"])
    assert lint_file(path, select=["JIT001"]) == []
    with pytest.raises(SystemExit, match="unknown rule"):
        lint_file(path, select=["NOPE999"])


def test_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    findings = lint_file(str(bad))
    assert len(findings) == 1 and findings[0].rule == "PARSE"
