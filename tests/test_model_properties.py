"""Property-based model invariants (hypothesis): causality, batch
permutation equivariance, sliding-window locality."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models import forward, init_params
from repro.models.config import ModelConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=61, q_chunk=8)

CFGS = {
    "dense": ModelConfig(name="d", **BASE),
    "ssm": ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                       vocab=61, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                       d_ff=0, rope="none"),
    "hybrid": ModelConfig(name="h", family="hybrid", n_layers=2, attn_every=2,
                          d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                          d_ff=128, vocab=61, ssm_state=16, ssm_head_dim=16,
                          ssm_chunk=8, q_chunk=8),
    "moe": ModelConfig(name="m", family="moe", n_experts=4, top_k=2,
                       moe_ff=32, moe_impl="dense", **BASE),
}
PARAMS = {k: init_params(c, jax.random.PRNGKey(7)) for k, c in CFGS.items()}


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(4, 28),
       st.sampled_from(sorted(CFGS)))
def test_causality(seed, t, fam):
    """Perturbing tokens at positions > t must not change logits[:, :t+1]."""
    cfg, params = CFGS[fam], PARAMS[fam]
    rng = np.random.default_rng(seed)
    S = 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, S)), jnp.int32)
    toks2 = toks.at[:, t + 1:].set(
        jnp.asarray(rng.integers(0, cfg.vocab, size=(1, S - t - 1)), jnp.int32))
    l1, _ = forward(params, cfg, {"tokens": toks})
    l2, _ = forward(params, cfg, {"tokens": toks2})
    np.testing.assert_allclose(l1[:, :t + 1], l2[:, :t + 1], atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["dense", "ssm", "moe"]))
def test_batch_permutation_equivariance(seed, fam):
    cfg, params = CFGS[fam], PARAMS[fam]
    rng = np.random.default_rng(seed)
    B, S = 4, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    perm = jnp.asarray(rng.permutation(B))
    l1, _ = forward(params, cfg, {"tokens": toks})
    l2, _ = forward(params, cfg, {"tokens": toks[perm]})
    np.testing.assert_allclose(l1[perm], l2, atol=2e-4)


def test_sliding_window_locality():
    """With window W and L layers, position t's receptive field reaches back
    L*(W-1) tokens: perturbations beyond it leave logits[t] unchanged, and
    perturbations inside the single-layer window do change them."""
    W = 8
    cfg = ModelConfig(name="w", sliding_window=W, **BASE)
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    S, t = 32, 28
    field = cfg.n_layers * (W - 1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(1, S)), jnp.int32)
    l1, _ = forward(params, cfg, {"tokens": toks})
    # outside the stacked receptive field: no effect
    lo = t - field
    toks_far = toks.at[:, :lo].set(
        jnp.asarray(rng.integers(0, cfg.vocab, size=(1, lo)), jnp.int32))
    l2, _ = forward(params, cfg, {"tokens": toks_far})
    np.testing.assert_allclose(l1[:, t], l2[:, t], atol=2e-4)
    # inside the window: effect
    toks_near = toks.at[:, t - 2].set((toks[0, t - 2] + 1) % cfg.vocab)
    l3, _ = forward(params, cfg, {"tokens": toks_near})
    assert float(jnp.abs(l1[:, t] - l3[:, t]).max()) > 1e-6


def test_encoder_is_bidirectional():
    cfg = ModelConfig(name="e", family="audio", embed_inputs=True,
                      causal=False, has_decode=False, **BASE)
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    emb = jnp.asarray(rng.normal(size=(1, 16, 64)), jnp.float32)
    emb2 = emb.at[:, -1].add(1.0)
    l1, _ = forward(params, cfg, {"embeds": emb})
    l2, _ = forward(params, cfg, {"embeds": emb2})
    # perturbing the LAST frame changes the FIRST frame's logits
    assert float(jnp.abs(l1[:, 0] - l2[:, 0]).max()) > 1e-6
