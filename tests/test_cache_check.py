"""Tests for REPRO_CACHE_CHECK: runtime fingerprinting of identity-keyed
captured arrays in sweep.cache."""
import numpy as np
import pytest

from repro.sweep import cache


@pytest.fixture
def clean_cache():
    cache.clear_program_cache()
    yield
    cache.clear_program_cache()


def _program(key):
    return cache.cached_program(key, lambda: "program")


def test_mutated_capture_raises_on_hit(clean_cache, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_CHECK", "1")
    data = np.ones(16)
    key = ("tag", 3, cache.IdKey(data))
    assert _program(key) == "program"
    assert _program(key) == "program"  # unchanged: hit verifies silently
    data[0] = 42.0  # in-place mutation after capture
    with pytest.raises(RuntimeError, match="REPRO_CACHE_CHECK"):
        _program(key)


def test_tree_key_captures_are_fingerprinted(clean_cache, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_CHECK", "1")
    x = np.zeros((4, 2))
    key = ("tag",) + cache.tree_key({"x": x})
    _program(key)
    x.fill(7.0)
    with pytest.raises(RuntimeError, match="mutated in place"):
        _program(key)


def test_disabled_by_default_is_silent(clean_cache, monkeypatch):
    monkeypatch.delenv("REPRO_CACHE_CHECK", raising=False)
    data = np.ones(8)
    key = ("tag", cache.IdKey(data))
    _program(key)
    data[0] = 5.0
    assert _program(key) == "program"  # documented stale-reuse contract


def test_clear_program_cache_resets_fingerprints(clean_cache, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_CHECK", "1")
    data = np.ones(8)
    key = ("tag", cache.IdKey(data))
    _program(key)
    data[0] = 5.0
    cache.clear_program_cache()  # the sanctioned intentional-mutation path
    assert _program(key) == "program"


def test_eviction_prunes_fingerprints():
    evicted = []
    lru = cache.LRU(1, on_evict=evicted.append)
    lru.get("a", lambda: 1)
    lru.get("b", lambda: 2)
    assert evicted == ["a"]
    assert list(lru.data) == ["b"]
