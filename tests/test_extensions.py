"""Beyond-paper extensions: on-line Lipschitz estimation (the paper's §5
future work) and chunked cross-entropy."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Adaptive1, AdaptiveLipschitz, L1, check_principle,
                        make_logreg, run_piag_lipschitz, run_piag_logreg,
                        simulate_parameter_server)
from repro.models import init_params, loss_fn
from repro.models.config import ModelConfig


@pytest.fixture(scope="module")
def setup():
    prob = make_logreg(800, 100, n_workers=6, seed=0)
    trace = simulate_parameter_server(6, 1500, seed=1)
    return prob, trace


def test_lipschitz_policy_no_constants_needed(setup):
    """Convergence with NEITHER the delay bound NOR L: start from a 1000x
    too-optimistic budget; the secant estimator self-corrects."""
    prob, trace = setup
    prox = L1(lam=prob.lam1)
    res = run_piag_lipschitz(prob, trace, prox, gamma0=1000.0)
    assert np.all(np.isfinite(res.objective))
    assert res.objective[-1] < res.objective[0] - 0.02
    # L_est ends within a sane band around the true constant
    L_est = float(res.opt_residual[-1])
    assert prob.L * 0.5 <= L_est <= prob.L * 1000


def test_lipschitz_matches_oracle_adaptive(setup):
    prob, trace = setup
    prox = L1(lam=prob.lam1)
    res_lip = run_piag_lipschitz(prob, trace, prox, gamma0=100.0)
    res_orc = run_piag_logreg(prob, trace,
                              Adaptive1(gamma_prime=0.99 / prob.L), prox)
    # near the oracle-L adaptive policy's final objective (the secant
    # estimator is deliberately conservative, so a small gap remains)
    assert res_lip.objective[-1] <= res_orc.objective[-1] * 1.05


def test_lipschitz_trace_respects_principle():
    """With a frozen L_est the emitted gammas satisfy Eq. (8) for
    gamma' = h/L_est."""
    pol = AdaptiveLipschitz(gamma_prime=0.5, h=0.9, alpha=0.9)
    rng = np.random.default_rng(0)
    taus = np.minimum(rng.integers(0, 9, size=200), np.arange(200))
    g = np.asarray(pol.run(taus.astype(np.int32)))
    assert check_principle(g, taus, 0.5)


def test_chunked_ce_matches_dense():
    cfg0 = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, head_dim=16, d_ff=128, vocab=97,
                       q_chunk=8)
    cfg1 = cfg0.replace(ce_chunk=8)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 97)}
    (l0, _), g0 = jax.value_and_grad(lambda p: loss_fn(p, cfg0, batch),
                                     has_aux=True)(params)
    (l1, _), g1 = jax.value_and_grad(lambda p: loss_fn(p, cfg1, batch),
                                     has_aux=True)(params)
    np.testing.assert_allclose(l0, l1, rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_chunked_ce_with_padding_labels():
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, head_dim=16, d_ff=128, vocab=97,
                      q_chunk=8, ce_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 97)
    tgt = tgt.at[:, 20:].set(-1)  # padding
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97),
             "targets": tgt}
    loss, m = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    dense = loss_fn(params, cfg.replace(ce_chunk=0), batch)[0]
    np.testing.assert_allclose(loss, dense, rtol=1e-6)
