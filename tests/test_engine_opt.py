"""Lean-carry engine contracts: measured-delay horizons, decimated
recording, and executable reuse.

Three pins, each the safety net of one optimization:

* ``horizon='auto'`` -- an auto-sized run is BITWISE-equal (objective,
  gammas, taus, x, clipped) to the 4096 worst-case default for every
  solver, and ``Results.horizon`` reports the size actually used.
* ``record_every=s`` -- a decimated run's recorded rows are bitwise rows
  ``s-1, 2s-1, ...`` of the stride-1 run, the final iterate and clipped
  counter are untouched, and the stride is validated (must divide K).
* executable reuse -- a repeated sweep (same objects, same knobs) hits the
  program cache instead of rebuilding+retracing, across direct runner
  calls AND repeated ``api.run`` invocations of value-equal specs.
"""
import numpy as np
import pytest

import jax

from repro import analysis, api
from repro.core import Adaptive1, Adaptive2, FixedStepSize, L1, make_logreg
from repro.core.engine import (WorkerModel, generate_trace,
                               heterogeneous_workers, sample_service_times,
                               strided_scan)
from repro.core.piag import run_piag
from repro.core.stepsize import HingeWeight, PolyWeight, auto_horizon
from repro.federated.events import heterogeneous_clients
from repro.sweep import (clear_program_cache, make_grid, measure_fed_tau_bar,
                         program_cache_stats, sweep_piag)
from repro.sweep.runners import resolve_grid_horizon

import jax.numpy as jnp

N_EVENTS = 96          # divisible by the strides under test
N_EVENTS_FED = 80
STRIDE = 4


@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


@pytest.fixture(scope="module")
def prox(problem):
    return L1(lam=problem.lam1)


@pytest.fixture(scope="module")
def worker_grid(problem):
    gp = 0.99 / problem.L
    return make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "a2": Adaptive2(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=40)},
        seeds=[0, 1],
        topologies={"uniform": [WorkerModel() for _ in range(4)],
                    "hetero": heterogeneous_workers(4, seed=1)},
        n_events=N_EVENTS)


@pytest.fixture(scope="module")
def fed_grid():
    return make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6),
                  "poly": PolyWeight(gamma_prime=0.6, a=0.5)},
        seeds=[0, 1],
        topologies={"edge": heterogeneous_clients(4, seed=2)},
        n_events=N_EVENTS_FED)


def _grid_for(solver, worker_grid, fed_grid):
    return fed_grid if solver in ("fedasync", "fedbuff") else worker_grid


SOLVER_KW = {"piag": {}, "bcd": {"m": 8}, "fedasync": {},
             "fedbuff": {"eta": 0.5, "buffer_size": 2}}


# ------------------------------------------------ auto-horizon bitwise ----

@pytest.mark.parametrize("solver", api.SOLVERS)
def test_auto_horizon_bitwise_equals_default(problem, worker_grid, fed_grid,
                                             prox, solver):
    grid = _grid_for(solver, worker_grid, fed_grid)
    base = api.run_components(solver, "batched", problem=problem, grid=grid,
                              prox=prox, horizon=4096, **SOLVER_KW[solver])
    auto = api.run_components(solver, "batched", problem=problem, grid=grid,
                              prox=prox, horizon="auto", **SOLVER_KW[solver])
    assert base.horizon == 4096
    assert auto.horizon < 4096       # the measured bound is far below 4095
    assert auto.horizon >= 2
    for f in base.raw._fields:
        np.testing.assert_array_equal(np.asarray(getattr(base.raw, f)),
                                      np.asarray(getattr(auto.raw, f)),
                                      err_msg=f"{solver}.{f}")


def test_auto_horizon_matches_measured_bound(worker_grid, fed_grid):
    h = resolve_grid_horizon("auto", worker_grid)
    assert h == auto_horizon(worker_grid.measure_tau_bar())
    hf = resolve_grid_horizon("auto", fed_grid, fed=True)
    assert hf == auto_horizon(measure_fed_tau_bar(fed_grid))
    # integers pass through verbatim
    assert resolve_grid_horizon(512, worker_grid) == 512


def test_solo_run_auto_horizon_bitwise(problem, prox):
    workers = heterogeneous_workers(4, seed=1)
    T = sample_service_times(workers, N_EVENTS + 1, seed=0)
    tr = generate_trace(T)
    Aw, bw = problem.worker_slices()
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    pol = Adaptive1(gamma_prime=0.99 / problem.L)
    base = run_piag(loss, x0, (Aw, bw), tr, pol, prox, objective=problem.P,
                    horizon=4096)
    auto = run_piag(loss, x0, (Aw, bw), tr, pol, prox, objective=problem.P,
                    horizon="auto")
    for f in base._fields:
        np.testing.assert_array_equal(np.asarray(getattr(base, f)),
                                      np.asarray(getattr(auto, f)), err_msg=f)


def test_declarative_auto_horizon_resolves_and_reports(problem):
    spec = api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=240, dim=40, seed=0)),
        solver=api.SolverSpec(name="piag", horizon="auto"),
        topology=api.TopologySpec(kind="standard", names=("uniform",),
                                  n_workers=(4,)),
        policies=api.PolicyGridSpec(names=("adaptive1",), seeds=(0,)),
        n_events=N_EVENTS)
    res = api.run(spec)
    assert res.tau_bar is not None
    assert res.horizon == auto_horizon(res.tau_bar)
    # a declared bound overrides measurement as the sizing input
    spec2 = spec.replace(delay=api.DelaySpec(expected_max_delay=100))
    assert api.run(spec2).horizon == auto_horizon(100)


def test_solver_spec_rejects_bad_horizon_strings():
    with pytest.raises(ValueError, match="auto"):
        api.SolverSpec(name="piag", horizon="tiny")


# ------------------------------------------------ decimated recording ----

@pytest.mark.parametrize("solver", api.SOLVERS)
def test_record_every_rows_bitwise_slices(problem, worker_grid, fed_grid,
                                          prox, solver):
    grid = _grid_for(solver, worker_grid, fed_grid)
    base = api.run_components(solver, "batched", problem=problem, grid=grid,
                              prox=prox, horizon=4096, **SOLVER_KW[solver])
    dec = api.run_components(solver, "batched", problem=problem, grid=grid,
                             prox=prox, horizon=4096, record_every=STRIDE,
                             **SOLVER_KW[solver])
    s = STRIDE
    assert dec.n_samples == grid.n_events // s
    # every recorded column family is the bitwise stride-s slice
    for name in ("objective", "gammas", "taus"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base, name))[:, s - 1::s],
            np.asarray(getattr(dec, name)), err_msg=f"{solver}.{name}")
    # trajectory-independent leaves are untouched
    np.testing.assert_array_equal(np.asarray(base.x), np.asarray(dec.x))
    np.testing.assert_array_equal(np.asarray(base.clipped),
                                  np.asarray(dec.clipped))
    # virtual time decimates with the same phase
    np.testing.assert_array_equal(base.virtual_time()[:, s - 1::s],
                                  dec.virtual_time())


def test_record_every_must_divide_n_events(problem, worker_grid, prox):
    with pytest.raises(ValueError, match="record_every"):
        api.run_components("piag", "batched", problem=problem,
                           grid=worker_grid, prox=prox, record_every=7)


def test_execution_spec_validates_record_every():
    with pytest.raises(ValueError, match="record_every"):
        api.ExecutionSpec(record_every=0)


def test_strided_scan_stride_one_is_plain_scan():
    def make_step(emit):
        def step(c, x):
            c = c + x
            return c, (c if emit else None)
        return step

    xs = jnp.arange(12, dtype=jnp.float32)
    c1, y1 = strided_scan(make_step, jnp.float32(0), xs, 1)
    c3, y3 = strided_scan(make_step, jnp.float32(0), xs, 3)
    ref = jax.lax.scan(make_step(True), jnp.float32(0), xs)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(ref[1]))
    assert float(c1) == float(c3) == float(ref[0])
    np.testing.assert_array_equal(np.asarray(y3), np.asarray(y1)[2::3])
    with pytest.raises(ValueError, match="divide"):
        strided_scan(make_step, jnp.float32(0), xs, 5)


def test_analysis_time_to_tolerance_stride_aware():
    obj = np.array([[5.0, 4.0, 3.0, 2.0, 1.0, 0.5]])
    # stride 1: first hit at event 3
    assert analysis.time_to_tolerance(obj, 2.0)[0] == 3
    # stride 2 view (events 1, 3, 5): hit at column 1 -> event 3
    assert analysis.time_to_tolerance(obj[:, 1::2], 2.0, record_every=2)[0] == 3
    # stride 3 view (events 2, 5): hit at column 1 -> event 5 (>= stride-1)
    assert analysis.time_to_tolerance(obj[:, 2::3], 2.0, record_every=3)[0] == 5
    # never reached stays -1 regardless of stride
    assert analysis.time_to_tolerance(obj[:, 1::2], -1.0, record_every=2)[0] == -1
    assert analysis.time_to_tolerance(obj[0], 2.0) == 3


# ------------------------------------------------- executable reuse ----

def test_repeated_sweep_hits_program_cache(problem, worker_grid, prox):
    clear_program_cache()
    Aw, bw = problem.worker_slices()
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    wd = (Aw, bw)
    obj = problem.P   # bind once: a fresh bound method per access would
    # key as a different captured object (api.run memoizes this for you)
    r1 = sweep_piag(loss, x0, wd, worker_grid, prox, objective=obj)
    s1 = program_cache_stats()
    r2 = sweep_piag(loss, x0, wd, worker_grid, prox, objective=obj)
    s2 = program_cache_stats()
    assert s1["misses"] == 1 and s2["hits"] == s1["hits"] + 1
    assert s2["misses"] == s1["misses"]  # nothing rebuilt
    np.testing.assert_array_equal(np.asarray(r1.objective),
                                  np.asarray(r2.objective))
    # a changed static knob is a different program
    sweep_piag(loss, x0, wd, worker_grid, prox, objective=obj,
               record_every=2)
    assert program_cache_stats()["misses"] == s2["misses"] + 1


def test_repeated_api_run_reuses_executables(problem):
    """Value-equal declarative specs resolve to memoized problem/prox/piece
    objects, so the second api.run finds its bucket programs in the cache
    (the cross-run reuse the resolve-time memoization exists for)."""
    spec = api.ExperimentSpec(
        problem=api.ProblemSpec(kind="logreg",
                                params=dict(n_samples=240, dim=40, seed=0)),
        solver=api.SolverSpec(name="piag"),
        topology=api.TopologySpec(kind="standard", names=("uniform",),
                                  n_workers=(4,)),
        policies=api.PolicyGridSpec(names=("adaptive1",), seeds=(0,)),
        n_events=N_EVENTS)
    clear_program_cache()
    r1 = api.run(spec)
    s1 = program_cache_stats()
    r2 = api.run(spec.replace())   # a fresh, value-equal spec object
    s2 = program_cache_stats()
    assert s2["hits"] > s1["hits"]
    assert s2["misses"] == s1["misses"]
    for f in r1.raw._fields:
        np.testing.assert_array_equal(np.asarray(getattr(r1.raw, f)),
                                      np.asarray(getattr(r2.raw, f)),
                                      err_msg=f)


def test_ragged_grid_buckets_cached_independently(problem, prox):
    gp = 0.99 / problem.L
    from repro.sweep import standard_topology_factories
    facs = standard_topology_factories()
    grid = make_grid({"a1": Adaptive1(gamma_prime=gp)}, [0, 1],
                     {"uniform": facs["uniform"]}, 64, n_workers=[2, 3])
    assert len(grid.buckets()) == 2
    Aw, bw = problem.worker_slices()
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    loss = lambda x, A, b: problem.worker_loss(x, A, b)
    obj = problem.P
    wd = (Aw, bw)
    clear_program_cache()
    sweep_piag(loss, x0, wd, grid, prox, objective=obj)
    s1 = program_cache_stats()
    assert s1["misses"] == 2       # one program per bucket width
    sweep_piag(loss, x0, wd, grid, prox, objective=obj)
    s2 = program_cache_stats()
    assert s2["misses"] == 2 and s2["hits"] == s1["hits"] + 2
