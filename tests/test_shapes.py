"""Input-shape policy logic: windows, ring caches, cache lengths, specs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, input_specs
from repro.configs.shapes import cache_len, decode_window, uses_ring


def test_long_context_uses_ring_for_attention_archs():
    for a in ARCH_IDS:
        cfg = get_config(a)
        shp = SHAPES["long_500k"]
        if not cfg.has_decode:
            continue
        if cfg.family == "ssm":
            assert not uses_ring(cfg, shp)
            assert decode_window(cfg, shp) is None
        else:
            assert uses_ring(cfg, shp)
            w = decode_window(cfg, shp)
            assert w is not None and w <= 8192
            assert cache_len(cfg, shp) == w  # cache is O(window), not O(500k)


def test_decode_32k_keeps_native_behaviour():
    cfg = get_config("starcoder2-15b")  # native sliding window 4096
    shp = SHAPES["decode_32k"]
    assert decode_window(cfg, shp) == 4096
    assert not uses_ring(cfg, shp)
    assert cache_len(cfg, shp) == 32768
    cfg2 = get_config("yi-34b")  # full attention
    assert decode_window(cfg2, shp) is None


def test_input_specs_are_abstract():
    """Specs must be ShapeDtypeStructs -- no device allocation in dry-run."""
    for a in ["deepseek-v2-236b", "mamba2-780m", "qwen2-vl-72b",
              "hubert-xlarge"]:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, _ = applicable(cfg, s)
            if not ok:
                continue
            specs = input_specs(cfg, s)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (a, s.name)


def test_vlm_specs_include_mrope_positions():
    cfg = get_config("qwen2-vl-72b")
    sp = input_specs(cfg, SHAPES["train_4k"])["batch"]
    assert "positions" in sp and sp["positions"].shape == (3, 256, 4096)
    assert "embeds" in sp and sp["embeds"].shape == (256, 4096, 8192)
    assert sp["embeds"].dtype == jnp.bfloat16


def test_audio_specs_are_embeddings_without_positions():
    cfg = get_config("hubert-xlarge")
    sp = input_specs(cfg, SHAPES["prefill_32k"])["batch"]
    assert "embeds" in sp and "tokens" not in sp and "positions" not in sp


def test_mla_cache_is_compressed():
    cfg = get_config("deepseek-v2-236b")
    sp = input_specs(cfg, SHAPES["decode_32k"])
    leaves = jax.tree_util.tree_leaves(sp["cache"])
    # latent cache: (L, B, S, 512) + rope (L, B, S, 64) -- NOT per-head KV
    total_per_tok = sum(l.size // (128 * 32768) for l in leaves)
    assert total_per_tok == 60 * (512 + 64)
