"""Dry-run infrastructure tests.

The full 16x16 / 2x16x16 sweeps run via ``python -m repro.launch.dryrun``
(artifacts in experiments/dryrun).  Here we verify the machinery end-to-end
on a reduced 2x4 mesh in a subprocess (XLA device count must be set before
jax init, hence subprocess), plus unit-test the sharding planner and the
HLO collective parser in-process.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(arch, shape, tmp, mesh="2x4", devices="8"):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_DRYRUN_DEVICES=devices)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", tmp]
    res = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    files = [f for f in os.listdir(tmp) if f.startswith(f"{arch}__{shape}")]
    assert files, res.stdout
    with open(os.path.join(tmp, files[0])) as f:
        return json.load(f)


@pytest.mark.slow
def test_dryrun_train_reduced_mesh(tmp_path):
    rec = _run_dryrun("qwen2-moe-a2.7b", "train_4k", str(tmp_path))
    assert rec["flops_per_device"] > 0
    assert rec["collective_bytes_per_device"] > 0  # DP grad sync must appear
    assert rec["mesh"] == "2x4"


@pytest.mark.slow
def test_dryrun_decode_reduced_mesh(tmp_path):
    rec = _run_dryrun("mamba2-780m", "decode_32k", str(tmp_path))
    assert rec["flops_per_device"] > 0


@pytest.mark.slow
def test_dryrun_skips_encoder_decode(tmp_path):
    rec = _run_dryrun("hubert-xlarge", "long_500k", str(tmp_path))
    assert "skipped" in rec


def test_collective_parser():
    from repro.launch.roofline import parse_collective_bytes
    hlo = """
  %ag = bf16[256,1024]{1,0} all-gather(bf16[16,1024]{1,0} %x), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %y), to_apply=%add
  %rs = f32[8,16]{1,0} reduce-scatter(f32[128,16]{1,0} %z), dimensions={0}
  %fusion = f32[2]{0} fusion(f32[2]{0} %w), calls=%c
  %cp = u32[4]{0} collective-permute(u32[4]{0} %p), source_target_pairs={{0,1}}
"""
    got = parse_collective_bytes(hlo)
    assert got["all-gather"] == 256 * 1024 * 2
    assert got["all-reduce"] == 128 * 4
    assert got["reduce-scatter"] == 128 * 16 * 4
    assert got["collective-permute"] == 4 * 4
    assert got["all-to-all"] == 0


def test_roofline_terms_math():
    from repro.launch.roofline import RooflineTerms, PEAK_FLOPS, HBM_BW, ICI_BW
    t = RooflineTerms(flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2,
                      collective_bytes=ICI_BW * 2,
                      model_flops_total=PEAK_FLOPS * 128, chips=256)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 0.5) < 1e-9
    assert abs(t.t_collective - 2.0) < 1e-9
    assert t.dominant == "collective"
    assert abs(t.useful_ratio - 0.5) < 1e-9


def test_model_flops_moe_uses_active_params():
    from repro.configs import SHAPES, get_config
    from repro.launch.roofline import count_params, model_flops
    cfg = get_config("deepseek-v2-236b")
    total, active = count_params(cfg)
    assert active < 0.25 * total  # 236B total, ~21B active + attn/embed
    mf = model_flops(cfg, SHAPES["train_4k"])
    assert mf == pytest.approx(6 * active * 256 * 4096, rel=1e-6)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "mamba2-780m",
                                  "deepseek-v2-236b"])
def test_distributed_execution(arch, tmp_path):
    """Beyond compile: EXECUTE the sharded delay-adaptive train step on an
    8-device host mesh (real collectives, real sharded params)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_DRYRUN_DEVICES="8")
    cmd = [sys.executable, "-m", "repro.launch.run_distributed", "--arch",
           arch, "--reduced", "--steps", "2", "--mesh", "2x4",
           "--batch", "8", "--seq", "32"]
    res = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "DISTRIBUTED_RUN_OK" in res.stdout
