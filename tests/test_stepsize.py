"""Step-size policies: principle (8), window sums, Proposition 1."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (Adaptive1, Adaptive2, FixedStepSize, NaiveAdaptive,
                        SunDengFixed, check_principle, make_delays,
                        make_policy, prop1_lower_bounds, window_sum)
from repro.core.stepsize import init_state

GAMMA = 0.7


def brute_window_sum(gammas, k, tau):
    return float(np.sum(gammas[max(k - tau, 0):k]))


def test_window_sum_matches_bruteforce():
    rng = np.random.default_rng(0)
    state = init_state(64)
    gammas = []
    pol = Adaptive1(gamma_prime=GAMMA)
    for k in range(200):
        tau = int(rng.integers(0, min(k, 50) + 1))
        ws, _ = window_sum(state, jnp.int32(tau))
        assert abs(float(ws) - brute_window_sum(gammas, k, tau)) < 1e-4
        g, state = pol.step(state, jnp.int32(tau))
        gammas.append(float(g))


@pytest.mark.parametrize("model", ["constant", "random", "burst", "markov"])
@pytest.mark.parametrize("policy_name", ["adaptive1", "adaptive2", "fixed"])
def test_policies_satisfy_principle(model, policy_name):
    taus = make_delays(model, 400, 15, seed=1)
    kwargs = {"tau_bound": 15} if policy_name == "fixed" else {}
    pol = make_policy(policy_name, GAMMA, **kwargs)
    g = np.asarray(pol.run(taus))
    assert check_principle(g, taus, GAMMA)
    assert g.sum() > 0  # and sum gamma = inf in the limit (nonzero rate)


def test_naive_violates_principle():
    taus = make_delays("constant", 300, 10, seed=0)
    g = np.asarray(NaiveAdaptive(gamma_prime=GAMMA, b=1.0).run(taus))
    assert not check_principle(g, taus, GAMMA)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40),
       st.sampled_from(["adaptive1", "adaptive2"]))
def test_principle_property(seed, tau_max, policy_name):
    """Hypothesis: for ANY bounded delay trace, the adaptive policies obey
    Eq. (8) -- the system invariant the convergence proof needs."""
    rng = np.random.default_rng(seed)
    n = 150
    taus = np.minimum(rng.integers(0, tau_max + 1, size=n), np.arange(n))
    pol = make_policy(policy_name, GAMMA)
    g = np.asarray(pol.run(taus.astype(np.int32)))
    assert check_principle(g, taus, GAMMA)
    assert np.all(g >= 0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 25))
def test_prop1_lower_bounds(seed, tau_max):
    rng = np.random.default_rng(seed)
    n = 300
    taus = np.minimum(rng.integers(0, tau_max + 1, size=n), np.arange(n))
    alpha = 0.9
    g1 = np.asarray(Adaptive1(gamma_prime=GAMMA, alpha=alpha).run(taus))
    lhs, b1, _ = prop1_lower_bounds(g1, taus, GAMMA, alpha, tau_max)
    assert np.all(lhs >= b1 - 1e-5), "Eq. (15) violated"
    g2 = np.asarray(Adaptive2(gamma_prime=GAMMA).run(taus))
    lhs2, _, b2 = prop1_lower_bounds(g2, taus, GAMMA, alpha, tau_max)
    assert np.all(lhs2 >= b2 - 1e-5), "Eq. (16) violated"


def test_burst_speedup_vs_fixed():
    """Paper §3.4: under burst delays the adaptive integral approaches
    alpha*(tau+1) x the fixed policy's."""
    tau = 5
    taus = make_delays("burst", 2000, tau, period=100)
    g_ad = np.asarray(Adaptive1(gamma_prime=GAMMA, alpha=0.9).run(taus)).sum()
    g_fx = np.asarray(FixedStepSize(gamma_prime=GAMMA, tau_bound=tau).run(taus)).sum()
    assert g_ad > 3.0 * g_fx  # asymptotically 0.9 * 6 = 5.4x


def test_no_delay_runs_at_full_budget():
    taus = np.zeros(50, np.int32)
    g = np.asarray(Adaptive2(gamma_prime=GAMMA).run(taus))
    np.testing.assert_allclose(g, GAMMA, rtol=1e-6)


def test_fixed_variants():
    for pol in [SunDengFixed(gamma_prime=GAMMA, tau_bound=7),
                make_policy("davis", GAMMA, tau_bound=7, ratio=0.5)]:
        g = np.asarray(pol.run(np.zeros(10, np.int32)))
        assert np.all(g > 0) and np.all(np.diff(g) == 0)
