"""Minimal deterministic stand-in for ``hypothesis`` (offline container).

The real package is not installed in this environment and cannot be added.
This stub implements the tiny slice of the API the test-suite uses --
``given`` / ``settings`` / ``strategies.{integers,floats,booleans,
sampled_from}`` / ``assume`` -- by drawing ``max_examples`` pseudo-random
examples from a generator seeded by the test's qualified name, so runs are
deterministic and failures reproducible.  It is installed into
``sys.modules`` by ``conftest.py`` ONLY when the real hypothesis is missing;
with hypothesis installed the tests run unchanged.
"""
from __future__ import annotations

import inspect
import zlib

import numpy as np


class _Unsatisfied(Exception):
    """Raised by assume(False); the example is skipped."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value) -> _Strategy:
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: lo + (hi - lo) * float(rng.random()))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 10)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(max(n * 5, n)):  # headroom for assume() rejections
                if ran >= n:
                    break
                vals = [s.draw(rng) for s in strats]
                kwvals = {k: s.draw(rng) for k, s in kwstrats.items()}
                try:
                    fn(*args, *vals, **kwargs, **kwvals)
                except _Unsatisfied:
                    continue
                ran += 1
        # pytest must NOT see the strategy params as fixtures: present a
        # zero-arg signature (the real hypothesis does the same).
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature([])
        wrapper._stub_max_examples = getattr(fn, "_stub_max_examples", 10)
        wrapper.is_hypothesis_test = True
        return wrapper
    return deco
