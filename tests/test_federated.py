"""`repro.federated`: staleness-weight policies, event traces, servers."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import L1, make_logreg, make_policy, solve_centralized
from repro.federated import (ClientModel, heterogeneous_clients,
                             local_prox_sgd, run_fedasync,
                             run_fedasync_problem, run_fedbuff,
                             run_fedbuff_problem, simulate_federated)
from repro.core.engine import WorkerModel


# ---------------------------------------------------------------- policies

@pytest.mark.parametrize("name,kwargs", [
    ("hinge", {"a": 4.0, "b": 4.0}),
    ("hinge", {"a": 0.5, "b": 16.0}),   # a < 1: regression for the +1 term
    ("poly", {"a": 0.5}),
])
def test_staleness_weights_monotone_in_tau(name, kwargs):
    """s(tau) must never up-weight a staler model."""
    pol = make_policy(name, 0.5, **kwargs)
    taus = np.arange(0, 60, dtype=np.int32)
    g = np.asarray(pol.run(taus))
    assert np.all(np.diff(g) <= 1e-7)
    assert g[0] == pytest.approx(0.5)      # fresh return gets the full weight
    assert np.all(g > 0)                   # stale models still participate


def test_constant_weight_reduces_to_fedavg_mixing():
    """make_policy('constant', alpha) ignores tau entirely: every upload is
    mixed with the same weight -- FedAvg-style aggregation."""
    pol = make_policy("constant", 0.3)
    taus = np.array([0, 1, 17, 300, 2], np.int32)
    np.testing.assert_allclose(np.asarray(pol.run(taus)), 0.3, rtol=1e-6)


# ------------------------------------------------------------------ traces

def test_federated_trace_deterministic():
    a = simulate_federated(6, 400, seed=7)
    b = simulate_federated(6, 400, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = simulate_federated(6, 400, seed=8)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, c))


def test_federated_trace_invariants():
    tr = simulate_federated(5, 300, buffer_size=3, seed=0)
    assert np.all(tr.tau >= 0)
    assert np.all(np.diff(tr.t_wall) >= 0)
    # versions only advance on aggregation events, one at a time
    v = np.concatenate([[0], np.asarray(tr.version)])
    assert np.array_equal(np.diff(v), np.asarray(tr.aggregate))
    # staleness = (version before the event) - (version the round read)
    assert np.array_equal(np.asarray(tr.tau),
                          np.asarray(tr.version) - np.asarray(tr.aggregate)
                          - np.asarray(tr.read_at))
    # every third upload closes the buffer
    assert tr.n_writes == 100


def test_dropout_increases_staleness():
    flaky = [ClientModel(compute=WorkerModel(mean=1.0), p_dropout=0.3,
                         rejoin_after=20.0) for _ in range(4)]
    steady = [ClientModel(compute=WorkerModel(mean=1.0)) for _ in range(4)]
    tr_flaky = simulate_federated(4, 500, flaky, seed=0)
    tr_steady = simulate_federated(4, 500, steady, seed=0)
    assert tr_flaky.t_wall[-1] > tr_steady.t_wall[-1]  # lost rounds cost time


# ----------------------------------------------------------------- servers

def _tiny_problem(seed=0):
    return make_logreg(n_samples=240, dim=24, n_workers=6, seed=seed)


def test_fedbuff_buffer1_equals_sequential_reference():
    """At |R| = 1 the buffered server must collapse to sequential application
    of x <- x + eta * s(tau) * (x_c - x_read): checked against a plain python
    loop over the same trace."""
    prob = _tiny_problem()
    prox = L1(lam=prob.lam1)
    tr = simulate_federated(6, 120, seed=2)
    pol = make_policy("poly", 1.0, a=0.5)
    lr = 0.5 / prob.L
    eta = 0.3
    res = run_fedbuff_problem(prob, tr, pol, prox, eta=eta, buffer_size=1,
                              local_lr=lr)

    # reference: numpy loop, same local prox-SGD client update
    Aw, bw = prob.worker_slices()
    update = local_prox_sgd(lambda x, A, b: prob.worker_loss(x, A, b), prox, lr)
    x = np.zeros((prob.dim,), np.float32)
    x_read = np.zeros((6, prob.dim), np.float32)
    for k in range(tr.n_events):
        w = int(tr.client[k])
        tau = int(tr.tau[k])
        xc = np.asarray(update(jnp.asarray(x_read[w]), int(tr.local_steps[k]),
                               Aw[w], bw[w]))
        s = (tau + 1.0) ** -0.5
        x = x + eta * s * (xc - x_read[w])
        x_read[w] = x
    np.testing.assert_allclose(np.asarray(res.x), x, rtol=2e-4, atol=2e-5)


def test_fedasync_updates_only_mix_toward_client_models():
    """Mixing weight in (0, 1] keeps the server model in the convex hull of
    {previous model, client model} -- a pure-mixing invariant FedBuff's delta
    form does not have."""
    prob = _tiny_problem()
    prox = L1(lam=prob.lam1)
    tr = simulate_federated(6, 100, seed=3)
    res = run_fedasync_problem(prob, tr, make_policy("hinge", 1.0, a=2.0, b=2.0),
                               prox, local_lr=0.5 / prob.L)
    w = np.asarray(res.weights)
    assert np.all(w > 0) and np.all(w <= 1.0)


def test_fedasync_delay_adaptive_converges_to_centralized_optimum():
    """Delay-adaptive FedAsync on heterogeneous straggler clients reaches the
    centralized logreg optimum (suboptimality well inside the initial gap)."""
    prob = make_logreg(n_samples=500, dim=50, n_workers=8, seed=0)
    prox = L1(lam=prob.lam1)
    _, objs = solve_centralized(prob, prox, iters=3000)
    p_star = float(objs[-1])
    gap0 = float(prob.P(jnp.zeros(prob.dim))) - p_star

    clients = heterogeneous_clients(8, spread=4.0, seed=1, p_straggle=0.05,
                                    p_dropout=0.02)
    tr = simulate_federated(8, 3000, clients, seed=1)
    assert tr.max_delay() > 20          # the straggler regime we care about

    pol = make_policy("hinge", 0.4, a=0.5, b=16.0)
    res = run_fedasync_problem(prob, tr, pol, prox, local_lr=0.5 / prob.L)
    sub = np.asarray(res.objective) - p_star
    assert sub[-1] <= 0.25 * gap0       # final model close to optimum
    assert sub.min() <= 0.1 * gap0      # and the trajectory got much closer


def test_fedbuff_matches_fedasync_scale():
    """FedBuff with a larger buffer takes fewer (but bigger) server writes;
    both reduce the objective on the same upload budget."""
    prob = _tiny_problem()
    prox = L1(lam=prob.lam1)
    p0 = float(prob.P(jnp.zeros(prob.dim)))
    tr1 = simulate_federated(6, 400, seed=4, buffer_size=1)
    tr4 = simulate_federated(6, 400, seed=4, buffer_size=4)
    r1 = run_fedasync_problem(prob, tr1, make_policy("poly", 0.4, a=0.5),
                              prox, local_lr=0.5 / prob.L)
    r4 = run_fedbuff_problem(prob, tr4, make_policy("poly", 1.0, a=0.5), prox,
                             eta=0.4, buffer_size=4, local_lr=0.5 / prob.L)
    assert float(r1.objective[-1]) < p0
    assert float(r4.objective[-1]) < p0
    assert tr4.n_writes == 100
