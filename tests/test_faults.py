"""The fault-injection & resilience layer (`repro.faults`).

Load-bearing pins, mirroring the telemetry contract:

* **bitwise-off**: a disabled ``FaultSpec`` normalizes to None and every
  solver output on every backend is bitwise-equal to never mentioning
  faults at all -- the fault layer adds zero risk to fault-free runs;
* **backend equivalence under chaos**: the same ``FaultSpec`` produces
  bitwise-equal integer outputs (taus) and fault counters on solo,
  batched and sharded backends (floats to the repo's solo-vs-batched
  XLA-program envelope), because the fault randomness folds the per-cell
  seed, not the backend layout;
* guard semantics at the unit level (drop / dup / corrupt / staleness /
  degradation);
* sweep checkpointing: a killed sweep resumes bitwise from saved buckets,
  and a checkpoint written by a different spec is refused;
* spec validation: the fused engine and the federated heapq reference
  twin refuse fault injection loudly instead of ignoring it.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.core import (Adaptive1, FixedStepSize, L1, make_logreg)
from repro.core.engine import WorkerModel, heterogeneous_workers
from repro.core.stepsize import HingeWeight
from repro.faults import (FAULT_PRESETS, FaultSpec, normalize_faults,
                          parse_faults)
from repro.faults.guards import (FaultState, fault_gamma_prime, guard_event,
                                 guarded_gamma, init_faults, summarize_faults)
from repro.faults.inject import inject_service_times, update_fault_codes
from repro.federated.events import heterogeneous_clients
from repro.sweep import make_grid

N_EVENTS = 100
N_EVENTS_FED = 80

SOLVER_KW = {"piag": {}, "bcd": {"m": 8}, "fedasync": {},
             "fedbuff": {"eta": 0.5, "buffer_size": 2}}

# the repo's documented solo-vs-batched float contract: integer outputs
# are exact, float outputs agree to a few ulps (different XLA programs)
FLOAT_TOL = dict(rtol=1e-5, atol=1e-6)

CHAOS = FaultSpec(p_crash=0.05, p_rejoin=0.3, crash_scale=20.0,
                  p_spike=0.05, p_drop=0.05, p_dup=0.05, p_corrupt=0.05,
                  staleness_cutoff=64, seed=3)


@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


@pytest.fixture(scope="module")
def prox(problem):
    return L1(lam=problem.lam1)


@pytest.fixture(scope="module")
def worker_grid(problem):
    gp = 0.99 / problem.L
    return make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=40)},
        seeds=[0, 1],
        topologies={"uniform": [WorkerModel() for _ in range(4)],
                    "hetero": heterogeneous_workers(4, seed=1)},
        n_events=N_EVENTS)


@pytest.fixture(scope="module")
def fed_grid():
    return make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6)},
        seeds=[0, 1],
        topologies={"edge": heterogeneous_clients(4, seed=2)},
        n_events=N_EVENTS_FED)


def _grid_for(solver, worker_grid, fed_grid):
    return fed_grid if solver in ("fedasync", "fedbuff") else worker_grid


def _run(solver, backend, problem, grid, prox, faults, **kw):
    return api.run_components(solver, backend, problem=problem, grid=grid,
                              prox=prox, horizon=4096, faults=faults,
                              **{**SOLVER_KW[solver], **kw})


# -------------------------------------------------- bitwise-off contract --

@pytest.mark.parametrize("backend", api.BACKENDS)
@pytest.mark.parametrize("solver", list(api.SOLVERS))
def test_faults_off_is_bitwise(solver, backend, problem, worker_grid,
                               fed_grid, prox):
    """A disabled FaultSpec must not perturb a single bit of any solver
    output on any backend: ``normalize_faults`` collapses it to None and
    every consumer branches on ``faults is None`` only."""
    grid = _grid_for(solver, worker_grid, fed_grid)
    off = _run(solver, backend, problem, grid, prox, faults=None)
    disabled = _run(solver, backend, problem, grid, prox,
                    faults=FaultSpec(enabled=False, p_drop=0.5, p_crash=0.5,
                                     p_rejoin=0.5))
    assert getattr(off.raw, "faults", None) is None
    assert getattr(disabled.raw, "faults", None) is None
    for f in off.raw._fields:
        if f in ("telemetry", "faults"):
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(off.raw, f)),
            np.asarray(getattr(disabled.raw, f)),
            err_msg=f"{solver}/{backend}/{f}")


# ---------------------------------------- backend equivalence under chaos --

@pytest.mark.parametrize("solver", list(api.SOLVERS))
def test_chaos_solo_matches_batched(solver, problem, worker_grid, fed_grid,
                                    prox):
    """Same FaultSpec, same cells: solo and batched agree -- taus bitwise,
    floats within the solo-vs-batched envelope, fault counters exactly."""
    grid = _grid_for(solver, worker_grid, fed_grid)
    batched = _run(solver, "batched", problem, grid, prox, faults=CHAOS)
    solo = _run(solver, "solo", problem, grid, prox, faults=CHAOS)
    np.testing.assert_array_equal(np.asarray(batched.raw.taus),
                                  np.asarray(solo.raw.taus))
    np.testing.assert_allclose(np.asarray(batched.raw.objective),
                               np.asarray(solo.raw.objective), **FLOAT_TOL)
    cb = summarize_faults(batched.raw.faults)
    cs = summarize_faults(solo.raw.faults)
    assert cb == cs
    assert cb["injected"] > 0 or cb["dropped"] > 0  # chaos actually bites
    assert batched.telemetry.faults == cb  # counters ride the ledger record


def test_chaos_counters_survive_sharded(problem, worker_grid, prox):
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    batched = _run("piag", "batched", problem, worker_grid, prox,
                   faults=CHAOS)
    sharded = _run("piag", "sharded", problem, worker_grid, prox,
                   faults=CHAOS)
    np.testing.assert_array_equal(np.asarray(batched.raw.taus),
                                  np.asarray(sharded.raw.taus))
    assert summarize_faults(batched.raw.faults) \
        == summarize_faults(sharded.raw.faults)


def test_corruption_without_guard_poisons_with_guard_rejects(problem,
                                                             worker_grid,
                                                             prox):
    """The non-finite guard is the difference between a poisoned iterate
    and a counted skip: with p_corrupt > 0 and the guard off the objective
    goes NaN; with the guard on every output stays finite."""
    corrupt = FaultSpec(p_corrupt=0.3, seed=1, guard_nonfinite=False)
    res_bad = _run("piag", "batched", problem, worker_grid, prox,
                   faults=corrupt)
    assert not np.all(np.isfinite(np.asarray(res_bad.raw.objective)))
    res_ok = _run("piag", "batched", problem, worker_grid, prox,
                  faults=corrupt.replace(guard_nonfinite=True))
    assert np.all(np.isfinite(np.asarray(res_ok.raw.objective)))
    counters = summarize_faults(res_ok.raw.faults)
    assert counters["rejected_nonfinite"] > 0
    assert counters["rejected_nonfinite"] >= counters["injected"] * 0 + 1


# -------------------------------------------------------- guard units ----

def test_guard_event_drop_dup_and_staleness():
    spec = FaultSpec(staleness_cutoff=8)
    fs = init_faults()
    # clean event: accepted, mult 1
    acc, mult, fs = guard_event(spec, jnp.int32(0), jnp.int32(2),
                                jnp.bool_(True), fs)
    assert bool(acc) and int(mult) == 1
    # drop: rejected, counted
    acc, mult, fs = guard_event(spec, jnp.int32(1), jnp.int32(2),
                                jnp.bool_(True), fs)
    assert not bool(acc) and int(fs.dropped) == 1
    # dup: accepted at mult 2
    acc, mult, fs = guard_event(spec, jnp.int32(2), jnp.int32(2),
                                jnp.bool_(True), fs)
    assert bool(acc) and int(mult) == 2 and int(fs.duplicated) == 1
    # non-finite payload: rejected
    acc, mult, fs = guard_event(spec, jnp.int32(0), jnp.int32(2),
                                jnp.bool_(False), fs)
    assert not bool(acc) and int(fs.rejected_nonfinite) == 1
    # stale beyond cutoff: rejected
    acc, mult, fs = guard_event(spec, jnp.int32(0), jnp.int32(9),
                                jnp.bool_(True), fs)
    assert not bool(acc) and int(fs.rejected_stale) == 1


def test_guarded_gamma_degrades_on_clip():
    """Horizon overflow with degrade_on_clip falls back to the worst-case
    bound gamma'/(tau+1) instead of trusting a truncated window sum."""
    from repro.core.stepsize import Adaptive1 as A1
    policy = A1(gamma_prime=0.5)
    ss = policy.init(horizon=4)
    spec = FaultSpec(degrade_on_clip=True)
    fs = init_faults()
    tau = jnp.int32(100)  # way past horizon 4 -> clipped
    gamma, ss2, fs = guarded_gamma(policy, ss, tau, jnp.int32(1), spec, fs)
    assert int(fs.degraded) == 1
    np.testing.assert_allclose(float(gamma),
                               fault_gamma_prime(policy) / (100 + 1),
                               rtol=1e-6)


def test_summarize_faults_none_and_zero():
    assert summarize_faults(None) == {}
    z = summarize_faults(init_faults())
    assert set(z) == set(FaultState._fields) and all(v == 0
                                                    for v in z.values())


# ----------------------------------------------------- injection units ----

def test_update_fault_codes_deterministic_and_bounded():
    spec = FaultSpec(p_drop=0.2, p_dup=0.2, p_corrupt=0.2, seed=5)
    c1 = np.asarray(update_fault_codes(spec, 512, jnp.int32(7)))
    c2 = np.asarray(update_fault_codes(spec, 512, jnp.int32(7)))
    np.testing.assert_array_equal(c1, c2)  # same cell seed -> same codes
    assert set(np.unique(c1)) <= {0, 1, 2, 3}
    assert (c1 > 0).mean() > 0.3  # ~60% of events faulted at these rates
    c3 = np.asarray(update_fault_codes(spec, 512, jnp.int32(8)))
    assert not np.array_equal(c1, c3)  # per-cell streams differ


def test_inject_service_times_spikes_stretch_time():
    T = jnp.ones((4, 64), jnp.float32)
    spec = FaultSpec(p_crash=0.1, p_rejoin=0.3, crash_scale=25.0, seed=0)
    Tf = np.asarray(inject_service_times(T, spec, jnp.int32(0)))
    assert Tf.shape == T.shape
    assert np.all(Tf >= np.asarray(T) - 1e-6)  # faults only slow workers
    assert Tf.sum() > float(np.asarray(T).sum()) * 1.5  # outages bite


# ----------------------------------------------- checkpointing / resume ----

def test_sweep_checkpoint_resume_bitwise(tmp_path, problem, worker_grid,
                                         prox):
    ckpt = str(tmp_path / "ck")
    first = _run("piag", "batched", problem, worker_grid, prox,
                 faults=CHAOS, resume=ckpt)
    files = sorted(p.name for p in (tmp_path / "ck").glob("*.npz"))
    assert files, "no checkpoint buckets written"
    again = _run("piag", "batched", problem, worker_grid, prox,
                 faults=CHAOS, resume=ckpt)
    for f in first.raw._fields:
        if f in ("telemetry", "faults"):
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(first.raw, f)),
            np.asarray(getattr(again.raw, f)), err_msg=f)
    assert summarize_faults(first.raw.faults) \
        == summarize_faults(again.raw.faults)


def test_sweep_checkpoint_refuses_other_spec(tmp_path, problem, worker_grid,
                                             prox):
    ckpt = str(tmp_path / "ck2")
    _run("piag", "batched", problem, worker_grid, prox, faults=CHAOS,
         resume=ckpt)
    with pytest.raises(ValueError, match="different spec"):
        _run("piag", "batched", problem, worker_grid, prox,
             faults=CHAOS.replace(seed=99), resume=ckpt)


# ------------------------------------------------------ spec validation ----

def test_fused_engine_refuses_faults(problem, worker_grid, prox):
    with pytest.raises(ValueError, match="fused"):
        api.component_spec("piag", "batched", problem=problem,
                           grid=worker_grid, prox=prox, engine="fused",
                           faults=FaultSpec(p_drop=0.1))


def test_fed_reference_refuses_faults(problem, fed_grid, prox):
    with pytest.raises(ValueError, match="reference"):
        api.component_spec("fedasync", "batched", problem=problem,
                           grid=fed_grid, prox=prox, reference=True,
                           faults=FaultSpec(p_drop=0.1))


def test_parse_faults_grammar():
    assert parse_faults(None) is None
    assert parse_faults("") is None
    f = parse_faults("chaos,staleness_cutoff=64,seed=7")
    assert f.p_crash == FAULT_PRESETS["chaos"]["p_crash"]
    assert f.staleness_cutoff == 64 and f.seed == 7
    assert parse_faults("p_drop=0.1").p_drop == 0.1
    with pytest.raises(ValueError, match="unknown fault preset"):
        parse_faults("nonsense")
    with pytest.raises(ValueError, match="unknown FaultSpec field"):
        parse_faults("p_typo=0.1")


def test_normalize_and_validation():
    assert normalize_faults(None) is None
    assert normalize_faults(FaultSpec(enabled=False)) is None
    assert normalize_faults(CHAOS) is CHAOS
    with pytest.raises(TypeError):
        normalize_faults({"p_drop": 0.1})
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(p_drop=1.5)
    with pytest.raises(ValueError, match="rejoin"):
        FaultSpec(p_crash=0.1, p_rejoin=0.0)
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultSpec(corrupt_mode="zero")


def test_fault_spec_is_hashable_cache_key():
    """FaultSpec keys the program cache: value-equal specs must hash
    equal, distinct specs must not collide trivially."""
    a = FaultSpec(p_drop=0.1, seed=3)
    b = FaultSpec(p_drop=0.1, seed=3)
    assert hash(a) == hash(b) and a == b
    assert dataclasses.replace(a, seed=4) != a
