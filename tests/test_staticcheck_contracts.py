"""Tests for repro.staticcheck.contracts (fast subset; the full solver x
backend matrix runs in CI's static-analysis lane via the CLI)."""
from repro.staticcheck import contracts


def test_scan_level_piag_contracts_hold():
    checks = contracts.verify_scan_level(("piag",))
    failed = [c for c in checks if not c.ok]
    assert not failed, "\n".join(f"{c.name}: {c.detail}" for c in failed)
    names = {c.name.rsplit("/", 1)[-1] for c in checks}
    assert names == {"explicit-none-is-omitted", "disabled-faults-are-none",
                     "faults-live", "telemetry-live", "fused-scan-io-parity",
                     "fused-is-a-different-body"}


def test_program_level_piag_batched_contracts_hold():
    checks = contracts.verify_program_level(("piag",), ("batched",))
    failed = [c for c in checks if not c.ok]
    assert not failed, "\n".join(f"{c.name}: {c.detail}" for c in failed)
    assert len(checks) == 4
