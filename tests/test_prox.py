"""Proximal operators: closed-form optimality + nonexpansiveness properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import Box, ElasticNet, GroupL2, L1, L2Squared, Zero, make_prox

OPS = [Zero(), L1(lam=0.3), L2Squared(lam=0.5), ElasticNet(lam1=0.2, lam2=0.4),
       Box(lo=-0.7, hi=0.7), GroupL2(lam=0.3)]


@pytest.mark.parametrize("op", OPS, ids=lambda o: type(o).__name__)
def test_prox_optimality(op):
    """prox(x) minimizes R(y) + ||y-x||^2/(2 gamma): compare against a grid
    of perturbations."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(12,)), jnp.float32)
    gamma = 0.37
    p = op.prox(x, gamma)
    def obj(y):
        return float(op.value(y) + jnp.sum((y - x) ** 2) / (2 * gamma))
    base = obj(p)
    for _ in range(30):
        y = p + jnp.asarray(rng.normal(size=(12,)) * 0.1, jnp.float32)
        if isinstance(op, Box):
            y = jnp.clip(y, op.lo, op.hi)
        assert obj(y) >= base - 1e-4


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.floats(0.01, 2.0),
       st.integers(0, len(OPS) - 1))
def test_prox_nonexpansive(seed, gamma, op_idx):
    """||prox(x) - prox(y)|| <= ||x - y|| (firm nonexpansiveness)."""
    op = OPS[op_idx]
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(20,)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(20,)), jnp.float32)
    px, py = op.prox(x, gamma), op.prox(y, gamma)
    assert float(jnp.linalg.norm(px - py)) <= float(jnp.linalg.norm(x - y)) + 1e-5


def test_prox_pytree():
    op = L1(lam=0.1)
    tree = {"a": jnp.ones((3,)), "b": {"c": -jnp.ones((2, 2)) * 0.05}}
    out = op.prox(tree, 1.0)
    np.testing.assert_allclose(out["a"], 0.9 * np.ones(3), atol=1e-6)
    np.testing.assert_allclose(out["b"]["c"], np.zeros((2, 2)), atol=1e-6)


def test_registry():
    assert type(make_prox("l1", lam=0.1)) is L1
    with pytest.raises(ValueError):
        make_prox("nope")
