"""Tests for the REPRO_PALLAS_INTERPRET mid-process staleness guard in
repro.kernels.dispatch."""
import pytest

from repro.kernels import dispatch

_VAR = "REPRO_PALLAS_INTERPRET"


@pytest.fixture
def fresh_guard(monkeypatch):
    monkeypatch.delenv(_VAR, raising=False)
    dispatch._reset_env_guard()
    yield monkeypatch
    dispatch._reset_env_guard()


def test_setting_env_after_first_resolve_raises(fresh_guard):
    assert dispatch.default_interpret() is True  # cpu: no Pallas lowering
    fresh_guard.setenv(_VAR, "1")
    with pytest.raises(RuntimeError, match="changed mid-process"):
        dispatch.default_interpret()


def test_unsetting_env_after_first_resolve_raises(fresh_guard):
    fresh_guard.setenv(_VAR, "0")
    assert dispatch.default_interpret() is False
    fresh_guard.delenv(_VAR)
    with pytest.raises(RuntimeError, match="forced off, now it is unset"):
        dispatch.default_interpret()


def test_equivalent_spellings_do_not_trip_the_guard(fresh_guard):
    fresh_guard.setenv(_VAR, "1")
    assert dispatch.default_interpret() is True
    for spelling in ("true", "YES", " on "):
        fresh_guard.setenv(_VAR, spelling)
        assert dispatch.default_interpret() is True  # same tri-state


def test_stable_env_never_raises(fresh_guard):
    fresh_guard.setenv(_VAR, "0")
    for _ in range(3):
        assert dispatch.default_interpret() is False


def test_parse_error_wins_over_guard(fresh_guard):
    assert dispatch.default_interpret() is True
    fresh_guard.setenv(_VAR, "maybe")
    with pytest.raises(ValueError, match="not understood"):
        dispatch.default_interpret()


def test_resolve_interpret_explicit_bypasses_resolution(fresh_guard):
    # an explicit flag never consults (or arms) the env guard
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False
    assert dispatch._FIRST_RESOLVED is None
