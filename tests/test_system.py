"""End-to-end behaviour tests: the paper's headline claims on this system,
plus the async training loop and serving path."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1, make_logreg,
                        run_piag_logreg, simulate_parameter_server)


def test_paper_headline_piag_speedup():
    """Fig. 2 analogue: iterations to reach a target objective -- adaptive
    needs fewer than the best fixed step-size on the same event trace."""
    prob = make_logreg(1200, 150, n_workers=8, seed=0)
    trace = simulate_parameter_server(8, 2500, seed=3)
    gp = 0.99 / prob.L
    prox = L1(lam=prob.lam1)
    res_a = run_piag_logreg(prob, trace, Adaptive1(gamma_prime=gp), prox)
    res_f = run_piag_logreg(
        prob, trace, FixedStepSize(gamma_prime=gp,
                                   tau_bound=trace.max_delay()), prox)
    target = float(res_f.objective[-1])  # whatever fixed achieves at the end
    it_a = int(np.argmax(np.asarray(res_a.objective) <= target))
    assert res_a.objective[-1] <= target + 1e-9
    # adaptive reaches the fixed policy's final objective in < 60% of events
    assert 0 < it_a < 0.6 * trace.n_events


def test_async_training_loop_loss_decreases():
    """examples driver path: delay-adaptive async training on a tiny LM."""
    from repro.launch.train import PRESETS, run_training
    cfg = PRESETS["25m"].replace(n_layers=2, d_model=128, n_heads=4,
                                 n_kv_heads=2, head_dim=32, d_ff=256,
                                 vocab=512, name="lm-tiny")
    log = run_training(cfg, steps=40, batch=4, seq=64, policy_name="adaptive1",
                       lr=3e-3, n_workers=3, log_every=5)
    assert log[-1]["loss"] < log[0]["loss"] - 0.3
    assert all(np.isfinite(r["loss"]) for r in log)


def test_serve_generate_greedy():
    from repro.launch.serve import generate
    from repro.launch.train import PRESETS
    cfg = PRESETS["25m"].replace(n_layers=2, d_model=128, n_heads=4,
                                 n_kv_heads=2, head_dim=32, d_ff=256,
                                 vocab=512, name="lm-tiny")
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab,
                                 dtype=jnp.int32)
    out, stats = generate(cfg, params, prompts, gen=8)
    assert out.shape == (2, 24)
    assert stats["tok_per_s"] > 0
    # greedy decode is deterministic
    out2, _ = generate(cfg, params, prompts, gen=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_adaptive2_matches_adaptive1_order():
    """Both adaptive policies converge on the same trace (Cor. 1 orders)."""
    prob = make_logreg(600, 80, n_workers=5, seed=1)
    trace = simulate_parameter_server(5, 1200, seed=5)
    gp = 0.99 / prob.L
    prox = L1(lam=prob.lam1)
    o1 = run_piag_logreg(prob, trace, Adaptive1(gamma_prime=gp), prox).objective
    o2 = run_piag_logreg(prob, trace, Adaptive2(gamma_prime=gp), prox).objective
    assert o1[-1] < o1[0] and o2[-1] < o2[0]


def test_train_checkpoint_resume(tmp_path):
    """Trainer saves full TrainState (params + delay-adaptive optimizer) and
    resumes continuing the loss trajectory."""
    import os
    from repro.launch.train import PRESETS, run_training
    cfg = PRESETS["25m"].replace(n_layers=2, d_model=64, n_heads=4,
                                 n_kv_heads=2, head_dim=16, d_ff=128,
                                 vocab=128, name="lm-ck")
    d = str(tmp_path)
    log1 = run_training(cfg, steps=10, batch=2, seq=32, n_workers=2,
                        log_every=5, out_dir=d)
    log2 = run_training(cfg, steps=10, batch=2, seq=32, n_workers=2,
                        log_every=5, out_dir=d,
                        resume_from=os.path.join(d, "final.npz"))
    assert log2[-1]["step"] == 19
    assert log2[-1]["loss"] <= log1[0]["loss"]


def test_async_bcd_nn_training():
    """The paper's Algorithm 2 at NN scale: parameter-block async updates
    from stale snapshots, delay-adaptive step-sizes."""
    from repro.core.stepsize import Adaptive1
    from repro.launch.train import PRESETS
    from repro.launch.train_bcd import run_bcd_training
    cfg = PRESETS["25m"].replace(n_layers=2, d_model=128, n_heads=4,
                                 n_kv_heads=2, head_dim=32, d_ff=256,
                                 vocab=512, name="lm-bcd")
    log = run_bcd_training(cfg, Adaptive1(gamma_prime=0.5), steps=120,
                           batch=4, seq=64, m_blocks=4, n_workers=3,
                           log_every=40)
    assert log[-1]["loss"] < log[0]["loss"] - 0.8
    assert all(r["tau"] >= 0 for r in log)
