"""`repro.analysis` pins: the aggregators must reproduce the numbers that
used to be computed inline in ``benchmarks/sweep_grid.py``,
``benchmarks/fig5_federated.py`` and ``launch/sweep.py`` -- the inline
formulas are restated here verbatim as the expected values, evaluated on
the 64-cell fast grid (the benchmark's policy x seed x topology shape at
smoke-test event counts).
"""
import numpy as np
import pytest

from repro import analysis, api
from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1,
                        SunDengFixed, make_logreg)
from repro.sweep import make_grid, standard_topologies


@pytest.fixture(scope="module")
def grid64_run():
    """The benchmarks/sweep_grid.py grid (4 policies x 4 seeds x 4
    topologies = 64 cells) at fast-test scale, run once, batched."""
    problem = make_logreg(240, 40, n_workers=4, seed=0)
    gp = 0.99 / problem.L
    grid = make_grid(
        policies={"adaptive1": Adaptive1(gamma_prime=gp),
                  "adaptive2": Adaptive2(gamma_prime=gp),
                  "fixed": FixedStepSize(gamma_prime=gp, tau_bound=40),
                  "sun_deng": SunDengFixed(gamma_prime=gp, tau_bound=40)},
        seeds=range(4),
        topologies=standard_topologies(4),
        n_events=120)
    assert len(grid) == 64
    res = api.run_components("piag", "batched", problem=problem, grid=grid,
                             prox=L1(lam=problem.lam1))
    return grid, res


def test_mean_final_objective_matches_inline_benchmark_formula(grid64_run):
    """benchmarks/sweep_grid.py used to compute
    ``float(np.mean(obj[rows, -1]))`` per policy inline."""
    grid, res = grid64_run
    obj = np.asarray(res.objective)
    finals = analysis.mean_final_objective(grid.cells, res.objective)
    assert list(finals) == ["adaptive1", "adaptive2", "fixed", "sun_deng"]
    for pn in finals:
        rows = [i for i, c in enumerate(grid.cells) if c.policy_name == pn]
        assert finals[pn] == float(np.mean(obj[rows, -1])), pn


def test_per_policy_summary_matches_inline_cli_formulas(grid64_run):
    """launch/sweep.py used to print, per policy: obj[rows, -1].mean(),
    obj[rows, -1].min(), gam[rows].sum(1).mean(), clipped[rows].sum()."""
    grid, res = grid64_run
    obj = np.asarray(res.objective)
    gam = np.asarray(res.gammas)
    clipped = np.asarray(res.clipped)
    summary = analysis.per_policy_summary(grid.cells, res.objective,
                                          res.gammas, res.clipped)
    for pn, s in summary.items():
        rows = [i for i, c in enumerate(grid.cells) if c.policy_name == pn]
        assert s.n_cells == 16
        assert s.mean_final == float(obj[rows, -1].mean())
        assert s.min_final == float(obj[rows, -1].min())
        assert s.mean_sum_gamma == float(gam[rows].sum(1).mean())
        assert s.clipped_events == int(clipped[rows].sum())
        assert s.clipped_cells == int(np.sum(clipped[rows] > 0))


def test_summarize_results_bridge(grid64_run):
    _, res = grid64_run
    assert analysis.summarize(res) == analysis.per_policy_summary(
        res.cells, res.objective, res.gammas, res.clipped)


def test_clipped_summary_counts():
    clipped = np.asarray([0, 3, 0, 7, 1])
    s = analysis.clipped_summary(clipped)
    assert s == {"cells": 5, "cells_clipped": 3, "events_clipped": 11,
                 "max_events_clipped": 7}


def test_time_to_tolerance_matches_inline_fig5_formula():
    """benchmarks/fig5_federated.py used
    ``int(np.argmax(sub <= target)) if (sub <= target).any() else -1``."""
    p_star, target = 0.25, 0.1
    obj = np.asarray([1.0, 0.6, 0.4, 0.34, 0.36, 0.3])
    sub = obj - p_star
    expected = int(np.argmax(sub <= target)) if (sub <= target).any() else -1
    assert analysis.time_to_tolerance(obj, target, p_star=p_star) == expected == 3
    # never reached
    assert analysis.time_to_tolerance(obj, 0.01, p_star=p_star) == -1
    # already at tolerance from event 0
    assert analysis.time_to_tolerance(np.full(4, 0.2), target,
                                      p_star=p_star) == 0


def test_time_to_tolerance_batched_rows():
    obj = np.asarray([[1.0, 0.5, 0.2], [1.0, 0.9, 0.8]])
    hits = analysis.time_to_tolerance(obj, 0.3)
    np.testing.assert_array_equal(hits, [2, -1])


def test_best_fixed_vs_adaptive_matches_inline_fig5_formula():
    events = {"hinge": 82, "poly": 120, "fixed_taubound": 292,
              "fixed_taubound_sqrt": -1, "fixed_taubound_x4": 310,
              "fedbuff4_poly": 40}
    gap = analysis.best_fixed_vs_adaptive(
        events, fixed={n for n in events if n.startswith("fixed_")},
        adaptive={"hinge", "poly"})
    # the inline formula: min over events >= 0 within each family
    assert gap["best_fixed"] == 292
    assert gap["best_adaptive"] == 82
    assert gap["speedup"] == 292 / 82


def test_best_fixed_vs_adaptive_handles_never_and_defaults():
    gap = analysis.best_fixed_vs_adaptive(
        {"fixed_a": -1, "fixed_b": None, "adaptive1": 50})
    assert gap == {"best_fixed": -1, "best_adaptive": 50, "speedup": None}
    # default split: names starting with "fixed" vs the rest
    gap = analysis.best_fixed_vs_adaptive({"fixed": 10, "adaptive1": 5})
    assert gap["speedup"] == 2.0
