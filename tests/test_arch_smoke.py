"""Per-assigned-architecture smoke tests (required deliverable f).

Each architecture instantiates its REDUCED variant (2 layers, d_model <= 512,
<= 4 experts) and runs one forward + one delay-adaptive train step on CPU,
asserting output shapes and finiteness; decode-capable archs also run one
decode step.  The FULL configs are exercised only via the dry-run.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data import EmbedStream, TokenStream
from repro.launch.steps import make_trainer
from repro.models import decode_step, forward, make_cache

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    if cfg.embed_inputs:
        stream = EmbedStream(d_model=cfg.d_model, vocab=cfg.vocab, batch=B,
                             seq=S, mrope=cfg.rope == "mrope")
    else:
        stream = TokenStream(vocab=cfg.vocab, batch=B, seq=S)
    return stream.batch_at(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_smoke(arch):
    cfg = get_config(arch).reduced()
    cfg.validate()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    trainer = make_trainer(cfg, n_workers=2, lr=1e-3)
    state = trainer.init(KEY)
    batch = _batch(cfg)

    # forward: shapes + finiteness
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(state.params, batch)
    assert logits.shape == (B, S, cfg.vocab), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    # one delay-adaptive train step
    step = jax.jit(trainer.train_step)
    new_state, metrics = step(state, batch, jnp.int32(0))
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert float(metrics["gamma"]) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(new_state.params),
                        jax.tree_util.tree_leaves(state.params)))
    assert moved, arch

    # decode (skips encoder-only)
    if cfg.has_decode:
        cache = make_cache(cfg, B, S)
        tok = jnp.zeros((B, 1), jnp.int32)
        lg, cache2 = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))(
                state.params, cache, tok, jnp.int32(S // 2))
        assert lg.shape == (B, 1, cfg.vocab), arch
        assert bool(jnp.all(jnp.isfinite(lg))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab=32000,
                            ssm_state=64),
        "starcoder2-15b": dict(n_layers=40, d_model=6144, n_heads=48,
                               n_kv_heads=4, d_ff=24576, vocab=49152),
        "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab=64000),
        "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                              n_kv_heads=16, d_ff=5120, vocab=504),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab=50280,
                            ssm_state=128),
        "nemotron-4-15b": dict(n_layers=32, d_model=6144, n_heads=48,
                               n_kv_heads=8, d_ff=24576, vocab=256000),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, moe_ff=1408, vocab=151936,
                                n_experts=60, top_k=4),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 moe_ff=1536, vocab=102400, n_experts=160,
                                 top_k=6, kv_lora_rank=512),
        "qwen2.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=8, d_ff=27648, vocab=152064),
        "qwen2-vl-72b": dict(n_layers=80, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=29568, vocab=152064),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_family_coverage():
    fams = {get_config(a).family for a in ARCH_IDS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}
