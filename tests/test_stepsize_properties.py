"""Property tests for the Eq. (8) step-size invariant

    0 <= gamma_k <= max(0, gamma' - sum_{t=k-tau_k}^{k-1} gamma_t)

across EVERY policy registered in ``core.stepsize.POLICIES``, plus the
circular-buffer window-sum machinery itself (O(1) buffer vs O(tau) direct
sum, including the horizon-clipping edge).

Every registered policy must be classified below; adding a policy to
``POLICIES`` without declaring where it stands w.r.t. the principle fails
``test_every_policy_is_classified`` -- the invariant the convergence proofs
rest on should never be implicit.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import POLICIES, make_delays, make_policy, window_sum
from repro.core.stepsize import (auto_horizon, clipped_count, init_state,
                                 next_pow2)

GAMMA = 0.7

# How each registered policy relates to principle (8):
#   always         satisfies (8) for ANY delay sequence (the paper's Eq. 13/14
#                  and the Lipschitz variant, whose run() budget is gamma')
#   bounded        satisfies (8) provided tau_k <= tau_bound (fixed policy;
#                  davis needs ratio >= 1)
#   bounded_slack  satisfies (8) only with slack: tau_k <= tau_bound - 1
#                  (sun_deng divides by tau_bound + 1/2, so at tau_k =
#                  tau_bound it overshoots the window budget by gamma_k/2)
#   weight         staleness *mixing weights* (FedAsync): bounded by gamma'
#                  and nonincreasing in tau, but deliberately not
#                  window-budgeted
#   violates       the paper's Example 1 failure mode
CLASSIFICATION = {
    "adaptive1": "always",
    "adaptive2": "always",
    "adaptive_lipschitz": "always",
    "fixed": "bounded",
    "davis": "bounded",
    "sun_deng": "bounded_slack",
    "constant": "weight",
    "hinge": "weight",
    "poly": "weight",
    "naive": "violates",
}


def test_every_policy_is_classified():
    assert set(CLASSIFICATION) == set(POLICIES), (
        "new policy registered without an Eq. (8) classification")


def _policy_for(name: str, tau_bar: int):
    if name in ("fixed", "davis"):
        return make_policy(name, GAMMA, tau_bound=tau_bar)
    if name == "sun_deng":
        return make_policy(name, GAMMA, tau_bound=tau_bar + 1)
    if name == "constant":
        return make_policy(name, GAMMA)  # tau_bound=0: gamma_k = gamma'
    return make_policy(name, GAMMA)


def _budgets(gammas: np.ndarray, taus: np.ndarray) -> np.ndarray:
    """max(0, gamma' - window_sum) via the O(tau) direct sum."""
    out = np.empty_like(gammas)
    for k, tau in enumerate(taus):
        out[k] = max(0.0, GAMMA - float(gammas[max(k - int(tau), 0):k].sum()))
    return out


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40),
       st.sampled_from(["constant", "random", "burst", "markov"]))
def test_principle_invariant_all_policies(seed, tau_bar, model):
    """For random bounded delay traces, every policy does what its
    classification claims: emits gamma_k inside [0, budget_k] (with an
    f32-accumulation tolerance), caps at gamma' for weights, and the naive
    policy's violation is CAUGHT by the same check."""
    taus = make_delays(model, 200, tau_bar, seed=seed)
    tol = 1e-4 * max(1.0, GAMMA)
    for name, cls in CLASSIFICATION.items():
        g = np.asarray(_policy_for(name, tau_bar).run(taus), np.float64)
        assert np.all(g >= 0.0), name
        assert np.all(np.isfinite(g)), name
        if cls in ("always", "bounded", "bounded_slack"):
            budget = _budgets(g, taus)
            assert np.all(g <= budget + tol), (
                f"{name}: Eq. (8) violated by {np.max(g - budget):.2e}")
        elif cls == "weight":
            assert np.all(g <= GAMMA + tol), name


def test_naive_violates_principle_under_constant_delay():
    """Example 1: gamma_k = c/(tau_k + b) overshoots the window budget."""
    taus = make_delays("constant", 200, 8, seed=0)
    g = np.asarray(make_policy("naive", GAMMA, b=1.0).run(taus), np.float64)
    budget = _budgets(g, taus)
    assert np.any(g > budget + 1e-6), "expected Example 1's violation"


def test_sun_deng_needs_the_slack():
    """At tau_k = tau_bound the Sun/Deng step overshoots (8) -- that is WHY
    it is classified bounded_slack and the paper treats it as a separate
    state-of-the-art baseline rather than an instance of the principle."""
    taus = make_delays("constant", 200, 8, seed=0)
    g = np.asarray(make_policy("sun_deng", GAMMA, tau_bound=8).run(taus),
                   np.float64)
    budget = _budgets(g, taus)
    assert np.any(g > budget + 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 30),
       st.sampled_from(["adaptive1", "adaptive2", "fixed"]))
def test_window_sum_buffer_matches_direct_sum(seed, tau_bar, policy_name):
    """The O(1) circular-buffer window sum equals the O(tau) direct sum at
    every step (no clipping when horizon >= trace length)."""
    rng = np.random.default_rng(seed)
    n = 120
    taus = np.minimum(rng.integers(0, tau_bar + 1, size=n), np.arange(n))
    pol = _policy_for(policy_name, tau_bar)
    state = pol.init(horizon=256)
    gammas = []
    for k in range(n):
        tau = int(taus[k])
        ws, clipped = window_sum(state, jnp.int32(tau))
        direct = float(np.sum(gammas[max(k - tau, 0):k], dtype=np.float64))
        assert abs(float(ws) - direct) < 1e-4
        assert int(clipped) == 0
        g, state = pol.step(state, jnp.int32(tau))
        gammas.append(float(g))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_window_sum_horizon_clipping_edge(seed):
    """With a tiny horizon H, delays beyond min(k, H-1) clip to the largest
    representable window and raise the clipped flag -- the under-estimation
    alarm the docstring promises.  The state's clipped counter totals exactly
    the flagged steps.

    Regression: the cap must be H-1, not H -- at tau = H the needed buffer
    slot (k-tau-1) % H has just been overwritten with S_k, so the window sum
    silently read as ZERO (full budget granted at the worst possible moment:
    the most-delayed step).
    """
    H = 8
    rng = np.random.default_rng(seed)
    n = 60
    taus = rng.integers(0, 20, size=n)
    pol = make_policy("adaptive1", GAMMA)
    state = pol.init(horizon=H)
    gammas, expected_clips = [], 0
    for k in range(n):
        tau = int(taus[k])
        eff = min(tau, k, H - 1)
        ws, clipped = window_sum(state, jnp.int32(tau))
        direct = float(np.sum(gammas[k - eff:k] if eff else [],
                              dtype=np.float64))
        assert abs(float(ws) - direct) < 1e-4
        should_clip = tau > min(k, H - 1)
        assert bool(clipped) == should_clip
        expected_clips += int(should_clip)
        g, state = pol.step(state, jnp.int32(tau))
        gammas.append(float(g))
    assert int(state.clipped) == expected_clips


def _run_with_horizon(pol, taus, horizon: int):
    """Full gamma sequence + final clipped count for an explicit horizon
    (``StepsizePolicy.run`` pins its own horizon, so scan manually)."""

    def body(state, tau):
        g, state = pol.step(state, tau)
        return state, g

    fin, g = jax.lax.scan(body, pol.init(horizon),
                          jnp.asarray(taus, jnp.int32))
    return np.asarray(g), int(clipped_count(fin))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 60),
       st.sampled_from(["constant", "random", "burst", "markov"]))
def test_horizon_invariance_all_policies(seed, tau_bar, model):
    """The measured-delay horizon contract, for EVERY registered policy: a
    run with the lean ``auto_horizon`` buffer is BITWISE-equal to the 4096
    worst-case default whenever no delay exceeds the smaller cap (the
    circular cumulative-sum buffer reads identical values), and neither run
    clips.  This is what lets the sweep engine size carries by tau-bar
    instead of paying the worst case."""
    taus = make_delays(model, 150, tau_bar, seed=seed)
    H_small = auto_horizon(int(np.max(taus)))
    assert H_small >= int(np.max(taus)) + 1  # every delay representable
    for name in POLICIES:
        pol = _policy_for(name, tau_bar)
        g_small, clip_small = _run_with_horizon(pol, taus, H_small)
        g_big, clip_big = _run_with_horizon(pol, taus, 4096)
        np.testing.assert_array_equal(g_small, g_big, err_msg=name)
        assert clip_small == 0 and clip_big == 0, name


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_undersized_horizon_clips_loudly_not_silently(seed):
    """When a delay DOES exceed the lean cap, the clipped counter fires on
    the small-horizon run (and stays zero on the roomy one) -- the failure
    mode is observable, never silent drift."""
    rng = np.random.default_rng(seed)
    H = 16
    n = 80
    # causal delays (tau_k <= k) below the small cap, so neither horizon
    # clips on its own ...
    taus = np.minimum(rng.integers(0, H - 1, size=n), np.arange(n))
    k = int(rng.integers(H, n))   # late enough that min(k, H-1) == H-1
    taus[k] = H                   # ... then one beyond the small cap only
    for name in ("adaptive1", "adaptive2", "fixed", "hinge"):
        pol = _policy_for(name, H - 1)
        _, clip_small = _run_with_horizon(pol, taus, H)
        _, clip_big = _run_with_horizon(pol, taus, 4096)
        assert clip_small >= 1, name
        assert clip_big == 0, name


def test_auto_horizon_sizing():
    """next_pow2(tau_bar + slack), floored at 2 (the smallest legal H)."""
    assert auto_horizon(0) == 2 and auto_horizon(1) == 2
    assert auto_horizon(2) == 4 and auto_horizon(3) == 4
    assert auto_horizon(138) == 256   # the BENCH_sweep_grid tau-bar
    assert auto_horizon(138, slack=200) == 512
    assert next_pow2(1) == 1 and next_pow2(255) == 256
    with pytest.raises(ValueError, match="slack"):
        auto_horizon(10, slack=0)
    # every sized horizon represents the measured bound: H - 1 >= tau_bar
    for tb in range(0, 300, 7):
        assert auto_horizon(tb) - 1 >= tb


def test_batched_init_state_shapes():
    """init_state(batch_shape=...) builds batched per-cell state; horizon
    reads from the last axis."""
    s = init_state(horizon=32, batch_shape=(5,))
    assert s.k.shape == (5,) and s.cumbuf.shape == (5, 32)
    assert s.horizon == 32
    s0 = init_state(horizon=16)
    assert s0.cumbuf.shape == (16,) and s0.horizon == 16


def test_batched_state_steps_like_independent_scalar_chains():
    """A batched state advanced with a batch of delays must evolve exactly
    like B independent scalar chains -- gammas, window sums, totals, and
    clipped counters all bitwise per cell (including horizon clipping)."""
    B, H, n = 3, 8, 40
    rng = np.random.default_rng(0)
    taus = rng.integers(0, 12, size=(n, B))
    pol = make_policy("adaptive1", GAMMA)
    batched = init_state(horizon=H, batch_shape=(B,))
    scalars = [pol.init(horizon=H) for _ in range(B)]
    for k in range(n):
        tb = jnp.asarray(taus[k], jnp.int32)
        ws_b, clip_b = window_sum(batched, tb)
        g_b, batched = pol.step(batched, tb)
        for c in range(B):
            ws_s, clip_s = window_sum(scalars[c], jnp.int32(taus[k, c]))
            g_s, scalars[c] = pol.step(scalars[c], jnp.int32(taus[k, c]))
            assert float(ws_s) == float(ws_b[c])
            assert int(clip_s) == int(clip_b[c])
            assert float(g_s) == float(g_b[c])
    for c in range(B):
        assert float(scalars[c].total) == float(batched.total[c])
        assert int(scalars[c].clipped) == int(batched.clipped[c])
        np.testing.assert_array_equal(np.asarray(scalars[c].cumbuf),
                                      np.asarray(batched.cumbuf[c]))
