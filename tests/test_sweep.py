"""Equivalence suite for the vectorized sweep engine.

Three layers, each tied to the trusted reference:

1. traces    -- the jitted ``trace_scan`` is BITWISE-equal to the heapq
               simulators when both consume the same service-time matrix
               (event order, read versions, staleness, f32 wall-clock),
               including simultaneous arrivals (tie-break by push order).
2. policies  -- ``ParamPolicy`` (the lax.switch parametric policy) steps
               bitwise-identically to every flattenable concrete policy.
3. solvers   -- a ``sweep_*`` row matches a solo ``run_*`` of the same
               config: integer outputs (taus, workers, blocks) exactly;
               float outputs to a few-ulp envelope (solo and batched are
               different XLA programs, so fusion may differ in the last
               ulps of gamma'-scale arithmetic -- the window-budget
               cancellation amplifies exactly that; everything else about
               the computation is shared code).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Adaptive1, Adaptive2, FixedStepSize, L1, make_logreg,
                        generate_trace, run_async_bcd, run_piag_logreg,
                        sample_blocks, sample_service_times,
                        simulate_parameter_server, simulate_shared_memory,
                        trace_scan)
from repro.core.engine import WorkerModel, heterogeneous_workers
from repro.core.stepsize import (DavisFixed, HingeWeight, NaiveAdaptive,
                                 PolyWeight, SunDengFixed)
from repro.federated.events import (generate_federated_trace,
                                    heterogeneous_clients)
from repro.federated.server import run_fedasync_problem
from repro.sweep import (ParamPolicy, make_grid, policy_params,
                         standard_topologies, sweep_bcd_logreg,
                         sweep_fedasync_problem, sweep_piag_logreg)

MODELS = {
    "lognormal": [WorkerModel(sigma=0.4) for _ in range(5)],
    "straggler": [WorkerModel(p_straggle=0.25, straggle_x=15.0)
                  for _ in range(5)],
    "heterogeneous": heterogeneous_workers(5, spread=3.0, seed=4),
}


# ------------------------------------------------------------ 1. traces ----

@pytest.mark.parametrize("model", sorted(MODELS))
def test_trace_scan_matches_heapq_parameter_server(model):
    workers = MODELS[model]
    T = sample_service_times(workers, 401, seed=11)
    ref = simulate_parameter_server(5, 400, workers, seed=0, service_times=T)
    jit = generate_trace(T)
    for field in ("worker", "read_at", "tau", "tau_max"):
        np.testing.assert_array_equal(getattr(ref, field), getattr(jit, field),
                                      err_msg=field)
    np.testing.assert_array_equal(ref.t_wall.astype(np.float32),
                                  jit.t_wall.astype(np.float32))


@pytest.mark.parametrize("model", sorted(MODELS))
def test_trace_scan_matches_heapq_shared_memory(model):
    workers = MODELS[model]
    T = sample_service_times(workers, 301, seed=5)
    ref = simulate_shared_memory(5, 300, 10, workers, seed=0, service_times=T)
    jit = generate_trace(T, kind="shared_memory")
    np.testing.assert_array_equal(ref.worker, jit.worker)
    np.testing.assert_array_equal(ref.read_at, jit.read_at)
    np.testing.assert_array_equal(ref.tau, jit.tau)
    np.testing.assert_array_equal(jit.tau, jit.tau_max)  # shared-memory tau_max


def test_trace_scan_ties_resolve_like_heap_push_order():
    """Regression for simultaneous arrivals: identical deterministic service
    times tie EVERY completion; both paths must order by (time, seq), which
    for equal constant durations is round-robin in worker order."""
    workers = [WorkerModel(sigma=0.0) for _ in range(4)]  # all tasks take 1.0
    T = sample_service_times(workers, 13, seed=0)
    assert np.all(T == 1.0)
    ref = simulate_parameter_server(4, 12, workers, seed=0, service_times=T)
    jit = generate_trace(T)
    np.testing.assert_array_equal(ref.worker, jit.worker)
    np.testing.assert_array_equal(ref.worker, np.tile(np.arange(4), 3))
    # round-robin => every gradient is exactly n_workers - 1 stale (post ramp)
    np.testing.assert_array_equal(ref.tau[4:], np.full(8, 3))


def test_trace_scan_vmaps():
    """A stacked batch of matrices -> a batch of traces in one program."""
    Ts = np.stack([sample_service_times(MODELS["lognormal"], 101, seed=s)
                   for s in range(6)])
    out = jax.jit(jax.vmap(trace_scan))(jnp.asarray(Ts))
    assert out.worker.shape == (6, 100)
    for s in range(6):
        solo = generate_trace(Ts[s])
        np.testing.assert_array_equal(solo.worker, np.asarray(out.worker[s]))
        np.testing.assert_array_equal(solo.tau_max, np.asarray(out.tau_max[s]))


# ---------------------------------------------------------- 2. policies ----

CONCRETE_POLICIES = [
    FixedStepSize(gamma_prime=0.7, tau_bound=9),
    SunDengFixed(gamma_prime=0.7, tau_bound=9),
    DavisFixed(gamma_prime=0.7, tau_bound=9, ratio=0.5),
    NaiveAdaptive(gamma_prime=0.7, b=1.5),
    Adaptive1(gamma_prime=0.7, alpha=0.9),
    Adaptive2(gamma_prime=0.7),
    HingeWeight(gamma_prime=0.7, a=10.0, b=4.0),
    PolyWeight(gamma_prime=0.7, a=0.5),
]


@pytest.mark.parametrize("policy", CONCRETE_POLICIES,
                         ids=lambda p: type(p).__name__)
def test_param_policy_steps_bitwise_like_concrete(policy):
    """ParamPolicy's lax.switch branch reproduces the concrete policy's
    arithmetic exactly: stepping both through the same random delay sequence
    yields bit-identical gammas and states."""
    par = ParamPolicy(policy_params(policy))
    rng = np.random.default_rng(3)
    s_c, s_p = policy.init(64), par.init(64)
    for k in range(80):
        tau = jnp.int32(min(int(rng.integers(0, 13)), k))
        g_c, s_c = policy.step(s_c, tau)
        g_p, s_p = par.step(s_p, tau)
        assert float(g_c) == float(g_p), (k, type(policy).__name__)
    assert float(s_c.total) == float(s_p.total)
    np.testing.assert_array_equal(np.asarray(s_c.cumbuf),
                                  np.asarray(s_p.cumbuf))


def test_param_policy_rejects_stateful_policies():
    from repro.core.stepsize import AdaptiveLipschitz
    with pytest.raises(TypeError):
        policy_params(AdaptiveLipschitz(gamma_prime=1.0))


# ----------------------------------------------------------- 3. solvers ----

@pytest.fixture(scope="module")
def problem():
    return make_logreg(240, 40, n_workers=4, seed=0)


def _gamma_envelope(gp: float) -> float:
    # a few ulps of gamma'-scale intermediates (see module docstring)
    return 32 * float(np.spacing(np.float32(gp)))


def test_sweep_piag_rows_match_solo(problem):
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "a2": Adaptive2(gamma_prime=gp),
                  "fx": FixedStepSize(gamma_prime=gp, tau_bound=12)},
        seeds=[0, 1],
        topologies={"uniform": [WorkerModel() for _ in range(4)],
                    "hetero": heterogeneous_workers(4, seed=1)},
        n_events=200)
    res = sweep_piag_logreg(problem, grid, prox)
    assert res.objective.shape == (len(grid), 200)
    Ts = grid.service_times()
    for i, cell in enumerate(grid.cells):
        trace = generate_trace(Ts[i])
        solo = run_piag_logreg(problem, trace, cell.policy, prox)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_allclose(np.asarray(solo.gammas),
                                   np.asarray(res.gammas[i]),
                                   rtol=1e-6, atol=_gamma_envelope(gp))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(solo.x), np.asarray(res.x[i]),
                                   rtol=1e-5, atol=1e-6)


def test_sweep_bcd_rows_match_solo(problem):
    m = 8
    gp = 0.99 / problem.block_smoothness(m)
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"a1": Adaptive1(gamma_prime=gp),
                  "dv": DavisFixed(gamma_prime=gp, tau_bound=10, ratio=0.5)},
        seeds=[0, 1],
        topologies={"uniform": [WorkerModel() for _ in range(4)]},
        n_events=150)
    res = sweep_bcd_logreg(problem, grid, prox, m=m)
    x0 = jnp.zeros((problem.dim,), jnp.float32)
    Ts = grid.service_times()
    for i, cell in enumerate(grid.cells):
        trace = generate_trace(Ts[i], kind="shared_memory")
        blocks = sample_blocks(m, 150, seed=cell.seed)
        solo = run_async_bcd(problem.grad_f, problem.P, x0, m, trace, blocks,
                             cell.policy, prox)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_array_equal(np.asarray(solo.blocks),
                                      np.asarray(res.blocks[i]))
        np.testing.assert_allclose(np.asarray(solo.gammas),
                                   np.asarray(res.gammas[i]),
                                   rtol=1e-6, atol=_gamma_envelope(gp))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)


def test_sweep_fedasync_rows_match_solo(problem):
    """The default sweep path fuses the jitted federated trace scan with the
    server scan; a row must match a solo run over the SAME trace -- which is
    now the pre-sampled-rounds trace (``generate_federated_trace``, bitwise
    the heapq reference on those rounds; see tests/test_fed_scan.py)."""
    prox = L1(lam=problem.lam1)
    clients = heterogeneous_clients(4, seed=2)
    grid = make_grid(
        policies={"hinge": HingeWeight(gamma_prime=0.6),
                  "poly": PolyWeight(gamma_prime=0.6, a=0.5),
                  "const": FixedStepSize(gamma_prime=0.6)},
        seeds=[0, 1],
        topologies={"edge": clients},
        n_events=120)
    res = sweep_fedasync_problem(problem, grid, prox)
    assert res.objective.shape == (len(grid), 120)
    for i, cell in enumerate(grid.cells):
        trace = generate_federated_trace(4, 120, clients=list(cell.workers),
                                         buffer_size=1, seed=cell.seed)
        solo = run_fedasync_problem(problem, trace, cell.policy, prox)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_allclose(np.asarray(solo.weights),
                                   np.asarray(res.weights[i]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_sweep_full_grid_64_cells(problem):
    """The benchmark-scale grid (4 policies x 4 seeds x 4 topologies = 64
    cells) runs as one batched program; sampled rows match solo runs."""
    gp = 0.99 / problem.L
    prox = L1(lam=problem.lam1)
    grid = make_grid(
        policies={"adaptive1": Adaptive1(gamma_prime=gp),
                  "adaptive2": Adaptive2(gamma_prime=gp),
                  "fixed": FixedStepSize(gamma_prime=gp, tau_bound=40),
                  "sun_deng": SunDengFixed(gamma_prime=gp, tau_bound=40)},
        seeds=range(4),
        topologies=standard_topologies(4),
        n_events=250)
    assert len(grid) == 64
    res = sweep_piag_logreg(problem, grid, prox)
    assert res.objective.shape == (64, 250)
    assert np.all(np.isfinite(np.asarray(res.objective)))
    Ts = grid.service_times()
    for i in (0, 21, 42, 63):
        trace = generate_trace(Ts[i])
        solo = run_piag_logreg(problem, trace, grid.cells[i].policy, prox)
        np.testing.assert_array_equal(np.asarray(solo.taus),
                                      np.asarray(res.taus[i]))
        np.testing.assert_allclose(np.asarray(solo.objective),
                                   np.asarray(res.objective[i]),
                                   rtol=1e-5, atol=1e-6)
