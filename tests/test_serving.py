"""Continuous-batching scheduler: correctness vs single-request generate."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.serve import generate
from repro.launch.train import PRESETS
from repro.models import init_params
from repro.serving import ContinuousBatcher, Request

CFG = PRESETS["25m"].replace(n_layers=2, d_model=128, n_heads=4,
                             n_kv_heads=2, head_dim=32, d_ff=256, vocab=256,
                             name="lm-serve")
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _prompts(n, rng):
    return [rng.integers(0, CFG.vocab, size=rng.integers(4, 12)).astype(np.int32)
            for _ in range(n)]


def test_batcher_completes_all_requests():
    rng = np.random.default_rng(0)
    cb = ContinuousBatcher(CFG, PARAMS, max_slots=3, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=int(rng.integers(3, 9)))
            for i, p in enumerate(_prompts(7, rng))]
    for r in reqs:
        cb.submit(r)
    stats = cb.run_until_idle()
    assert stats["completed"] == 7
    for r in reqs:
        assert r.output is not None and 1 <= len(r.output) <= r.max_new
        assert r.t_first_token is not None and r.t_done >= r.t_first_token


def test_batcher_matches_single_request_greedy():
    """Greedy outputs must equal the reference single-sequence generate
    (continuous batching is a scheduling change, not a model change)."""
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, CFG.vocab, size=8).astype(np.int32)
    gen = 6
    ref, _ = generate(CFG, PARAMS, jnp.asarray(prompt)[None, :], gen)
    ref_new = np.asarray(ref[0, len(prompt):])

    cb = ContinuousBatcher(CFG, PARAMS, max_slots=2, max_len=64)
    # add a competing request so scheduling actually interleaves
    cb.submit(Request(rid=0, prompt=prompt, max_new=gen))
    cb.submit(Request(rid=1, prompt=_prompts(1, rng)[0], max_new=4))
    cb.run_until_idle()
    out = next(r for r in cb.done if r.rid == 0).output
    np.testing.assert_array_equal(out, ref_new)


def test_slots_recycle():
    rng = np.random.default_rng(2)
    cb = ContinuousBatcher(CFG, PARAMS, max_slots=1, max_len=64)
    for i, p in enumerate(_prompts(3, rng)):
        cb.submit(Request(rid=i, prompt=p, max_new=3))
    stats = cb.run_until_idle()
    assert stats["completed"] == 3  # one slot served all three sequentially
